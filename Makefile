# Convenience entry points (referenced by runtime error messages/docs).

ARTIFACT_SCALE ?= 0.02

.PHONY: artifacts check-interp test bench-auto

# AOT-lower every L2 program to HLO text + manifest (the rust side's input)
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts --scale $(ARTIFACT_SCALE)

# differential check: the HLO interpreter's semantics vs jax
check-interp:
	cd python && python -m compile.interp_check

test:
	cd rust && cargo test -q
	cd python && python -m pytest tests -q

bench-auto:
	cd rust && cargo bench --bench auto_schedule
