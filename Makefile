# Convenience entry points (referenced by runtime error messages/docs).

ARTIFACT_SCALE ?= 0.02

.PHONY: artifacts check check-interp check-sched test docs bench-auto bench-interp bench-hybrid bench-fleet bench-cluster bench-serve bench-qos bench-pipeline bench-obs

# The one-stop gate: build everything (library, binaries, benches AND
# examples), run both test suites, then the docs checks.
check:
	cd rust && cargo build --release --examples
	cd rust && cargo test -q
	cd python && python -m pytest tests -q
	$(MAKE) docs

# rustdoc must build warning-free (missing_docs is warn-at-crate-level)
# and every relative markdown link must resolve.
docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	python3 scripts/check_links.py

# AOT-lower every L2 program to HLO text + manifest (the rust side's input)
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts --scale $(ARTIFACT_SCALE)

# differential check: the HLO interpreter's semantics vs jax
check-interp:
	cd python && python -m compile.interp_check

# differential check: the compiled lane's schedule/liveness/move
# discipline vs the tree walker, over the committed artifacts (offline)
check-sched:
	cd python && python -m compile.sched_check

test:
	cd rust && cargo test -q
	cd python && python -m pytest tests -q

bench-auto:
	cd rust && cargo bench --bench auto_schedule

# interpreter lanes: bitwise equivalence over all artifacts under BOTH
# fusion schedules (XLA_FUSE governs the default compile path), then the
# throughput baseline with the compiled-not-slower-than-naive and
# fused-not-slower-than-unfused gates (writes rust/BENCH_interp.json)
bench-interp:
	cd rust && XLA_FUSE=off cargo test --release --test interp_equivalence
	cd rust && XLA_FUSE=on cargo test --release --test interp_equivalence
	cd rust && cargo run --release -- bench interp --check

# hybrid co-execution: correctness suite, then the smp/device/hybrid
# report with the hybrid-not-slower gate (writes rust/BENCH_hybrid.json)
bench-hybrid:
	cd rust && cargo test --release --test hybrid_exec
	cd rust && cargo run --release -- bench hybrid --check

# device fleet: N-way sharding correctness suite, then the fleet report
# with the fleet-not-slower-than-best-single-lane gate (writes
# rust/BENCH_fleet.json)
bench-fleet:
	cd rust && cargo test --release --test fleet_exec
	cd rust && cargo run --release -- bench fleet --check

# cluster lane: multi-process sharding correctness suite (spawned
# peers, bitwise vs pure SMP, kill/deadline cover), then the cluster
# report with the participation gate (writes rust/BENCH_cluster.json)
bench-cluster:
	cd rust && cargo test --release --test cluster_exec
	cd rust && cargo run --release -- bench cluster --check

# serving layer: batching correctness suite, then the open-loop load
# sweep with the batched-throughput gate (writes rust/BENCH_serve.json)
bench-serve:
	cd rust && cargo test --release --test serve_batching
	cd rust && cargo run --release -- bench serve --check

# multi-tenant QoS: priority/cancellation/property suites, then the
# scenario matrix with the priority/quota/cancellation gates (writes
# rust/BENCH_serve.json), the out-of-process schema + non-vacuity
# check, and the three QoS figures (writes figures/*.svg)
bench-qos:
	cd rust && cargo test --release --test serve_qos --test serve_cancel --test proptest_qos
	cd rust && cargo run --release -- bench serve --check
	python3 scripts/collect_results.py --check rust/BENCH_serve.json
	python3 scripts/generate_figures.py rust/BENCH_serve.json --out-dir figures

# method pipelines: bitwise fused-vs-roundtrip suite under BOTH fusion
# schedules, then the fused report with the not-slower + provably
# resident-boundary gates (writes rust/BENCH_pipeline.json)
bench-pipeline:
	cd rust && XLA_FUSE=off cargo test --release --test pipeline_exec
	cd rust && XLA_FUSE=on cargo test --release --test pipeline_exec
	cd rust && cargo run --release -- bench pipeline --check

# observability: span-tree correctness suite under BOTH fusion
# schedules, then the tracing-overhead report with the disabled/enabled
# overhead gates (writes rust/BENCH_obs.json)
bench-obs:
	cd rust && XLA_FUSE=off cargo test --release --test trace_obs
	cd rust && XLA_FUSE=on cargo test --release --test trace_obs
	cd rust && cargo run --release -- bench obs --check
