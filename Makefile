# Convenience entry points (referenced by runtime error messages/docs).

ARTIFACT_SCALE ?= 0.02

.PHONY: artifacts check-interp check-sched test bench-auto bench-interp

# AOT-lower every L2 program to HLO text + manifest (the rust side's input)
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts --scale $(ARTIFACT_SCALE)

# differential check: the HLO interpreter's semantics vs jax
check-interp:
	cd python && python -m compile.interp_check

# differential check: the compiled lane's schedule/liveness/move
# discipline vs the tree walker, over the committed artifacts (offline)
check-sched:
	cd python && python -m compile.sched_check

test:
	cd rust && cargo test -q
	cd python && python -m pytest tests -q

bench-auto:
	cd rust && cargo bench --bench auto_schedule

# compiled-vs-naive interpreter lanes: bitwise equivalence over all
# artifacts, then the throughput baseline (writes rust/BENCH_interp.json)
bench-interp:
	cd rust && cargo test --release --test interp_equivalence
	cd rust && cargo run --release -- bench interp --check
