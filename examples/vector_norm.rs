//! Vector normalization (paper Listings 10 and 14): intermediate
//! reductions and `sync reduce(+)` over a shared scalar.
//!
//! Version 1 (Listing 10): an auxiliary `reduce(+)` method — every MI's
//! `sumProd(a)` is folded across MIs (an all-reduce) before each MI
//! normalizes its own partition.
//!
//! Version 2 (Listing 14): a `shared double norm` accumulated inside a
//! `sync reduce(+)(norm) { … }` block.
//!
//! Run: `cargo run --release --example vector_norm`

use std::sync::Arc;

use somd::somd::partition::Block1D;
use somd::somd::reduction::{self, Assemble};
use somd::somd::shared::Shared;
use somd::somd::{Engine, SomdMethod};

fn main() {
    let n = 200_000;
    let data: Vec<f64> = (0..n).map(|i| ((i % 97) as f64) - 48.0).collect();
    let expected_norm = data.iter().map(|x| x * x).sum::<f64>().sqrt();

    // --- Version 1: intermediate reduction (Listing 10) ---
    let norm_v1 = SomdMethod::new(
        "Norm.normalize",
        |v: &Vec<f64>, n| Block1D::new().ranges(v.len(), n),
        |_, _| (),
        |v, part, _, ctx| {
            // sumProd(a): local partial, then the intermediate reduce(+)
            let local: f64 = part.own.iter().map(|i| v[i] * v[i]).sum();
            let norm = ctx.allreduce(local, &reduction::sum::<f64>()).sqrt();
            // each MI normalizes its partition (line 3 of Listing 10)
            part.own.iter().map(|i| v[i] / norm).collect::<Vec<f64>>()
        },
        Assemble,
    );

    // --- Version 2: shared scalar + sync reduce (Listing 14) ---
    let norm_v2 = SomdMethod::new(
        "Norm.normalize2",
        |v: &Vec<f64>, n| Block1D::new().ranges(v.len(), n),
        |_, nparts| Arc::new(Shared::<f64>::new(nparts, 0.0)),
        |v, part, shared: &Arc<Shared<f64>>, ctx| {
            ctx.sync_reduce(shared, &reduction::sum::<f64>(), || {
                let local: f64 = part.own.iter().map(|i| v[i] * v[i]).sum();
                shared.update(ctx.rank(), |s| *s += local);
            });
            // all copies of norm are now identical in every MI
            let norm = shared.get(ctx.rank()).sqrt();
            part.own.iter().map(|i| v[i] / norm).collect::<Vec<f64>>()
        },
        Assemble,
    );

    let engine = Engine::new(8);
    let check = |name: &str, out: Vec<f64>| {
        let out_norm: f64 = out.iter().map(|x| x * x).sum::<f64>();
        assert!((out_norm - 1.0).abs() < 1e-9, "{name}: |x|={out_norm}");
        // spot-check one element
        assert!((out[17] - data[17] / expected_norm).abs() < 1e-12);
        println!("{name}: normalized {n} elements across 8 MIs, |out| = {out_norm:.12}");
    };
    check("v1 (intermediate reduction)", engine.invoke(&norm_v1, &data));
    check("v2 (shared + sync reduce)", engine.invoke(&norm_v2, &data));
}
