//! End-to-end driver (DESIGN.md §7): runs the full JavaGrande Section-2
//! suite through the public API on ALL backends — SMP, device, and the
//! hybrid co-execution lane — validates numerics against the sequential
//! substrate, and prints the paper-style speedup rows.  This is the run
//! recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_suite [-- --scale 0.1]`

use anyhow::Result;

use somd::backend::Executed;
use somd::bench_suite::params::SERIES_INTERVALS;
use somd::bench_suite::{crypt, gpu, harness, hybrid, lufact, modeled, series, sor, sparse};
use somd::bench_suite::{Class, Sizes};
use somd::device::{DeviceProfile, DeviceSession};
use somd::runtime::Registry;
use somd::somd::grid::SharedGrid;
use somd::somd::Engine;
use somd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.opt_f64("scale", 0.1);
    let s = Sizes::scaled(Class::A, scale);
    println!("=== SOMD end-to-end suite (class A, scale {scale}) ===\n");

    // ---- 1. correctness across the SMP SOMD path --------------------------
    println!("-- SMP correctness (SOMD vs sequential) --");
    {
        let p = crypt::Problem::generate(s.crypt_bytes, 1);
        let mismatches = crypt::roundtrip_mismatches(&p, 8);
        println!("crypt      roundtrip mismatches: {mismatches}");
        assert_eq!(mismatches, 0);

        let orig = lufact::generate(s.lufact_n, 2);
        let a = SharedGrid::from_vec(s.lufact_n, s.lufact_n, orig.clone());
        let piv = lufact::somd(&a, 8);
        let err = lufact::reconstruction_error(&orig, &a, &piv);
        println!("lufact     |PA-LU|max:           {err:.2e}");
        assert!(err < 1e-8);

        let want = series::sequential(s.series_n, 1000);
        let got = series::somd(series::Input { count: s.series_n, m: 1000 }, 8);
        let maxd = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g.0 - w.0).abs().max((g.1 - w.1).abs()))
            .fold(0.0, f64::max);
        println!("series     max |Δcoeff|:         {maxd:.2e}");
        assert!(maxd < 1e-12);

        let g0 = sor::generate(s.sor_n, 3);
        let (_, want) = sor::sequential(&g0, s.sor_n, 100);
        let got = sor::somd_method().invoke(&sor::Input { g0: &g0, n: s.sor_n, iters: 100 }, 8);
        println!("sor        |ΔGtotal|:            {:.2e}", (got - want).abs());
        assert!((got - want).abs() < 1e-6);

        let p = sparse::Problem::generate(s.sparse_n, s.sparse_nnz(), 200, 4);
        let want = sparse::sequential(&p);
        let (got, _) = sparse::somd_run(&p, 8);
        let maxd = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        println!("sparse     max |Δy|:             {maxd:.2e}");
        assert!(maxd < 1e-9);
    }

    // ---- 2. device-path correctness (real PJRT execution) -----------------
    println!("\n-- Device correctness (AOT kernels vs rust sequential) --");
    let reg = Registry::load_default()?;
    {
        let mut sess = DeviceSession::new(&reg, DeviceProfile::passthrough());
        let blocks = reg.info("crypt_A")?.meta_usize("blocks").unwrap();
        let p = crypt::Problem::generate(blocks * 8, 5);
        let (_, dec) = gpu::crypt_run(&mut sess, &p)?;
        println!("crypt      device roundtrip:     {}", if dec == p.data { "OK" } else { "FAIL" });
        assert_eq!(dec, p.data);

        let got = gpu::series_run(&mut sess, 2048)?;
        let want = series::sequential(2048, 1000);
        let maxd = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g.0 as f64 - w.0).abs())
            .fold(0.0, f64::max);
        println!("series     device max |Δa| (f32): {maxd:.2e}");
        assert!(maxd < 5e-3);

        let n = reg.info("sor_step_A")?.meta_usize("n").unwrap();
        let g064 = sor::generate(n, 6);
        let g0: Vec<f32> = g064.iter().map(|&v| v as f32).collect();
        let (_, want) = sor::sequential(&g064, n, 100);
        let (_, got) = gpu::sor_run(&mut sess, &g0, n, 100)?;
        let rel = (got - want).abs() / want.abs().max(1.0);
        println!("sor        device Gtotal rel err: {rel:.2e}");
        assert!(rel < 1e-2);

        let sn = reg.info("spmv_acc_A")?.meta_usize("n").unwrap();
        let p = sparse::Problem::generate(sn, sn * 5, 200, 7);
        let want = sparse::sequential(&p);
        let got = gpu::spmv_run(&mut sess, &p)?;
        let maxrel = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (*g as f64 - w).abs() / w.abs().max(1.0))
            .fold(0.0, f64::max);
        println!("sparse     device max rel err:    {maxrel:.2e}");
        assert!(maxrel < 2e-2);
    }

    // ---- 3. hybrid co-execution correctness (one invocation, two lanes) ----
    println!("\n-- Hybrid correctness (SMP share + device share vs reference) --");
    {
        let engine = Engine::new(4);

        // crypt: integer IDEA on both lanes — the merged ciphertext must
        // equal the sequential cipher BITWISE at any split
        let blocks = reg.info("crypt_A")?.meta_usize("blocks").unwrap();
        let p = crypt::Problem::generate(blocks * crypt::BLOCK_BYTES, 11);
        let m = hybrid::crypt_hybrid_generic();
        let want = crypt::sequential(&p.data, &p.ekeys);
        let inp = crypt::PassInput { src: &p.data, keys: p.ekeys };
        let (got, how) = m.invoke_hybrid(&engine, &reg, &inp, Some(0.5))?;
        let bitwise = got == want;
        println!(
            "crypt      hybrid bitwise:       {}",
            if bitwise { "OK" } else { "FAIL" }
        );
        assert!(bitwise);
        assert!(matches!(how, Executed::Hybrid { .. }));

        // series: f64 SMP share + f32 device share — float tolerance
        let count = 1024;
        let m = hybrid::series_hybrid();
        let inp = series::Input { count, m: SERIES_INTERVALS };
        let want = series::sequential(count, SERIES_INTERVALS);
        let (got, how) = m.invoke_hybrid(&engine, &reg, &inp, Some(0.5))?;
        let maxd = got
            .iter()
            .enumerate()
            .map(|(i, g)| (g.0 - want[i + 1].0).abs().max((g.1 - want[i + 1].1).abs()))
            .fold(0.0, f64::max);
        println!("series     hybrid max |Δcoeff|:  {maxd:.2e}");
        assert!(maxd < 5e-3);
        if let Executed::Hybrid { device_fraction, smp_items, device_items, .. } = how {
            println!(
                "series     split: {smp_items} SMP + {device_items} device items (f={device_fraction:.2})"
            );
        }

        // the ratio learner saw both runs and serialized state round-trips
        let state = engine.scheduler().to_json().dump();
        let restored = somd::somd::Scheduler::from_json(
            engine.scheduler().config(),
            &somd::util::json::Json::parse(&state).expect("state parses"),
        )
        .expect("state restores");
        assert_eq!(
            restored.history("Series.coefficients"),
            engine.scheduler().history("Series.coefficients")
        );
    }

    // ---- 4. the paper's tables and figures ---------------------------------
    println!();
    harness::print_table2();
    println!();
    harness::print_table1(scale, 3);
    println!();
    let o = modeled::calibrate();
    println!("calibrated overheads: {o:?}\n");
    for class in [Class::A, Class::B, Class::C] {
        harness::print_fig10(class, scale, 3, &o);
        println!();
    }
    harness::print_fig11(Class::A, scale, 3, &o, &reg)?;

    println!("\n=== e2e suite complete: all validations passed ===");
    Ok(())
}
