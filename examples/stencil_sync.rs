//! The stencil of paper Listing 13: `dist(view = <1,1>,<1,1>)`, a `sync`
//! block per iteration, and `reduce(+)` for Gtotal — the complete SOMD
//! shared-array story on both backends.
//!
//! Run: `cargo run --release --example stencil_sync`

use somd::bench_suite::sor;
use somd::somd::Engine;

fn main() -> anyhow::Result<()> {
    let n = 128;
    let iters = 100;
    let g0 = sor::generate(n, 7);

    // sequential baseline
    let (_, want) = sor::sequential(&g0, n, iters);

    // SOMD: (block, block) distribution, 1-halo views, sync per iteration
    let engine = Engine::new(4);
    let method = sor::somd_method();
    let got = engine.invoke(&method, &sor::Input { g0: &g0, n, iters });
    println!("SMP SOMD stencil {n}x{n}, {iters} sync iterations: Gtotal = {got:.6}");
    assert!((got - want).abs() < 1e-9, "somd {got} vs seq {want}");

    // JG-style row bands (the 1D-vs-2D ablation point)
    let jg = sor::jg_method().invoke(&sor::Input { g0: &g0, n, iters }, 4);
    assert!((jg - want).abs() < 1e-9);
    println!("JG-style row-band stencil: Gtotal = {jg:.6} (same result)");

    // Device backend: one kernel launch per sync iteration (Listing 17) —
    // uses the AOT class-A artifact size.
    match somd::runtime::Registry::load_default() {
        Ok(reg) => {
            use somd::device::{DeviceProfile, DeviceSession};
            let an = reg.info("sor_step_A")?.meta_usize("n").unwrap();
            let g0d: Vec<f32> = sor::generate(an, 7).iter().map(|&v| v as f32).collect();
            let (_, want_d) = sor::sequential(
                &g0d.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                an,
                iters,
            );
            let mut sess = DeviceSession::new(&reg, DeviceProfile::fermi());
            let (_, total) = somd::bench_suite::gpu::sor_run(&mut sess, &g0d, an, iters)?;
            let st = sess.stats();
            let rel = (total - want_d).abs() / want_d.abs().max(1.0);
            println!(
                "device stencil {an}x{an} [{}]: Gtotal = {total:.4} (rel err {rel:.2e} vs f64 seq)",
                sess.profile().name
            );
            println!(
                "  launches={} (one per sync iteration + reduction), matrix put once: h2d={}B",
                st.launches, st.bytes_h2d
            );
            assert_eq!(st.launches, iters + 1);
            assert!(rel < 1e-2);
        }
        Err(_) => println!("(artifacts not built — run `make artifacts` for the device half)"),
    }
    Ok(())
}
