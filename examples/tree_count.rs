//! Parallel tree node count (paper Listings 11 and 12): a *user-defined*
//! distribution over a non-array structure, with `reduce(+)`.
//!
//! `TreeDist` splits the tree into 2^k subtrees plus a top copy; each MI
//! runs the unchanged sequential `countSize`, and `reduce(+)` sums the
//! partial counts.
//!
//! Run: `cargo run --release --example tree_count`

use somd::somd::partition::TreeDist;
use somd::somd::reduction;
use somd::somd::tree::Tree;
use somd::somd::SomdMethod;
use somd::util::prng::Xorshift64;

fn main() {
    let mut rng = Xorshift64::new(2013);
    let n_nodes = 300_000;
    let tree: Tree<u8> = Tree::with_nodes(n_nodes, 0, &mut rng);

    // countSizeParallel (Listing 11): dist(TreeDist()) + reduce(+)
    let count_method = SomdMethod::new(
        "Tree.countSizeParallel",
        |t: &Tree<u8>, n| TreeDist::default().parts(t, n),
        |_, _| (),
        // the body is the sequential countSize applied to the MI's subtree
        |_, part: &Tree<u8>, _, _| part.count(),
        reduction::sum::<usize>(),
    );

    for parts in [1, 2, 4, 8] {
        let total = count_method.invoke(&tree, parts);
        assert_eq!(total, n_nodes, "partition count {parts}");
        println!("countSizeParallel with {parts} MIs: {total} nodes (exact)");
    }

    // The partition really is a partition: the pieces are disjoint and
    // cover the tree (demonstrated on a full binary tree).
    let full = Tree::full(14, 0u8); // 2^15 - 1 nodes
    let parts = TreeDist::default().parts(&full, 8);
    let sum: usize = parts.iter().map(Tree::count).sum();
    assert_eq!(sum, (1 << 15) - 1);
    println!("TreeDist over a full tree: {} pieces, {} nodes total", parts.len(), sum);
}
