//! Quickstart: the paper's Listing 8 — vector addition as a SOMD method.
//!
//! ```text
//! int[] vectorAdd(dist int[] a, dist int[] b) { ... }
//! ```
//!
//! The `dist` qualifier becomes a `Block1D` partition strategy, the method
//! body stays the sequential loop over the MI's index range, and the
//! default array reduction assembles the result.  The same method also
//! runs on the device backend (the AOT `vecadd` Pallas kernel) when
//! artifacts are available — with a `VectorAdd.add:auto` rule the engine
//! picks the architecture itself from recorded execution history, and
//! with `VectorAdd.add:hybrid` (or when `auto` learns it pays off) ONE
//! invocation is split across the SMP pool and the device at the
//! scheduler's learned throughput ratio.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use somd::backend::{DeviceFn, Executed, HeteroMethod, HybridSpec};
use somd::device::Arg;
use somd::runtime::HostTensor;
use somd::somd::master::run_mis;
use somd::somd::partition::Block1D;
use somd::somd::reduction::Assemble;
use somd::somd::{Engine, Rules, Scheduler, SchedulerConfig, SomdMethod, Target};

fn vector_add_smp() -> SomdMethod<(Vec<f32>, Vec<f32>), somd::somd::BlockPart, (), Vec<f32>> {
    SomdMethod::new(
        "VectorAdd.add",
        // dist a, dist b: built-in block partitioning (copy-free ranges)
        |inp: &(Vec<f32>, Vec<f32>), n| Block1D::new().ranges(inp.0.len(), n),
        |_, _| (),
        // the UNCHANGED sequential body, over the MI's range
        |inp, part, _, _| {
            let (a, b) = inp;
            part.own.iter().map(|i| a[i] + b[i]).collect::<Vec<f32>>()
        },
        Assemble,
    )
}

/// The multi-version method: SMP + whole-invocation device offload +
/// hybrid spec (sub-range evaluators for both lanes).
fn vector_add_hetero() -> HeteroMethod<(Vec<f32>, Vec<f32>), somd::somd::BlockPart, (), Vec<f32>> {
    // device master code (Algorithm 2): whole-invocation offload
    let device: DeviceFn<(Vec<f32>, Vec<f32>), Vec<f32>> = Box::new(|sess, inp| {
        let x = HostTensor::vec_f32(inp.0.clone());
        let y = HostTensor::vec_f32(inp.1.clone());
        let out = sess.launch_to_host("vecadd", &[Arg::Host(&x), Arg::Host(&y)], inp.0.len())?;
        Ok(out[0].as_f32()?.to_vec())
    });
    // hybrid spec: index-space size + per-lane sub-range evaluators; the
    // SMP share fans out across MIs exactly like a whole invocation, the
    // device share launches the artifact but downloads only its rows
    let hybrid = HybridSpec::new(
        |inp: &(Vec<f32>, Vec<f32>)| inp.0.len(),
        |inp, span, n| {
            let parts = Block1D::new().ranges_in(span, inp.0.len(), n);
            run_mis(inp, &parts, &(), &|inp, p, _, _| {
                let (a, b) = inp;
                p.own.iter().map(|i| a[i] + b[i]).collect::<Vec<f32>>()
            })
        },
        |sess, inp, span| {
            let x = HostTensor::vec_f32(inp.0.clone());
            let y = HostTensor::vec_f32(inp.1.clone());
            let ids = sess.launch("vecadd", &[Arg::Host(&x), Arg::Host(&y)], span.len())?;
            let out = sess.get_rows(ids[0], span.lo, span.hi);
            sess.free(ids[0])?;
            Ok(out?.as_f32()?.to_vec())
        },
    );
    HeteroMethod::with_device(vector_add_smp(), device).with_hybrid(hybrid)
}

fn describe(how: &Executed) -> String {
    match how {
        Executed::Smp { partitions } => format!("smp({partitions} MIs)"),
        Executed::Device { profile, stats } => format!(
            "device({profile}, modeled {:.2} ms)",
            stats.device_time.as_secs_f64() * 1e3
        ),
        Executed::Hybrid { profile, smp_partitions, smp_items, device_items, device_fraction, .. } => {
            format!(
                "hybrid({smp_partitions} MIs x {smp_items} items + {profile} x {device_items} \
                 items, f={device_fraction:.2})"
            )
        }
        Executed::Sharded { smp_partitions, smp_items, weights, lanes } => {
            let shares: Vec<String> = lanes
                .iter()
                .map(|l| format!("{} x {} items", l.profile, l.items))
                .collect();
            format!(
                "sharded({smp_partitions} MIs x {smp_items} items + {}, weights {:?})",
                shares.join(" + "),
                weights.iter().map(|w| (w * 100.0).round() / 100.0).collect::<Vec<f64>>()
            )
        }
    }
}

fn main() -> anyhow::Result<()> {
    // --- 1. Synchronous SMP invocation (Figure 1) ------------------------
    let n = 1 << 20;
    let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();

    let engine = Engine::new(4);
    let c = engine.invoke(&vector_add_smp(), &(a.clone(), b.clone()));
    assert!(c.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
    println!("SMP SOMD vectorAdd over {n} elements: OK (4 MIs)");

    // --- 2. The same method under `auto` rules ---------------------------
    // The runtime learns where the method runs fastest: observed SMP wall
    // vs measured device execute time (vs hybrid wall, once explored)
    // feed the scheduler history; `VectorAdd.add:auto` resolves per
    // invocation.
    let artifacts =
        std::env::var("SOMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let mut rules = Rules::empty();
    rules.set("VectorAdd.add", Target::Auto);
    let engine = match Engine::with_rules(4, rules).with_device_master(&artifacts, "fermi") {
        Ok(e) => e,
        Err(e) => {
            println!("(artifacts not built — run `make artifacts` for the auto half: {e:#})");
            return Ok(());
        }
    };

    let hetero = Arc::new(vector_add_hetero());
    let input = Arc::new((a, b));

    // concurrent submissions: device-targeted jobs queue on the master
    // thread and share ONE warm session; SMP jobs compete for the pool;
    // hybrid-resolved jobs fork across both.
    for round in 0..4 {
        let handles: Vec<_> =
            (0..3).map(|_| engine.submit_hetero(hetero.clone(), input.clone())).collect();
        for h in handles {
            let (out, how) = h.join()?;
            assert!((out[3] - 9.0).abs() < 1e-3);
            println!("round {round}: ran on {}", describe(&how));
        }
    }

    if let Some(c) = engine.device_counters() {
        println!(
            "device lane: {} jobs over {} warm session(s) ({} warm hits)",
            c.jobs_run, c.sessions_created, c.warm_hits
        );
    }
    if let Some(h) = engine.scheduler().history("VectorAdd.add") {
        println!(
            "history: {} smp runs (mean {:.2} ms), {} device runs (mean {:.2} ms)",
            h.smp_runs,
            h.smp_estimate().unwrap_or(0.0) * 1e3,
            h.device_runs,
            h.device_estimate().unwrap_or(0.0) * 1e3,
        );
    }

    // --- 3. Forced hybrid co-execution -----------------------------------
    // `VectorAdd.add:hybrid` splits EVERY invocation across both lanes at
    // the learned ratio (starting at an even split); each run feeds the
    // per-side throughputs back, converging the ratio toward the
    // throughput-proportional equilibrium.
    let mut rules = Rules::empty();
    rules.set("VectorAdd.add", Target::Hybrid);
    let engine = Engine::with_rules(4, rules)
        .with_scheduler(Scheduler::new(SchedulerConfig::default()))
        .with_device_master(&artifacts, "fermi")?;
    for round in 0..3 {
        let (out, how) = engine.submit_hetero(hetero.clone(), input.clone()).join()?;
        assert!((out[3] - 9.0).abs() < 1e-3);
        println!("hybrid round {round}: ran on {}", describe(&how));
    }
    if let Some(h) = engine.scheduler().history("VectorAdd.add") {
        println!(
            "hybrid history: {} runs, learned device fraction {:.2}",
            h.hybrid_runs,
            h.device_fraction.unwrap_or(f64::NAN),
        );
    }
    println!("scheduler state: {}", engine.scheduler().to_json().dump());
    Ok(())
}
