//! Quickstart: the paper's Listing 8 — vector addition as a SOMD method.
//!
//! ```text
//! int[] vectorAdd(dist int[] a, dist int[] b) { ... }
//! ```
//!
//! The `dist` qualifier becomes a `Block1D` partition strategy, the method
//! body stays the sequential loop over the MI's index range, and the
//! default array reduction assembles the result.  The same method also
//! runs on the device backend (the AOT `vecadd` Pallas kernel) when
//! artifacts are available.
//!
//! Run: `cargo run --release --example quickstart`

use somd::somd::partition::Block1D;
use somd::somd::reduction::Assemble;
use somd::somd::{Engine, SomdMethod};

fn main() -> anyhow::Result<()> {
    // vectorAdd as a SOMD method
    let vector_add = SomdMethod::new(
        "VectorAdd.add",
        // dist a, dist b: built-in block partitioning (copy-free ranges)
        |inp: &(Vec<i64>, Vec<i64>), n| Block1D::new().ranges(inp.0.len(), n),
        |_, _| (),
        // the UNCHANGED sequential body, over the MI's range
        |inp, part, _, _| {
            let (a, b) = inp;
            part.own.iter().map(|i| a[i] + b[i]).collect::<Vec<i64>>()
        },
        Assemble,
    );

    let n = 1 << 20;
    let a: Vec<i64> = (0..n).collect();
    let b: Vec<i64> = (0..n).map(|i| 2 * i).collect();

    // Synchronous invocation (Figure 1): the caller sees a plain call.
    let engine = Engine::new(4);
    let c = engine.invoke(&vector_add, &(a.clone(), b.clone()));
    assert!(c.iter().enumerate().all(|(i, &v)| v == 3 * i as i64));
    println!("SMP SOMD vectorAdd over {n} elements: OK (4 MIs)");

    // The same operation offloaded to the device backend (paper Listing 3
    // territory, but with zero extra user code — the compiler's Algorithm 2
    // equivalent lives in the runtime).
    match somd::runtime::Registry::load_default() {
        Ok(reg) => {
            use somd::device::{Arg, DeviceProfile, DeviceSession};
            use somd::runtime::HostTensor;
            let elems = reg.info("vecadd")?.inputs[0].elems();
            let mut sess = DeviceSession::new(&reg, DeviceProfile::fermi());
            let x = HostTensor::vec_f32(vec![1.5; elems]);
            let y = HostTensor::vec_f32(vec![2.5; elems]);
            let out = sess.launch_to_host("vecadd", &[Arg::Host(&x), Arg::Host(&y)], elems)?;
            assert!(out[0].as_f32()?.iter().all(|&v| v == 4.0));
            let st = sess.stats();
            println!(
                "device vectorAdd ({}): OK — launches={} h2d={}B modeled_device_time={:.3}ms",
                sess.profile().name,
                st.launches,
                st.bytes_h2d,
                st.device_time.as_secs_f64() * 1e3
            );
        }
        Err(_) => println!("(artifacts not built — run `make artifacts` for the device half)"),
    }
    Ok(())
}
