//! Quickstart: the paper's Listing 8 — vector addition as a SOMD method.
//!
//! ```text
//! int[] vectorAdd(dist int[] a, dist int[] b) { ... }
//! ```
//!
//! The `dist` qualifier becomes a `Block1D` partition strategy, the method
//! body stays the sequential loop over the MI's index range, and the
//! default array reduction assembles the result.  The same method also
//! runs on the device backend (the AOT `vecadd` Pallas kernel) when
//! artifacts are available — and with a `VectorAdd.add:auto` rule the
//! engine picks the architecture itself from recorded execution history.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use somd::backend::{DeviceFn, Executed, HeteroMethod};
use somd::somd::partition::Block1D;
use somd::somd::reduction::Assemble;
use somd::somd::{Engine, Rules, SomdMethod, Target};

fn vector_add_smp() -> SomdMethod<(Vec<f32>, Vec<f32>), somd::somd::BlockPart, (), Vec<f32>> {
    SomdMethod::new(
        "VectorAdd.add",
        // dist a, dist b: built-in block partitioning (copy-free ranges)
        |inp: &(Vec<f32>, Vec<f32>), n| Block1D::new().ranges(inp.0.len(), n),
        |_, _| (),
        // the UNCHANGED sequential body, over the MI's range
        |inp, part, _, _| {
            let (a, b) = inp;
            part.own.iter().map(|i| a[i] + b[i]).collect::<Vec<f32>>()
        },
        Assemble,
    )
}

fn main() -> anyhow::Result<()> {
    // --- 1. Synchronous SMP invocation (Figure 1) ------------------------
    let n = 1 << 20;
    let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();

    let engine = Engine::new(4);
    let c = engine.invoke(&vector_add_smp(), &(a.clone(), b.clone()));
    assert!(c.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
    println!("SMP SOMD vectorAdd over {n} elements: OK (4 MIs)");

    // --- 2. The same method under `auto` rules ---------------------------
    // The runtime learns where the method runs fastest: SMP wall times vs
    // modeled device times (compute + transfers + launches) feed the
    // scheduler history; `VectorAdd.add:auto` resolves per invocation.
    let artifacts =
        std::env::var("SOMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let mut rules = Rules::empty();
    rules.set("VectorAdd.add", Target::Auto);
    let engine = match Engine::with_rules(4, rules).with_device_master(&artifacts, "fermi") {
        Ok(e) => e,
        Err(e) => {
            println!("(artifacts not built — run `make artifacts` for the auto half: {e:#})");
            return Ok(());
        }
    };

    // the hetero method: SMP version + device master code (Algorithm 2)
    let device: DeviceFn<(Vec<f32>, Vec<f32>), Vec<f32>> = Box::new(|sess, inp| {
        use somd::device::Arg;
        use somd::runtime::HostTensor;
        let x = HostTensor::vec_f32(inp.0.clone());
        let y = HostTensor::vec_f32(inp.1.clone());
        let out = sess.launch_to_host("vecadd", &[Arg::Host(&x), Arg::Host(&y)], inp.0.len())?;
        Ok(out[0].as_f32()?.to_vec())
    });
    let hetero = Arc::new(HeteroMethod::with_device(vector_add_smp(), device));
    let input = Arc::new((a, b));

    // concurrent submissions: device-targeted jobs queue on the master
    // thread and share ONE warm session; SMP jobs compete for the pool.
    for round in 0..4 {
        let handles: Vec<_> =
            (0..3).map(|_| engine.submit_hetero(hetero.clone(), input.clone())).collect();
        for h in handles {
            let (out, how) = h.join()?;
            assert!((out[3] - 9.0).abs() < 1e-3);
            let how = match how {
                Executed::Smp { partitions } => format!("smp({partitions} MIs)"),
                Executed::Device { profile, stats } => format!(
                    "device({profile}, modeled {:.2} ms)",
                    stats.device_time.as_secs_f64() * 1e3
                ),
            };
            println!("round {round}: ran on {how}");
        }
    }

    if let Some(c) = engine.device_counters() {
        println!(
            "device lane: {} jobs over {} warm session(s) ({} warm hits)",
            c.jobs_run, c.sessions_created, c.warm_hits
        );
    }
    if let Some(h) = engine.scheduler().history("VectorAdd.add") {
        println!(
            "history: {} smp runs (mean {:.2} ms), {} device runs (mean {:.2} ms)",
            h.smp_runs,
            h.smp_estimate().unwrap_or(0.0) * 1e3,
            h.device_runs,
            h.device_estimate().unwrap_or(0.0) * 1e3,
        );
        println!("scheduler state: {}", engine.scheduler().to_json().dump());
    }
    Ok(())
}
