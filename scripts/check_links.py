#!/usr/bin/env python3
"""Offline markdown link checker for the docs surface (CI `docs` job).

Verifies that every relative link in the checked markdown files resolves
to an existing file or directory, and that intra-document / cross-document
`#fragment` anchors match a heading.  External (http/https/mailto) links
are not fetched — the build is offline by design.

Usage: python3 scripts/check_links.py [files...]
Defaults to README.md, docs/*.md and rust/vendor/*/README.md.
"""

from __future__ import annotations

import glob
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (enough for our ASCII headings)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"\s+", "-", s).strip("-")


def anchors_of(path: Path) -> set[str]:
    text = path.read_text(encoding="utf-8")
    return {slugify(h) for h in HEADING_RE.findall(text)}


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if base and not dest.exists():
            errors.append(f"{path}: broken link -> {target}")
            continue
        if frag:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ""):
                continue  # anchors only checked in markdown
            if slugify(frag) not in anchors_of(dest):
                errors.append(f"{path}: missing anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = [ROOT / "README.md"]
        files += [Path(p) for p in glob.glob(str(ROOT / "docs" / "*.md"))]
        files += [Path(p) for p in glob.glob(str(ROOT / "rust" / "vendor" / "**" / "README.md"), recursive=True)]
    errors = []
    for f in sorted(set(files)):
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
