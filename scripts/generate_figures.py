#!/usr/bin/env python3
"""Render the serve_qos/v1 QoS figures as standalone SVG (no plotting
dependencies — the build is offline, so the bars are hand-rolled).

Reads BENCH_serve.json (``somd bench serve`` / ``make bench-qos``) and
writes three figures:

* ``serve_class_p99.svg`` — per-class p99 latency bars for every
  scenario that served both Interactive and Batch traffic: the priority
  gate (Interactive p99 < Batch p99 under saturation) made visible.
* ``serve_quota_goodput.svg`` — per-tenant goodput for the
  quota-isolated vs quota-shared pair: the in-quota tenants' bars
  should barely move when the greedy tenant arrives.
* ``serve_cancel_goodput.svg`` — survivor goodput for the
  cancel-off vs cancel-on pair: explicit cancellation returns queue
  capacity to requests that can still meet their deadline.

Usage:
    python3 scripts/generate_figures.py [BENCH_serve.json] [--out-dir figures]

Pure stdlib, offline.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

# class -> fill color (kept colorblind-distinguishable)
COLORS = {"interactive": "#1b9e77", "batch": "#d95f02", "best_effort": "#7570b3", "": "#666666"}
FONT = 'font-family="Helvetica,Arial,sans-serif"'


def esc(s: str) -> str:
    return s.replace("&", "&amp;").replace("<", "&lt;").replace(">", "&gt;").replace('"', "&quot;")


def bar_chart(title: str, ylabel: str, groups: list[tuple[str, list[tuple[str, float, str]]]]) -> str:
    """Grouped vertical bars: groups = [(group_label, [(bar_label, value, color), ...]), ...]."""
    bar_w, gap, group_gap = 34, 6, 36
    margin_l, margin_r, margin_t, margin_b = 64, 16, 44, 76
    plot_h = 220
    n_bars = sum(len(bars) for _, bars in groups)
    plot_w = n_bars * (bar_w + gap) + (len(groups) - 1) * group_gap
    width = margin_l + plot_w + margin_r
    height = margin_t + plot_h + margin_b
    vmax = max((v for _, bars in groups for _, v, _ in bars), default=1.0) or 1.0
    scale = plot_h / (vmax * 1.15)

    parts = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" '
        f'viewBox="0 0 {width} {height}">',
        f'<rect width="{width}" height="{height}" fill="white"/>',
        f'<text x="{width / 2:.1f}" y="20" text-anchor="middle" {FONT} font-size="14" '
        f'font-weight="bold">{esc(title)}</text>',
        f'<text x="14" y="{margin_t + plot_h / 2:.1f}" text-anchor="middle" {FONT} '
        f'font-size="11" transform="rotate(-90 14 {margin_t + plot_h / 2:.1f})">'
        f"{esc(ylabel)}</text>",
    ]
    # y axis + gridlines
    x0, y0 = margin_l, margin_t + plot_h
    parts.append(f'<line x1="{x0}" y1="{margin_t}" x2="{x0}" y2="{y0}" stroke="#333"/>')
    parts.append(f'<line x1="{x0}" y1="{y0}" x2="{x0 + plot_w}" y2="{y0}" stroke="#333"/>')
    for i in range(1, 5):
        v = vmax * 1.15 * i / 5
        y = y0 - v * scale
        parts.append(
            f'<line x1="{x0}" y1="{y:.1f}" x2="{x0 + plot_w}" y2="{y:.1f}" '
            f'stroke="#ddd" stroke-dasharray="3,3"/>'
        )
        parts.append(
            f'<text x="{x0 - 6}" y="{y + 3:.1f}" text-anchor="end" {FONT} font-size="10">'
            f"{v:.3g}</text>"
        )
    # bars
    x = float(x0)
    for group_label, bars in groups:
        gx0 = x
        for bar_label, value, color in bars:
            h = value * scale
            parts.append(
                f'<rect x="{x:.1f}" y="{y0 - h:.1f}" width="{bar_w}" height="{h:.1f}" '
                f'fill="{color}"/>'
            )
            parts.append(
                f'<text x="{x + bar_w / 2:.1f}" y="{y0 - h - 4:.1f}" text-anchor="middle" '
                f'{FONT} font-size="9">{value:.3g}</text>'
            )
            parts.append(
                f'<text x="{x + bar_w / 2:.1f}" y="{y0 + 12}" text-anchor="middle" {FONT} '
                f'font-size="9">{esc(bar_label)}</text>'
            )
            x += bar_w + gap
        cx = (gx0 + x - gap) / 2
        parts.append(
            f'<text x="{cx:.1f}" y="{y0 + 30}" text-anchor="middle" {FONT} font-size="10" '
            f'font-weight="bold">{esc(group_label)}</text>'
        )
        x += group_gap
    parts.append("</svg>")
    return "\n".join(parts) + "\n"


def class_stat(row: dict, name: str) -> dict | None:
    for c in row.get("classes", []):
        if c.get("class") == name:
            return c
    return None


def fig_class_p99(scenarios: list[dict]) -> str | None:
    groups = []
    for row in scenarios:
        inter, batch = class_stat(row, "interactive"), class_stat(row, "batch")
        if not inter or not batch or not inter["completed"] or not batch["completed"]:
            continue
        bars = [("int", inter["p99_ms"], COLORS["interactive"]),
                ("bat", batch["p99_ms"], COLORS["batch"])]
        be = class_stat(row, "best_effort")
        if be and be["completed"]:
            bars.append(("be", be["p99_ms"], COLORS["best_effort"]))
        groups.append((row["name"], bars))
    if not groups:
        return None
    return bar_chart("Per-class p99 latency under load", "p99 latency (ms)", groups)


def fig_quota_goodput(scenarios: list[dict]) -> str | None:
    pair = {r["name"]: r for r in scenarios if r["name"] in ("quota-isolated", "quota-shared")}
    if len(pair) != 2:
        return None
    groups = []
    for name in ("quota-isolated", "quota-shared"):
        bars = []
        for t in pair[name].get("tenants_detail", []):
            color = COLORS["batch"] if t["tenant"].startswith("greedy") else COLORS["interactive"]
            bars.append((t["tenant"], t["goodput_rps"], color))
        groups.append((name, bars))
    return bar_chart("Per-tenant goodput: quota isolation", "goodput (req/s)", groups)


def fig_cancel_goodput(scenarios: list[dict]) -> str | None:
    pair = {r["name"]: r for r in scenarios if r["name"] in ("cancel-off", "cancel-on")}
    if len(pair) != 2:
        return None
    groups = [
        (name, [("goodput", pair[name]["goodput_rps"], COLORS["interactive"]),
                ("expired", float(pair[name]["expired"]), COLORS["batch"])])
        for name in ("cancel-off", "cancel-on")
    ]
    return bar_chart("Cancellation returns capacity to survivors", "req/s | requests", groups)


def main(argv: list[str]) -> int:
    args = list(argv)
    out_dir = Path("figures")
    if "--out-dir" in args:
        i = args.index("--out-dir")
        out_dir = Path(args[i + 1])
        del args[i : i + 2]
    src = Path(args[0]) if args else Path("BENCH_serve.json")
    try:
        doc = json.loads(src.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        print(f"generate_figures: cannot read {src}: {e}", file=sys.stderr)
        return 1
    if doc.get("schema") != "serve_qos/v1":
        print(f"generate_figures: {src} is not serve_qos/v1", file=sys.stderr)
        return 1
    scenarios = doc.get("scenarios") or []
    out_dir.mkdir(parents=True, exist_ok=True)
    wrote = 0
    for fname, svg in [
        ("serve_class_p99.svg", fig_class_p99(scenarios)),
        ("serve_quota_goodput.svg", fig_quota_goodput(scenarios)),
        ("serve_cancel_goodput.svg", fig_cancel_goodput(scenarios)),
    ]:
        if svg is None:
            print(f"generate_figures: skipping {fname} (scenario rows missing)")
            continue
        (out_dir / fname).write_text(svg, encoding="utf-8")
        print(f"generate_figures: wrote {out_dir / fname}")
        wrote += 1
    return 0 if wrote else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
