#!/usr/bin/env python3
"""Flatten BENCH_*.json result files into one CSV, and gate the
serve_qos/v1 schema in CI.

Every bench emitter in this repo writes a top-level object with a
``schema`` tag and one or more arrays of flat row objects (see
docs/BENCHMARKS.md).  This script turns any of them into tidy CSV rows
(`file, schema, section, <row keys...>`), exploding the serve_qos/v1
nested per-class / per-tenant arrays into their own sections so
downstream tooling never has to parse JSON.

``--check`` validates the serve_qos/v1 file *non-vacuously*: the
scenario matrix must be present with per-class breakdowns, and the
overload/cancellation scenarios must actually have exercised the QoS
machinery (>= 1 shed request, >= 1 cancelled request, >= 1 expired
request across the matrix) — a bench run where no request was ever
shed or cancelled proves nothing about priority serving.

Usage:
    python3 scripts/collect_results.py [BENCH_foo.json ...] [--out results.csv]
    python3 scripts/collect_results.py --check [BENCH_serve.json]

With no file arguments, every BENCH_*.json in the repository root (or
current directory) is collected.  Pure stdlib, offline.
"""

from __future__ import annotations

import csv
import glob
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Keys every serve_qos/v1 scenario row must carry (docs/BENCHMARKS.md).
QOS_ROW_KEYS = {
    "name", "tenants", "requests", "elems", "workers", "queue_depth",
    "admission", "tenant_quota", "span_s", "wall_s", "throughput_rps",
    "goodput_rps", "mean_batch", "batches", "submitted", "completed",
    "rejected", "quota_rejected", "shed", "expired", "cancelled",
    "cancelled_queued", "classes", "tenants_detail",
}
QOS_CLASS_KEYS = {"class", "offered", "completed", "p50_ms", "p95_ms", "p99_ms", "goodput_rps"}


def scalars(row: dict) -> dict:
    return {k: v for k, v in row.items() if not isinstance(v, (list, dict))}


def flatten(path: Path) -> list[dict]:
    """One file -> flat CSV-ready dicts with file/schema/section columns."""
    doc = json.loads(path.read_text(encoding="utf-8"))
    schema = doc.get("schema", "?")
    out = []

    def emit(section: str, row: dict, extra: dict | None = None):
        flat = {"file": path.name, "schema": schema, "section": section}
        flat.update(extra or {})
        flat.update(scalars(row))
        out.append(flat)

    for key, val in doc.items():
        if not (isinstance(val, list) and val and all(isinstance(r, dict) for r in val)):
            continue
        for row in val:
            emit(key, row)
            # serve_qos/v1 nests per-class and per-tenant breakdowns
            for nested_key in ("classes", "tenants_detail"):
                for nested in row.get(nested_key, []) or []:
                    if isinstance(nested, dict):
                        emit(f"{key}.{nested_key}", nested, {"scenario": row.get("name", "")})
    if not out:  # no row arrays at all: emit the top-level scalars
        emit("top", doc)
    return out


def check_serve_qos(path: Path) -> list[str]:
    """Validate the serve_qos/v1 shape and that the matrix is non-vacuous."""
    errors = []
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if doc.get("schema") != "serve_qos/v1":
        errors.append(f"{path}: schema is {doc.get('schema')!r}, want 'serve_qos/v1'")
    if not isinstance(doc.get("capacity_rps"), (int, float)) or doc.get("capacity_rps", 0) <= 0:
        errors.append(f"{path}: capacity_rps missing or non-positive")
    if not doc.get("baseline"):
        errors.append(f"{path}: baseline sweep rows missing")
    scenarios = doc.get("scenarios") or []
    if not scenarios:
        errors.append(f"{path}: scenario matrix missing or empty")
    for row in scenarios:
        missing = QOS_ROW_KEYS - set(row)
        if missing:
            errors.append(f"{path}: scenario {row.get('name', '?')!r} lacks {sorted(missing)}")
            continue
        for cls in row["classes"]:
            lacking = QOS_CLASS_KEYS - set(cls)
            if lacking:
                errors.append(
                    f"{path}: scenario {row['name']!r} class row lacks {sorted(lacking)}"
                )
        if row["completed"] > row["submitted"]:
            errors.append(f"{path}: scenario {row['name']!r} completed > submitted")
    # non-vacuity: the matrix must have exercised shedding, expiry AND
    # cancellation somewhere, or the QoS gates tested nothing
    for counter in ("shed", "expired", "cancelled"):
        if scenarios and sum(row.get(counter, 0) for row in scenarios) < 1:
            errors.append(f"{path}: vacuous matrix — no scenario recorded a {counter} request")
    return errors


def main(argv: list[str]) -> int:
    args = list(argv)
    check = "--check" in args
    if check:
        args.remove("--check")
    out_csv = None
    if "--out" in args:
        i = args.index("--out")
        out_csv = Path(args[i + 1])
        del args[i : i + 2]

    files = [Path(a) for a in args]
    if not files:
        pattern = [str(ROOT / "BENCH_*.json"), "BENCH_*.json"]
        files = sorted({Path(p) for pat in pattern for p in glob.glob(pat)})
    if not files:
        print("collect_results: no BENCH_*.json files found", file=sys.stderr)
        return 1

    if check:
        errors = []
        serve_files = [f for f in files if "serve" in f.name] or files
        for f in serve_files:
            errors.extend(check_serve_qos(f))
        for e in errors:
            print(f"collect_results: {e}", file=sys.stderr)
        if not errors:
            print(f"collect_results: serve_qos/v1 check ok ({len(serve_files)} file(s))")
        return 1 if errors else 0

    rows = []
    for f in files:
        try:
            rows.extend(flatten(f))
        except (OSError, ValueError) as e:
            print(f"collect_results: skipping {f}: {e}", file=sys.stderr)
    if not rows:
        print("collect_results: nothing to collect", file=sys.stderr)
        return 1
    columns: list[str] = []
    for row in rows:
        for key in row:
            if key not in columns:
                columns.append(key)
    sink = open(out_csv, "w", newline="", encoding="utf-8") if out_csv else sys.stdout
    try:
        writer = csv.DictWriter(sink, fieldnames=columns, restval="")
        writer.writeheader()
        writer.writerows(rows)
    finally:
        if out_csv:
            sink.close()
            print(f"collect_results: wrote {len(rows)} rows to {out_csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
