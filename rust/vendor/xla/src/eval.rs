//! HLO interpreter: evaluates a parsed [`HloModule`] on host tensors.
//!
//! Covers the op set the AOT artifact suite uses (elementwise arithmetic
//! and logic, shape ops, dynamic slicing, while/call control flow,
//! variadic reduce, gather/scatter) with logical row-major semantics.
//! Reductions and scatters evaluate their `to_apply` computation per
//! element, with a fast path for the common single-binary-op regions.

use std::cell::Cell;

use crate::hlo::{Computation, HloModule, Instr};
use crate::value::{linear_index, next_index, strides_of, Data, Tensor, Value};
use crate::{ElementType, Error, Result};

thread_local! {
    /// Constant-literal text parses on this thread (both lanes).  The
    /// compiled lane parses at lowering time only; steady-state executes
    /// must leave this counter untouched (regression-tested).
    static CONST_PARSES: Cell<u64> = const { Cell::new(0) };
    /// Kernel *dispatches* on this thread (both lanes; while-loop bodies
    /// count once per iteration).  A fused chain is one dispatch.  Basis
    /// of the interp bench's ops/s metric.
    static EXEC_INSTRS: Cell<u64> = const { Cell::new(0) };
    /// HLO instructions executed on this thread, counting a fused chain
    /// by its constituent count.  Always >= `EXEC_INSTRS`; the two are
    /// equal when nothing fuses, and the gap measures fusion coverage.
    static FUSED_INSTRS: Cell<u64> = const { Cell::new(0) };
}

/// Constant-literal parses performed on this thread so far.
pub fn constant_parse_count() -> u64 {
    CONST_PARSES.with(|c| c.get())
}

/// Kernel dispatches on this thread so far (a fused chain counts once).
pub fn executed_instruction_count() -> u64 {
    EXEC_INSTRS.with(|c| c.get())
}

/// HLO instructions executed on this thread so far, with fused chains
/// counted by their constituents — comparable across fused and unfused
/// schedules of the same module.
pub fn fused_instruction_count() -> u64 {
    FUSED_INSTRS.with(|c| c.get())
}

pub(crate) fn note_const_parse() {
    CONST_PARSES.with(|c| c.set(c.get() + 1));
}

pub(crate) fn note_exec(n: u64) {
    EXEC_INSTRS.with(|c| c.set(c.get() + n));
    FUSED_INSTRS.with(|c| c.set(c.get() + n));
}

/// Credit a fused dispatch with its extra constituents (beyond the one
/// dispatch `note_exec` already counted).
pub(crate) fn note_fused_extra(n: u64) {
    FUSED_INSTRS.with(|c| c.set(c.get() + n));
}

/// Evaluate the module's entry computation over `args`.
pub fn execute_module(module: &HloModule, args: &[Value]) -> Result<Value> {
    evaluate(module, module.entry_computation()?, args)
}

/// Evaluate one computation with the given parameter values.
fn evaluate(module: &HloModule, comp: &Computation, args: &[Value]) -> Result<Value> {
    let n = comp.instrs.len();
    let mut values: Vec<Option<Value>> = (0..n).map(|_| None).collect();
    let mut stack: Vec<usize> = vec![comp.root];
    while let Some(&i) = stack.last() {
        if values[i].is_some() {
            stack.pop();
            continue;
        }
        let ins = &comp.instrs[i];
        let mut pending = false;
        if ins.op != "parameter" {
            for opnd in &ins.operands {
                let j = *comp.index.get(opnd).ok_or_else(|| {
                    Error(format!("'{}' references unknown operand '{opnd}'", ins.name))
                })?;
                if values[j].is_none() {
                    stack.push(j);
                    pending = true;
                }
            }
        }
        if pending {
            continue;
        }
        let operands: Vec<&Value> = if ins.op == "parameter" {
            Vec::new()
        } else {
            ins.operands
                .iter()
                .map(|o| values[comp.index[o]].as_ref().expect("operand evaluated"))
                .collect()
        };
        let v = eval_instr(module, ins, &operands, args)?;
        note_exec(1);
        values[i] = Some(v);
        stack.pop();
    }
    Ok(values[comp.root].take().expect("root evaluated"))
}

fn out_array(ins: &Instr) -> Result<(ElementType, Vec<usize>)> {
    let (ty, dims) = ins.shape.expect_array()?;
    Ok((ty, dims.to_vec()))
}

fn eval_instr(
    module: &HloModule,
    ins: &Instr,
    operands: &[&Value],
    args: &[Value],
) -> Result<Value> {
    match ins.op.as_str() {
        "parameter" => {
            let k: usize = ins
                .operands
                .first()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| Error(format!("bad parameter index on '{}'", ins.name)))?;
            args.get(k)
                .cloned()
                .ok_or_else(|| Error(format!("parameter({k}) out of range ({} args)", args.len())))
        }
        "constant" => eval_constant(ins),
        "tuple" => Ok(Value::Tuple(operands.iter().map(|v| (*v).clone()).collect())),
        "get-tuple-element" => {
            let idx = ins.attr_i64("index")? as usize;
            match operands[0] {
                Value::Tuple(parts) => parts
                    .get(idx)
                    .cloned()
                    .ok_or_else(|| Error(format!("tuple index {idx} out of range"))),
                Value::T(_) => Err(Error("get-tuple-element on non-tuple".into())),
            }
        }
        "call" => {
            let target = ins.attr_computation("to_apply")?;
            let callee = module.computation(&target)?;
            let call_args: Vec<Value> = operands.iter().map(|v| (*v).clone()).collect();
            evaluate(module, callee, &call_args)
        }
        "while" => {
            let cond = module.computation(&ins.attr_computation("condition")?)?;
            let body = module.computation(&ins.attr_computation("body")?)?;
            let mut state = operands[0].clone();
            loop {
                let keep = evaluate(module, cond, std::slice::from_ref(&state))?
                    .into_tensor()?
                    .scalar_bool()?;
                if !keep {
                    return Ok(state);
                }
                state = evaluate(module, body, std::slice::from_ref(&state))?;
            }
        }
        "broadcast" => eval_broadcast(ins, operands[0].tensor()?),
        "reshape" => {
            let (_, dims) = out_array(ins)?;
            let t = operands[0].tensor()?;
            Ok(Value::T(Tensor::new(dims, t.data.clone())?))
        }
        "transpose" => eval_transpose(ins, operands[0].tensor()?),
        "convert" => eval_convert(ins, operands[0].tensor()?),
        "iota" => eval_iota(ins),
        "slice" => eval_slice(ins, operands[0].tensor()?),
        "dynamic-slice" => eval_dynamic_slice(ins, operands),
        "dynamic-update-slice" => eval_dynamic_update_slice(ins, operands),
        "concatenate" => eval_concatenate(ins, operands),
        "compare" => eval_compare(ins, operands[0].tensor()?, operands[1].tensor()?),
        "select" => eval_select(ins, operands),
        "reduce" => eval_reduce(module, ins, operands),
        "gather" => eval_gather(ins, operands[0].tensor()?, operands[1].tensor()?),
        "scatter" => eval_scatter(module, ins, operands),
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "remainder"
        | "power" | "and" | "or" | "xor" | "shift-left" | "shift-right-logical"
        | "shift-right-arithmetic" => {
            eval_binary(ins, operands[0].tensor()?, operands[1].tensor()?)
        }
        "abs" | "negate" | "sine" | "cosine" | "tanh" | "exponential" | "log" | "sqrt"
        | "rsqrt" | "floor" | "ceil" | "sign" | "not" | "logistic" | "exponential-minus-one"
        | "log-plus-one" | "round-nearest-afz" | "copy" => eval_unary(ins, operands[0].tensor()?),
        other => Err(Error(format!("unsupported HLO op '{other}' ('{}')", ins.name))),
    }
}

// ---------------------------------------------------------------------------
// constants / iota
// ---------------------------------------------------------------------------

fn eval_constant(ins: &Instr) -> Result<Value> {
    Ok(Value::T(parse_constant_tensor(ins)?))
}

/// Parse a `constant(...)` payload into a tensor.  The naive lane calls
/// this on every evaluation; the compiled lane calls it exactly once per
/// constant at lowering time (see `compile.rs`).
pub(crate) fn parse_constant_tensor(ins: &Instr) -> Result<Tensor> {
    note_const_parse();
    let (ty, dims) = out_array(ins)?;
    let text = ins
        .const_text
        .as_deref()
        .ok_or_else(|| Error(format!("constant '{}' without payload", ins.name)))?;
    let want: usize = dims.iter().product();
    // strip braces; the remaining comma-separated scalars are row-major
    let cleaned: String = text.chars().map(|c| if c == '{' || c == '}' { ' ' } else { c }).collect();
    let toks: Vec<&str> =
        cleaned.split(',').map(str::trim).filter(|t| !t.is_empty()).collect();
    if toks.len() != want {
        return Err(Error(format!(
            "constant '{}': {} values for shape {:?}",
            ins.name,
            toks.len(),
            dims
        )));
    }
    let data = match ty {
        ElementType::Pred => Data::Pred(
            toks.iter()
                .map(|t| match *t {
                    "true" | "1" => Ok(true),
                    "false" | "0" => Ok(false),
                    other => Err(Error(format!("bad pred literal '{other}'"))),
                })
                .collect::<Result<_>>()?,
        ),
        ElementType::S32 => Data::S32(parse_nums::<i32>(&toks)?),
        ElementType::S64 => Data::S64(parse_nums::<i64>(&toks)?),
        ElementType::U32 => Data::U32(parse_nums::<u32>(&toks)?),
        ElementType::U64 => Data::U64(parse_nums::<u64>(&toks)?),
        ElementType::F32 => Data::F32(parse_nums::<f32>(&toks)?),
        ElementType::F64 => Data::F64(parse_nums::<f64>(&toks)?),
        other => return Err(Error(format!("unsupported constant dtype {other:?}"))),
    };
    Tensor::new(dims, data)
}

fn parse_nums<T: std::str::FromStr>(toks: &[&str]) -> Result<Vec<T>> {
    toks.iter()
        .map(|t| t.parse::<T>().map_err(|_| Error(format!("bad numeric literal '{t}'"))))
        .collect()
}

fn eval_iota(ins: &Instr) -> Result<Value> {
    Ok(Value::T(materialize_iota(ins)?))
}

/// Materialize an `iota()` tensor (shared with the compiled lane, which
/// evaluates it once at lowering time).
pub(crate) fn materialize_iota(ins: &Instr) -> Result<Tensor> {
    let (ty, dims) = out_array(ins)?;
    let d = ins.attr_i64("iota_dimension")? as usize;
    if d >= dims.len() {
        return Err(Error(format!("iota dimension {d} out of range for {dims:?}")));
    }
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(ty, total)?;
    let strides = strides_of(&dims);
    let mut idx = vec![0usize; dims.len()];
    let mut first = total > 0;
    while first {
        let lin = linear_index(&idx, &strides);
        let v = idx[d] as i64;
        write_i64(&mut out, lin, v);
        first = next_index(&mut idx, &dims);
    }
    Tensor::new(dims, out)
}

pub(crate) fn write_i64(d: &mut Data, i: usize, v: i64) {
    match d {
        Data::Pred(x) => x[i] = v != 0,
        Data::S32(x) => x[i] = v as i32,
        Data::S64(x) => x[i] = v,
        Data::U32(x) => x[i] = v as u32,
        Data::U64(x) => x[i] = v as u64,
        Data::F32(x) => x[i] = v as f32,
        Data::F64(x) => x[i] = v as f64,
    }
}

pub(crate) fn write_f64(d: &mut Data, i: usize, v: f64) {
    match d {
        Data::Pred(x) => x[i] = v != 0.0,
        Data::S32(x) => x[i] = v as i32,
        Data::S64(x) => x[i] = v as i64,
        Data::U32(x) => x[i] = v as u32,
        Data::U64(x) => x[i] = v as u64,
        Data::F32(x) => x[i] = v as f32,
        Data::F64(x) => x[i] = v,
    }
}

// ---------------------------------------------------------------------------
// shape ops
// ---------------------------------------------------------------------------

fn eval_broadcast(ins: &Instr, t: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let map = ins.attr_dims("dimensions")?; // operand dim k -> out dim map[k]
    if map.len() != t.rank() {
        return Err(Error(format!(
            "broadcast '{}': {} mapped dims for rank-{} operand",
            ins.name,
            map.len(),
            t.rank()
        )));
    }
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(t.dtype(), total)?;
    let out_strides = strides_of(&dims);
    let src_strides = t.strides();
    let mut idx = vec![0usize; dims.len()];
    let mut more = total > 0;
    while more {
        let mut src_lin = 0usize;
        for (k, &od) in map.iter().enumerate() {
            src_lin += idx[od as usize] * src_strides[k];
        }
        let lin = linear_index(&idx, &out_strides);
        out.copy_elem(lin, &t.data, src_lin)?;
        more = next_index(&mut idx, &dims);
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

fn eval_transpose(ins: &Instr, t: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let perm = ins.attr_dims("dimensions")?; // out dim i <- operand dim perm[i]
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(t.dtype(), total)?;
    let out_strides = strides_of(&dims);
    let src_strides = t.strides();
    let mut idx = vec![0usize; dims.len()];
    let mut more = total > 0;
    while more {
        let mut src_lin = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            src_lin += idx[i] * src_strides[p as usize];
        }
        out.copy_elem(linear_index(&idx, &out_strides), &t.data, src_lin)?;
        more = next_index(&mut idx, &dims);
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

fn eval_convert(ins: &Instr, t: &Tensor) -> Result<Value> {
    let (ty, dims) = out_array(ins)?;
    let n = t.elems();
    let mut out = Data::zeros(ty, n)?;
    let src_is_float = matches!(t.dtype(), ElementType::F32 | ElementType::F64);
    for i in 0..n {
        if src_is_float {
            write_f64(&mut out, i, t.data.get_f64(i));
        } else {
            write_i64(&mut out, i, t.data.get_i64(i));
        }
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

pub(crate) fn parse_slice_spec(s: &str) -> Result<Vec<(usize, usize, usize)>> {
    // {[lo:hi], [lo:hi:stride], ...}
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim().trim_start_matches('[').trim_end_matches(']');
        if part.is_empty() {
            continue;
        }
        let nums: Vec<usize> = part
            .split(':')
            .map(|x| x.trim().parse::<usize>().map_err(|_| Error(format!("bad slice '{s}'"))))
            .collect::<Result<_>>()?;
        match nums.as_slice() {
            [lo, hi] => out.push((*lo, *hi, 1)),
            [lo, hi, st] => out.push((*lo, *hi, *st)),
            _ => return Err(Error(format!("bad slice bounds '{part}'"))),
        }
    }
    Ok(out)
}

fn eval_slice(ins: &Instr, t: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let spec = parse_slice_spec(ins.attr("slice")?)?;
    if spec.len() != t.rank() {
        return Err(Error(format!("slice spec rank mismatch on '{}'", ins.name)));
    }
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(t.dtype(), total)?;
    let out_strides = strides_of(&dims);
    let src_strides = t.strides();
    let mut idx = vec![0usize; dims.len()];
    let mut more = total > 0;
    while more {
        let mut src_lin = 0usize;
        for d in 0..dims.len() {
            src_lin += (spec[d].0 + idx[d] * spec[d].2) * src_strides[d];
        }
        out.copy_elem(linear_index(&idx, &out_strides), &t.data, src_lin)?;
        more = next_index(&mut idx, &dims);
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

/// Clamped start indices for dynamic-slice/dynamic-update-slice.
fn dynamic_starts(
    operands: &[&Value],
    first_idx: usize,
    in_dims: &[usize],
    window: &[usize],
) -> Result<Vec<usize>> {
    let mut starts = Vec::with_capacity(in_dims.len());
    for d in 0..in_dims.len() {
        let s = operands
            .get(first_idx + d)
            .ok_or_else(|| Error("missing dynamic start index".into()))?
            .tensor()?
            .scalar_i64()?;
        let max = in_dims[d].saturating_sub(window[d]) as i64;
        starts.push(s.clamp(0, max) as usize);
    }
    Ok(starts)
}

fn eval_dynamic_slice(ins: &Instr, operands: &[&Value]) -> Result<Value> {
    let t = operands[0].tensor()?;
    let (_, dims) = out_array(ins)?;
    let sizes: Vec<usize> = match ins.attrs.get("dynamic_slice_sizes") {
        Some(v) => crate::hlo::parse_brace_list(v)?.into_iter().map(|x| x as usize).collect(),
        None => dims.clone(),
    };
    let starts = dynamic_starts(operands, 1, &t.dims, &sizes)?;
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(t.dtype(), total)?;
    let out_strides = strides_of(&dims);
    let src_strides = t.strides();
    let mut idx = vec![0usize; dims.len()];
    let mut more = total > 0;
    while more {
        let mut src_lin = 0usize;
        for d in 0..dims.len() {
            src_lin += (starts[d] + idx[d]) * src_strides[d];
        }
        out.copy_elem(linear_index(&idx, &out_strides), &t.data, src_lin)?;
        more = next_index(&mut idx, &dims);
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

fn eval_dynamic_update_slice(ins: &Instr, operands: &[&Value]) -> Result<Value> {
    let t = operands[0].tensor()?;
    let u = operands[1].tensor()?;
    let (_, dims) = out_array(ins)?;
    let starts = dynamic_starts(operands, 2, &t.dims, &u.dims)?;
    let mut out = t.data.clone();
    let dst_strides = t.strides();
    let src_strides = u.strides();
    let mut idx = vec![0usize; u.rank()];
    let mut more = u.elems() > 0;
    while more {
        let mut dst_lin = 0usize;
        for d in 0..u.rank() {
            dst_lin += (starts[d] + idx[d]) * dst_strides[d];
        }
        out.copy_elem(dst_lin, &u.data, linear_index(&idx, &src_strides))?;
        more = next_index(&mut idx, &u.dims);
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

fn eval_concatenate(ins: &Instr, operands: &[&Value]) -> Result<Value> {
    let (ty, dims) = out_array(ins)?;
    let axis = ins
        .attr_dims("dimensions")?
        .first()
        .copied()
        .ok_or_else(|| Error("concatenate without dimension".into()))? as usize;
    let total: usize = dims.iter().product();
    let mut out = Data::zeros(ty, total)?;
    let out_strides = strides_of(&dims);
    let mut offset = 0usize;
    for v in operands {
        let t = v.tensor()?;
        let src_strides = t.strides();
        let mut idx = vec![0usize; t.rank()];
        let mut more = t.elems() > 0;
        while more {
            let mut dst_lin = 0usize;
            for d in 0..t.rank() {
                let pos = if d == axis { idx[d] + offset } else { idx[d] };
                dst_lin += pos * out_strides[d];
            }
            out.copy_elem(dst_lin, &t.data, linear_index(&idx, &src_strides))?;
            more = next_index(&mut idx, &t.dims);
        }
        offset += t.dims[axis];
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

// ---------------------------------------------------------------------------
// elementwise
// ---------------------------------------------------------------------------

/// Resolve (elementwise) operand pairs where one side may be a scalar.
pub(crate) fn pair_index(i: usize, len: usize) -> usize {
    if len == 1 {
        0
    } else {
        i
    }
}

fn eval_compare(ins: &Instr, a: &Tensor, b: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let dir = ins.attr("direction")?.to_string();
    let n: usize = dims.iter().product();
    let float = matches!(a.dtype(), ElementType::F32 | ElementType::F64);
    let mut out = vec![false; n];
    for (i, o) in out.iter_mut().enumerate() {
        let (ia, ib) = (pair_index(i, a.elems()), pair_index(i, b.elems()));
        *o = if float {
            let (x, y) = (a.data.get_f64(ia), b.data.get_f64(ib));
            match dir.as_str() {
                "EQ" => x == y,
                "NE" => x != y,
                "LT" => x < y,
                "LE" => x <= y,
                "GT" => x > y,
                "GE" => x >= y,
                other => return Err(Error(format!("bad compare direction '{other}'"))),
            }
        } else {
            let (x, y) = (a.data.get_i64(ia), b.data.get_i64(ib));
            match dir.as_str() {
                "EQ" => x == y,
                "NE" => x != y,
                "LT" => x < y,
                "LE" => x <= y,
                "GT" => x > y,
                "GE" => x >= y,
                other => return Err(Error(format!("bad compare direction '{other}'"))),
            }
        };
    }
    Ok(Value::T(Tensor::new(dims, Data::Pred(out))?))
}

fn eval_select(ins: &Instr, operands: &[&Value]) -> Result<Value> {
    let p = operands[0].tensor()?;
    let t = operands[1].tensor()?;
    let f = operands[2].tensor()?;
    let (_, dims) = out_array(ins)?;
    let n: usize = dims.iter().product();
    let preds = match &p.data {
        Data::Pred(v) => v,
        _ => return Err(Error("select predicate must be pred".into())),
    };
    let mut out = Data::zeros(t.dtype(), n)?;
    for i in 0..n {
        let cond = preds[pair_index(i, preds.len())];
        let src = if cond { t } else { f };
        out.copy_elem(i, &src.data, pair_index(i, src.elems()))?;
    }
    Ok(Value::T(Tensor::new(dims, out)?))
}

fn eval_binary(ins: &Instr, a: &Tensor, b: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let n: usize = dims.iter().product();
    let op = ins.op.as_str();
    macro_rules! float_case {
        ($variant:ident, $ty:ty, $av:expr, $bv:expr) => {{
            let mut out: Vec<$ty> = Vec::with_capacity(n);
            for i in 0..n {
                let x = $av[pair_index(i, $av.len())];
                let y = $bv[pair_index(i, $bv.len())];
                out.push(match op {
                    "add" => x + y,
                    "subtract" => x - y,
                    "multiply" => x * y,
                    "divide" => x / y,
                    "maximum" => x.max(y),
                    "minimum" => x.min(y),
                    "remainder" => x % y,
                    "power" => x.powf(y),
                    other => {
                        return Err(Error(format!("op '{other}' unsupported on floats")))
                    }
                });
            }
            Data::$variant(out)
        }};
    }
    macro_rules! int_case {
        ($variant:ident, $ty:ty, $av:expr, $bv:expr) => {{
            let mut out: Vec<$ty> = Vec::with_capacity(n);
            for i in 0..n {
                let x = $av[pair_index(i, $av.len())];
                let y = $bv[pair_index(i, $bv.len())];
                let bits = <$ty>::BITS as u64;
                out.push(match op {
                    "add" => x.wrapping_add(y),
                    "subtract" => x.wrapping_sub(y),
                    "multiply" => x.wrapping_mul(y),
                    "divide" => {
                        if y == 0 {
                            0
                        } else {
                            x.wrapping_div(y)
                        }
                    }
                    "remainder" => {
                        if y == 0 {
                            0
                        } else {
                            x.wrapping_rem(y)
                        }
                    }
                    "maximum" => x.max(y),
                    "minimum" => x.min(y),
                    "and" => x & y,
                    "or" => x | y,
                    "xor" => x ^ y,
                    "shift-left" => {
                        let s = y as u64;
                        if s >= bits {
                            0
                        } else {
                            x << s
                        }
                    }
                    "shift-right-logical" => {
                        let s = y as u64;
                        if s >= bits {
                            0
                        } else {
                            (((x as u64) & ((!0u64) >> (64 - bits))) >> s) as $ty
                        }
                    }
                    "shift-right-arithmetic" => {
                        let s = (y as u64).min(bits - 1);
                        x >> s
                    }
                    other => {
                        return Err(Error(format!("op '{other}' unsupported on integers")))
                    }
                });
            }
            Data::$variant(out)
        }};
    }
    let data = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => float_case!(F32, f32, x, y),
        (Data::F64(x), Data::F64(y)) => float_case!(F64, f64, x, y),
        (Data::S32(x), Data::S32(y)) => int_case!(S32, i32, x, y),
        (Data::S64(x), Data::S64(y)) => int_case!(S64, i64, x, y),
        (Data::U32(x), Data::U32(y)) => int_case!(U32, u32, x, y),
        (Data::U64(x), Data::U64(y)) => int_case!(U64, u64, x, y),
        (Data::Pred(x), Data::Pred(y)) => {
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let xa = x[pair_index(i, x.len())];
                let yb = y[pair_index(i, y.len())];
                out.push(match op {
                    "and" => xa && yb,
                    "or" => xa || yb,
                    "xor" => xa != yb,
                    other => return Err(Error(format!("op '{other}' unsupported on pred"))),
                });
            }
            Data::Pred(out)
        }
        (x, y) => {
            return Err(Error(format!(
                "binary '{}' dtype mismatch: {:?} vs {:?}",
                op,
                x.dtype(),
                y.dtype()
            )))
        }
    };
    Ok(Value::T(Tensor::new(dims, data)?))
}

fn eval_unary(ins: &Instr, t: &Tensor) -> Result<Value> {
    let (_, dims) = out_array(ins)?;
    let op = ins.op.as_str();
    macro_rules! float_case {
        ($variant:ident, $ty:ty, $v:expr) => {{
            let out: Vec<$ty> = $v
                .iter()
                .map(|&x| match op {
                    "abs" => x.abs(),
                    "negate" => -x,
                    "sine" => x.sin(),
                    "cosine" => x.cos(),
                    "tanh" => x.tanh(),
                    "exponential" => x.exp(),
                    "exponential-minus-one" => x.exp_m1(),
                    "log" => x.ln(),
                    "log-plus-one" => x.ln_1p(),
                    "sqrt" => x.sqrt(),
                    "rsqrt" => x.sqrt().recip(),
                    "floor" => x.floor(),
                    "ceil" => x.ceil(),
                    "round-nearest-afz" => x.round(),
                    "sign" => {
                        if x > 0.0 {
                            1.0
                        } else if x < 0.0 {
                            -1.0
                        } else {
                            x
                        }
                    }
                    "logistic" => 1.0 / (1.0 + (-x).exp()),
                    "copy" => x,
                    _ => <$ty>::NAN, // checked below
                })
                .collect();
            if !matches!(
                op,
                "abs" | "negate"
                    | "sine"
                    | "cosine"
                    | "tanh"
                    | "exponential"
                    | "exponential-minus-one"
                    | "log"
                    | "log-plus-one"
                    | "sqrt"
                    | "rsqrt"
                    | "floor"
                    | "ceil"
                    | "round-nearest-afz"
                    | "sign"
                    | "logistic"
                    | "copy"
            ) {
                return Err(Error(format!("op '{op}' unsupported on floats")));
            }
            Data::$variant(out)
        }};
    }
    let data = match &t.data {
        Data::F32(v) => float_case!(F32, f32, v),
        Data::F64(v) => float_case!(F64, f64, v),
        Data::S32(v) => int_unary_s32_like(op, v)?,
        Data::S64(v) => match op {
            "abs" => Data::S64(v.iter().map(|&x| x.wrapping_abs()).collect()),
            "negate" => Data::S64(v.iter().map(|&x| x.wrapping_neg()).collect()),
            "not" => Data::S64(v.iter().map(|&x| !x).collect()),
            "sign" => Data::S64(v.iter().map(|&x| x.signum()).collect()),
            "copy" => Data::S64(v.clone()),
            other => return Err(Error(format!("op '{other}' unsupported on s64"))),
        },
        Data::U32(v) => match op {
            "abs" | "copy" => Data::U32(v.clone()),
            "negate" => Data::U32(v.iter().map(|&x| x.wrapping_neg()).collect()),
            "not" => Data::U32(v.iter().map(|&x| !x).collect()),
            "sign" => Data::U32(v.iter().map(|&x| u32::from(x != 0)).collect()),
            other => return Err(Error(format!("op '{other}' unsupported on u32"))),
        },
        Data::U64(v) => match op {
            "abs" | "copy" => Data::U64(v.clone()),
            "negate" => Data::U64(v.iter().map(|&x| x.wrapping_neg()).collect()),
            "not" => Data::U64(v.iter().map(|&x| !x).collect()),
            "sign" => Data::U64(v.iter().map(|&x| u64::from(x != 0)).collect()),
            other => return Err(Error(format!("op '{other}' unsupported on u64"))),
        },
        Data::Pred(v) => match op {
            "not" => Data::Pred(v.iter().map(|&x| !x).collect()),
            "copy" => Data::Pred(v.clone()),
            other => return Err(Error(format!("op '{other}' unsupported on pred"))),
        },
    };
    Ok(Value::T(Tensor::new(dims, data)?))
}

fn int_unary_s32_like(op: &str, v: &[i32]) -> Result<Data> {
    Ok(match op {
        "abs" => Data::S32(v.iter().map(|&x| x.wrapping_abs()).collect()),
        "negate" => Data::S32(v.iter().map(|&x| x.wrapping_neg()).collect()),
        "not" => Data::S32(v.iter().map(|&x| !x).collect()),
        "sign" => Data::S32(v.iter().map(|&x| x.signum()).collect()),
        "copy" => Data::S32(v.to_vec()),
        other => return Err(Error(format!("op '{other}' unsupported on s32"))),
    })
}

// ---------------------------------------------------------------------------
// reduce / gather / scatter (use `to_apply` computations)
// ---------------------------------------------------------------------------

/// Recognized single-instruction combiner regions (fast path).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum FastCombine {
    Add,
    Mul,
    Max,
    Min,
    Or,
    And,
    /// `ROOT = parameter(0)` — keep the accumulator.
    First,
    /// `ROOT = parameter(1)` — overwrite with the element.
    Second,
}

pub(crate) fn fast_combiner(comp: &Computation) -> Option<FastCombine> {
    let root = &comp.instrs[comp.root];
    let param_no = |name: &str| -> Option<usize> {
        let idx = *comp.index.get(name)?;
        let ins = &comp.instrs[idx];
        if ins.op == "parameter" {
            ins.operands.first()?.parse().ok()
        } else {
            None
        }
    };
    if root.op == "parameter" {
        return match root.operands.first()?.parse::<usize>().ok()? {
            0 => Some(FastCombine::First),
            1 => Some(FastCombine::Second),
            _ => None,
        };
    }
    if root.operands.len() != 2 {
        return None;
    }
    let (a, b) = (param_no(&root.operands[0])?, param_no(&root.operands[1])?);
    if (a, b) != (0, 1) {
        return None;
    }
    match root.op.as_str() {
        "add" => Some(FastCombine::Add),
        "multiply" => Some(FastCombine::Mul),
        "maximum" => Some(FastCombine::Max),
        "minimum" => Some(FastCombine::Min),
        "or" => Some(FastCombine::Or),
        "and" => Some(FastCombine::And),
        _ => None,
    }
}

/// Combine two elements (same dtype) by `fc`, reading from `acc[ai]` and
/// `elem[ei]`, writing back into `acc[ai]`.
pub(crate) fn fast_combine_elem(
    fc: FastCombine,
    acc: &mut Data,
    ai: usize,
    elem: &Data,
    ei: usize,
) -> Result<()> {
    match fc {
        FastCombine::First => Ok(()),
        FastCombine::Second => acc.copy_elem(ai, elem, ei),
        _ => {
            match (acc, elem) {
                (Data::F32(a), Data::F32(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai] + e[ei],
                        FastCombine::Mul => a[ai] * e[ei],
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        _ => return Err(Error("bad combiner for f32".into())),
                    }
                }
                (Data::F64(a), Data::F64(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai] + e[ei],
                        FastCombine::Mul => a[ai] * e[ei],
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        _ => return Err(Error("bad combiner for f64".into())),
                    }
                }
                (Data::S32(a), Data::S32(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai].wrapping_add(e[ei]),
                        FastCombine::Mul => a[ai].wrapping_mul(e[ei]),
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        FastCombine::Or => a[ai] | e[ei],
                        FastCombine::And => a[ai] & e[ei],
                        _ => unreachable!(),
                    }
                }
                (Data::S64(a), Data::S64(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai].wrapping_add(e[ei]),
                        FastCombine::Mul => a[ai].wrapping_mul(e[ei]),
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        FastCombine::Or => a[ai] | e[ei],
                        FastCombine::And => a[ai] & e[ei],
                        _ => unreachable!(),
                    }
                }
                (Data::U32(a), Data::U32(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai].wrapping_add(e[ei]),
                        FastCombine::Mul => a[ai].wrapping_mul(e[ei]),
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        FastCombine::Or => a[ai] | e[ei],
                        FastCombine::And => a[ai] & e[ei],
                        _ => unreachable!(),
                    }
                }
                (Data::U64(a), Data::U64(e)) => {
                    a[ai] = match fc {
                        FastCombine::Add => a[ai].wrapping_add(e[ei]),
                        FastCombine::Mul => a[ai].wrapping_mul(e[ei]),
                        FastCombine::Max => a[ai].max(e[ei]),
                        FastCombine::Min => a[ai].min(e[ei]),
                        FastCombine::Or => a[ai] | e[ei],
                        FastCombine::And => a[ai] & e[ei],
                        _ => unreachable!(),
                    }
                }
                (Data::Pred(a), Data::Pred(e)) => {
                    a[ai] = match fc {
                        FastCombine::Or => a[ai] || e[ei],
                        FastCombine::And => a[ai] && e[ei],
                        FastCombine::Add => a[ai] != e[ei],
                        FastCombine::Max => a[ai] || e[ei],
                        FastCombine::Min => a[ai] && e[ei],
                        _ => return Err(Error("bad combiner for pred".into())),
                    }
                }
                (a, e) => {
                    return Err(Error(format!(
                        "combiner dtype mismatch: {:?} vs {:?}",
                        a.dtype(),
                        e.dtype()
                    )))
                }
            }
            Ok(())
        }
    }
}

fn scalar_tensor_from(data: &Data, i: usize) -> Result<Tensor> {
    let mut d = Data::zeros(data.dtype(), 1)?;
    d.copy_elem(0, data, i)?;
    Tensor::new(vec![], d)
}

pub(crate) fn eval_reduce(module: &HloModule, ins: &Instr, operands: &[&Value]) -> Result<Value> {
    let k = operands.len() / 2;
    if operands.len() != 2 * k || k == 0 {
        return Err(Error(format!("reduce '{}' needs k inputs + k inits", ins.name)));
    }
    let region = module.computation(&ins.attr_computation("to_apply")?)?;
    let red_dims: Vec<usize> =
        ins.attr_dims("dimensions")?.into_iter().map(|d| d as usize).collect();
    let inputs: Vec<&Tensor> =
        operands[..k].iter().map(|v| v.tensor()).collect::<Result<_>>()?;
    let inits: Vec<&Tensor> =
        operands[k..].iter().map(|v| v.tensor()).collect::<Result<_>>()?;
    let in_dims = inputs[0].dims.clone();
    for t in &inputs {
        if t.dims != in_dims {
            return Err(Error("reduce inputs must share dims".into()));
        }
    }
    // output dims: input dims with reduced dims removed (in order)
    let kept: Vec<usize> =
        (0..in_dims.len()).filter(|d| !red_dims.contains(d)).collect();
    let out_dims: Vec<usize> = kept.iter().map(|&d| in_dims[d]).collect();
    let out_elems: usize = out_dims.iter().product();
    let out_strides = strides_of(&out_dims);
    let in_strides = strides_of(&in_dims);

    // accumulators, seeded from the inits
    let mut accs: Vec<Data> = Vec::with_capacity(k);
    for init in &inits {
        let mut d = Data::zeros(init.dtype(), out_elems)?;
        for i in 0..out_elems {
            d.copy_elem(i, &init.data, 0)?;
        }
        accs.push(d);
    }

    let fast = if k == 1 { fast_combiner(region) } else { None };

    // f32 sum reduction: accumulate in f64 (the 1001-term trapezoid sums
    // of the Series kernel cancel catastrophically in f32; the bench
    // suite validates against the f64 sequential oracle)
    if fast == Some(FastCombine::Add) {
        if let (Data::F32(input), Data::F32(acc)) = (&inputs[0].data, &mut accs[0]) {
            let mut wide: Vec<f64> = acc.iter().map(|&v| v as f64).collect();
            let total: usize = in_dims.iter().product();
            let mut idx = vec![0usize; in_dims.len()];
            let mut more = total > 0;
            while more {
                let mut out_lin = 0usize;
                for (pos, &d) in kept.iter().enumerate() {
                    out_lin += idx[d] * out_strides[pos];
                }
                wide[out_lin] += input[linear_index(&idx, &in_strides)] as f64;
                more = next_index(&mut idx, &in_dims);
            }
            for (a, w) in acc.iter_mut().zip(&wide) {
                *a = *w as f32;
            }
            return Ok(Value::T(Tensor::new(out_dims, accs.pop().unwrap())?));
        }
    }

    let total: usize = in_dims.iter().product();
    let mut idx = vec![0usize; in_dims.len()];
    let mut more = total > 0;
    while more {
        let mut out_lin = 0usize;
        for (pos, &d) in kept.iter().enumerate() {
            out_lin += idx[d] * out_strides[pos];
        }
        let in_lin = linear_index(&idx, &in_strides);
        if let Some(fc) = fast {
            fast_combine_elem(fc, &mut accs[0], out_lin, &inputs[0].data, in_lin)?;
        } else {
            // generic: region(acc..., elem...)
            let mut call_args: Vec<Value> = Vec::with_capacity(2 * k);
            for a in &accs {
                call_args.push(Value::T(scalar_tensor_from(a, out_lin)?));
            }
            for t in &inputs {
                call_args.push(Value::T(scalar_tensor_from(&t.data, in_lin)?));
            }
            let res = evaluate(module, region, &call_args)?;
            let parts: Vec<Value> = match res {
                Value::Tuple(p) => p,
                v @ Value::T(_) => vec![v],
            };
            if parts.len() != k {
                return Err(Error("reduce region arity mismatch".into()));
            }
            for (a, p) in accs.iter_mut().zip(&parts) {
                a.copy_elem(out_lin, &p.tensor()?.data, 0)?;
            }
        }
        more = next_index(&mut idx, &in_dims);
    }

    let mut outs: Vec<Value> = Vec::with_capacity(k);
    for d in accs {
        outs.push(Value::T(Tensor::new(out_dims.clone(), d)?));
    }
    if k == 1 {
        Ok(outs.pop().unwrap())
    } else {
        Ok(Value::Tuple(outs))
    }
}

/// Read the start-index vector for gather/scatter index position
/// `batch_idx` (the scatter/batch coordinates, in order).
fn start_vector(
    s_dims: &[usize],
    s_data: &Data,
    batch_idx: &[usize],
    index_vector_dim: usize,
    vec_len: usize,
) -> Result<Vec<i64>> {
    let strides = strides_of(s_dims);
    let mut out = Vec::with_capacity(vec_len);
    for comp in 0..vec_len {
        // rebuild the full index into S: batch coords with `comp` inserted
        // at index_vector_dim (or nothing inserted if ivd == rank)
        let mut lin = 0usize;
        let mut b = 0usize;
        for d in 0..s_dims.len() {
            let coord = if d == index_vector_dim {
                comp
            } else {
                let c = batch_idx[b];
                b += 1;
                c
            };
            lin += coord * strides[d];
        }
        out.push(s_data.get_i64(lin));
    }
    Ok(out)
}

pub(crate) fn eval_gather(ins: &Instr, operand: &Tensor, indices: &Tensor) -> Result<Value> {
    let (out_dims, out) =
        gather_core(ins, &operand.dims, &operand.data, &indices.dims, &indices.data)?;
    Ok(Value::T(Tensor::new(out_dims, out)?))
}

/// Container-agnostic gather core, shared by both interpreter lanes.
pub(crate) fn gather_core(
    ins: &Instr,
    op_dims: &[usize],
    op_data: &Data,
    idx_dims: &[usize],
    idx_data: &Data,
) -> Result<(Vec<usize>, Data)> {
    let (_, out_dims) = out_array(ins)?;
    let op_rank = op_dims.len();
    let offset_dims: Vec<usize> =
        ins.attr_dims("offset_dims")?.into_iter().map(|d| d as usize).collect();
    let collapsed: Vec<usize> =
        ins.attr_dims("collapsed_slice_dims")?.into_iter().map(|d| d as usize).collect();
    let start_index_map: Vec<usize> =
        ins.attr_dims("start_index_map")?.into_iter().map(|d| d as usize).collect();
    let ivd = ins.attr_i64("index_vector_dim")? as usize;
    let slice_sizes: Vec<usize> =
        ins.attr_dims("slice_sizes")?.into_iter().map(|d| d as usize).collect();

    let out_rank = out_dims.len();
    let batch_dims_in_out: Vec<usize> =
        (0..out_rank).filter(|d| !offset_dims.contains(d)).collect();
    // operand dims that survive collapsing, in order — matched with
    // offset_dims in order
    let kept_operand_dims: Vec<usize> =
        (0..op_rank).filter(|d| !collapsed.contains(d)).collect();
    if kept_operand_dims.len() != offset_dims.len() {
        return Err(Error(format!("gather '{}' offset/collapsed mismatch", ins.name)));
    }

    let total: usize = out_dims.iter().product();
    let mut out = Data::zeros(op_data.dtype(), total)?;
    let out_strides = strides_of(&out_dims);
    let op_strides = strides_of(op_dims);
    let mut idx = vec![0usize; out_rank];
    let mut more = total > 0;
    while more {
        let batch_idx: Vec<usize> = batch_dims_in_out.iter().map(|&d| idx[d]).collect();
        let starts =
            start_vector(idx_dims, idx_data, &batch_idx, ivd, start_index_map.len())?;
        let mut full_start = vec![0i64; op_rank];
        for (k, &d) in start_index_map.iter().enumerate() {
            let max = op_dims[d] as i64 - slice_sizes[d] as i64;
            full_start[d] = starts[k].clamp(0, max.max(0));
        }
        let mut lin = 0usize;
        for (pos, &d) in kept_operand_dims.iter().enumerate() {
            let off = idx[offset_dims[pos]];
            lin += (full_start[d] as usize + off) * op_strides[d];
        }
        for &d in &collapsed {
            lin += full_start[d] as usize * op_strides[d];
        }
        out.copy_elem(linear_index(&idx, &out_strides), op_data, lin)?;
        more = next_index(&mut idx, &out_dims);
    }
    Ok((out_dims, out))
}

pub(crate) fn eval_scatter(module: &HloModule, ins: &Instr, operands: &[&Value]) -> Result<Value> {
    // single-operand scatter: (operand, scatter_indices, updates)
    if operands.len() != 3 {
        return Err(Error(format!("scatter '{}' expects 3 operands", ins.name)));
    }
    let operand = operands[0].tensor()?;
    let indices = operands[1].tensor()?;
    let updates = operands[2].tensor()?;
    let (out_dims, out) = scatter_core(
        module,
        ins,
        &operand.dims,
        operand.data.clone(),
        &indices.dims,
        &indices.data,
        &updates.dims,
        &updates.data,
    )?;
    Ok(Value::T(Tensor::new(out_dims, out)?))
}

/// Container-agnostic scatter core, shared by both interpreter lanes.
/// Takes the operand data *owned* so the compiled lane can hand over a
/// uniquely held buffer and scatter in place.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scatter_core(
    module: &HloModule,
    ins: &Instr,
    op_dims: &[usize],
    mut out: Data,
    idx_dims: &[usize],
    idx_data: &Data,
    upd_dims: &[usize],
    upd_data: &Data,
) -> Result<(Vec<usize>, Data)> {
    let op_rank = op_dims.len();
    let upd_rank = upd_dims.len();
    let (_, out_dims) = out_array(ins)?;
    let update_window_dims: Vec<usize> =
        ins.attr_dims("update_window_dims")?.into_iter().map(|d| d as usize).collect();
    let inserted: Vec<usize> =
        ins.attr_dims("inserted_window_dims")?.into_iter().map(|d| d as usize).collect();
    let to_operand: Vec<usize> = ins
        .attr_dims("scatter_dims_to_operand_dims")?
        .into_iter()
        .map(|d| d as usize)
        .collect();
    let ivd = ins.attr_i64("index_vector_dim")? as usize;
    let region = module.computation(&ins.attr_computation("to_apply")?)?;
    let fast = fast_combiner(region);

    // operand window dims (not inserted), matched in order with
    // update_window_dims
    let window_operand_dims: Vec<usize> =
        (0..op_rank).filter(|d| !inserted.contains(d)).collect();
    if window_operand_dims.len() != update_window_dims.len() {
        return Err(Error(format!("scatter '{}' window dims mismatch", ins.name)));
    }
    let scatter_dims_in_updates: Vec<usize> =
        (0..upd_rank).filter(|d| !update_window_dims.contains(d)).collect();

    let op_strides = strides_of(op_dims);
    let up_strides = strides_of(upd_dims);
    let total: usize = upd_dims.iter().product();
    let mut idx = vec![0usize; upd_rank];
    let mut more = total > 0;
    while more {
        let batch_idx: Vec<usize> =
            scatter_dims_in_updates.iter().map(|&d| idx[d]).collect();
        let starts = start_vector(idx_dims, idx_data, &batch_idx, ivd, to_operand.len())?;
        let mut full_start = vec![0i64; op_rank];
        for (k, &d) in to_operand.iter().enumerate() {
            full_start[d] = starts[k];
        }
        // resolve the target element; out-of-bounds updates are dropped
        let mut lin = 0usize;
        let mut oob = false;
        for d in 0..op_rank {
            let coord = if let Some(pos) = window_operand_dims.iter().position(|&w| w == d) {
                full_start[d] + idx[update_window_dims[pos]] as i64
            } else {
                full_start[d]
            };
            if coord < 0 || coord >= op_dims[d] as i64 {
                oob = true;
                break;
            }
            lin += coord as usize * op_strides[d];
        }
        if !oob {
            let up_lin = linear_index(&idx, &up_strides);
            if let Some(fc) = fast {
                fast_combine_elem(fc, &mut out, lin, upd_data, up_lin)?;
            } else {
                let call_args = vec![
                    Value::T(scalar_tensor_from(&out, lin)?),
                    Value::T(scalar_tensor_from(upd_data, up_lin)?),
                ];
                let res = evaluate(module, region, &call_args)?;
                out.copy_elem(lin, &res.tensor()?.data, 0)?;
            }
        }
        more = next_index(&mut idx, upd_dims);
    }
    Ok((out_dims, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    fn run(text: &str, args: &[Value]) -> Value {
        let m = parse_module(text).unwrap();
        execute_module(&m, args).unwrap()
    }

    fn f32v(v: Vec<f32>) -> Value {
        let n = v.len();
        Value::T(Tensor::new(vec![n], Data::F32(v)).unwrap())
    }

    #[test]
    fn add_two_vectors() {
        let text = "HloModule m\n\nENTRY e.3 {\n  a.1 = f32[3]{0} parameter(0)\n  b.2 = f32[3]{0} parameter(1)\n  ROOT add.3 = f32[3]{0} add(a.1, b.2)\n}\n";
        let out = run(text, &[f32v(vec![1.0, 2.0, 3.0]), f32v(vec![10.0, 20.0, 30.0])]);
        assert_eq!(out, f32v(vec![11.0, 22.0, 33.0]));
    }

    #[test]
    fn while_loop_counts_and_accumulates() {
        let text = r#"
HloModule m

%body.1 (s.2: (s32[], f32[])) -> (s32[], f32[]) {
  %s.2 = (s32[], f32[]) parameter(0)
  %i.3 = s32[] get-tuple-element((s32[], f32[]) %s.2), index=0
  %x.4 = f32[] get-tuple-element((s32[], f32[]) %s.2), index=1
  %one.5 = s32[] constant(1)
  %ip.6 = s32[] add(s32[] %i.3, s32[] %one.5)
  %half.7 = f32[] constant(2.5)
  %xp.8 = f32[] add(f32[] %x.4, f32[] %half.7)
  ROOT %t.9 = (s32[], f32[]) tuple(s32[] %ip.6, f32[] %xp.8)
}

%cond.10 (s.11: (s32[], f32[])) -> pred[] {
  %s.11 = (s32[], f32[]) parameter(0)
  %i.12 = s32[] get-tuple-element((s32[], f32[]) %s.11), index=0
  %lim.13 = s32[] constant(4)
  ROOT %c.14 = pred[] compare(s32[] %i.12, s32[] %lim.13), direction=LT
}

ENTRY %main.20 {
  %z.15 = s32[] constant(0)
  %f.16 = f32[] constant(0)
  %t.17 = (s32[], f32[]) tuple(s32[] %z.15, f32[] %f.16)
  %w.18 = (s32[], f32[]) while((s32[], f32[]) %t.17), condition=%cond.10, body=%body.1
  ROOT %r.19 = f32[] get-tuple-element((s32[], f32[]) %w.18), index=1
}
"#;
        let out = run(text, &[]);
        assert_eq!(out, Value::T(Tensor::new(vec![], Data::F32(vec![10.0])).unwrap()));
    }

    #[test]
    fn dynamic_slice_and_update_roundtrip() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[6]{0} parameter(0)\n  i.2 = s32[] parameter(1)\n  ds.3 = f32[2]{0} dynamic-slice(a.1, i.2), dynamic_slice_sizes={2}\n  two.4 = f32[] constant(10)\n  b.5 = f32[2]{0} broadcast(two.4), dimensions={}\n  sum.6 = f32[2]{0} add(ds.3, b.5)\n  ROOT dus.7 = f32[6]{0} dynamic-update-slice(a.1, sum.6, i.2)\n}\n";
        let a = f32v(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let i = Value::T(Tensor::new(vec![], Data::S32(vec![2])).unwrap());
        let out = run(text, &[a, i]);
        assert_eq!(out, f32v(vec![0.0, 1.0, 12.0, 13.0, 4.0, 5.0]));
    }

    #[test]
    fn reduce_sum_over_matrix() {
        let text = r#"
HloModule m

%sum.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %r.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %e.4 {
  %p.1 = f32[2,3]{1,0} parameter(0)
  %z.2 = f32[] constant(0)
  ROOT %red.3 = f32[2]{0} reduce(f32[2,3]{1,0} %p.1, f32[] %z.2), dimensions={1}, to_apply=%sum.1
}
"#;
        let m = Value::T(
            Tensor::new(vec![2, 3], Data::F32(vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0])).unwrap(),
        );
        let out = run(text, &[m]);
        assert_eq!(out, Value::T(Tensor::new(vec![2], Data::F32(vec![6.0, 60.0])).unwrap()));
    }

    #[test]
    fn variadic_reduce_argmax() {
        // argmax over (values, iota) — the LUFact pivot pattern
        let text = r#"
HloModule m

%amax.1 (a.2: f32[], ai.3: s32[], b.4: f32[], bi.5: s32[]) -> (f32[], s32[]) {
  %a.2 = f32[] parameter(0)
  %ai.3 = s32[] parameter(1)
  %b.4 = f32[] parameter(2)
  %bi.5 = s32[] parameter(3)
  %ge.6 = pred[] compare(f32[] %a.2, f32[] %b.4), direction=GE
  %v.7 = f32[] select(pred[] %ge.6, f32[] %a.2, f32[] %b.4)
  %i.8 = s32[] select(pred[] %ge.6, s32[] %ai.3, s32[] %bi.5)
  ROOT %t.9 = (f32[], s32[]) tuple(f32[] %v.7, s32[] %i.8)
}

ENTRY %e.9 {
  %p.1 = f32[4]{0} parameter(0)
  %io.2 = s32[4]{0} iota(), iota_dimension=0
  %ninf.3 = f32[] constant(-inf)
  %zero.4 = s32[] constant(0)
  %r.5 = (f32[], s32[]) reduce(f32[4]{0} %p.1, s32[4]{0} %io.2, f32[] %ninf.3, s32[] %zero.4), dimensions={0}, to_apply=%amax.1
  ROOT %i.6 = s32[] get-tuple-element((f32[], s32[]) %r.5), index=1
}
"#;
        let out = run(text, &[f32v(vec![3.0, 9.0, 1.0, 9.0])]);
        assert_eq!(out, Value::T(Tensor::new(vec![], Data::S32(vec![1])).unwrap()));
    }

    #[test]
    fn gather_elementwise_from_matrix() {
        // x[col[i]] pattern: operand f32[1,4], indices s32[3,2]
        let text = "HloModule m\n\nENTRY e.3 {\n  o.1 = f32[1,4]{1,0} parameter(0)\n  i.2 = s32[3,2]{1,0} parameter(1)\n  ROOT g.3 = f32[3]{0} gather(o.1, i.2), offset_dims={}, collapsed_slice_dims={0,1}, start_index_map={0,1}, index_vector_dim=1, slice_sizes={1,1}\n}\n";
        let o = Value::T(Tensor::new(vec![1, 4], Data::F32(vec![5.0, 6.0, 7.0, 8.0])).unwrap());
        let i =
            Value::T(Tensor::new(vec![3, 2], Data::S32(vec![0, 3, 0, 0, 0, 2])).unwrap());
        let out = run(text, &[o, i]);
        assert_eq!(out, f32v(vec![8.0, 5.0, 7.0]));
    }

    #[test]
    fn scatter_add_segment_sum() {
        let text = r#"
HloModule m

%add.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %r.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %e.9 {
  %o.1 = f32[3]{0} parameter(0)
  %i.2 = s32[4,1]{1,0} parameter(1)
  %u.3 = f32[4]{0} parameter(2)
  ROOT %s.4 = f32[3]{0} scatter(f32[3]{0} %o.1, s32[4,1]{1,0} %i.2, f32[4]{0} %u.3), update_window_dims={}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%add.1
}
"#;
        let o = f32v(vec![0.0, 0.0, 0.0]);
        let i = Value::T(Tensor::new(vec![4, 1], Data::S32(vec![0, 2, 0, 1])).unwrap());
        let u = f32v(vec![1.0, 2.0, 3.0, 4.0]);
        let out = run(text, &[o, i, u]);
        assert_eq!(out, f32v(vec![4.0, 4.0, 2.0]));
    }

    #[test]
    fn scatter_row_write_with_window() {
        // write a whole row of a [2,3] matrix (the LUFact row-swap shape)
        let text = r#"
HloModule m

%second.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  ROOT %b.3 = f32[] parameter(1)
}

ENTRY %e.9 {
  %o.1 = f32[2,3]{1,0} parameter(0)
  %i.2 = s32[1]{0} parameter(1)
  %u.3 = f32[3]{0} parameter(2)
  ROOT %s.4 = f32[2,3]{1,0} scatter(f32[2,3]{1,0} %o.1, s32[1]{0} %i.2, f32[3]{0} %u.3), update_window_dims={0}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=0, indices_are_sorted=true, unique_indices=true, to_apply=%second.1
}
"#;
        let o = Value::T(
            Tensor::new(vec![2, 3], Data::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])).unwrap(),
        );
        let i = Value::T(Tensor::new(vec![1], Data::S32(vec![1])).unwrap());
        let u = f32v(vec![7.0, 8.0, 9.0]);
        let out = run(text, &[o, i, u]);
        assert_eq!(
            out,
            Value::T(
                Tensor::new(vec![2, 3], Data::F32(vec![1.0, 2.0, 3.0, 7.0, 8.0, 9.0])).unwrap()
            )
        );
    }

    #[test]
    fn slice_concatenate_broadcast_iota_convert() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[4]{0} parameter(0)\n  s.2 = f32[2]{0} slice(a.1), slice={[1:3]}\n  i.3 = s32[2]{0} iota(), iota_dimension=0\n  f.4 = f32[2]{0} convert(i.3)\n  c.5 = f32[4]{0} concatenate(s.2, f.4), dimensions={0}\n  ROOT n.6 = f32[4]{0} negate(c.5)\n}\n";
        let out = run(text, &[f32v(vec![9.0, 1.0, 2.0, 9.0])]);
        assert_eq!(out, f32v(vec![-1.0, -2.0, -0.0, -1.0]));
    }

    #[test]
    fn crypt_style_u32_bit_ops() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = u32[4]{0} parameter(0)\n  m.2 = u32[] constant(65535)\n  mb.3 = u32[4]{0} broadcast(m.2), dimensions={}\n  and.4 = u32[4]{0} and(a.1, mb.3)\n  s.5 = u32[] constant(8)\n  sb.6 = u32[4]{0} broadcast(s.5), dimensions={}\n  sh.7 = u32[4]{0} shift-right-logical(and.4, sb.6)\n  ROOT x.8 = u32[4]{0} xor(sh.7, and.4)\n}\n";
        let a = Value::T(
            Tensor::new(vec![4], Data::U32(vec![0x12345678, 0xFFFF0000, 0xABCD, 7])).unwrap(),
        );
        let out = run(text, &[a]);
        let want = [0x12345678u32, 0xFFFF0000, 0xABCD, 7u32].map(|v| {
            let x = v & 0xFFFF;
            (x >> 8) ^ x
        });
        assert_eq!(
            out,
            Value::T(Tensor::new(vec![4], Data::U32(want.to_vec())).unwrap())
        );
    }
}
