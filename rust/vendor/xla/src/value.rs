//! Runtime values for the HLO interpreter: dense row-major tensors of the
//! element types the artifact set uses, plus tuples.

use crate::{ElementType, Error, Result};

/// Flat storage, logically row-major over [`Tensor::dims`].
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    Pred(Vec<bool>),
    S32(Vec<i32>),
    S64(Vec<i64>),
    U32(Vec<u32>),
    U64(Vec<u64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::Pred(v) => v.len(),
            Data::S32(v) => v.len(),
            Data::S64(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::U64(v) => v.len(),
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> ElementType {
        match self {
            Data::Pred(_) => ElementType::Pred,
            Data::S32(_) => ElementType::S32,
            Data::S64(_) => ElementType::S64,
            Data::U32(_) => ElementType::U32,
            Data::U64(_) => ElementType::U64,
            Data::F32(_) => ElementType::F32,
            Data::F64(_) => ElementType::F64,
        }
    }

    /// Allocate a zero-filled buffer of `n` elements.
    pub fn zeros(ty: ElementType, n: usize) -> Result<Data> {
        Ok(match ty {
            ElementType::Pred => Data::Pred(vec![false; n]),
            ElementType::S32 => Data::S32(vec![0; n]),
            ElementType::S64 => Data::S64(vec![0; n]),
            ElementType::U32 => Data::U32(vec![0; n]),
            ElementType::U64 => Data::U64(vec![0; n]),
            ElementType::F32 => Data::F32(vec![0.0; n]),
            ElementType::F64 => Data::F64(vec![0.0; n]),
            other => return Err(Error(format!("unsupported element type {other:?}"))),
        })
    }

    /// Typed slice views (None on dtype mismatch).
    pub fn preds(&self) -> Option<&[bool]> {
        match self {
            Data::Pred(v) => Some(v),
            _ => None,
        }
    }

    pub fn s32s(&self) -> Option<&[i32]> {
        match self {
            Data::S32(v) => Some(v),
            _ => None,
        }
    }

    pub fn s64s(&self) -> Option<&[i64]> {
        match self {
            Data::S64(v) => Some(v),
            _ => None,
        }
    }

    pub fn u32s(&self) -> Option<&[u32]> {
        match self {
            Data::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn u64s(&self) -> Option<&[u64]> {
        match self {
            Data::U64(v) => Some(v),
            _ => None,
        }
    }

    pub fn f32s(&self) -> Option<&[f32]> {
        match self {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn f64s(&self) -> Option<&[f64]> {
        match self {
            Data::F64(v) => Some(v),
            _ => None,
        }
    }

    /// Read element `i` as f64 (predicates as 0/1).
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            Data::Pred(v) => v[i] as u8 as f64,
            Data::S32(v) => v[i] as f64,
            Data::S64(v) => v[i] as f64,
            Data::U32(v) => v[i] as f64,
            Data::U64(v) => v[i] as f64,
            Data::F32(v) => v[i] as f64,
            Data::F64(v) => v[i],
        }
    }

    /// Read element `i` as i64 (floats truncate toward zero).
    pub fn get_i64(&self, i: usize) -> i64 {
        match self {
            Data::Pred(v) => v[i] as i64,
            Data::S32(v) => v[i] as i64,
            Data::S64(v) => v[i],
            Data::U32(v) => v[i] as i64,
            Data::U64(v) => v[i] as i64,
            Data::F32(v) => v[i] as i64,
            Data::F64(v) => v[i] as i64,
        }
    }

    /// Copy the contiguous block `src[src_i .. src_i + len]` over
    /// `self[dst_i .. dst_i + len]` (dtypes must match).  The compiled
    /// lane's memcpy fast path for contiguous windows.
    pub fn copy_block(&mut self, dst_i: usize, src: &Data, src_i: usize, len: usize) -> Result<()> {
        match (self, src) {
            (Data::Pred(d), Data::Pred(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::S32(d), Data::S32(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::S64(d), Data::S64(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::U32(d), Data::U32(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::U64(d), Data::U64(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::F32(d), Data::F32(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (Data::F64(d), Data::F64(s)) => d[dst_i..dst_i + len].copy_from_slice(&s[src_i..src_i + len]),
            (d, s) => {
                return Err(Error(format!(
                    "dtype mismatch in block copy: {:?} vs {:?}",
                    d.dtype(),
                    s.dtype()
                )))
            }
        }
        Ok(())
    }

    /// Gather elements of `self` at `idxs`, in order (typed fast path for
    /// the compiled lane's strided shape ops).
    pub fn take_by(&self, idxs: &[usize]) -> Data {
        match self {
            Data::Pred(v) => Data::Pred(idxs.iter().map(|&i| v[i]).collect()),
            Data::S32(v) => Data::S32(idxs.iter().map(|&i| v[i]).collect()),
            Data::S64(v) => Data::S64(idxs.iter().map(|&i| v[i]).collect()),
            Data::U32(v) => Data::U32(idxs.iter().map(|&i| v[i]).collect()),
            Data::U64(v) => Data::U64(idxs.iter().map(|&i| v[i]).collect()),
            Data::F32(v) => Data::F32(idxs.iter().map(|&i| v[i]).collect()),
            Data::F64(v) => Data::F64(idxs.iter().map(|&i| v[i]).collect()),
        }
    }

    /// Copy out the contiguous range `[start, start + len)`.
    pub fn copy_range(&self, start: usize, len: usize) -> Data {
        match self {
            Data::Pred(v) => Data::Pred(v[start..start + len].to_vec()),
            Data::S32(v) => Data::S32(v[start..start + len].to_vec()),
            Data::S64(v) => Data::S64(v[start..start + len].to_vec()),
            Data::U32(v) => Data::U32(v[start..start + len].to_vec()),
            Data::U64(v) => Data::U64(v[start..start + len].to_vec()),
            Data::F32(v) => Data::F32(v[start..start + len].to_vec()),
            Data::F64(v) => Data::F64(v[start..start + len].to_vec()),
        }
    }

    /// A length-`n` buffer filled with element `i` of `self`.
    pub fn splat(&self, i: usize, n: usize) -> Data {
        match self {
            Data::Pred(v) => Data::Pred(vec![v[i]; n]),
            Data::S32(v) => Data::S32(vec![v[i]; n]),
            Data::S64(v) => Data::S64(vec![v[i]; n]),
            Data::U32(v) => Data::U32(vec![v[i]; n]),
            Data::U64(v) => Data::U64(vec![v[i]; n]),
            Data::F32(v) => Data::F32(vec![v[i]; n]),
            Data::F64(v) => Data::F64(vec![v[i]; n]),
        }
    }

    /// Copy element `src_i` of `src` over element `dst_i` of `self`
    /// (dtypes must match).
    pub fn copy_elem(&mut self, dst_i: usize, src: &Data, src_i: usize) -> Result<()> {
        match (self, src) {
            (Data::Pred(d), Data::Pred(s)) => d[dst_i] = s[src_i],
            (Data::S32(d), Data::S32(s)) => d[dst_i] = s[src_i],
            (Data::S64(d), Data::S64(s)) => d[dst_i] = s[src_i],
            (Data::U32(d), Data::U32(s)) => d[dst_i] = s[src_i],
            (Data::U64(d), Data::U64(s)) => d[dst_i] = s[src_i],
            (Data::F32(d), Data::F32(s)) => d[dst_i] = s[src_i],
            (Data::F64(d), Data::F64(s)) => d[dst_i] = s[src_i],
            (d, s) => {
                return Err(Error(format!(
                    "dtype mismatch in element copy: {:?} vs {:?}",
                    d.dtype(),
                    s.dtype()
                )))
            }
        }
        Ok(())
    }
}

/// A dense tensor: dims + row-major flat data.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Data) -> Result<Tensor> {
        let want: usize = dims.iter().product();
        if data.len() != want {
            return Err(Error(format!(
                "tensor data length {} does not match dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(Tensor { dims, data })
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn dtype(&self) -> ElementType {
        self.data.dtype()
    }

    /// Row-major strides for the current dims.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.dims)
    }

    /// The scalar value as i64 (for loop counters / dynamic indices).
    pub fn scalar_i64(&self) -> Result<i64> {
        if self.elems() != 1 {
            return Err(Error(format!("expected scalar, got dims {:?}", self.dims)));
        }
        Ok(self.data.get_i64(0))
    }

    /// The scalar value as bool (for while conditions / select predicates).
    pub fn scalar_bool(&self) -> Result<bool> {
        if self.elems() != 1 {
            return Err(Error(format!("expected scalar pred, got dims {:?}", self.dims)));
        }
        Ok(match &self.data {
            Data::Pred(v) => v[0],
            other => other.get_i64(0) != 0,
        })
    }
}

/// Row-major strides of a dim list.
pub fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Linear offset of a multi-index under row-major strides.
pub fn linear_index(idx: &[usize], strides: &[usize]) -> usize {
    idx.iter().zip(strides).map(|(i, s)| i * s).sum()
}

/// Advance a row-major multi-index; returns false on wrap-around (done).
pub fn next_index(idx: &mut [usize], dims: &[usize]) -> bool {
    for d in (0..dims.len()).rev() {
        idx[d] += 1;
        if idx[d] < dims[d] {
            return true;
        }
        idx[d] = 0;
    }
    false
}

/// An interpreter value: a tensor or a tuple of values.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    T(Tensor),
    Tuple(Vec<Value>),
}

impl Value {
    pub fn tensor(&self) -> Result<&Tensor> {
        match self {
            Value::T(t) => Ok(t),
            Value::Tuple(_) => Err(Error("expected tensor, got tuple".into())),
        }
    }

    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            Value::T(t) => Ok(t),
            Value::Tuple(_) => Err(Error("expected tensor, got tuple".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_linear_index() {
        let dims = vec![2, 3, 4];
        let s = strides_of(&dims);
        assert_eq!(s, vec![12, 4, 1]);
        assert_eq!(linear_index(&[1, 2, 3], &s), 23);
    }

    #[test]
    fn next_index_iterates_row_major() {
        let dims = vec![2, 2];
        let mut idx = vec![0, 0];
        let mut seen = vec![idx.clone()];
        while next_index(&mut idx, &dims) {
            seen.push(idx.clone());
        }
        assert_eq!(seen, vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]]);
    }

    #[test]
    fn scalar_accessors() {
        let t = Tensor::new(vec![], Data::S32(vec![7])).unwrap();
        assert_eq!(t.scalar_i64().unwrap(), 7);
        let p = Tensor::new(vec![], Data::Pred(vec![true])).unwrap();
        assert!(p.scalar_bool().unwrap());
    }

    #[test]
    fn tensor_shape_checked() {
        assert!(Tensor::new(vec![2, 2], Data::F32(vec![0.0; 3])).is_err());
    }
}
