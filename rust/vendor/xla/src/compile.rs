//! Load-time lowering of a parsed [`HloModule`] into an executable form.
//!
//! The naive lane (`eval.rs`) walks the instruction tree per execution:
//! string opcode dispatch, operand-name hash lookups, constant text
//! re-parsing, and whole-tensor clones for `while` state.  This module
//! removes all of that once, at `PjRtClient::compile` time:
//!
//! * **bytecode** — every instruction is lowered to a dense [`Op`] with
//!   operand *register indices*; attributes, `constant(...)` payloads and
//!   `iota()` tensors are parsed/materialized exactly once into a
//!   module-level constant pool;
//! * **schedule** — instructions reachable from the root are placed in a
//!   topological order; execution is a flat loop over a register file
//!   (one slot per scheduled instruction);
//! * **liveness / buffer reuse** — each instruction carries the list of
//!   registers whose *last use* it is; those registers are dropped before
//!   the kernel runs, so tensor data behind an `Arc` with no remaining
//!   owner can be mutated in place (`dynamic-update-slice`, elementwise
//!   ops) or passed through without a copy (`copy`, `reshape`,
//!   full-tensor updates).  `while` state is *moved* through iterations
//!   instead of cloned;
//! * **SMP parallelism** — big elementwise / compare / select kernels and
//!   the f32 sum-reduction chunk their output across [`crate::parallel`]
//!   (threshold-gated; small tensors stay serial).
//!
//! Semantics are bit-identical to the naive lane by construction: index
//! walks, clamping, wrapping arithmetic and the f32→f64 reduction
//! widening are shared with or ported verbatim from `eval.rs`, and the
//! `tests/interp_equivalence.rs` suite in the host crate asserts
//! bitwise-equal outputs over every committed artifact.  `gather`,
//! `scatter` and generic-region `reduce` bridge into the shared `eval.rs`
//! cores rather than duplicating their (subtle) semantics.

use std::collections::{HashMap, HashSet};
use std::ops::Range;
use std::sync::{Arc, Mutex};

use crate::eval::{
    eval_reduce, fast_combine_elem, fast_combiner, gather_core, materialize_iota, pair_index,
    parse_constant_tensor, parse_slice_spec, scatter_core, write_f64, write_i64, FastCombine,
};
use crate::hlo::{Computation, HloModule, Instr, ShapeTy};
use crate::parallel;
use crate::value::{linear_index, next_index, strides_of, Data, Tensor, Value};
use crate::{eval, ElementType, Error, Result};

// ---------------------------------------------------------------------------
// Register values: tensors with reference-counted storage
// ---------------------------------------------------------------------------

/// A tensor in the register file.  `Arc<Data>` makes every structural op
/// (parameter load, tuple assembly, `reshape`, `copy`, loop-carried
/// state) an O(1) pointer copy, and makes "uniquely owned" checkable at
/// the in-place fast paths via [`Arc::try_unwrap`].
#[derive(Clone, Debug)]
pub(crate) struct RTensor {
    pub dims: Vec<usize>,
    pub data: Arc<Data>,
}

impl RTensor {
    fn new(dims: Vec<usize>, data: Data) -> RTensor {
        RTensor { dims, data: Arc::new(data) }
    }

    fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    fn rank(&self) -> usize {
        self.dims.len()
    }

    fn dtype(&self) -> ElementType {
        self.data.dtype()
    }

    fn strides(&self) -> Vec<usize> {
        strides_of(&self.dims)
    }

    fn scalar_i64(&self) -> Result<i64> {
        if self.elems() != 1 {
            return Err(Error(format!("expected scalar, got dims {:?}", self.dims)));
        }
        Ok(self.data.get_i64(0))
    }

    fn scalar_bool(&self) -> Result<bool> {
        if self.elems() != 1 {
            return Err(Error(format!("expected scalar pred, got dims {:?}", self.dims)));
        }
        Ok(match &*self.data {
            Data::Pred(v) => v[0],
            other => other.get_i64(0) != 0,
        })
    }

    /// Owned data: zero-copy when this is the last owner.
    fn into_data(self) -> Data {
        Arc::try_unwrap(self.data).unwrap_or_else(|a| (*a).clone())
    }
}

/// A register value: tensor or tuple (loop state, multi-output roots).
#[derive(Clone, Debug)]
pub(crate) enum RValue {
    T(RTensor),
    Tuple(Vec<RValue>),
}

impl RValue {
    fn from_value(v: Value) -> RValue {
        match v {
            Value::T(t) => RValue::T(RTensor::new(t.dims, t.data)),
            Value::Tuple(p) => RValue::Tuple(p.into_iter().map(RValue::from_value).collect()),
        }
    }

    fn into_value(self) -> Value {
        match self {
            RValue::T(t) => {
                let dims = t.dims.clone();
                Value::T(Tensor { dims, data: t.into_data() })
            }
            RValue::Tuple(p) => Value::Tuple(p.into_iter().map(RValue::into_value).collect()),
        }
    }

    fn tensor(&self) -> Result<&RTensor> {
        match self {
            RValue::T(t) => Ok(t),
            RValue::Tuple(_) => Err(Error("expected tensor, got tuple".into())),
        }
    }

    fn into_rtensor(self) -> Result<RTensor> {
        match self {
            RValue::T(t) => Ok(t),
            RValue::Tuple(_) => Err(Error("expected tensor, got tuple".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// Bytecode
// ---------------------------------------------------------------------------

/// Compare directions, resolved from the `direction=` attr at lowering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpDir {
    fn parse(s: &str) -> Result<CmpDir> {
        Ok(match s {
            "EQ" => CmpDir::Eq,
            "NE" => CmpDir::Ne,
            "LT" => CmpDir::Lt,
            "LE" => CmpDir::Le,
            "GT" => CmpDir::Gt,
            "GE" => CmpDir::Ge,
            other => return Err(Error(format!("bad compare direction '{other}'"))),
        })
    }
}

/// Elementwise binary opcodes (dense mirror of the naive string set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    ShrL,
    ShrA,
}

/// Elementwise unary opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum UnOp {
    Abs,
    Neg,
    Sine,
    Cosine,
    Tanh,
    Exp,
    Expm1,
    Log,
    Log1p,
    Sqrt,
    Rsqrt,
    Floor,
    Ceil,
    Round,
    Sign,
    Not,
    Logistic,
    Copy,
}

/// One lowered instruction.
#[derive(Clone, Debug)]
enum Op {
    /// Load entry/computation argument `k` (moved out of the arg vector).
    Parameter(usize),
    /// Load constant-pool entry (parsed constants and materialized iotas).
    Const(usize),
    Tuple,
    Gte(usize),
    Call(usize),
    While { cond: usize, body: usize },
    Broadcast { map: Vec<usize> },
    Reshape,
    Convert,
    Transpose { perm: Vec<usize> },
    Slice { spec: Vec<(usize, usize, usize)> },
    DynamicSlice { sizes: Vec<usize> },
    DynamicUpdateSlice,
    Concatenate { axis: usize },
    Compare(CmpDir),
    Select,
    /// Single-input reduce with a recognized combiner region.
    ReduceFast { red: Vec<usize>, fc: FastCombine },
    /// Variadic / generic-region reduce: bridges to the shared eval core.
    ReduceBridge(Box<Instr>),
    Gather(Box<Instr>),
    Scatter(Box<Instr>),
    Binary(BinOp),
    Unary(UnOp),
    /// A fused elementwise chain: a post-order expression tape over
    /// external inputs, evaluated tile-by-tile in a single dispatch
    /// (see [`exec_fused`]).  Built by [`fuse_kernel`] after scheduling.
    Fused(Arc<FusedKernel>),
}

/// Output shape of an instruction (tuple-shaped outputs never consult it).
#[derive(Clone, Debug)]
enum OutShape {
    Array(ElementType, Vec<usize>),
    Other,
}

impl OutShape {
    fn array(&self) -> Result<(ElementType, &[usize])> {
        match self {
            OutShape::Array(ty, dims) => Ok((*ty, dims)),
            OutShape::Other => Err(Error("expected array shape, got tuple".into())),
        }
    }
}

#[derive(Clone, Debug)]
struct CInstr {
    op: Op,
    /// Operand registers (schedule positions within this computation).
    operands: Vec<usize>,
    out: OutShape,
    /// Registers whose last use is this instruction; dropped before the
    /// kernel runs so uniquely-owned operands can be recycled in place.
    free_after: Vec<usize>,
}

/// One lowered computation: a topologically ordered instruction schedule
/// over a flat register file (register `i` holds instruction `i`'s
/// output; the root is always the last register).
#[derive(Clone, Debug)]
struct CCKernel {
    instrs: Vec<CInstr>,
    root: usize,
}

/// A fully lowered module: computations by dense index, plus the shared
/// constant pool.  Keeps the parsed module for the `eval.rs` bridge ops.
pub(crate) struct CompiledModule {
    hlo: Arc<HloModule>,
    comps: Vec<CCKernel>,
    consts: Vec<RValue>,
    entry: usize,
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

fn to_usize_vec(v: Vec<i64>) -> Vec<usize> {
    v.into_iter().map(|d| d as usize).collect()
}

struct Lowerer<'m> {
    module: &'m HloModule,
    comps: Vec<Option<CCKernel>>,
    index_of: HashMap<String, usize>,
    consts: Vec<RValue>,
    fuse: bool,
}

/// Whether the `XLA_FUSE` knob enables the fusion pass (default on;
/// `0`/`off`/`false`/`no` disable it).  Read per compile, not cached, so
/// a single process can compile both forms for differential testing.
pub(crate) fn fuse_enabled_env() -> bool {
    match std::env::var("XLA_FUSE") {
        Ok(v) => !matches!(v.to_ascii_lowercase().as_str(), "0" | "off" | "false" | "no"),
        Err(_) => true,
    }
}

/// Lower every computation reachable from the entry.  Errors mean "this
/// module has no compiled form" — the caller falls back to the naive
/// tree-walker, which reports the same unsupported construct at runtime.
/// The elementwise fusion pass honors the `XLA_FUSE` env knob; use
/// [`lower_module_with`] to pick a form explicitly.
pub(crate) fn lower_module(module: &Arc<HloModule>) -> Result<CompiledModule> {
    lower_module_with(module, fuse_enabled_env())
}

/// [`lower_module`] with the fusion pass explicitly on or off.
pub(crate) fn lower_module_with(module: &Arc<HloModule>, fuse: bool) -> Result<CompiledModule> {
    let mut lw = Lowerer {
        module: module.as_ref(),
        comps: Vec::new(),
        index_of: HashMap::new(),
        consts: Vec::new(),
        fuse,
    };
    let entry = lw.comp_index(&module.entry)?;
    let comps = lw
        .comps
        .into_iter()
        .map(|c| c.ok_or_else(|| Error("computation left unlowered".into())))
        .collect::<Result<Vec<_>>>()?;
    Ok(CompiledModule { hlo: module.clone(), comps, consts: lw.consts, entry })
}

impl<'m> Lowerer<'m> {
    fn comp_index(&mut self, name: &str) -> Result<usize> {
        if let Some(&i) = self.index_of.get(name) {
            return if self.comps[i].is_some() {
                Ok(i)
            } else {
                Err(Error(format!("recursive computation '{name}'")))
            };
        }
        let i = self.comps.len();
        self.index_of.insert(name.to_string(), i);
        self.comps.push(None);
        let module = self.module;
        let comp = module.computation(name)?;
        let lowered = self.lower_computation(comp)?;
        self.comps[i] = Some(lowered);
        Ok(i)
    }

    fn lower_computation(&mut self, comp: &'m Computation) -> Result<CCKernel> {
        // topological schedule of the instructions reachable from the
        // root (same dependency walk the naive evaluator does per run)
        let n = comp.instrs.len();
        let mut reg_of: Vec<Option<usize>> = vec![None; n];
        let mut order: Vec<usize> = Vec::new();
        let mut stack: Vec<usize> = vec![comp.root];
        while let Some(&i) = stack.last() {
            if reg_of[i].is_some() {
                stack.pop();
                continue;
            }
            let ins = &comp.instrs[i];
            let mut pending = false;
            if ins.op != "parameter" {
                for opnd in &ins.operands {
                    let j = *comp.index.get(opnd).ok_or_else(|| {
                        Error(format!("'{}' references unknown operand '{opnd}'", ins.name))
                    })?;
                    if reg_of[j].is_none() {
                        stack.push(j);
                        pending = true;
                    }
                }
            }
            if pending {
                continue;
            }
            reg_of[i] = Some(order.len());
            order.push(i);
            stack.pop();
        }

        let mut instrs: Vec<CInstr> = Vec::with_capacity(order.len());
        let mut seen_params: HashSet<usize> = HashSet::new();
        for &i in &order {
            let ins = &comp.instrs[i];
            let operands: Vec<usize> = if ins.op == "parameter" {
                Vec::new()
            } else {
                ins.operands
                    .iter()
                    .map(|o| reg_of[comp.index[o]].expect("operand scheduled"))
                    .collect()
            };
            let op = self.lower_op(ins, &mut seen_params)?;
            let out = match &ins.shape {
                ShapeTy::Array { ty, dims } => OutShape::Array(*ty, dims.clone()),
                ShapeTy::Tuple(_) => OutShape::Other,
            };
            instrs.push(CInstr { op, operands, out, free_after: Vec::new() });
        }

        // elementwise fusion: merge single-consumer chains into one
        // dispatch *before* liveness, so the rebuilt schedule gets its
        // own last-use analysis (and fused inputs still donate buffers)
        if self.fuse {
            instrs = fuse_kernel(instrs);
        }

        // last-use liveness: register r dies after the highest schedule
        // position that reads it (the root register never dies)
        let m = instrs.len();
        let root = m - 1;
        let mut last_use: Vec<usize> = vec![usize::MAX; m];
        for (p, ci) in instrs.iter().enumerate() {
            for &r in &ci.operands {
                last_use[r] = p;
            }
        }
        for r in 0..m {
            let p = last_use[r];
            if p != usize::MAX && r != root {
                instrs[p].free_after.push(r);
            }
        }
        Ok(CCKernel { instrs, root })
    }

    fn lower_op(&mut self, ins: &Instr, seen_params: &mut HashSet<usize>) -> Result<Op> {
        Ok(match ins.op.as_str() {
            "parameter" => {
                let k: usize = ins
                    .operands
                    .first()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| Error(format!("bad parameter index on '{}'", ins.name)))?;
                if !seen_params.insert(k) {
                    return Err(Error(format!("duplicate parameter({k})")));
                }
                Op::Parameter(k)
            }
            "constant" => {
                let t = parse_constant_tensor(ins)?;
                self.consts.push(RValue::T(RTensor::new(t.dims, t.data)));
                Op::Const(self.consts.len() - 1)
            }
            "iota" => {
                let t = materialize_iota(ins)?;
                self.consts.push(RValue::T(RTensor::new(t.dims, t.data)));
                Op::Const(self.consts.len() - 1)
            }
            "tuple" => Op::Tuple,
            "get-tuple-element" => Op::Gte(ins.attr_i64("index")? as usize),
            "call" => Op::Call(self.comp_index(&ins.attr_computation("to_apply")?)?),
            "while" => {
                let cond = self.comp_index(&ins.attr_computation("condition")?)?;
                let body = self.comp_index(&ins.attr_computation("body")?)?;
                Op::While { cond, body }
            }
            "broadcast" => Op::Broadcast { map: to_usize_vec(ins.attr_dims("dimensions")?) },
            "reshape" => Op::Reshape,
            "convert" => Op::Convert,
            "transpose" => Op::Transpose { perm: to_usize_vec(ins.attr_dims("dimensions")?) },
            "slice" => Op::Slice { spec: parse_slice_spec(ins.attr("slice")?)? },
            "dynamic-slice" => {
                let sizes = match ins.attrs.get("dynamic_slice_sizes") {
                    Some(v) => to_usize_vec(crate::hlo::parse_brace_list(v)?),
                    None => match &ins.shape {
                        ShapeTy::Array { dims, .. } => dims.clone(),
                        ShapeTy::Tuple(_) => {
                            return Err(Error("tuple-shaped dynamic-slice".into()))
                        }
                    },
                };
                Op::DynamicSlice { sizes }
            }
            "dynamic-update-slice" => Op::DynamicUpdateSlice,
            "concatenate" => {
                let axis = ins
                    .attr_dims("dimensions")?
                    .first()
                    .copied()
                    .ok_or_else(|| Error("concatenate without dimension".into()))?
                    as usize;
                Op::Concatenate { axis }
            }
            "compare" => Op::Compare(CmpDir::parse(ins.attr("direction")?)?),
            "select" => Op::Select,
            "reduce" => {
                let k = ins.operands.len() / 2;
                let region = self.module.computation(&ins.attr_computation("to_apply")?)?;
                match if k == 1 { fast_combiner(region) } else { None } {
                    Some(fc) => {
                        Op::ReduceFast { red: to_usize_vec(ins.attr_dims("dimensions")?), fc }
                    }
                    None => Op::ReduceBridge(Box::new(ins.clone())),
                }
            }
            "gather" => Op::Gather(Box::new(ins.clone())),
            "scatter" => Op::Scatter(Box::new(ins.clone())),
            "add" => Op::Binary(BinOp::Add),
            "subtract" => Op::Binary(BinOp::Sub),
            "multiply" => Op::Binary(BinOp::Mul),
            "divide" => Op::Binary(BinOp::Div),
            "remainder" => Op::Binary(BinOp::Rem),
            "maximum" => Op::Binary(BinOp::Max),
            "minimum" => Op::Binary(BinOp::Min),
            "power" => Op::Binary(BinOp::Pow),
            "and" => Op::Binary(BinOp::And),
            "or" => Op::Binary(BinOp::Or),
            "xor" => Op::Binary(BinOp::Xor),
            "shift-left" => Op::Binary(BinOp::Shl),
            "shift-right-logical" => Op::Binary(BinOp::ShrL),
            "shift-right-arithmetic" => Op::Binary(BinOp::ShrA),
            "abs" => Op::Unary(UnOp::Abs),
            "negate" => Op::Unary(UnOp::Neg),
            "sine" => Op::Unary(UnOp::Sine),
            "cosine" => Op::Unary(UnOp::Cosine),
            "tanh" => Op::Unary(UnOp::Tanh),
            "exponential" => Op::Unary(UnOp::Exp),
            "exponential-minus-one" => Op::Unary(UnOp::Expm1),
            "log" => Op::Unary(UnOp::Log),
            "log-plus-one" => Op::Unary(UnOp::Log1p),
            "sqrt" => Op::Unary(UnOp::Sqrt),
            "rsqrt" => Op::Unary(UnOp::Rsqrt),
            "floor" => Op::Unary(UnOp::Floor),
            "ceil" => Op::Unary(UnOp::Ceil),
            "round-nearest-afz" => Op::Unary(UnOp::Round),
            "sign" => Op::Unary(UnOp::Sign),
            "not" => Op::Unary(UnOp::Not),
            "logistic" => Op::Unary(UnOp::Logistic),
            "copy" => Op::Unary(UnOp::Copy),
            other => return Err(Error(format!("cannot lower HLO op '{other}'"))),
        })
    }
}

// ---------------------------------------------------------------------------
// Elementwise fusion
// ---------------------------------------------------------------------------
//
// After topological scheduling, adjacent elementwise / compare / select /
// scalar-broadcast instructions are greedily merged into one `Op::Fused`
// whose body is a small post-order expression tape, evaluated tile by
// tile in a single dispatch — one memory traversal where the unfused
// schedule pays a full register-file round-trip per step.  Eligibility
// mirrors the runtime checks of the standalone kernels exactly (supported
// (op, dtype) pairs, operand lengths in {1, n}), so a chain the runtime
// would reject never fuses and unsupported modules keep their exact error
// behavior.  Interior members must have a single consumer and the same
// element count as the fused root; scalar operands become pre-splatted
// external inputs, which resolves `pair_index` at fusion time.

/// A tape operand: an external input slot or an earlier tape step.
#[derive(Clone, Copy, Debug)]
enum TapeRef {
    Input(usize),
    Step(usize),
}

/// One fused constituent, in post-order (operands precede consumers).
/// Compare widens by operand dtype exactly like the standalone kernel
/// (floats through f64, everything else through i64).
#[derive(Clone, Copy, Debug)]
enum TapeStep {
    Bin { op: BinOp, a: TapeRef, b: TapeRef },
    Un { op: UnOp, a: TapeRef },
    Cmp { dir: CmpDir, a: TapeRef, b: TapeRef },
    Sel { p: TapeRef, t: TapeRef, f: TapeRef },
}

/// The compiled form of one fused chain.
#[derive(Debug)]
pub(crate) struct FusedKernel {
    /// Post-order tape; the last step is the fused root.
    steps: Vec<TapeStep>,
    /// Output dtype of each step.
    step_ty: Vec<ElementType>,
    /// Dtype of each external input slot (the fused instr's operand order).
    input_ty: Vec<ElementType>,
    /// True when the external input is a scalar (length 1, pre-splatted).
    input_scalar: Vec<bool>,
    /// Output element count (== every non-scalar input's length).
    n: usize,
    /// Constituent instruction count (root + interiors + absorbed
    /// broadcasts) — the kernel's weight in `fused_instruction_count`.
    constituents: u64,
    /// Scalar-value specialization state (guarded constant folding).
    spec: Mutex<SpecState>,
}

/// Specialization state: the first execution records the bit patterns of
/// the scalar inputs; later executions that observe the same values run
/// a constant-folded tape.  A mismatch trips the guard — that run falls
/// back to the generic tape, the offending slot is marked volatile and
/// never folded again, and the fold is rebuilt without it.
#[derive(Debug, Default)]
struct SpecState {
    runs: u64,
    /// Observed bit pattern per scalar slot (first run).
    observed: Vec<u64>,
    /// Slots whose value changed at least once — excluded from folding.
    volatile: Vec<bool>,
    /// Steps pre-evaluated to length-1 constants under `observed`.
    folded: Option<Arc<Vec<Option<Data>>>>,
}

/// The scalar's raw bit pattern (value identity, including NaN payloads
/// and signed zeros — the guard must be at least as strict as `==`).
fn scalar_bits(d: &Data) -> u64 {
    match d {
        Data::Pred(v) => v[0] as u64,
        Data::S32(v) => v[0] as u32 as u64,
        Data::S64(v) => v[0] as u64,
        Data::U32(v) => v[0] as u64,
        Data::U64(v) => v[0],
        Data::F32(v) => v[0].to_bits() as u64,
        Data::F64(v) => v[0].to_bits(),
    }
}

/// Output dtype and element count of an array-shaped instruction.
fn out_elems(ci: &CInstr) -> Option<(ElementType, usize)> {
    match &ci.out {
        OutShape::Array(ty, dims) => Some((*ty, dims.iter().product())),
        OutShape::Other => None,
    }
}

/// Whether instruction `p` can be a fused constituent of an `n`-element
/// group (see the module-level eligibility notes above).
fn fusible_at(instrs: &[CInstr], p: usize, n: usize) -> bool {
    let ci = &instrs[p];
    let Some((ty, pn)) = out_elems(ci) else { return false };
    if pn != n {
        return false;
    }
    let opnd = |k: usize| out_elems(&instrs[ci.operands[k]]);
    let len_ok = |m: usize| m == 1 || m == n;
    match &ci.op {
        Op::Binary(op) => {
            if ci.operands.len() != 2 || !bin_supported(*op, ty) {
                return false;
            }
            match (opnd(0), opnd(1)) {
                (Some((ta, la)), Some((tb, lb))) => {
                    ta == ty && tb == ty && len_ok(la) && len_ok(lb)
                }
                _ => false,
            }
        }
        Op::Unary(op) => {
            if ci.operands.len() != 1 || *op == UnOp::Copy || !un_supported(*op, ty) {
                return false;
            }
            // the standalone unary kernel requires a full-length operand
            match opnd(0) {
                Some((ta, la)) => ta == ty && la == n,
                _ => false,
            }
        }
        Op::Compare(_) => {
            if ci.operands.len() != 2 || ty != ElementType::Pred {
                return false;
            }
            match (opnd(0), opnd(1)) {
                (Some((ta, la)), Some((tb, lb))) => ta == tb && len_ok(la) && len_ok(lb),
                _ => false,
            }
        }
        Op::Select => {
            if ci.operands.len() != 3 {
                return false;
            }
            match (opnd(0), opnd(1), opnd(2)) {
                (Some((tp, lp)), Some((tt, lt)), Some((tf, lf))) => {
                    tp == ElementType::Pred
                        && tt == ty
                        && tf == ty
                        && len_ok(lp)
                        && len_ok(lt)
                        && len_ok(lf)
                }
                _ => false,
            }
        }
        _ => false,
    }
}

/// `q` broadcasts a scalar to the group's element count: absorbable.  Its
/// scalar operand becomes a pre-splatted external input and the broadcast
/// itself disappears into the fused dispatch.
fn scalar_broadcast(instrs: &[CInstr], q: usize, n: usize) -> Option<usize> {
    let ci = &instrs[q];
    if !matches!(ci.op, Op::Broadcast { .. }) || ci.operands.len() != 1 {
        return None;
    }
    let (ty, qn) = out_elems(ci)?;
    let (sty, sn) = out_elems(&instrs[ci.operands[0]])?;
    (qn == n && sn == 1 && sty == ty).then_some(ci.operands[0])
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Role {
    Free,
    Root,
    Interior,
    Absorbed,
}

/// The fusion pass: greedily claim maximal single-consumer elementwise
/// chains, deepest roots first, then rebuild the schedule with each chain
/// collapsed into one `Op::Fused` at its root's position.  `instrs` must
/// be in topological order with the root last and `free_after` not yet
/// computed; the returned schedule preserves both properties (liveness
/// runs after fusion, so fused inputs still donate dying buffers).
fn fuse_kernel(instrs: Vec<CInstr>) -> Vec<CInstr> {
    let m = instrs.len();
    let root_reg = m - 1;
    let mut consumers = vec![0usize; m];
    for ci in &instrs {
        for &r in &ci.operands {
            consumers[r] += 1;
        }
    }
    let mut role = vec![Role::Free; m];
    let mut groups: Vec<usize> = Vec::new();
    for p in (0..m).rev() {
        if role[p] != Role::Free {
            continue;
        }
        let Some((_, n)) = out_elems(&instrs[p]) else { continue };
        if !fusible_at(&instrs, p, n) {
            continue;
        }
        // grow the group downward from the root's operands
        let mut claimed: Vec<usize> = Vec::new();
        let mut stack: Vec<usize> = instrs[p].operands.clone();
        while let Some(q) = stack.pop() {
            if role[q] != Role::Free || claimed.contains(&q) {
                continue; // another group's value: external input edge
            }
            if consumers[q] != 1 || q == root_reg {
                continue; // multi-consumer values stay materialized
            }
            let Some((_, qn)) = out_elems(&instrs[q]) else { continue };
            if qn != n {
                continue; // scalar (or mismatched) operand: external
            }
            if fusible_at(&instrs, q, n) {
                claimed.push(q);
                stack.extend(instrs[q].operands.iter().copied());
            } else if scalar_broadcast(&instrs, q, n).is_some() {
                claimed.push(q); // absorbed: splat resolved per run
            }
        }
        if claimed.is_empty() {
            continue; // a single instruction gains nothing from fusing
        }
        role[p] = Role::Root;
        for &q in &claimed {
            role[q] = if matches!(instrs[q].op, Op::Broadcast { .. }) {
                Role::Absorbed
            } else {
                Role::Interior
            };
        }
        groups.push(p);
    }
    if groups.is_empty() {
        return instrs;
    }

    // build each group's tape, then rebuild the schedule without the
    // claimed interiors (register = position, so operands are remapped)
    let mut fused: HashMap<usize, (Arc<FusedKernel>, Vec<usize>)> = HashMap::new();
    for &p in &groups {
        let (_, n) = out_elems(&instrs[p]).expect("fused root is array-shaped");
        let mut tb = TapeBuilder {
            instrs: &instrs,
            role: &role,
            steps: Vec::new(),
            step_ty: Vec::new(),
            externals: Vec::new(),
            input_ty: Vec::new(),
            input_scalar: Vec::new(),
            input_of: HashMap::new(),
            step_of: HashMap::new(),
            constituents: 0,
        };
        tb.member(p);
        let kernel = FusedKernel {
            steps: tb.steps,
            step_ty: tb.step_ty,
            input_ty: tb.input_ty,
            input_scalar: tb.input_scalar,
            n,
            constituents: tb.constituents,
            spec: Mutex::new(SpecState::default()),
        };
        fused.insert(p, (Arc::new(kernel), tb.externals));
    }
    let mut remap: Vec<Option<usize>> = vec![None; m];
    let mut out: Vec<CInstr> = Vec::with_capacity(m);
    for (p, ci) in instrs.into_iter().enumerate() {
        match role[p] {
            Role::Interior | Role::Absorbed => continue,
            Role::Root => {
                let (fk, externals) = fused.remove(&p).expect("group built");
                let operands = externals
                    .iter()
                    .map(|&r| remap[r].expect("external precedes fused root"))
                    .collect();
                out.push(CInstr {
                    op: Op::Fused(fk),
                    operands,
                    out: ci.out,
                    free_after: Vec::new(),
                });
            }
            Role::Free => {
                let operands = ci
                    .operands
                    .iter()
                    .map(|&r| remap[r].expect("operand precedes consumer"))
                    .collect();
                out.push(CInstr { op: ci.op, operands, out: ci.out, free_after: Vec::new() });
            }
        }
        remap[p] = Some(out.len() - 1);
    }
    out
}

/// Builds one group's post-order tape (operands before consumers), with
/// external inputs deduplicated by register.
struct TapeBuilder<'a> {
    instrs: &'a [CInstr],
    role: &'a [Role],
    steps: Vec<TapeStep>,
    step_ty: Vec<ElementType>,
    externals: Vec<usize>,
    input_ty: Vec<ElementType>,
    input_scalar: Vec<bool>,
    input_of: HashMap<usize, usize>,
    step_of: HashMap<usize, usize>,
    constituents: u64,
}

impl TapeBuilder<'_> {
    fn external(&mut self, r: usize) -> TapeRef {
        if let Some(&k) = self.input_of.get(&r) {
            return TapeRef::Input(k);
        }
        let (ty, len) = out_elems(&self.instrs[r]).expect("external input is array-shaped");
        let k = self.externals.len();
        self.externals.push(r);
        self.input_ty.push(ty);
        self.input_scalar.push(len == 1);
        self.input_of.insert(r, k);
        TapeRef::Input(k)
    }

    fn operand(&mut self, r: usize) -> TapeRef {
        match self.role[r] {
            Role::Interior => self.member(r),
            Role::Absorbed => {
                // the broadcast disappears; count it, splat its scalar
                self.constituents += 1;
                let scalar = self.instrs[r].operands[0];
                self.external(scalar)
            }
            _ => self.external(r),
        }
    }

    fn member(&mut self, q: usize) -> TapeRef {
        if let Some(&s) = self.step_of.get(&q) {
            return TapeRef::Step(s);
        }
        let instrs = self.instrs;
        let ops = instrs[q].operands.clone();
        let (ty, _) = out_elems(&instrs[q]).expect("member is array-shaped");
        let step = match &instrs[q].op {
            Op::Binary(op) => {
                let (op, a) = (*op, self.operand(ops[0]));
                let b = self.operand(ops[1]);
                TapeStep::Bin { op, a, b }
            }
            Op::Unary(op) => {
                let (op, a) = (*op, self.operand(ops[0]));
                TapeStep::Un { op, a }
            }
            Op::Compare(dir) => {
                let (dir, a) = (*dir, self.operand(ops[0]));
                let b = self.operand(ops[1]);
                TapeStep::Cmp { dir, a, b }
            }
            Op::Select => {
                let p = self.operand(ops[0]);
                let t = self.operand(ops[1]);
                let f = self.operand(ops[2]);
                TapeStep::Sel { p, t, f }
            }
            other => unreachable!("non-fusible op {other:?} claimed as member"),
        };
        self.steps.push(step);
        self.step_ty.push(ty);
        self.constituents += 1;
        let s = self.steps.len() - 1;
        self.step_of.insert(q, s);
        TapeRef::Step(s)
    }
}

// -- fused execution --------------------------------------------------------

/// Tile size for the fused evaluator: small enough that every live
/// buffer (one per input plus one per step) stays cache-resident, large
/// enough to amortize the per-step dispatch.
const FUSE_BLOCK: usize = 1024;

fn tape_bin(op: BinOp, a: &Data, b: &Data, dst: &mut Data, len: usize) {
    macro_rules! arm {
        ($d:expr, $x:expr, $y:expr, $apply:ident) => {
            for ((d, x), y) in $d[..len].iter_mut().zip(&$x[..len]).zip(&$y[..len]) {
                *d = $apply(op, *x, *y);
            }
        };
    }
    match (dst, a, b) {
        (Data::Pred(d), Data::Pred(x), Data::Pred(y)) => arm!(d, x, y, apply_pred),
        (Data::S32(d), Data::S32(x), Data::S32(y)) => arm!(d, x, y, apply_s32),
        (Data::S64(d), Data::S64(x), Data::S64(y)) => arm!(d, x, y, apply_s64),
        (Data::U32(d), Data::U32(x), Data::U32(y)) => arm!(d, x, y, apply_u32),
        (Data::U64(d), Data::U64(x), Data::U64(y)) => arm!(d, x, y, apply_u64),
        (Data::F32(d), Data::F32(x), Data::F32(y)) => arm!(d, x, y, apply_f32),
        (Data::F64(d), Data::F64(x), Data::F64(y)) => arm!(d, x, y, apply_f64),
        _ => unreachable!("fused dtypes fixed at lowering"),
    }
}

fn tape_un(op: UnOp, a: &Data, dst: &mut Data, len: usize) {
    macro_rules! arm {
        ($d:expr, $x:expr, $apply:ident) => {
            for (d, x) in $d[..len].iter_mut().zip(&$x[..len]) {
                *d = $apply(op, *x);
            }
        };
    }
    match (dst, a) {
        (Data::Pred(d), Data::Pred(x)) => arm!(d, x, un_apply_pred),
        (Data::S32(d), Data::S32(x)) => arm!(d, x, un_apply_s32),
        (Data::S64(d), Data::S64(x)) => arm!(d, x, un_apply_s64),
        (Data::U32(d), Data::U32(x)) => arm!(d, x, un_apply_u32),
        (Data::U64(d), Data::U64(x)) => arm!(d, x, un_apply_u64),
        (Data::F32(d), Data::F32(x)) => arm!(d, x, un_apply_f32),
        (Data::F64(d), Data::F64(x)) => arm!(d, x, un_apply_f64),
        _ => unreachable!("fused dtypes fixed at lowering"),
    }
}

fn tape_cmp(dir: CmpDir, a: &Data, b: &Data, dst: &mut Data, len: usize) {
    let d = match dst {
        Data::Pred(d) => d,
        _ => unreachable!("compare output is pred"),
    };
    // same widening as `cmp_range`: floats through f64, the rest through
    // i64 (including the u64 wrap quirk of `Data::get_i64`)
    macro_rules! arm {
        ($x:expr, $y:expr, $cmp:ident, $conv:expr) => {
            for ((d, x), y) in d[..len].iter_mut().zip(&$x[..len]).zip(&$y[..len]) {
                *d = $cmp(dir, $conv(*x), $conv(*y));
            }
        };
    }
    match (a, b) {
        (Data::F32(x), Data::F32(y)) => arm!(x, y, cmp_f64, |v: f32| v as f64),
        (Data::F64(x), Data::F64(y)) => arm!(x, y, cmp_f64, |v: f64| v),
        (Data::Pred(x), Data::Pred(y)) => arm!(x, y, cmp_i64, |v: bool| v as i64),
        (Data::S32(x), Data::S32(y)) => arm!(x, y, cmp_i64, |v: i32| v as i64),
        (Data::S64(x), Data::S64(y)) => arm!(x, y, cmp_i64, |v: i64| v),
        (Data::U32(x), Data::U32(y)) => arm!(x, y, cmp_i64, |v: u32| v as i64),
        (Data::U64(x), Data::U64(y)) => arm!(x, y, cmp_i64, |v: u64| v as i64),
        _ => unreachable!("fused compare operands share a dtype"),
    }
}

fn tape_sel(p: &Data, t: &Data, f: &Data, dst: &mut Data, len: usize) {
    let p = match p {
        Data::Pred(v) => v,
        _ => unreachable!("select predicate is pred"),
    };
    macro_rules! arm {
        ($d:expr, $t:expr, $f:expr) => {
            for (((d, p), t), f) in
                $d[..len].iter_mut().zip(&p[..len]).zip(&$t[..len]).zip(&$f[..len])
            {
                *d = if *p { *t } else { *f };
            }
        };
    }
    match (dst, t, f) {
        (Data::Pred(d), Data::Pred(t), Data::Pred(f)) => arm!(d, t, f),
        (Data::S32(d), Data::S32(t), Data::S32(f)) => arm!(d, t, f),
        (Data::S64(d), Data::S64(t), Data::S64(f)) => arm!(d, t, f),
        (Data::U32(d), Data::U32(t), Data::U32(f)) => arm!(d, t, f),
        (Data::U64(d), Data::U64(t), Data::U64(f)) => arm!(d, t, f),
        (Data::F32(d), Data::F32(t), Data::F32(f)) => arm!(d, t, f),
        (Data::F64(d), Data::F64(t), Data::F64(f)) => arm!(d, t, f),
        _ => unreachable!("fused select dtypes fixed at lowering"),
    }
}

/// Evaluate a fused tape over `range`, writing output element `i` to
/// `out[i - out_base]`.  A `None` source reads from `out` itself — the
/// donated-buffer case, safe because each tile copies its input block
/// into scratch *before* the root store overwrites that block.
fn run_tape(
    fk: &FusedKernel,
    folded: Option<&[Option<Data>]>,
    srcs: &[Option<Arc<Data>>],
    out: &mut Data,
    out_base: usize,
    range: Range<usize>,
) -> Result<()> {
    let block = FUSE_BLOCK.min(range.len()).max(1);
    let mut in_bufs: Vec<Data> = Vec::with_capacity(fk.input_ty.len());
    for (k, &ty) in fk.input_ty.iter().enumerate() {
        if fk.input_scalar[k] {
            let src: &Data = match &srcs[k] {
                Some(a) => a,
                None => out,
            };
            in_bufs.push(src.splat(0, block));
        } else {
            in_bufs.push(Data::zeros(ty, block)?);
        }
    }
    let root = fk.steps.len() - 1;
    let mut step_bufs: Vec<Data> = Vec::with_capacity(fk.steps.len());
    for (s, &ty) in fk.step_ty.iter().enumerate() {
        match folded.and_then(|f| f[s].as_ref()) {
            Some(c) => step_bufs.push(c.splat(0, block)),
            None => step_bufs.push(Data::zeros(ty, block)?),
        }
    }
    let mut off = range.start;
    while off < range.end {
        let len = block.min(range.end - off);
        for (k, buf) in in_bufs.iter_mut().enumerate() {
            if fk.input_scalar[k] {
                continue;
            }
            let src: &Data = match &srcs[k] {
                Some(a) => a,
                None => out,
            };
            buf.copy_block(0, src, off, len)?;
        }
        for s in 0..fk.steps.len() {
            if folded.is_some_and(|f| f[s].is_some()) {
                continue;
            }
            let (done, rest) = step_bufs.split_at_mut(s);
            let dst = &mut rest[0];
            let buf = |r: TapeRef| -> &Data {
                match r {
                    TapeRef::Input(k) => &in_bufs[k],
                    TapeRef::Step(j) => &done[j],
                }
            };
            match fk.steps[s] {
                TapeStep::Bin { op, a, b } => tape_bin(op, buf(a), buf(b), dst, len),
                TapeStep::Un { op, a } => tape_un(op, buf(a), dst, len),
                TapeStep::Cmp { dir, a, b } => tape_cmp(dir, buf(a), buf(b), dst, len),
                TapeStep::Sel { p, t, f } => tape_sel(buf(p), buf(t), buf(f), dst, len),
            }
        }
        out.copy_block(off - out_base, &step_bufs[root], 0, len)?;
        off += len;
    }
    Ok(())
}

impl FusedKernel {
    /// Constituent instruction count (bench/test surface).
    pub(crate) fn constituent_count(&self) -> u64 {
        self.constituents
    }

    /// Scalar-value specialization with a guard (see [`SpecState`]).
    fn specialize(&self, inputs: &[Option<Arc<Data>>]) -> Option<Arc<Vec<Option<Data>>>> {
        let scalars: Vec<usize> =
            (0..self.input_scalar.len()).filter(|&k| self.input_scalar[k]).collect();
        if scalars.is_empty() {
            return None;
        }
        let cur: Vec<u64> = scalars
            .iter()
            .map(|&k| scalar_bits(inputs[k].as_ref().expect("input present")))
            .collect();
        let mut st = self.spec.lock().expect("spec lock");
        st.runs += 1;
        if st.runs == 1 {
            st.observed = cur;
            st.volatile = vec![false; scalars.len()];
            return None;
        }
        let mut tripped = false;
        for (j, &bits) in cur.iter().enumerate() {
            if !st.volatile[j] && st.observed[j] != bits {
                st.volatile[j] = true;
                tripped = true;
            }
        }
        if tripped {
            // guard failed: generic fallback this run, fold rebuilt
            // without the volatile slots on the next clean run
            st.folded = None;
            return None;
        }
        if st.volatile.iter().all(|&v| v) {
            return None;
        }
        if st.folded.is_none() {
            st.folded = Some(Arc::new(self.fold(&st.volatile, &scalars, inputs)));
        }
        st.folded.clone()
    }

    /// Pre-evaluate every step whose operands are all stable scalars (or
    /// already-folded steps) to a length-1 constant.
    fn fold(
        &self,
        volatile: &[bool],
        scalars: &[usize],
        inputs: &[Option<Arc<Data>>],
    ) -> Vec<Option<Data>> {
        let mut const_in = vec![false; self.input_ty.len()];
        for (j, &k) in scalars.iter().enumerate() {
            const_in[k] = !volatile[j];
        }
        let mut folded: Vec<Option<Data>> = Vec::with_capacity(self.steps.len());
        for (s, step) in self.steps.iter().enumerate() {
            let is_const = |r: TapeRef, folded: &[Option<Data>]| match r {
                TapeRef::Input(k) => const_in[k],
                TapeRef::Step(j) => folded[j].is_some(),
            };
            let all_const = match *step {
                TapeStep::Bin { a, b, .. } => is_const(a, &folded) && is_const(b, &folded),
                TapeStep::Un { a, .. } => is_const(a, &folded),
                TapeStep::Cmp { a, b, .. } => is_const(a, &folded) && is_const(b, &folded),
                TapeStep::Sel { p, t, f } => {
                    is_const(p, &folded) && is_const(t, &folded) && is_const(f, &folded)
                }
            };
            if !all_const {
                folded.push(None);
                continue;
            }
            let get = |r: TapeRef, folded: &[Option<Data>]| -> Data {
                match r {
                    TapeRef::Input(k) => inputs[k].as_ref().expect("input present").splat(0, 1),
                    TapeRef::Step(j) => folded[j].clone().expect("folded step"),
                }
            };
            let mut dst = Data::zeros(self.step_ty[s], 1).expect("scalar buffer");
            match *step {
                TapeStep::Bin { op, a, b } => {
                    tape_bin(op, &get(a, &folded), &get(b, &folded), &mut dst, 1)
                }
                TapeStep::Un { op, a } => tape_un(op, &get(a, &folded), &mut dst, 1),
                TapeStep::Cmp { dir, a, b } => {
                    tape_cmp(dir, &get(a, &folded), &get(b, &folded), &mut dst, 1)
                }
                TapeStep::Sel { p, t, f } => {
                    tape_sel(&get(p, &folded), &get(t, &folded), &get(f, &folded), &mut dst, 1)
                }
            }
            folded.push(Some(dst));
        }
        folded
    }
}

/// Execute a fused kernel: specialize/guard on scalar inputs, then run
/// the tape serially (donating a uniquely-owned dying input's buffer when
/// length and dtype line up) or chunked across the worker pool.
fn exec_fused(
    fk: &Arc<FusedKernel>,
    ops: Vec<RValue>,
    ty: ElementType,
    dims: Vec<usize>,
) -> Result<RValue> {
    let n = fk.n;
    if ops.len() != fk.input_ty.len() {
        return Err(Error("fused operand count mismatch".into()));
    }
    eval::note_fused_extra(fk.constituents.saturating_sub(1));
    let mut inputs: Vec<Option<Arc<Data>>> = Vec::with_capacity(ops.len());
    for v in ops {
        inputs.push(Some(v.into_rtensor()?.data));
    }
    let folded = fk.specialize(&inputs);
    if parallel::should_parallelize(n) {
        let arcs: Vec<Arc<Data>> =
            inputs.into_iter().map(|a| a.expect("input present")).collect();
        let make = {
            let fk = fk.clone();
            let folded = folded.clone();
            move |r: Range<usize>| -> Data {
                let srcs: Vec<Option<Arc<Data>>> = arcs.iter().cloned().map(Some).collect();
                let mut chunk = Data::zeros(ty, r.len()).expect("chunk alloc");
                let f = folded.as_deref().map(|v| v.as_slice());
                run_tape(&fk, f, &srcs, &mut chunk, r.start, r.clone())
                    .expect("fused tape eval");
                chunk
            }
        };
        macro_rules! par_fused {
            ($variant:ident) => {
                Data::$variant(parallel::build_chunked(n, move |r| match make(r) {
                    Data::$variant(v) => v,
                    _ => unreachable!("fused output dtype fixed at lowering"),
                }))
            };
        }
        let data = match Data::zeros(ty, 0)? {
            Data::Pred(_) => par_fused!(Pred),
            Data::S32(_) => par_fused!(S32),
            Data::S64(_) => par_fused!(S64),
            Data::U32(_) => par_fused!(U32),
            Data::U64(_) => par_fused!(U64),
            Data::F32(_) => par_fused!(F32),
            Data::F64(_) => par_fused!(F64),
        };
        return Ok(RValue::T(RTensor::new(dims, data)));
    }
    // serial: donate a uniquely-owned, full-size input of the output
    // dtype (dying registers were dropped before this kernel ran, so
    // unique ownership means "no other live user")
    let mut out: Option<Data> = None;
    for k in 0..inputs.len() {
        if fk.input_scalar[k] {
            continue;
        }
        let fits = {
            let a = inputs[k].as_ref().expect("input present");
            a.len() == n && a.dtype() == ty
        };
        if !fits {
            continue;
        }
        let arc = inputs[k].take().expect("input present");
        match Arc::try_unwrap(arc) {
            Ok(d) => {
                out = Some(d);
                break;
            }
            Err(arc) => inputs[k] = Some(arc),
        }
    }
    let mut out = match out {
        Some(d) => d,
        None => Data::zeros(ty, n)?,
    };
    run_tape(fk, folded.as_deref().map(|v| v.as_slice()), &inputs, &mut out, 0, 0..n)?;
    Ok(RValue::T(RTensor::new(dims, out)))
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

impl CompiledModule {
    /// Execute the entry computation over owned argument values.
    pub(crate) fn execute(&self, args: Vec<Value>) -> Result<Value> {
        let rargs: Vec<RValue> = args.into_iter().map(RValue::from_value).collect();
        Ok(self.run_computation(self.entry, rargs)?.into_value())
    }

    /// Total lowered instructions across all computations (bench
    /// surface).  Under fusion this counts *dispatches*: a fused chain
    /// is one instruction here; see [`Self::static_constituent_count`].
    pub(crate) fn static_instruction_count(&self) -> usize {
        self.comps.iter().map(|c| c.instrs.len()).sum()
    }

    /// Total constituent instructions — fused chains counted by their
    /// members, everything else as 1.  Equals the unfused schedule's
    /// `static_instruction_count`.
    pub(crate) fn static_constituent_count(&self) -> usize {
        self.comps
            .iter()
            .flat_map(|c| c.instrs.iter())
            .map(|i| match &i.op {
                Op::Fused(fk) => fk.constituents as usize,
                _ => 1,
            })
            .sum()
    }

    /// Number of `Op::Fused` dispatch sites across all computations.
    pub(crate) fn fused_kernel_count(&self) -> usize {
        self.comps
            .iter()
            .flat_map(|c| c.instrs.iter())
            .filter(|i| matches!(i.op, Op::Fused(_)))
            .count()
    }

    /// Largest constituent count among fused kernels (0 when none).
    pub(crate) fn max_fused_constituents(&self) -> u64 {
        self.comps
            .iter()
            .flat_map(|c| c.instrs.iter())
            .filter_map(|i| match &i.op {
                Op::Fused(fk) => Some(fk.constituent_count()),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    fn run_computation(&self, ci: usize, mut args: Vec<RValue>) -> Result<RValue> {
        let comp = &self.comps[ci];
        eval::note_exec(comp.instrs.len() as u64);
        let mut regs: Vec<Option<RValue>> = (0..comp.instrs.len()).map(|_| None).collect();
        for (p, ins) in comp.instrs.iter().enumerate() {
            let mut ops: Vec<RValue> = Vec::with_capacity(ins.operands.len());
            for &r in &ins.operands {
                ops.push(
                    regs[r]
                        .clone()
                        .ok_or_else(|| Error("operand register empty".into()))?,
                );
            }
            // drop dying registers *before* the kernel runs: a uniquely
            // owned operand can then be recycled in place
            for &r in &ins.free_after {
                regs[r] = None;
            }
            let v = self.exec_op(ins, ops, &mut args)?;
            regs[p] = Some(v);
        }
        regs[comp.root]
            .take()
            .ok_or_else(|| Error("root register empty".into()))
    }

    fn exec_op(&self, ins: &CInstr, mut ops: Vec<RValue>, args: &mut Vec<RValue>) -> Result<RValue> {
        match &ins.op {
            Op::Parameter(k) => {
                if *k >= args.len() {
                    return Err(Error(format!(
                        "parameter({k}) out of range ({} args)",
                        args.len()
                    )));
                }
                Ok(std::mem::replace(&mut args[*k], RValue::Tuple(Vec::new())))
            }
            Op::Const(i) => Ok(self.consts[*i].clone()),
            Op::Tuple => Ok(RValue::Tuple(ops)),
            Op::Gte(i) => match ops.swap_remove(0) {
                RValue::Tuple(mut parts) => {
                    if *i < parts.len() {
                        Ok(parts.swap_remove(*i))
                    } else {
                        Err(Error(format!("tuple index {i} out of range")))
                    }
                }
                RValue::T(_) => Err(Error("get-tuple-element on non-tuple".into())),
            },
            Op::Call(ci) => self.run_computation(*ci, ops),
            Op::While { cond, body } => {
                // double-buffer-free loop state: the state tuple *moves*
                // into each body run and back out, so loop-carried tensors
                // that the body updates in place are never deep-cloned
                let mut state = ops.swap_remove(0);
                loop {
                    let keep = self
                        .run_computation(*cond, vec![state.clone()])?
                        .tensor()?
                        .scalar_bool()?;
                    if !keep {
                        return Ok(state);
                    }
                    state = self.run_computation(*body, vec![state])?;
                }
            }
            Op::Reshape => {
                let (_, dims) = ins.out.array()?;
                let t = ops.swap_remove(0).into_rtensor()?;
                passthrough(t, dims)
            }
            Op::Convert => self.exec_convert(ins, &ops),
            Op::Broadcast { map } => self.exec_broadcast(ins, map, &ops),
            Op::Transpose { perm } => self.exec_transpose(ins, perm, &ops),
            Op::Slice { spec } => self.exec_slice(ins, spec, &ops),
            Op::DynamicSlice { sizes } => self.exec_dynamic_slice(ins, sizes, ops),
            Op::DynamicUpdateSlice => self.exec_dynamic_update_slice(ins, ops),
            Op::Concatenate { axis } => self.exec_concatenate(ins, *axis, &ops),
            Op::Compare(dir) => self.exec_compare(ins, *dir, ops),
            Op::Select => self.exec_select(ins, ops),
            Op::Binary(op) => {
                let (_, dims) = ins.out.array()?;
                let dims = dims.to_vec();
                let b = ops.pop().ok_or_else(|| Error("binary needs 2 operands".into()))?;
                let a = ops.pop().ok_or_else(|| Error("binary needs 2 operands".into()))?;
                drop(ops);
                exec_binary(*op, a.into_rtensor()?, b.into_rtensor()?, dims)
            }
            Op::Unary(op) => {
                let (_, dims) = ins.out.array()?;
                if *op == UnOp::Copy {
                    // value-identity: share the storage, keep declared dims
                    let dims = dims.to_vec();
                    let t = ops.swap_remove(0).into_rtensor()?;
                    return passthrough(t, &dims);
                }
                let dims = dims.to_vec();
                let t = ops.swap_remove(0).into_rtensor()?;
                exec_unary(*op, t, dims)
            }
            Op::Fused(fk) => {
                let (ty, dims) = ins.out.array()?;
                let dims = dims.to_vec();
                exec_fused(fk, ops, ty, dims)
            }
            Op::ReduceFast { red, fc } => {
                let init = ops.pop().ok_or_else(|| Error("reduce needs input + init".into()))?;
                let input = ops.pop().ok_or_else(|| Error("reduce needs input + init".into()))?;
                drop(ops);
                exec_reduce_fast(red, *fc, input.into_rtensor()?, init.into_rtensor()?)
            }
            Op::ReduceBridge(hins) => {
                let vals: Vec<Value> = ops.into_iter().map(RValue::into_value).collect();
                let refs: Vec<&Value> = vals.iter().collect();
                Ok(RValue::from_value(eval_reduce(self.hlo.as_ref(), hins, &refs)?))
            }
            Op::Gather(hins) => {
                let operand = ops[0].tensor()?;
                let indices = ops[1].tensor()?;
                let (dims, data) = gather_core(
                    hins,
                    &operand.dims,
                    &operand.data,
                    &indices.dims,
                    &indices.data,
                )?;
                Ok(RValue::T(RTensor::new(dims, data)))
            }
            Op::Scatter(hins) => {
                let (op_dims, op_arc) = {
                    let t = ops[0].tensor()?;
                    (t.dims.clone(), t.data.clone())
                };
                let (idx_dims, idx_arc) = {
                    let t = ops[1].tensor()?;
                    (t.dims.clone(), t.data.clone())
                };
                let (upd_dims, upd_arc) = {
                    let t = ops[2].tensor()?;
                    (t.dims.clone(), t.data.clone())
                };
                drop(ops);
                // in place when the target register died and is unowned
                let owned = Arc::try_unwrap(op_arc).unwrap_or_else(|a| (*a).clone());
                let (dims, data) = scatter_core(
                    self.hlo.as_ref(),
                    hins,
                    &op_dims,
                    owned,
                    &idx_dims,
                    &idx_arc,
                    &upd_dims,
                    &upd_arc,
                )?;
                Ok(RValue::T(RTensor::new(dims, data)))
            }
        }
    }

    fn exec_convert(&self, ins: &CInstr, ops: &[RValue]) -> Result<RValue> {
        let (ty, dims) = ins.out.array()?;
        let t = ops[0].tensor()?;
        let n = t.elems();
        let mut out = Data::zeros(ty, n)?;
        let src_is_float = matches!(t.dtype(), ElementType::F32 | ElementType::F64);
        for i in 0..n {
            if src_is_float {
                write_f64(&mut out, i, t.data.get_f64(i));
            } else {
                write_i64(&mut out, i, t.data.get_i64(i));
            }
        }
        Ok(RValue::T(RTensor::new(dims.to_vec(), out)))
    }

    fn exec_broadcast(&self, ins: &CInstr, map: &[usize], ops: &[RValue]) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let t = ops[0].tensor()?;
        if map.len() != t.rank() {
            return Err(Error(format!(
                "broadcast: {} mapped dims for rank-{} operand",
                map.len(),
                t.rank()
            )));
        }
        let total: usize = dims.iter().product();
        // scalar splat (the overwhelmingly common case in the artifacts)
        if t.elems() == 1 && total > 0 {
            return Ok(RValue::T(RTensor::new(dims.to_vec(), t.data.splat(0, total))));
        }
        // identity: same dims mapped in order — share storage
        if dims == t.dims && map.iter().enumerate().all(|(k, &od)| k == od) {
            return Ok(RValue::T(RTensor { dims: dims.to_vec(), data: t.data.clone() }));
        }
        let src_strides = t.strides();
        let mut idxs: Vec<usize> = Vec::with_capacity(total);
        let mut idx = vec![0usize; dims.len()];
        let mut more = total > 0;
        while more {
            let mut src_lin = 0usize;
            for (k, &od) in map.iter().enumerate() {
                src_lin += idx[od] * src_strides[k];
            }
            idxs.push(src_lin);
            more = next_index(&mut idx, dims);
        }
        Ok(RValue::T(RTensor::new(dims.to_vec(), t.data.take_by(&idxs))))
    }

    fn exec_transpose(&self, ins: &CInstr, perm: &[usize], ops: &[RValue]) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let t = ops[0].tensor()?;
        let total: usize = dims.iter().product();
        let src_strides = t.strides();
        let mut idxs: Vec<usize> = Vec::with_capacity(total);
        let mut idx = vec![0usize; dims.len()];
        let mut more = total > 0;
        while more {
            let mut src_lin = 0usize;
            for (i, &p) in perm.iter().enumerate() {
                src_lin += idx[i] * src_strides[p];
            }
            idxs.push(src_lin);
            more = next_index(&mut idx, dims);
        }
        Ok(RValue::T(RTensor::new(dims.to_vec(), t.data.take_by(&idxs))))
    }

    fn exec_slice(
        &self,
        ins: &CInstr,
        spec: &[(usize, usize, usize)],
        ops: &[RValue],
    ) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let t = ops[0].tensor()?;
        if spec.len() != t.rank() {
            return Err(Error("slice spec rank mismatch".into()));
        }
        let total: usize = dims.iter().product();
        let src_strides = t.strides();
        let mut idxs: Vec<usize> = Vec::with_capacity(total);
        let mut idx = vec![0usize; dims.len()];
        let mut more = total > 0;
        while more {
            let mut src_lin = 0usize;
            for d in 0..dims.len() {
                src_lin += (spec[d].0 + idx[d] * spec[d].2) * src_strides[d];
            }
            idxs.push(src_lin);
            more = next_index(&mut idx, dims);
        }
        Ok(RValue::T(RTensor::new(dims.to_vec(), t.data.take_by(&idxs))))
    }

    fn exec_dynamic_slice(
        &self,
        ins: &CInstr,
        sizes: &[usize],
        ops: Vec<RValue>,
    ) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let dims = dims.to_vec();
        let t = ops[0].tensor()?;
        let starts = dyn_starts(&ops, 1, &t.dims, sizes)?;
        // full-window slice degenerates to the operand itself
        if sizes == t.dims.as_slice() && dims == t.dims {
            return passthrough(t.clone(), &dims);
        }
        let total: usize = dims.iter().product();
        let src_strides = t.strides();
        // rows of the leading dim are contiguous when all trailing dims
        // are taken whole (and the declared shape agrees with the window)
        if !t.dims.is_empty() && dims == sizes && sizes[1..] == t.dims[1..] {
            let data = t.data.copy_range(starts[0] * src_strides[0], total);
            return Ok(RValue::T(RTensor::new(dims, data)));
        }
        let mut idxs: Vec<usize> = Vec::with_capacity(total);
        let mut idx = vec![0usize; dims.len()];
        let mut more = total > 0;
        while more {
            let mut src_lin = 0usize;
            for d in 0..dims.len() {
                src_lin += (starts[d] + idx[d]) * src_strides[d];
            }
            idxs.push(src_lin);
            more = next_index(&mut idx, &dims);
        }
        Ok(RValue::T(RTensor::new(dims, t.data.take_by(&idxs))))
    }

    fn exec_dynamic_update_slice(&self, ins: &CInstr, ops: Vec<RValue>) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let dims = dims.to_vec();
        let (tdims, tarc) = {
            let t = ops[0].tensor()?;
            (t.dims.clone(), t.data.clone())
        };
        let (udims, uarc) = {
            let u = ops[1].tensor()?;
            (u.dims.clone(), u.data.clone())
        };
        let starts = dyn_starts(&ops, 2, &tdims, &udims)?;
        // full-tensor update: the result IS the update (starts clamp to 0)
        if udims == tdims {
            if uarc.dtype() != tarc.dtype() {
                return Err(Error(format!(
                    "dtype mismatch in element copy: {:?} vs {:?}",
                    tarc.dtype(),
                    uarc.dtype()
                )));
            }
            let want: usize = dims.iter().product();
            if uarc.len() != want {
                return Err(Error(format!(
                    "tensor data length {} does not match dims {:?}",
                    uarc.len(),
                    dims
                )));
            }
            return Ok(RValue::T(RTensor { dims, data: uarc }));
        }
        drop(ops); // release operand register refs: unique targets mutate in place
        let mut out = Arc::try_unwrap(tarc).unwrap_or_else(|a| (*a).clone());
        let dst_strides = strides_of(&tdims);
        let total_u: usize = udims.iter().product();
        if !udims.is_empty() && udims[1..] == tdims[1..] {
            // contiguous row window
            if total_u > 0 {
                out.copy_block(starts[0] * dst_strides[0], &uarc, 0, total_u)?;
            }
        } else {
            let src_strides = strides_of(&udims);
            let mut idx = vec![0usize; udims.len()];
            let mut more = total_u > 0;
            while more {
                let mut dst_lin = 0usize;
                for d in 0..udims.len() {
                    dst_lin += (starts[d] + idx[d]) * dst_strides[d];
                }
                out.copy_elem(dst_lin, &uarc, linear_index(&idx, &src_strides))?;
                more = next_index(&mut idx, &udims);
            }
        }
        let want: usize = dims.iter().product();
        if out.len() != want {
            return Err(Error(format!(
                "tensor data length {} does not match dims {:?}",
                out.len(),
                dims
            )));
        }
        Ok(RValue::T(RTensor::new(dims, out)))
    }

    fn exec_concatenate(&self, ins: &CInstr, axis: usize, ops: &[RValue]) -> Result<RValue> {
        let (ty, dims) = ins.out.array()?;
        let total: usize = dims.iter().product();
        let mut out = Data::zeros(ty, total)?;
        let inner: usize = dims[axis + 1..].iter().product();
        let out_axis = dims[axis];
        let mut offset = 0usize;
        for v in ops {
            let t = v.tensor()?;
            let t_axis = t.dims[axis];
            let prefix: usize = t.dims[..axis].iter().product();
            let run = t_axis * inner;
            for outer in 0..prefix {
                out.copy_block(
                    outer * out_axis * inner + offset * inner,
                    &t.data,
                    outer * run,
                    run,
                )?;
            }
            offset += t_axis;
        }
        Ok(RValue::T(RTensor::new(dims.to_vec(), out)))
    }

    fn exec_compare(&self, ins: &CInstr, dir: CmpDir, ops: Vec<RValue>) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let dims = dims.to_vec();
        let n: usize = dims.iter().product();
        let a = ops[0].tensor()?;
        let b = ops[1].tensor()?;
        // same numeric widening as the naive lane (floats through f64,
        // everything else through i64 — including the u64-wrap quirk)
        let float = matches!(a.dtype(), ElementType::F32 | ElementType::F64);
        if parallel::should_parallelize(n) {
            let (ad, bd) = (a.data.clone(), b.data.clone());
            let out = parallel::build_chunked(n, move |r| cmp_range(dir, &ad, &bd, float, r));
            return Ok(RValue::T(RTensor::new(dims, Data::Pred(out))));
        }
        let out = cmp_range(dir, &a.data, &b.data, float, 0..n);
        Ok(RValue::T(RTensor::new(dims, Data::Pred(out))))
    }

    fn exec_select(&self, ins: &CInstr, ops: Vec<RValue>) -> Result<RValue> {
        let (_, dims) = ins.out.array()?;
        let dims = dims.to_vec();
        let n: usize = dims.iter().product();
        let p = ops[0].tensor()?;
        let t = ops[1].tensor()?;
        let f = ops[2].tensor()?;
        if p.data.preds().is_none() {
            return Err(Error("select predicate must be pred".into()));
        }
        if t.dtype() != f.dtype() {
            return Err(Error(format!(
                "dtype mismatch in element copy: {:?} vs {:?}",
                t.dtype(),
                f.dtype()
            )));
        }
        if parallel::should_parallelize(n) {
            let (pd, td, fd) = (p.data.clone(), t.data.clone(), f.data.clone());
            macro_rules! par_sel {
                ($variant:ident, $acc:ident) => {{
                    let (pd, td, fd) = (pd.clone(), td.clone(), fd.clone());
                    Data::$variant(parallel::build_chunked(n, move |r| {
                        sel_range(
                            pd.preds().expect("pred checked"),
                            td.$acc().expect("dtype matched"),
                            fd.$acc().expect("dtype matched"),
                            r,
                        )
                    }))
                }};
            }
            let data = match &*t.data {
                Data::Pred(_) => par_sel!(Pred, preds),
                Data::S32(_) => par_sel!(S32, s32s),
                Data::S64(_) => par_sel!(S64, s64s),
                Data::U32(_) => par_sel!(U32, u32s),
                Data::U64(_) => par_sel!(U64, u64s),
                Data::F32(_) => par_sel!(F32, f32s),
                Data::F64(_) => par_sel!(F64, f64s),
            };
            return Ok(RValue::T(RTensor::new(dims, data)));
        }
        let preds = p.data.preds().expect("pred checked");
        macro_rules! ser_sel {
            ($variant:ident, $tv:expr, $fv:expr) => {
                Data::$variant(sel_range(preds, $tv, $fv, 0..n))
            };
        }
        let data = match (&*t.data, &*f.data) {
            (Data::Pred(tv), Data::Pred(fv)) => ser_sel!(Pred, tv, fv),
            (Data::S32(tv), Data::S32(fv)) => ser_sel!(S32, tv, fv),
            (Data::S64(tv), Data::S64(fv)) => ser_sel!(S64, tv, fv),
            (Data::U32(tv), Data::U32(fv)) => ser_sel!(U32, tv, fv),
            (Data::U64(tv), Data::U64(fv)) => ser_sel!(U64, tv, fv),
            (Data::F32(tv), Data::F32(fv)) => ser_sel!(F32, tv, fv),
            (Data::F64(tv), Data::F64(fv)) => ser_sel!(F64, tv, fv),
            _ => unreachable!("dtype equality checked above"),
        };
        Ok(RValue::T(RTensor::new(dims, data)))
    }
}

/// Share the operand's storage under the declared output dims
/// (`reshape`, `copy`, full-window dynamic-slice).
fn passthrough(t: RTensor, dims: &[usize]) -> Result<RValue> {
    let want: usize = dims.iter().product();
    if t.data.len() != want {
        return Err(Error(format!(
            "tensor data length {} does not match dims {:?}",
            t.data.len(),
            dims
        )));
    }
    Ok(RValue::T(RTensor { dims: dims.to_vec(), data: t.data }))
}

/// Clamped start indices (identical to the naive lane's `dynamic_starts`).
fn dyn_starts(
    ops: &[RValue],
    first: usize,
    in_dims: &[usize],
    window: &[usize],
) -> Result<Vec<usize>> {
    let mut starts = Vec::with_capacity(in_dims.len());
    for d in 0..in_dims.len() {
        let s = ops
            .get(first + d)
            .ok_or_else(|| Error("missing dynamic start index".into()))?
            .tensor()?
            .scalar_i64()?;
        let max = in_dims[d].saturating_sub(window[d]) as i64;
        starts.push(s.clamp(0, max) as usize);
    }
    Ok(starts)
}

// ---------------------------------------------------------------------------
// Elementwise kernels (typed; parallel above the chunking threshold)
// ---------------------------------------------------------------------------

#[inline]
fn cmp_i64(dir: CmpDir, x: i64, y: i64) -> bool {
    match dir {
        CmpDir::Eq => x == y,
        CmpDir::Ne => x != y,
        CmpDir::Lt => x < y,
        CmpDir::Le => x <= y,
        CmpDir::Gt => x > y,
        CmpDir::Ge => x >= y,
    }
}

#[inline]
fn cmp_f64(dir: CmpDir, x: f64, y: f64) -> bool {
    match dir {
        CmpDir::Eq => x == y,
        CmpDir::Ne => x != y,
        CmpDir::Lt => x < y,
        CmpDir::Le => x <= y,
        CmpDir::Gt => x > y,
        CmpDir::Ge => x >= y,
    }
}

fn cmp_range(dir: CmpDir, a: &Data, b: &Data, float: bool, range: Range<usize>) -> Vec<bool> {
    let (an, bn) = (a.len(), b.len());
    range
        .map(|i| {
            let (ia, ib) = (pair_index(i, an), pair_index(i, bn));
            if float {
                cmp_f64(dir, a.get_f64(ia), b.get_f64(ib))
            } else {
                cmp_i64(dir, a.get_i64(ia), b.get_i64(ib))
            }
        })
        .collect()
}

fn sel_range<T: Copy>(p: &[bool], t: &[T], f: &[T], range: Range<usize>) -> Vec<T> {
    let (pn, tn, fln) = (p.len(), t.len(), f.len());
    range
        .map(|i| {
            if p[pair_index(i, pn)] {
                t[pair_index(i, tn)]
            } else {
                f[pair_index(i, fln)]
            }
        })
        .collect()
}

/// Which binary ops the naive lane accepts per dtype family.
fn bin_supported(op: BinOp, ty: ElementType) -> bool {
    use BinOp::*;
    match ty {
        ElementType::F32 | ElementType::F64 => {
            matches!(op, Add | Sub | Mul | Div | Rem | Max | Min | Pow)
        }
        ElementType::Pred => matches!(op, And | Or | Xor),
        _ => !matches!(op, Pow),
    }
}

fn un_supported(op: UnOp, ty: ElementType) -> bool {
    use UnOp::*;
    match ty {
        ElementType::F32 | ElementType::F64 => !matches!(op, Not),
        ElementType::Pred => matches!(op, Not | Copy),
        _ => matches!(op, Abs | Neg | Not | Sign | Copy),
    }
}

// Scalar appliers: exactly the naive lane's per-element expressions,
// dispatched on a dense enum instead of a string.  Unsupported
// combinations are rejected by `bin_supported` before any loop runs.
macro_rules! int_apply_fn {
    ($name:ident, $ty:ty) => {
        #[inline]
        fn $name(op: BinOp, x: $ty, y: $ty) -> $ty {
            let bits = <$ty>::BITS as u64;
            match op {
                BinOp::Add => x.wrapping_add(y),
                BinOp::Sub => x.wrapping_sub(y),
                BinOp::Mul => x.wrapping_mul(y),
                BinOp::Div => {
                    if y == 0 {
                        0
                    } else {
                        x.wrapping_div(y)
                    }
                }
                BinOp::Rem => {
                    if y == 0 {
                        0
                    } else {
                        x.wrapping_rem(y)
                    }
                }
                BinOp::Max => x.max(y),
                BinOp::Min => x.min(y),
                BinOp::And => x & y,
                BinOp::Or => x | y,
                BinOp::Xor => x ^ y,
                BinOp::Shl => {
                    let s = y as u64;
                    if s >= bits {
                        0
                    } else {
                        x << s
                    }
                }
                BinOp::ShrL => {
                    let s = y as u64;
                    if s >= bits {
                        0
                    } else {
                        (((x as u64) & ((!0u64) >> (64 - bits))) >> s) as $ty
                    }
                }
                BinOp::ShrA => {
                    let s = (y as u64).min(bits - 1);
                    x >> s
                }
                BinOp::Pow => unreachable!("pow pre-checked unsupported on integers"),
            }
        }
    };
}

int_apply_fn!(apply_s32, i32);
int_apply_fn!(apply_s64, i64);
int_apply_fn!(apply_u32, u32);
int_apply_fn!(apply_u64, u64);

macro_rules! float_apply_fn {
    ($name:ident, $ty:ty) => {
        #[inline]
        fn $name(op: BinOp, x: $ty, y: $ty) -> $ty {
            match op {
                BinOp::Add => x + y,
                BinOp::Sub => x - y,
                BinOp::Mul => x * y,
                BinOp::Div => x / y,
                BinOp::Rem => x % y,
                BinOp::Max => x.max(y),
                BinOp::Min => x.min(y),
                BinOp::Pow => x.powf(y),
                _ => unreachable!("bitwise op pre-checked unsupported on floats"),
            }
        }
    };
}

float_apply_fn!(apply_f32, f32);
float_apply_fn!(apply_f64, f64);

#[inline]
fn apply_pred(op: BinOp, x: bool, y: bool) -> bool {
    match op {
        BinOp::And => x && y,
        BinOp::Or => x || y,
        BinOp::Xor => x != y,
        _ => unreachable!("op pre-checked unsupported on pred"),
    }
}

macro_rules! bin_range_fn {
    ($name:ident, $apply:ident, $ty:ty) => {
        fn $name(op: BinOp, a: &[$ty], b: &[$ty], range: Range<usize>) -> Vec<$ty> {
            let (an, bn) = (a.len(), b.len());
            range
                .map(|i| $apply(op, a[pair_index(i, an)], b[pair_index(i, bn)]))
                .collect()
        }
    };
}

bin_range_fn!(bin_range_s32, apply_s32, i32);
bin_range_fn!(bin_range_s64, apply_s64, i64);
bin_range_fn!(bin_range_u32, apply_u32, u32);
bin_range_fn!(bin_range_u64, apply_u64, u64);
bin_range_fn!(bin_range_f32, apply_f32, f32);
bin_range_fn!(bin_range_f64, apply_f64, f64);
bin_range_fn!(bin_range_pred, apply_pred, bool);

macro_rules! bin_in_fn {
    ($name:ident, $apply:ident, $ty:ty) => {
        fn $name(op: BinOp, a: &mut [$ty], b: &[$ty]) {
            let bn = b.len();
            for i in 0..a.len() {
                a[i] = $apply(op, a[i], b[pair_index(i, bn)]);
            }
        }
    };
}

bin_in_fn!(bin_in_s32, apply_s32, i32);
bin_in_fn!(bin_in_s64, apply_s64, i64);
bin_in_fn!(bin_in_u32, apply_u32, u32);
bin_in_fn!(bin_in_u64, apply_u64, u64);
bin_in_fn!(bin_in_f32, apply_f32, f32);
bin_in_fn!(bin_in_f64, apply_f64, f64);
bin_in_fn!(bin_in_pred, apply_pred, bool);

fn exec_binary(op: BinOp, a: RTensor, b: RTensor, dims: Vec<usize>) -> Result<RValue> {
    let n: usize = dims.iter().product();
    if a.dtype() != b.dtype() {
        return Err(Error(format!(
            "binary {op:?} dtype mismatch: {:?} vs {:?}",
            a.dtype(),
            b.dtype()
        )));
    }
    if !bin_supported(op, a.dtype()) {
        return Err(Error(format!("op {op:?} unsupported on {:?}", a.dtype())));
    }
    if parallel::should_parallelize(n) {
        macro_rules! par_bin {
            ($variant:ident, $acc:ident, $f:ident) => {{
                let (ad, bd) = (a.data.clone(), b.data.clone());
                Data::$variant(parallel::build_chunked(n, move |r| {
                    $f(op, ad.$acc().expect("dtype"), bd.$acc().expect("dtype"), r)
                }))
            }};
        }
        let data = match &*a.data {
            Data::Pred(_) => par_bin!(Pred, preds, bin_range_pred),
            Data::S32(_) => par_bin!(S32, s32s, bin_range_s32),
            Data::S64(_) => par_bin!(S64, s64s, bin_range_s64),
            Data::U32(_) => par_bin!(U32, u32s, bin_range_u32),
            Data::U64(_) => par_bin!(U64, u64s, bin_range_u64),
            Data::F32(_) => par_bin!(F32, f32s, bin_range_f32),
            Data::F64(_) => par_bin!(F64, f64s, bin_range_f64),
        };
        return Ok(RValue::T(RTensor::new(dims, data)));
    }
    // serial: recycle a uniquely-owned full-size lhs in place
    let full = a.data.len() == n;
    match (full, Arc::try_unwrap(a.data)) {
        (true, Ok(mut d)) => {
            match (&mut d, &*b.data) {
                (Data::Pred(x), Data::Pred(y)) => bin_in_pred(op, x, y),
                (Data::S32(x), Data::S32(y)) => bin_in_s32(op, x, y),
                (Data::S64(x), Data::S64(y)) => bin_in_s64(op, x, y),
                (Data::U32(x), Data::U32(y)) => bin_in_u32(op, x, y),
                (Data::U64(x), Data::U64(y)) => bin_in_u64(op, x, y),
                (Data::F32(x), Data::F32(y)) => bin_in_f32(op, x, y),
                (Data::F64(x), Data::F64(y)) => bin_in_f64(op, x, y),
                _ => unreachable!("dtype equality checked above"),
            }
            Ok(RValue::T(RTensor::new(dims, d)))
        }
        (_, owned_or_shared) => {
            let aref: &Data = match &owned_or_shared {
                Ok(d) => d,
                Err(arc) => &**arc,
            };
            let data = match (aref, &*b.data) {
                (Data::Pred(x), Data::Pred(y)) => Data::Pred(bin_range_pred(op, x, y, 0..n)),
                (Data::S32(x), Data::S32(y)) => Data::S32(bin_range_s32(op, x, y, 0..n)),
                (Data::S64(x), Data::S64(y)) => Data::S64(bin_range_s64(op, x, y, 0..n)),
                (Data::U32(x), Data::U32(y)) => Data::U32(bin_range_u32(op, x, y, 0..n)),
                (Data::U64(x), Data::U64(y)) => Data::U64(bin_range_u64(op, x, y, 0..n)),
                (Data::F32(x), Data::F32(y)) => Data::F32(bin_range_f32(op, x, y, 0..n)),
                (Data::F64(x), Data::F64(y)) => Data::F64(bin_range_f64(op, x, y, 0..n)),
                _ => unreachable!("dtype equality checked above"),
            };
            Ok(RValue::T(RTensor::new(dims, data)))
        }
    }
}

macro_rules! float_un_apply_fn {
    ($name:ident, $ty:ty) => {
        #[inline]
        fn $name(op: UnOp, x: $ty) -> $ty {
            match op {
                UnOp::Abs => x.abs(),
                UnOp::Neg => -x,
                UnOp::Sine => x.sin(),
                UnOp::Cosine => x.cos(),
                UnOp::Tanh => x.tanh(),
                UnOp::Exp => x.exp(),
                UnOp::Expm1 => x.exp_m1(),
                UnOp::Log => x.ln(),
                UnOp::Log1p => x.ln_1p(),
                UnOp::Sqrt => x.sqrt(),
                UnOp::Rsqrt => x.sqrt().recip(),
                UnOp::Floor => x.floor(),
                UnOp::Ceil => x.ceil(),
                UnOp::Round => x.round(),
                UnOp::Sign => {
                    if x > 0.0 {
                        1.0
                    } else if x < 0.0 {
                        -1.0
                    } else {
                        x
                    }
                }
                UnOp::Logistic => 1.0 / (1.0 + (-x).exp()),
                UnOp::Copy => x,
                UnOp::Not => unreachable!("not pre-checked unsupported on floats"),
            }
        }
    };
}

float_un_apply_fn!(un_apply_f32, f32);
float_un_apply_fn!(un_apply_f64, f64);

macro_rules! sint_un_apply_fn {
    ($name:ident, $ty:ty) => {
        #[inline]
        fn $name(op: UnOp, x: $ty) -> $ty {
            match op {
                UnOp::Abs => x.wrapping_abs(),
                UnOp::Neg => x.wrapping_neg(),
                UnOp::Not => !x,
                UnOp::Sign => x.signum(),
                UnOp::Copy => x,
                _ => unreachable!("op pre-checked unsupported on signed ints"),
            }
        }
    };
}

sint_un_apply_fn!(un_apply_s32, i32);
sint_un_apply_fn!(un_apply_s64, i64);

macro_rules! uint_un_apply_fn {
    ($name:ident, $ty:ty) => {
        #[inline]
        fn $name(op: UnOp, x: $ty) -> $ty {
            match op {
                UnOp::Abs | UnOp::Copy => x,
                UnOp::Neg => x.wrapping_neg(),
                UnOp::Not => !x,
                UnOp::Sign => <$ty>::from(x != 0),
                _ => unreachable!("op pre-checked unsupported on unsigned ints"),
            }
        }
    };
}

uint_un_apply_fn!(un_apply_u32, u32);
uint_un_apply_fn!(un_apply_u64, u64);

#[inline]
fn un_apply_pred(op: UnOp, x: bool) -> bool {
    match op {
        UnOp::Not => !x,
        UnOp::Copy => x,
        _ => unreachable!("op pre-checked unsupported on pred"),
    }
}

macro_rules! un_range_fn {
    ($name:ident, $apply:ident, $ty:ty) => {
        fn $name(op: UnOp, v: &[$ty], range: Range<usize>) -> Vec<$ty> {
            range.map(|i| $apply(op, v[i])).collect()
        }
    };
}

un_range_fn!(un_range_s32, un_apply_s32, i32);
un_range_fn!(un_range_s64, un_apply_s64, i64);
un_range_fn!(un_range_u32, un_apply_u32, u32);
un_range_fn!(un_range_u64, un_apply_u64, u64);
un_range_fn!(un_range_f32, un_apply_f32, f32);
un_range_fn!(un_range_f64, un_apply_f64, f64);
un_range_fn!(un_range_pred, un_apply_pred, bool);

macro_rules! un_in_fn {
    ($name:ident, $apply:ident, $ty:ty) => {
        fn $name(op: UnOp, v: &mut [$ty]) {
            for x in v.iter_mut() {
                *x = $apply(op, *x);
            }
        }
    };
}

un_in_fn!(un_in_s32, un_apply_s32, i32);
un_in_fn!(un_in_s64, un_apply_s64, i64);
un_in_fn!(un_in_u32, un_apply_u32, u32);
un_in_fn!(un_in_u64, un_apply_u64, u64);
un_in_fn!(un_in_f32, un_apply_f32, f32);
un_in_fn!(un_in_f64, un_apply_f64, f64);
un_in_fn!(un_in_pred, un_apply_pred, bool);

fn exec_unary(op: UnOp, t: RTensor, dims: Vec<usize>) -> Result<RValue> {
    if !un_supported(op, t.dtype()) {
        return Err(Error(format!("op {op:?} unsupported on {:?}", t.dtype())));
    }
    let n = t.data.len();
    let want: usize = dims.iter().product();
    if n != want {
        return Err(Error(format!(
            "tensor data length {n} does not match dims {dims:?}"
        )));
    }
    if parallel::should_parallelize(n) {
        macro_rules! par_un {
            ($variant:ident, $acc:ident, $f:ident) => {{
                let vd = t.data.clone();
                Data::$variant(parallel::build_chunked(n, move |r| {
                    $f(op, vd.$acc().expect("dtype"), r)
                }))
            }};
        }
        let data = match &*t.data {
            Data::Pred(_) => par_un!(Pred, preds, un_range_pred),
            Data::S32(_) => par_un!(S32, s32s, un_range_s32),
            Data::S64(_) => par_un!(S64, s64s, un_range_s64),
            Data::U32(_) => par_un!(U32, u32s, un_range_u32),
            Data::U64(_) => par_un!(U64, u64s, un_range_u64),
            Data::F32(_) => par_un!(F32, f32s, un_range_f32),
            Data::F64(_) => par_un!(F64, f64s, un_range_f64),
        };
        return Ok(RValue::T(RTensor::new(dims, data)));
    }
    match Arc::try_unwrap(t.data) {
        Ok(mut d) => {
            match &mut d {
                Data::Pred(v) => un_in_pred(op, v),
                Data::S32(v) => un_in_s32(op, v),
                Data::S64(v) => un_in_s64(op, v),
                Data::U32(v) => un_in_u32(op, v),
                Data::U64(v) => un_in_u64(op, v),
                Data::F32(v) => un_in_f32(op, v),
                Data::F64(v) => un_in_f64(op, v),
            }
            Ok(RValue::T(RTensor::new(dims, d)))
        }
        Err(arc) => {
            let data = match &*arc {
                Data::Pred(v) => Data::Pred(un_range_pred(op, v, 0..n)),
                Data::S32(v) => Data::S32(un_range_s32(op, v, 0..n)),
                Data::S64(v) => Data::S64(un_range_s64(op, v, 0..n)),
                Data::U32(v) => Data::U32(un_range_u32(op, v, 0..n)),
                Data::U64(v) => Data::U64(un_range_u64(op, v, 0..n)),
                Data::F32(v) => Data::F32(un_range_f32(op, v, 0..n)),
                Data::F64(v) => Data::F64(un_range_f64(op, v, 0..n)),
            };
            Ok(RValue::T(RTensor::new(dims, data)))
        }
    }
}

// ---------------------------------------------------------------------------
// Fast reduce (k == 1, recognized combiner)
// ---------------------------------------------------------------------------

fn exec_reduce_fast(
    red: &[usize],
    fc: FastCombine,
    input: RTensor,
    init: RTensor,
) -> Result<RValue> {
    let in_dims = input.dims.clone();
    let kept: Vec<usize> = (0..in_dims.len()).filter(|d| !red.contains(d)).collect();
    let out_dims: Vec<usize> = kept.iter().map(|&d| in_dims[d]).collect();
    let out_elems: usize = out_dims.iter().product();
    let out_strides = strides_of(&out_dims);
    let in_strides = strides_of(&in_dims);
    let total: usize = in_dims.iter().product();

    // f32 sum accumulates in f64 exactly like the naive lane (the Series
    // trapezoid sums cancel catastrophically in f32)
    if fc == FastCombine::Add {
        if let (Some(src), Some(iv)) = (input.data.f32s(), init.data.f32s()) {
            let init_w = iv[0] as f64;
            // chunk along the leading dim when it is *kept*: every input
            // row then contributes only to its own output rows, so each
            // per-output-element accumulation order — and therefore every
            // output bit — matches the serial walk
            let dim0_kept = kept.first() == Some(&0) && in_dims.len() > 1;
            if dim0_kept && parallel::should_parallelize(total) {
                let rows = in_dims[0];
                let ranges = parallel::split_ranges(rows, parallel::max_workers());
                if ranges.len() > 1 {
                    let orow = out_elems / rows;
                    let sub_dims: Vec<usize> = in_dims[1..].to_vec();
                    let sub_total: usize = sub_dims.iter().product();
                    let src_arc = input.data.clone();
                    let (in_dims_c, in_strides_c) = (in_dims.clone(), in_strides.clone());
                    let (kept_c, out_strides_c) = (kept.clone(), out_strides.clone());
                    let make = move |rrange: Range<usize>| -> Vec<f32> {
                        let src = src_arc.f32s().expect("dtype checked");
                        let mut wide = vec![init_w; rrange.len() * orow];
                        let mut idx = vec![0usize; in_dims_c.len()];
                        for (ri, r) in rrange.clone().enumerate() {
                            idx[0] = r;
                            for d in idx[1..].iter_mut() {
                                *d = 0;
                            }
                            let mut more = sub_total > 0;
                            while more {
                                let mut out_lin = ri * orow;
                                for (pos, &d) in kept_c.iter().enumerate().skip(1) {
                                    out_lin += idx[d] * out_strides_c[pos];
                                }
                                wide[out_lin] += src[linear_index(&idx, &in_strides_c)] as f64;
                                more = next_index(&mut idx[1..], &sub_dims);
                            }
                        }
                        wide.into_iter().map(|w| w as f32).collect()
                    };
                    let out = parallel::build_with_ranges(out_elems, ranges, make);
                    return Ok(RValue::T(RTensor::new(out_dims, Data::F32(out))));
                }
            }
            // serial widened walk (identical to eval.rs)
            let mut wide = vec![init_w; out_elems];
            let mut idx = vec![0usize; in_dims.len()];
            let mut more = total > 0;
            while more {
                let mut out_lin = 0usize;
                for (pos, &d) in kept.iter().enumerate() {
                    out_lin += idx[d] * out_strides[pos];
                }
                wide[out_lin] += src[linear_index(&idx, &in_strides)] as f64;
                more = next_index(&mut idx, &in_dims);
            }
            let out: Vec<f32> = wide.into_iter().map(|w| w as f32).collect();
            return Ok(RValue::T(RTensor::new(out_dims, Data::F32(out))));
        }
    }

    // generic fast combine, seeded from the init scalar
    let mut acc = init.data.splat(0, out_elems);
    let mut idx = vec![0usize; in_dims.len()];
    let mut more = total > 0;
    while more {
        let mut out_lin = 0usize;
        for (pos, &d) in kept.iter().enumerate() {
            out_lin += idx[d] * out_strides[pos];
        }
        fast_combine_elem(fc, &mut acc, out_lin, &input.data, linear_index(&idx, &in_strides))?;
        more = next_index(&mut idx, &in_dims);
    }
    Ok(RValue::T(RTensor::new(out_dims, acc)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    fn run_both(text: &str, args: &[Value]) -> (Value, Value) {
        let m = Arc::new(parse_module(text).unwrap());
        let naive = crate::eval::execute_module(&m, args).unwrap();
        let compiled = lower_module(&m).unwrap().execute(args.to_vec()).unwrap();
        (naive, compiled)
    }

    fn f32v(v: Vec<f32>) -> Value {
        let n = v.len();
        Value::T(Tensor::new(vec![n], Data::F32(v)).unwrap())
    }

    #[test]
    fn matches_naive_on_elementwise_chain() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[4]{0} parameter(0)\n  b.2 = f32[4]{0} parameter(1)\n  s.3 = f32[4]{0} add(a.1, b.2)\n  m.4 = f32[4]{0} multiply(s.3, a.1)\n  n.5 = f32[4]{0} negate(m.4)\n  ROOT d.6 = f32[4]{0} divide(n.5, b.2)\n}\n";
        let args = [f32v(vec![1.0, -2.5, 3.0, 0.25]), f32v(vec![2.0, 4.0, -1.0, 8.0])];
        let (naive, compiled) = run_both(text, &args);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn duplicate_operand_still_correct() {
        // add(x, x): both operand slots alias one register, so the
        // in-place path must observe a shared Arc and allocate
        let text = "HloModule m\n\nENTRY e.3 {\n  x.1 = f32[3]{0} parameter(0)\n  ROOT a.2 = f32[3]{0} add(x.1, x.1)\n}\n";
        let args = [f32v(vec![1.0, 2.0, 3.0])];
        let (naive, compiled) = run_both(text, &args);
        assert_eq!(naive, compiled);
        assert_eq!(compiled, f32v(vec![2.0, 4.0, 6.0]));
    }

    #[test]
    fn while_and_dus_match_naive() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[6]{0} parameter(0)\n  i.2 = s32[] parameter(1)\n  ds.3 = f32[2]{0} dynamic-slice(a.1, i.2), dynamic_slice_sizes={2}\n  two.4 = f32[] constant(10)\n  b.5 = f32[2]{0} broadcast(two.4), dimensions={}\n  sum.6 = f32[2]{0} add(ds.3, b.5)\n  ROOT dus.7 = f32[6]{0} dynamic-update-slice(a.1, sum.6, i.2)\n}\n";
        let a = f32v(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let i = Value::T(Tensor::new(vec![], Data::S32(vec![2])).unwrap());
        let (naive, compiled) = run_both(text, &[a, i]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn constants_parse_once_at_lowering() {
        let text = "HloModule m\n\nENTRY e.4 {\n  a.1 = f32[4]{0} parameter(0)\n  c.2 = f32[4]{0} constant({1, 2, 3, 4})\n  ROOT s.3 = f32[4]{0} add(a.1, c.2)\n}\n";
        let m = Arc::new(parse_module(text).unwrap());
        let compiled = lower_module(&m).unwrap();
        let after_lowering = crate::eval::constant_parse_count();
        let args = [f32v(vec![1.0; 4])];
        compiled.execute(args.to_vec()).unwrap();
        compiled.execute(args.to_vec()).unwrap();
        assert_eq!(
            crate::eval::constant_parse_count(),
            after_lowering,
            "steady-state executes must not re-parse constants"
        );
        // the naive lane re-parses on every run
        crate::eval::execute_module(&m, &args).unwrap();
        assert_eq!(crate::eval::constant_parse_count(), after_lowering + 1);
    }

    #[test]
    fn reduce_compare_select_match_naive() {
        let text = r#"
HloModule m

%sum.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %r.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %e.9 {
  %p.1 = f32[3,4]{1,0} parameter(0)
  %z.2 = f32[] constant(0.5)
  %red.3 = f32[3]{0} reduce(f32[3,4]{1,0} %p.1, f32[] %z.2), dimensions={1}, to_apply=%sum.1
  %zb.4 = f32[3]{0} broadcast(f32[] %z.2), dimensions={}
  %c.5 = pred[3]{0} compare(f32[3]{0} %red.3, f32[3]{0} %zb.4), direction=GT
  ROOT %s.6 = f32[3]{0} select(pred[3]{0} %c.5, f32[3]{0} %red.3, f32[3]{0} %zb.4)
}
"#;
        let p = Value::T(
            Tensor::new(
                vec![3, 4],
                Data::F32(vec![
                    0.1, 0.2, 0.3, 0.4, -1.0, -2.0, -3.0, -4.0, 10.0, 20.0, 30.0, 40.0,
                ]),
            )
            .unwrap(),
        );
        let (naive, compiled) = run_both(text, &[p]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn variadic_reduce_bridges_to_naive_core() {
        let text = r#"
HloModule m

%amax.1 (a.2: f32[], ai.3: s32[], b.4: f32[], bi.5: s32[]) -> (f32[], s32[]) {
  %a.2 = f32[] parameter(0)
  %ai.3 = s32[] parameter(1)
  %b.4 = f32[] parameter(2)
  %bi.5 = s32[] parameter(3)
  %ge.6 = pred[] compare(f32[] %a.2, f32[] %b.4), direction=GE
  %v.7 = f32[] select(pred[] %ge.6, f32[] %a.2, f32[] %b.4)
  %i.8 = s32[] select(pred[] %ge.6, s32[] %ai.3, s32[] %bi.5)
  ROOT %t.9 = (f32[], s32[]) tuple(f32[] %v.7, s32[] %i.8)
}

ENTRY %e.9 {
  %p.1 = f32[4]{0} parameter(0)
  %io.2 = s32[4]{0} iota(), iota_dimension=0
  %ninf.3 = f32[] constant(-inf)
  %zero.4 = s32[] constant(0)
  %r.5 = (f32[], s32[]) reduce(f32[4]{0} %p.1, s32[4]{0} %io.2, f32[] %ninf.3, s32[] %zero.4), dimensions={0}, to_apply=%amax.1
  ROOT %i.6 = s32[] get-tuple-element((f32[], s32[]) %r.5), index=1
}
"#;
        let (naive, compiled) = run_both(text, &[f32v(vec![3.0, 9.0, 1.0, 9.0])]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn gather_scatter_bridge_matches_naive() {
        let text = r#"
HloModule m

%add.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %r.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %e.9 {
  %o.1 = f32[3]{0} parameter(0)
  %i.2 = s32[4,1]{1,0} parameter(1)
  %u.3 = f32[4]{0} parameter(2)
  ROOT %s.4 = f32[3]{0} scatter(f32[3]{0} %o.1, s32[4,1]{1,0} %i.2, f32[4]{0} %u.3), update_window_dims={}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%add.1
}
"#;
        let o = f32v(vec![0.0, 0.0, 0.0]);
        let i = Value::T(Tensor::new(vec![4, 1], Data::S32(vec![0, 2, 0, 1])).unwrap());
        let u = f32v(vec![1.0, 2.0, 3.0, 4.0]);
        let (naive, compiled) = run_both(text, &[o, i, u]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn while_loop_matches_naive() {
        let text = r#"
HloModule m

%body.1 (s.2: (s32[], f32[4])) -> (s32[], f32[4]) {
  %s.2 = (s32[], f32[4]{0}) parameter(0)
  %i.3 = s32[] get-tuple-element((s32[], f32[4]{0}) %s.2), index=0
  %x.4 = f32[4]{0} get-tuple-element((s32[], f32[4]{0}) %s.2), index=1
  %one.5 = s32[] constant(1)
  %ip.6 = s32[] add(s32[] %i.3, s32[] %one.5)
  %half.7 = f32[] constant(2.5)
  %hb.8 = f32[4]{0} broadcast(f32[] %half.7), dimensions={}
  %xp.9 = f32[4]{0} add(f32[4]{0} %x.4, f32[4]{0} %hb.8)
  ROOT %t.10 = (s32[], f32[4]{0}) tuple(s32[] %ip.6, f32[4]{0} %xp.9)
}

%cond.11 (s.12: (s32[], f32[4])) -> pred[] {
  %s.12 = (s32[], f32[4]{0}) parameter(0)
  %i.13 = s32[] get-tuple-element((s32[], f32[4]{0}) %s.12), index=0
  %lim.14 = s32[] constant(4)
  ROOT %c.15 = pred[] compare(s32[] %i.13, s32[] %lim.14), direction=LT
}

ENTRY %main.20 {
  %z.15 = s32[] constant(0)
  %f.16 = f32[4]{0} constant({0, 1, 2, 3})
  %t.17 = (s32[], f32[4]{0}) tuple(s32[] %z.15, f32[4]{0} %f.16)
  %w.18 = (s32[], f32[4]{0}) while((s32[], f32[4]{0}) %t.17), condition=%cond.11, body=%body.1
  ROOT %r.19 = f32[4]{0} get-tuple-element((s32[], f32[4]{0}) %w.18), index=1
}
"#;
        let (naive, compiled) = run_both(text, &[]);
        assert_eq!(naive, compiled);
        assert_eq!(compiled, f32v(vec![10.0, 11.0, 12.0, 13.0]));
    }

    #[test]
    fn liveness_frees_dead_registers() {
        let text = "HloModule m\n\nENTRY e.4 {\n  a.1 = f32[2]{0} parameter(0)\n  n.2 = f32[2]{0} negate(a.1)\n  m.3 = f32[2]{0} multiply(n.2, n.2)\n  ROOT s.4 = f32[2]{0} add(m.3, a.1)\n}\n";
        let m = Arc::new(parse_module(text).unwrap());
        // fusion off: this pins the *unfused* schedule's liveness
        let cm = lower_module_with(&m, false).unwrap();
        let comp = &cm.comps[cm.entry];
        // every non-root register must die somewhere
        let freed: usize = comp.instrs.iter().map(|i| i.free_after.len()).sum();
        assert_eq!(freed, comp.instrs.len() - 1);
        // and execution still matches the naive lane
        let args = [f32v(vec![3.0, -4.0])];
        let naive = crate::eval::execute_module(&m, &args).unwrap();
        assert_eq!(cm.execute(args.to_vec()).unwrap(), naive);
    }

    #[test]
    fn shift_and_bit_semantics_match_naive() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = u32[6]{0} parameter(0)\n  s.2 = u32[6]{0} parameter(1)\n  sl.3 = u32[6]{0} shift-left(a.1, s.2)\n  sr.4 = u32[6]{0} shift-right-logical(a.1, s.2)\n  x.5 = u32[6]{0} xor(sl.3, sr.4)\n  an.6 = u32[6]{0} and(x.5, a.1)\n  ROOT o.7 = u32[6]{0} or(an.6, s.2)\n}\n";
        let a = Value::T(
            Tensor::new(vec![6], Data::U32(vec![0xFFFF_FFFF, 1, 0x8000_0000, 7, 0, 0xABCD])).unwrap(),
        );
        let s = Value::T(Tensor::new(vec![6], Data::U32(vec![0, 1, 31, 32, 40, 16])).unwrap());
        let (naive, compiled) = run_both(text, &[a, s]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn transpose_concat_slice_match_naive() {
        let text = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[2,3]{1,0} parameter(0)\n  t.2 = f32[3,2]{1,0} transpose(a.1), dimensions={1,0}\n  r.3 = f32[2,3]{1,0} reshape(t.2)\n  c.4 = f32[4,3]{1,0} concatenate(a.1, r.3), dimensions={0}\n  ROOT s.5 = f32[2,3]{1,0} slice(c.4), slice={[1:3], [0:3]}\n}\n";
        let a = Value::T(
            Tensor::new(vec![2, 3], Data::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])).unwrap(),
        );
        let (naive, compiled) = run_both(text, &[a]);
        assert_eq!(naive, compiled);
    }

    #[test]
    fn fast_reduce_widened_sum_matches_naive_bits() {
        let rows = 64usize;
        let cols = 37usize;
        let mut vals = Vec::with_capacity(rows * cols);
        let mut x = 0.1f32;
        for _ in 0..rows * cols {
            x = (x * 1.7).rem_euclid(3.1) - 1.3;
            vals.push(x);
        }
        let input = RTensor::new(vec![rows, cols], Data::F32(vals));
        let init = RTensor::new(vec![], Data::F32(vec![0.25]));
        let serial = exec_reduce_fast(&[1], FastCombine::Add, input.clone(), init)
            .unwrap()
            .into_value();
        let text = r#"
HloModule m

%sum.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %r.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %e.4 {
  %p.1 = f32[64,37]{1,0} parameter(0)
  %z.2 = f32[] constant(0.25)
  ROOT %red.3 = f32[64]{0} reduce(f32[64,37]{1,0} %p.1, f32[] %z.2), dimensions={1}, to_apply=%sum.1
}
"#;
        let m = Arc::new(parse_module(text).unwrap());
        let arg = RValue::T(input).into_value();
        let naive = crate::eval::execute_module(&m, std::slice::from_ref(&arg)).unwrap();
        assert_eq!(serial, naive);
    }

    const CHAIN: &str = "HloModule m\n\nENTRY e.9 {\n  a.1 = f32[4]{0} parameter(0)\n  b.2 = f32[4]{0} parameter(1)\n  s.3 = f32[4]{0} add(a.1, b.2)\n  m.4 = f32[4]{0} multiply(s.3, a.1)\n  n.5 = f32[4]{0} negate(m.4)\n  ROOT d.6 = f32[4]{0} divide(n.5, b.2)\n}\n";

    fn f32s(x: f32) -> Value {
        Value::T(Tensor::new(vec![], Data::F32(vec![x])).unwrap())
    }

    #[test]
    fn fusion_collapses_elementwise_chain() {
        let m = Arc::new(parse_module(CHAIN).unwrap());
        let fused = lower_module_with(&m, true).unwrap();
        let unfused = lower_module_with(&m, false).unwrap();
        // add -> multiply -> negate -> divide collapses to one dispatch
        assert_eq!(fused.fused_kernel_count(), 1);
        assert_eq!(fused.max_fused_constituents(), 4);
        assert!(fused.static_instruction_count() < unfused.static_instruction_count());
        assert_eq!(fused.static_constituent_count(), unfused.static_instruction_count());
        let args = [f32v(vec![1.0, -2.5, 3.0, 0.25]), f32v(vec![2.0, 4.0, -1.0, 8.0])];
        let naive = crate::eval::execute_module(&m, &args).unwrap();
        assert_eq!(fused.execute(args.to_vec()).unwrap(), naive);
        assert_eq!(unfused.execute(args.to_vec()).unwrap(), naive);
    }

    #[test]
    fn fused_counters_track_dispatches_and_constituents() {
        let m = Arc::new(parse_module(CHAIN).unwrap());
        let fused = lower_module_with(&m, true).unwrap();
        let unfused = lower_module_with(&m, false).unwrap();
        let args = [f32v(vec![1.0, -2.5, 3.0, 0.25]), f32v(vec![2.0, 4.0, -1.0, 8.0])];
        let (d0, f0) =
            (crate::eval::executed_instruction_count(), crate::eval::fused_instruction_count());
        let rf = fused.execute(args.to_vec()).unwrap();
        let (d1, f1) =
            (crate::eval::executed_instruction_count(), crate::eval::fused_instruction_count());
        // dispatches drop, constituent count is preserved exactly
        assert_eq!(d1 - d0, fused.static_instruction_count() as u64);
        assert_eq!(f1 - f0, fused.static_constituent_count() as u64);
        assert!(d1 - d0 < f1 - f0);
        let ru = unfused.execute(args.to_vec()).unwrap();
        let (d2, f2) =
            (crate::eval::executed_instruction_count(), crate::eval::fused_instruction_count());
        // the unfused lane dispatches one kernel per constituent
        assert_eq!(d2 - d1, unfused.static_instruction_count() as u64);
        assert_eq!(d2 - d1, f2 - f1);
        assert_eq!(f2 - f1, f1 - f0);
        assert_eq!(rf, ru);
    }

    #[test]
    fn fusion_keeps_multi_consumer_values_materialized() {
        // negate's output feeds three operand slots: it must stay a real
        // register, with only multiply -> add fusing above it
        let text = "HloModule m\n\nENTRY e.5 {\n  a.1 = f32[4]{0} parameter(0)\n  n.2 = f32[4]{0} negate(a.1)\n  m.3 = f32[4]{0} multiply(n.2, n.2)\n  ROOT s.4 = f32[4]{0} add(m.3, n.2)\n}\n";
        let m = Arc::new(parse_module(text).unwrap());
        let cm = lower_module_with(&m, true).unwrap();
        assert_eq!(cm.fused_kernel_count(), 1);
        assert_eq!(cm.max_fused_constituents(), 2);
        assert_eq!(cm.static_instruction_count(), 3); // param, negate, fused
        let args = [f32v(vec![1.5, -2.0, 0.0, 7.0])];
        let naive = crate::eval::execute_module(&m, &args).unwrap();
        assert_eq!(cm.execute(args.to_vec()).unwrap(), naive);
    }

    #[test]
    fn fusion_matches_naive_on_compare_select_broadcast() {
        // compare + select + absorbed scalar broadcast in one tape
        let text = "HloModule m\n\nENTRY e.8 {\n  x.1 = f32[5]{0} parameter(0)\n  y.2 = f32[5]{0} parameter(1)\n  z.3 = f32[] constant(0)\n  zb.4 = f32[5]{0} broadcast(z.3), dimensions={}\n  c.5 = pred[5]{0} compare(x.1, zb.4), direction=GT\n  s.6 = f32[5]{0} select(c.5, x.1, y.2)\n  ROOT a.7 = f32[5]{0} add(s.6, y.2)\n}\n";
        let m = Arc::new(parse_module(text).unwrap());
        let cm = lower_module_with(&m, true).unwrap();
        assert!(cm.fused_kernel_count() >= 1);
        assert!(cm.max_fused_constituents() >= 3);
        let args = [f32v(vec![1.0, -2.0, 0.0, 3.5, -0.5]), f32v(vec![9.0, 8.0, 7.0, 6.0, 5.0])];
        let naive = crate::eval::execute_module(&m, &args).unwrap();
        assert_eq!(cm.execute(args.to_vec()).unwrap(), naive);
    }

    #[test]
    fn scalar_specialization_guard_and_fold() {
        // multiply(broadcast(s), broadcast(t)) folds to a constant once
        // both scalars have been observed stable; changing one trips the
        // guard and must fall back without changing results
        let text = "HloModule m\n\nENTRY e.8 {\n  x.1 = f32[8]{0} parameter(0)\n  s.2 = f32[] parameter(1)\n  t.3 = f32[] parameter(2)\n  bs.4 = f32[8]{0} broadcast(s.2), dimensions={}\n  bt.5 = f32[8]{0} broadcast(t.3), dimensions={}\n  m.6 = f32[8]{0} multiply(bs.4, bt.5)\n  ROOT a.7 = f32[8]{0} add(x.1, m.6)\n}\n";
        let m = Arc::new(parse_module(text).unwrap());
        let cm = lower_module_with(&m, true).unwrap();
        assert_eq!(cm.fused_kernel_count(), 1);
        assert_eq!(cm.max_fused_constituents(), 4); // add, multiply, 2 broadcasts
        let x = f32v(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let run = |s: f32, t: f32| {
            let args = vec![x.clone(), f32s(s), f32s(t)];
            let naive = crate::eval::execute_module(&m, &args).unwrap();
            assert_eq!(cm.execute(args).unwrap(), naive, "s={s} t={t}");
        };
        run(2.0, 0.5); // run 1: records scalar bit patterns
        run(2.0, 0.5); // run 2: builds and uses the fold
        run(2.0, 0.5); // run 3: cached fold
        run(2.0, -3.0); // guard trips: t goes volatile, generic fallback
        run(2.0, -3.0); // fold rebuilt without t (nothing left to fold)
        run(9.0, 1.0); // s volatile too: fully generic from here on
    }

    #[test]
    fn fused_parallel_path_matches_unfused() {
        // past the parallel threshold the fused tape runs chunked across
        // the pool; results must stay bitwise-equal to the unfused lane
        let n = 70_000usize;
        let text = format!(
            "HloModule m\n\nENTRY e.9 {{\n  a.1 = f32[{n}]{{0}} parameter(0)\n  b.2 = f32[{n}]{{0}} parameter(1)\n  s.3 = f32[{n}]{{0}} add(a.1, b.2)\n  m.4 = f32[{n}]{{0}} multiply(s.3, a.1)\n  n.5 = f32[{n}]{{0}} negate(m.4)\n  ROOT d.6 = f32[{n}]{{0}} divide(n.5, b.2)\n}}\n"
        );
        let m = Arc::new(parse_module(&text).unwrap());
        let fused = lower_module_with(&m, true).unwrap();
        let unfused = lower_module_with(&m, false).unwrap();
        assert_eq!(fused.fused_kernel_count(), 1);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        let mut x = 0.3f32;
        for i in 0..n {
            x = (x * 1.9).rem_euclid(2.7) - 1.2;
            a.push(x);
            b.push(x + 0.5 + (i % 7) as f32);
        }
        let args = [f32v(a), f32v(b)];
        let rf = fused.execute(args.to_vec()).unwrap();
        let ru = unfused.execute(args.to_vec()).unwrap();
        assert_eq!(rf, ru);
    }
}
