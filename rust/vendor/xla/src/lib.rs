//! Offline in-tree stand-in for the `xla` crate (xla-rs 0.5.x API subset).
//!
//! The real crate binds `xla_extension` (PJRT + the XLA compiler).  This
//! shim keeps the exact API surface the `somd` crate uses but backs it
//! with a pure-Rust **HLO-text executor**: artifacts written by
//! `python -m compile.aot` are parsed (`hlo`) and, at
//! [`PjRtClient::compile`] time, lowered into a bytecode schedule with
//! register-indexed operands, hoisted constants, last-use liveness
//! (in-place buffer reuse) and threshold-gated SMP-parallel kernels
//! (`compile` + `parallel`); the original tree-walking evaluator
//! (`eval`) remains as the reference lane (`XLA_INTERP_LANE=naive`,
//! [`PjRtLoadedExecutable::execute_lane`]).  See `README.md` in this
//! crate for the pipeline and the buffer-reuse rules.  Numerical
//! semantics are logical row-major and bitwise-identical across lanes;
//! the device *cost* model lives upstream in `somd::device` and is
//! unaffected by this substitution.
//!
//! Thread-confinement is preserved: like real PJRT handles, the client,
//! executable, buffer and literal types are `!Send` (they embed a
//! `PhantomData<Rc<()>>`), so the coordinator's master-thread discipline
//! is enforced at compile time exactly as with the real binding.

mod compile;
mod eval;
mod hlo;
mod parallel;
mod value;

pub use parallel::{install_parallel_runner, ParallelJob, ParallelRunner};

/// Constant-literal text parses performed on the calling thread so far.
/// The compiled lane parses constants once at load time; the naive lane
/// re-parses per evaluation (regression surface for the lowering).
pub fn constant_parse_count() -> u64 {
    eval::constant_parse_count()
}

/// HLO instructions executed on the calling thread so far (both lanes;
/// `while` bodies count once per iteration).  Basis of the interp
/// bench's ops/s metric.
pub fn executed_instruction_count() -> u64 {
    eval::executed_instruction_count()
}

/// HLO instructions executed on the calling thread so far, counting a
/// fused kernel by its constituent instructions.  Equal to
/// [`executed_instruction_count`] when nothing fuses; the gap between
/// the two is the number of dispatches fusion eliminated.
pub fn fused_instruction_count() -> u64 {
    eval::fused_instruction_count()
}

/// Which interpreter lane executes a module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalLane {
    /// The original tree-walking evaluator (`eval.rs`).
    Naive,
    /// The lowered bytecode executor (`compile.rs`).
    Compiled,
}

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::marker::PhantomData;
use std::rc::Rc;
use std::sync::Arc;

use value::{Data, Tensor, Value};

/// Error type (mirrors `xla::Error` closely enough for `?` conversion).
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

type NotSend = PhantomData<Rc<()>>;

/// Element types of the artifact set (plus the common extras).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host element types the shim can move in and out of literals.
pub trait NativeType: Clone + 'static {
    const TY: ElementType;
    fn vec_to_data(v: Vec<Self>) -> Data;
    fn data_to_vec(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $ty:ident) => {
        impl NativeType for $t {
            const TY: ElementType = ElementType::$ty;
            fn vec_to_data(v: Vec<Self>) -> Data {
                Data::$ty(v)
            }
            fn data_to_vec(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$ty(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, S32);
native!(i64, S64);
native!(u32, U32);
native!(u64, U64);

// ---------------------------------------------------------------------------
// Shapes
// ---------------------------------------------------------------------------

/// Array-or-tuple shape of a literal.
#[derive(Debug, Clone, PartialEq)]
pub struct Shape {
    tuple: bool,
}

impl Shape {
    pub fn is_tuple(&self) -> bool {
        self.tuple
    }
}

/// The dims of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// Literals and buffers
// ---------------------------------------------------------------------------

/// A host-side value: an array or a tuple (multi-output roots).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    value: Value,
    _confined: NotSend,
}

impl Literal {
    fn from_value(value: Value) -> Literal {
        Literal { value, _confined: PhantomData }
    }

    /// A rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len();
        let t = Tensor::new(vec![n], T::vec_to_data(data.to_vec())).expect("vec1 shape");
        Literal::from_value(Value::T(t))
    }

    /// Reinterpret with new dims (row-major data unchanged).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let t = self.value.tensor()?;
        let new_dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let want: usize = new_dims.iter().product();
        if want != t.elems() {
            return Err(Error(format!(
                "reshape: {} elements into dims {:?}",
                t.elems(),
                dims
            )));
        }
        Ok(Literal::from_value(Value::T(Tensor::new(new_dims, t.data.clone())?)))
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { tuple: matches!(self.value, Value::Tuple(_)) })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let t = self.value.tensor()?;
        Ok(ArrayShape { dims: t.dims.iter().map(|&d| d as i64).collect() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.value.tensor()?.dtype())
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        let t = self.value.tensor()?;
        T::data_to_vec(&t.data).ok_or_else(|| {
            Error(format!("literal is {:?}, not {:?}", t.dtype(), T::TY))
        })
    }

    /// Split a tuple literal into its leaves (leaves the tuple empty).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.value, Value::Tuple(Vec::new())) {
            Value::Tuple(parts) => Ok(parts.into_iter().map(Literal::from_value).collect()),
            v @ Value::T(_) => {
                self.value = v;
                Err(Error("decompose_tuple on a non-tuple literal".into()))
            }
        }
    }
}

/// A "device"-resident buffer (host memory here; the residency/transfer
/// cost model lives in `somd::device`).
pub struct PjRtBuffer {
    value: Value,
    _confined: NotSend,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal::from_value(self.value.clone()))
    }
}

// ---------------------------------------------------------------------------
// HLO module handles
// ---------------------------------------------------------------------------

/// A parsed HLO module (the artifact interchange object).
pub struct HloModuleProto {
    module: Arc<hlo::HloModule>,
}

impl HloModuleProto {
    /// Parse HLO *text* from a file (the `.hlo.txt` artifacts).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { module: Arc::new(hlo::parse_module(&text)?) })
    }

    /// Parse HLO text directly (tests / tools).
    pub fn parse_text(text: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto { module: Arc::new(hlo::parse_module(text)?) })
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    module: Arc<hlo::HloModule>,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.module.clone() }
    }
}

// ---------------------------------------------------------------------------
// Client and executable
// ---------------------------------------------------------------------------

/// The CPU "PJRT" client.
pub struct PjRtClient {
    _confined: NotSend,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _confined: PhantomData })
    }

    pub fn platform_name(&self) -> String {
        "interpreter-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// Compile: validate the entry computation and lower the module into
    /// its bytecode form (opcodes resolved, operands register-indexed,
    /// constants/iotas materialized, schedule + liveness computed).  A
    /// module the lowering cannot handle falls back to the naive
    /// tree-walker, which reports the unsupported construct at runtime.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        comp.module.entry_computation()?;
        let compiled = compile::lower_module(&comp.module).ok().map(Arc::new);
        Ok(PjRtLoadedExecutable {
            module: comp.module.clone(),
            compiled,
            _confined: PhantomData,
        })
    }

    /// Like [`Self::compile`] but with elementwise fusion forced on or
    /// off, ignoring `XLA_FUSE`.  The programmatic path for in-process
    /// fused-vs-unfused comparisons (env mutation would race threads).
    pub fn compile_with_fusion(
        &self,
        comp: &XlaComputation,
        fuse: bool,
    ) -> Result<PjRtLoadedExecutable> {
        comp.module.entry_computation()?;
        let compiled = compile::lower_module_with(&comp.module, fuse).ok().map(Arc::new);
        Ok(PjRtLoadedExecutable {
            module: comp.module.clone(),
            compiled,
            _confined: PhantomData,
        })
    }

    /// Upload a host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error(format!(
                "buffer_from_host_buffer: {} elements for dims {:?}",
                data.len(),
                dims
            )));
        }
        let t = Tensor::new(dims.to_vec(), T::vec_to_data(data.to_vec()))?;
        Ok(PjRtBuffer { value: Value::T(t), _confined: PhantomData })
    }
}

/// A loaded executable: the parsed module, its lowered bytecode form, and
/// the interpreter entry.
pub struct PjRtLoadedExecutable {
    module: Arc<hlo::HloModule>,
    compiled: Option<Arc<compile::CompiledModule>>,
    _confined: NotSend,
}

impl PjRtLoadedExecutable {
    /// The lane [`PjRtLoadedExecutable::execute`] will use: the compiled
    /// bytecode when available, unless `XLA_INTERP_LANE=naive` forces the
    /// tree-walker (the differential-equivalence escape hatch).  The env
    /// override is read once per process — `execute` is the per-launch
    /// hot path (use [`PjRtLoadedExecutable::execute_lane`] to pick a
    /// lane programmatically).
    pub fn default_lane(&self) -> EvalLane {
        static FORCED_NAIVE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        let forced = *FORCED_NAIVE.get_or_init(|| {
            std::env::var("XLA_INTERP_LANE").map(|v| v == "naive").unwrap_or(false)
        });
        if forced || self.compiled.is_none() {
            EvalLane::Naive
        } else {
            EvalLane::Compiled
        }
    }

    /// Whether the module lowered successfully at load time.
    pub fn has_compiled_form(&self) -> bool {
        self.compiled.is_some()
    }

    /// Total lowered instructions across all computations, if compiled.
    /// Under fusion this counts *dispatches* — a fused chain is one; see
    /// [`Self::compiled_constituent_count`] for the pre-fusion count.
    pub fn compiled_instruction_count(&self) -> Option<usize> {
        self.compiled.as_ref().map(|c| c.static_instruction_count())
    }

    /// Total constituent instructions (fused chains counted by their
    /// members), if compiled.  Equals `compiled_instruction_count` of
    /// the unfused schedule of the same module.
    pub fn compiled_constituent_count(&self) -> Option<usize> {
        self.compiled.as_ref().map(|c| c.static_constituent_count())
    }

    /// Number of fused dispatch sites in the schedule, if compiled.
    pub fn fused_kernel_count(&self) -> Option<usize> {
        self.compiled.as_ref().map(|c| c.fused_kernel_count())
    }

    /// Largest fused chain's constituent count, if compiled (0 when
    /// nothing fused).
    pub fn max_fused_constituents(&self) -> Option<u64> {
        self.compiled.as_ref().map(|c| c.max_fused_constituents())
    }

    fn run_lane(&self, args: Vec<Value>, lane: EvalLane) -> Result<Vec<Vec<PjRtBuffer>>> {
        let out = match lane {
            EvalLane::Naive => eval::execute_module(&self.module, &args)?,
            EvalLane::Compiled => self
                .compiled
                .as_ref()
                .ok_or_else(|| Error("module has no compiled form".into()))?
                .execute(args)?,
        };
        // one buffer per root value; tuple roots stay one tuple buffer
        // (callers flatten via decompose_tuple, matching real PJRT with
        // untupled outputs)
        Ok(vec![vec![PjRtBuffer { value: out, _confined: PhantomData }]])
    }

    fn run(&self, args: Vec<Value>) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run_lane(args, self.default_lane())
    }

    /// Execute over host literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run(args.iter().map(|l| l.borrow().value.clone()).collect())
    }

    /// Execute over host literals on an explicit lane (equivalence suite
    /// and interp bench entry; `Compiled` errors if lowering failed).
    pub fn execute_lane<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
        lane: EvalLane,
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run_lane(args.iter().map(|l| l.borrow().value.clone()).collect(), lane)
    }

    /// Execute over device-resident buffers.
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run(args.iter().map(|b| b.borrow().value.clone()).collect())
    }
}

// ---------------------------------------------------------------------------
// Artifact cache (interned parsed modules, keyed by path)
// ---------------------------------------------------------------------------

thread_local! {
    static MODULE_CACHE: RefCell<HashMap<String, Arc<hlo::HloModule>>> =
        RefCell::new(HashMap::new());
}

impl HloModuleProto {
    /// Like [`HloModuleProto::from_text_file`], but re-reads of the same
    /// path on the same thread share one parsed module.
    pub fn from_text_file_cached(path: &str) -> Result<HloModuleProto> {
        if let Some(m) = MODULE_CACHE.with(|c| c.borrow().get(path).cloned()) {
            return Ok(HloModuleProto { module: m });
        }
        let proto = Self::from_text_file(path)?;
        MODULE_CACHE.with(|c| {
            c.borrow_mut().insert(path.to_string(), proto.module.clone());
        });
        Ok(proto)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD: &str = "HloModule m\n\nENTRY e.3 {\n  a.1 = f32[4]{0} parameter(0)\n  b.2 = f32[4]{0} parameter(1)\n  ROOT add.3 = f32[4]{0} add(a.1, b.2)\n}\n";

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(m.ty().unwrap(), ElementType::F32);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(!m.shape().unwrap().is_tuple());
        assert!(m.to_vec::<u32>().is_err());
    }

    #[test]
    fn compile_and_execute_literals() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let b = Literal::vec1(&[10.0f32, 20.0, 30.0, 40.0]);
        let rows = exe.execute::<Literal>(&[a, b]).unwrap();
        let lit = rows[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn execute_with_buffers() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let x = client.buffer_from_host_buffer(&[2.0f32; 4], &[4], None).unwrap();
        let y = client.buffer_from_host_buffer(&[3.0f32; 4], &[4], None).unwrap();
        let rows = exe.execute_b::<&PjRtBuffer>(&[&x, &y]).unwrap();
        let lit = rows[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![5.0; 4]);
    }

    #[test]
    fn tuple_roots_decompose() {
        let text = "HloModule m\n\nENTRY e.3 {\n  a.1 = f32[2]{0} parameter(0)\n  n.2 = f32[2]{0} negate(a.1)\n  ROOT t.3 = (f32[2]{0}, f32[2]{0}) tuple(a.1, n.2)\n}\n";
        let client = PjRtClient::cpu().unwrap();
        let exe = client
            .compile(&XlaComputation::from_proto(&HloModuleProto::parse_text(text).unwrap()))
            .unwrap();
        let rows = exe.execute::<Literal>(&[Literal::vec1(&[1.0f32, -2.0])]).unwrap();
        let mut lit = rows[0][0].to_literal_sync().unwrap();
        assert!(lit.shape().unwrap().is_tuple());
        let leaves = lit.decompose_tuple().unwrap();
        assert_eq!(leaves.len(), 2);
        assert_eq!(leaves[1].to_vec::<f32>().unwrap(), vec![-1.0, 2.0]);
    }

    #[test]
    fn platform_reports_cpu() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().to_lowercase().contains("cpu"));
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn both_lanes_agree_on_literals() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&proto)).unwrap();
        assert!(exe.has_compiled_form());
        assert!(exe.compiled_instruction_count().unwrap() >= 3);
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let b = Literal::vec1(&[10.0f32, 20.0, 30.0, 40.0]);
        let naive = exe.execute_lane(&[&a, &b], EvalLane::Naive).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let compiled = exe.execute_lane(&[&a, &b], EvalLane::Compiled).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(naive, compiled);
        assert_eq!(compiled.to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn default_lane_is_compiled_when_lowered() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&proto)).unwrap();
        // not asserting the env (tests run in one process); the default
        // must simply be consistent with the compiled form's presence
        match exe.default_lane() {
            EvalLane::Compiled => assert!(exe.has_compiled_form()),
            EvalLane::Naive => { /* forced via XLA_INTERP_LANE */ }
        }
    }
}
