//! Offline in-tree stand-in for the `xla` crate (xla-rs 0.5.x API subset).
//!
//! The real crate binds `xla_extension` (PJRT + the XLA compiler).  This
//! shim keeps the exact API surface the `somd` crate uses but backs it
//! with a pure-Rust **HLO-text interpreter** ([`hlo`] + [`eval`]): the
//! AOT artifacts written by `python -m compile.aot` are parsed and
//! executed on the host CPU.  Numerical semantics are logical row-major;
//! the device *cost* model lives upstream in `somd::device` and is
//! unaffected by this substitution.
//!
//! Thread-confinement is preserved: like real PJRT handles, the client,
//! executable, buffer and literal types are `!Send` (they embed a
//! `PhantomData<Rc<()>>`), so the coordinator's master-thread discipline
//! is enforced at compile time exactly as with the real binding.

mod eval;
mod hlo;
mod value;

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::marker::PhantomData;
use std::rc::Rc;
use std::sync::Arc;

use value::{Data, Tensor, Value};

/// Error type (mirrors `xla::Error` closely enough for `?` conversion).
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

type NotSend = PhantomData<Rc<()>>;

/// Element types of the artifact set (plus the common extras).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host element types the shim can move in and out of literals.
pub trait NativeType: Clone + 'static {
    const TY: ElementType;
    fn vec_to_data(v: Vec<Self>) -> Data;
    fn data_to_vec(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $ty:ident) => {
        impl NativeType for $t {
            const TY: ElementType = ElementType::$ty;
            fn vec_to_data(v: Vec<Self>) -> Data {
                Data::$ty(v)
            }
            fn data_to_vec(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$ty(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, S32);
native!(i64, S64);
native!(u32, U32);
native!(u64, U64);

// ---------------------------------------------------------------------------
// Shapes
// ---------------------------------------------------------------------------

/// Array-or-tuple shape of a literal.
#[derive(Debug, Clone, PartialEq)]
pub struct Shape {
    tuple: bool,
}

impl Shape {
    pub fn is_tuple(&self) -> bool {
        self.tuple
    }
}

/// The dims of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// Literals and buffers
// ---------------------------------------------------------------------------

/// A host-side value: an array or a tuple (multi-output roots).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    value: Value,
    _confined: NotSend,
}

impl Literal {
    fn from_value(value: Value) -> Literal {
        Literal { value, _confined: PhantomData }
    }

    /// A rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len();
        let t = Tensor::new(vec![n], T::vec_to_data(data.to_vec())).expect("vec1 shape");
        Literal::from_value(Value::T(t))
    }

    /// Reinterpret with new dims (row-major data unchanged).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let t = self.value.tensor()?;
        let new_dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let want: usize = new_dims.iter().product();
        if want != t.elems() {
            return Err(Error(format!(
                "reshape: {} elements into dims {:?}",
                t.elems(),
                dims
            )));
        }
        Ok(Literal::from_value(Value::T(Tensor::new(new_dims, t.data.clone())?)))
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { tuple: matches!(self.value, Value::Tuple(_)) })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let t = self.value.tensor()?;
        Ok(ArrayShape { dims: t.dims.iter().map(|&d| d as i64).collect() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.value.tensor()?.dtype())
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        let t = self.value.tensor()?;
        T::data_to_vec(&t.data).ok_or_else(|| {
            Error(format!("literal is {:?}, not {:?}", t.dtype(), T::TY))
        })
    }

    /// Split a tuple literal into its leaves (leaves the tuple empty).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.value, Value::Tuple(Vec::new())) {
            Value::Tuple(parts) => Ok(parts.into_iter().map(Literal::from_value).collect()),
            v @ Value::T(_) => {
                self.value = v;
                Err(Error("decompose_tuple on a non-tuple literal".into()))
            }
        }
    }
}

/// A "device"-resident buffer (host memory here; the residency/transfer
/// cost model lives in `somd::device`).
pub struct PjRtBuffer {
    value: Value,
    _confined: NotSend,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal::from_value(self.value.clone()))
    }
}

// ---------------------------------------------------------------------------
// HLO module handles
// ---------------------------------------------------------------------------

/// A parsed HLO module (the artifact interchange object).
pub struct HloModuleProto {
    module: Arc<hlo::HloModule>,
}

impl HloModuleProto {
    /// Parse HLO *text* from a file (the `.hlo.txt` artifacts).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { module: Arc::new(hlo::parse_module(&text)?) })
    }

    /// Parse HLO text directly (tests / tools).
    pub fn parse_text(text: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto { module: Arc::new(hlo::parse_module(text)?) })
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    module: Arc<hlo::HloModule>,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.module.clone() }
    }
}

// ---------------------------------------------------------------------------
// Client and executable
// ---------------------------------------------------------------------------

/// The CPU "PJRT" client.
pub struct PjRtClient {
    _confined: NotSend,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _confined: PhantomData })
    }

    pub fn platform_name(&self) -> String {
        "interpreter-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// "Compile": validate the entry computation exists and wrap the
    /// module for execution.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        comp.module.entry_computation()?;
        Ok(PjRtLoadedExecutable { module: comp.module.clone(), _confined: PhantomData })
    }

    /// Upload a host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error(format!(
                "buffer_from_host_buffer: {} elements for dims {:?}",
                data.len(),
                dims
            )));
        }
        let t = Tensor::new(dims.to_vec(), T::vec_to_data(data.to_vec()))?;
        Ok(PjRtBuffer { value: Value::T(t), _confined: PhantomData })
    }
}

/// A loaded executable: the parsed module plus the interpreter entry.
pub struct PjRtLoadedExecutable {
    module: Arc<hlo::HloModule>,
    _confined: NotSend,
}

impl PjRtLoadedExecutable {
    fn run(&self, args: Vec<Value>) -> Result<Vec<Vec<PjRtBuffer>>> {
        let out = eval::execute_module(&self.module, &args)?;
        // one buffer per root value; tuple roots stay one tuple buffer
        // (callers flatten via decompose_tuple, matching real PJRT with
        // untupled outputs)
        Ok(vec![vec![PjRtBuffer { value: out, _confined: PhantomData }]])
    }

    /// Execute over host literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run(args.iter().map(|l| l.borrow().value.clone()).collect())
    }

    /// Execute over device-resident buffers.
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        self.run(args.iter().map(|b| b.borrow().value.clone()).collect())
    }
}

// ---------------------------------------------------------------------------
// Artifact cache (interned parsed modules, keyed by path)
// ---------------------------------------------------------------------------

thread_local! {
    static MODULE_CACHE: RefCell<HashMap<String, Arc<hlo::HloModule>>> =
        RefCell::new(HashMap::new());
}

impl HloModuleProto {
    /// Like [`HloModuleProto::from_text_file`], but re-reads of the same
    /// path on the same thread share one parsed module.
    pub fn from_text_file_cached(path: &str) -> Result<HloModuleProto> {
        if let Some(m) = MODULE_CACHE.with(|c| c.borrow().get(path).cloned()) {
            return Ok(HloModuleProto { module: m });
        }
        let proto = Self::from_text_file(path)?;
        MODULE_CACHE.with(|c| {
            c.borrow_mut().insert(path.to_string(), proto.module.clone());
        });
        Ok(proto)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD: &str = "HloModule m\n\nENTRY e.3 {\n  a.1 = f32[4]{0} parameter(0)\n  b.2 = f32[4]{0} parameter(1)\n  ROOT add.3 = f32[4]{0} add(a.1, b.2)\n}\n";

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(m.ty().unwrap(), ElementType::F32);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(!m.shape().unwrap().is_tuple());
        assert!(m.to_vec::<u32>().is_err());
    }

    #[test]
    fn compile_and_execute_literals() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let a = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let b = Literal::vec1(&[10.0f32, 20.0, 30.0, 40.0]);
        let rows = exe.execute::<Literal>(&[a, b]).unwrap();
        let lit = rows[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn execute_with_buffers() {
        let proto = HloModuleProto::parse_text(ADD).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let x = client.buffer_from_host_buffer(&[2.0f32; 4], &[4], None).unwrap();
        let y = client.buffer_from_host_buffer(&[3.0f32; 4], &[4], None).unwrap();
        let rows = exe.execute_b::<&PjRtBuffer>(&[&x, &y]).unwrap();
        let lit = rows[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![5.0; 4]);
    }

    #[test]
    fn tuple_roots_decompose() {
        let text = "HloModule m\n\nENTRY e.3 {\n  a.1 = f32[2]{0} parameter(0)\n  n.2 = f32[2]{0} negate(a.1)\n  ROOT t.3 = (f32[2]{0}, f32[2]{0}) tuple(a.1, n.2)\n}\n";
        let client = PjRtClient::cpu().unwrap();
        let exe = client
            .compile(&XlaComputation::from_proto(&HloModuleProto::parse_text(text).unwrap()))
            .unwrap();
        let rows = exe.execute::<Literal>(&[Literal::vec1(&[1.0f32, -2.0])]).unwrap();
        let mut lit = rows[0][0].to_literal_sync().unwrap();
        assert!(lit.shape().unwrap().is_tuple());
        let leaves = lit.decompose_tuple().unwrap();
        assert_eq!(leaves.len(), 2);
        assert_eq!(leaves[1].to_vec::<f32>().unwrap(), vec![-1.0, 2.0]);
    }

    #[test]
    fn platform_reports_cpu() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().to_lowercase().contains("cpu"));
        assert_eq!(c.device_count(), 1);
    }
}
