//! Parser for XLA HLO *text* modules (the `.hlo.txt` artifact format
//! written by `python -m compile.aot`).
//!
//! Accepts both printer styles XLA emits: the compact default
//! (`add.3 = f32[8]{0} add(Arg_0.1, Arg_1.2)`) and the verbose one with
//! `%`-prefixed names and typed operands
//! (`%add.3 = f32[8]{0} add(f32[8]{0} %Arg_0.1, ...)`).  Layout suffixes
//! (`{1,0}`) are parsed and ignored — interpretation is logical/row-major.

use std::collections::HashMap;

use crate::{ElementType, Error, Result};

/// An array or tuple shape as written in HLO text.
#[derive(Clone, Debug, PartialEq)]
pub enum ShapeTy {
    Array { ty: ElementType, dims: Vec<usize> },
    Tuple(Vec<ShapeTy>),
}

impl ShapeTy {
    pub fn expect_array(&self) -> Result<(ElementType, &[usize])> {
        match self {
            ShapeTy::Array { ty, dims } => Ok((*ty, dims)),
            ShapeTy::Tuple(_) => Err(Error("expected array shape, got tuple".into())),
        }
    }
}

/// One parsed instruction.
#[derive(Clone, Debug)]
pub struct Instr {
    pub name: String,
    pub shape: ShapeTy,
    pub op: String,
    pub operands: Vec<String>,
    pub attrs: HashMap<String, String>,
    /// Raw text between the parens for `constant(...)`.
    pub const_text: Option<String>,
    pub is_root: bool,
}

impl Instr {
    pub fn attr(&self, key: &str) -> Result<&str> {
        self.attrs
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| Error(format!("instruction '{}' missing attr '{key}'", self.name)))
    }

    /// Parse a `{1,2,3}`-style attr into numbers; missing attr -> empty.
    pub fn attr_dims(&self, key: &str) -> Result<Vec<i64>> {
        match self.attrs.get(key) {
            None => Ok(Vec::new()),
            Some(v) => parse_brace_list(v),
        }
    }

    pub fn attr_i64(&self, key: &str) -> Result<i64> {
        self.attr(key)?
            .trim()
            .parse::<i64>()
            .map_err(|_| Error(format!("bad integer attr '{key}' on '{}'", self.name)))
    }

    /// The computation name in a `to_apply=`/`condition=`/`body=` attr.
    pub fn attr_computation(&self, key: &str) -> Result<String> {
        Ok(self.attr(key)?.trim().trim_start_matches('%').to_string())
    }
}

/// A named computation: instruction list in printed order.
#[derive(Clone, Debug)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub index: HashMap<String, usize>,
    pub root: usize,
}

/// A parsed HLO module.
#[derive(Clone, Debug)]
pub struct HloModule {
    pub name: String,
    pub computations: HashMap<String, Computation>,
    pub entry: String,
}

impl HloModule {
    pub fn entry_computation(&self) -> Result<&Computation> {
        self.computations
            .get(&self.entry)
            .ok_or_else(|| Error(format!("entry computation '{}' missing", self.entry)))
    }

    pub fn computation(&self, name: &str) -> Result<&Computation> {
        self.computations
            .get(name)
            .ok_or_else(|| Error(format!("computation '{name}' missing")))
    }
}

/// Remove `/* ... */` spans: XLA annotates wide tuple shapes with
/// `/*index=N*/` comments, which would otherwise confuse both the shape
/// parser and the computation-header detection (they contain `=`).
fn strip_block_comments(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut rest = line;
    while let Some(open) = rest.find("/*") {
        out.push_str(&rest[..open]);
        match rest[open..].find("*/") {
            Some(close) => rest = &rest[open + close + 2..],
            None => return out, // unterminated: drop the remainder
        }
    }
    out.push_str(rest);
    out
}

/// Parse `{a,b,c}` (or bare `a,b,c`) into i64s; empty braces -> empty.
pub fn parse_brace_list(s: &str) -> Result<Vec<i64>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}').trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<i64>()
                .map_err(|_| Error(format!("bad number '{}' in list '{s}'", t.trim())))
        })
        .collect()
}

fn parse_element_type(tok: &str) -> Result<ElementType> {
    Ok(match tok {
        "pred" => ElementType::Pred,
        "s8" => ElementType::S8,
        "s16" => ElementType::S16,
        "s32" => ElementType::S32,
        "s64" => ElementType::S64,
        "u8" => ElementType::U8,
        "u16" => ElementType::U16,
        "u32" => ElementType::U32,
        "u64" => ElementType::U64,
        "f16" => ElementType::F16,
        "bf16" => ElementType::Bf16,
        "f32" => ElementType::F32,
        "f64" => ElementType::F64,
        other => return Err(Error(format!("unknown element type '{other}'"))),
    })
}

/// Cursor-based shape parser: `f32[64,64]{1,0}`, `pred[]`, `(s32[], f32[8]{0})`.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(s: &'a str) -> Self {
        Cur { b: s.as_bytes(), i: 0 }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error(format!(
                "shape parse: expected '{}' at byte {} of '{}'",
                c as char,
                self.i,
                String::from_utf8_lossy(self.b)
            )))
        }
    }

    fn ident(&mut self) -> String {
        let start = self.i;
        while self.i < self.b.len()
            && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
        {
            self.i += 1;
        }
        String::from_utf8_lossy(&self.b[start..self.i]).to_string()
    }

    fn number(&mut self) -> Result<usize> {
        let start = self.i;
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        String::from_utf8_lossy(&self.b[start..self.i])
            .parse()
            .map_err(|_| Error("shape parse: expected number".into()))
    }

    fn shape(&mut self) -> Result<ShapeTy> {
        self.ws();
        if self.peek() == Some(b'(') {
            self.i += 1;
            let mut parts = Vec::new();
            self.ws();
            if self.peek() == Some(b')') {
                self.i += 1;
                return Ok(ShapeTy::Tuple(parts));
            }
            loop {
                parts.push(self.shape()?);
                self.ws();
                match self.peek() {
                    Some(b',') => {
                        self.i += 1;
                    }
                    Some(b')') => {
                        self.i += 1;
                        return Ok(ShapeTy::Tuple(parts));
                    }
                    _ => return Err(Error("shape parse: expected ',' or ')' in tuple".into())),
                }
            }
        }
        let ty = parse_element_type(&self.ident())?;
        self.eat(b'[')?;
        let mut dims = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
        } else {
            loop {
                self.ws();
                dims.push(self.number()?);
                self.ws();
                match self.peek() {
                    Some(b',') => {
                        self.i += 1;
                    }
                    Some(b']') => {
                        self.i += 1;
                        break;
                    }
                    _ => return Err(Error("shape parse: expected ',' or ']' in dims".into())),
                }
            }
        }
        // optional layout suffix {1,0} — parsed and discarded
        if self.peek() == Some(b'{') {
            while self.peek().is_some() && self.peek() != Some(b'}') {
                self.i += 1;
            }
            self.eat(b'}')?;
        }
        Ok(ShapeTy::Array { ty, dims })
    }
}

/// Parse a shape from the front of `s`; returns the shape and the number
/// of bytes consumed.
fn parse_shape_prefix(s: &str) -> Result<(ShapeTy, usize)> {
    let mut c = Cur::new(s);
    let sh = c.shape()?;
    Ok((sh, c.i))
}

/// Split `s` on top-level `,` (ignoring commas inside (), [], {}).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '(' | '[' | '{' => {
                depth += 1;
                cur.push(ch);
            }
            ')' | ']' | '}' => {
                depth -= 1;
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur = String::new();
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Find the span of the operand list: the parens directly after the
/// opcode, balancing nested parens (tuple-typed operands contain parens).
fn operand_span(rest: &str) -> Result<(usize, usize)> {
    let open = rest
        .find('(')
        .ok_or_else(|| Error(format!("no '(' in instruction tail '{rest}'")))?;
    let mut depth = 0i32;
    for (i, ch) in rest.char_indices().skip(open) {
        match ch {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((open, i));
                }
            }
            _ => {}
        }
    }
    Err(Error(format!("unbalanced parens in '{rest}'")))
}

/// The operand name from one entry like `f32[8]{0} %add.3` or `add.3`.
fn operand_name(entry: &str) -> String {
    let tok = entry.rsplit(|c: char| c.is_ascii_whitespace()).next().unwrap_or(entry);
    tok.trim_start_matches('%').to_string()
}

fn parse_instruction(line: &str) -> Result<Instr> {
    let line = line.trim();
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let eq = line
        .find(" = ")
        .ok_or_else(|| Error(format!("instruction without '=': '{line}'")))?;
    let name = line[..eq].trim().trim_start_matches('%').to_string();
    let rest = &line[eq + 3..];
    let (shape, used) = parse_shape_prefix(rest)?;
    let rest = rest[used..].trim_start();
    // opcode runs up to the '('
    let paren = rest
        .find('(')
        .ok_or_else(|| Error(format!("instruction '{name}' without operand list")))?;
    let op = rest[..paren].trim().to_string();
    let (o_lo, o_hi) = operand_span(rest)?;
    let inside = &rest[o_lo + 1..o_hi];
    let tail = rest[o_hi + 1..].trim_start();

    let mut const_text = None;
    let mut operands = Vec::new();
    if op == "constant" {
        const_text = Some(inside.trim().to_string());
    } else {
        for entry in split_top_level(inside) {
            if entry.is_empty() {
                continue;
            }
            operands.push(operand_name(&entry));
        }
    }

    // attributes: `, key=value` pairs after the operand list
    let mut attrs = HashMap::new();
    let tail = tail.strip_prefix(',').unwrap_or(tail);
    for part in split_top_level(tail) {
        if part.is_empty() {
            continue;
        }
        match part.split_once('=') {
            Some((k, v)) => {
                attrs.insert(k.trim().to_string(), v.trim().to_string());
            }
            None => {
                // bare flags (none expected today) — keep as key=true
                attrs.insert(part.trim().to_string(), "true".to_string());
            }
        }
    }

    Ok(Instr { name, shape, op, operands, attrs, const_text, is_root })
}

/// Computation header: `%name (params) -> type {` / `ENTRY %main.1 {` etc.
/// Returns (name, is_entry).
fn parse_computation_header(line: &str) -> Result<(String, bool)> {
    let line = line.trim().trim_end_matches('{').trim();
    let (is_entry, rest) = match line.strip_prefix("ENTRY ") {
        Some(r) => (true, r.trim()),
        None => (false, line),
    };
    let name_end = rest.find(|c: char| c == ' ' || c == '(').unwrap_or(rest.len());
    let name = rest[..name_end].trim_start_matches('%').to_string();
    if name.is_empty() {
        return Err(Error(format!("bad computation header '{line}'")));
    }
    Ok((name, is_entry))
}

/// Parse a full HLO text module.
pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut module_name = String::from("module");
    let mut computations = HashMap::new();
    let mut entry: Option<String> = None;
    let mut cur: Option<(String, bool, Vec<Instr>)> = None;

    for raw in text.lines() {
        let cleaned = if raw.contains("/*") { strip_block_comments(raw) } else { raw.to_string() };
        let line = cleaned.trim();
        if line.is_empty() || line.starts_with("//") || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule ") {
            module_name = rest.split([',', ' ']).next().unwrap_or("module").to_string();
            continue;
        }
        if line == "}" {
            let (name, is_entry, instrs) =
                cur.take().ok_or_else(|| Error("stray '}' outside computation".into()))?;
            let mut index = HashMap::new();
            let mut root = instrs.len().saturating_sub(1);
            for (i, ins) in instrs.iter().enumerate() {
                index.insert(ins.name.clone(), i);
                if ins.is_root {
                    root = i;
                }
            }
            if instrs.is_empty() {
                return Err(Error(format!("computation '{name}' has no instructions")));
            }
            if is_entry {
                entry = Some(name.clone());
            }
            computations.insert(name.clone(), Computation { name, instrs, index, root });
            continue;
        }
        if line.ends_with('{') && !line.contains('=') {
            if cur.is_some() {
                return Err(Error(format!("nested computation at '{line}'")));
            }
            let (name, is_entry) = parse_computation_header(line)?;
            cur = Some((name, is_entry, Vec::new()));
            continue;
        }
        match cur.as_mut() {
            Some((_, _, instrs)) => instrs.push(parse_instruction(line)?),
            None => return Err(Error(format!("instruction outside computation: '{line}'"))),
        }
    }

    let entry = entry
        .or_else(|| {
            // single-computation module without ENTRY marker
            if computations.len() == 1 {
                computations.keys().next().cloned()
            } else {
                None
            }
        })
        .ok_or_else(|| Error("module has no ENTRY computation".into()))?;
    Ok(HloModule { name: module_name, computations, entry })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[4]{0}, f32[4]{0})->f32[4]{0}}

%helper.1 (a.2: f32[], b.3: f32[]) -> f32[] {
  %a.2 = f32[] parameter(0)
  %b.3 = f32[] parameter(1)
  ROOT %add.4 = f32[] add(f32[] %a.2, f32[] %b.3)
}

ENTRY %main.9 (Arg_0.1: f32[4], Arg_1.2: f32[4]) -> f32[4] {
  %Arg_0.1 = f32[4]{0} parameter(0)
  %Arg_1.2 = f32[4]{0} parameter(1)
  %constant.3 = f32[] constant(1.5)
  %constant.4 = f32[4]{0} constant({1, 2, 3, 4.25})
  %broadcast.5 = f32[4]{0} broadcast(f32[] %constant.3), dimensions={}
  %add.6 = f32[4]{0} add(f32[4]{0} %Arg_0.1, f32[4]{0} %broadcast.5)
  %reduce.7 = f32[] reduce(f32[4]{0} %add.6, f32[] %constant.3), dimensions={0}, to_apply=%helper.1
  %gte.8 = f32[4]{0} add(f32[4]{0} %add.6, f32[4]{0} %constant.4)
  ROOT %mul.9 = f32[4]{0} multiply(f32[4]{0} %gte.8, f32[4]{0} %Arg_1.2)
}
"#;

    #[test]
    fn parses_module_structure() {
        let m = parse_module(SAMPLE).unwrap();
        assert_eq!(m.entry, "main.9");
        assert_eq!(m.computations.len(), 2);
        let main = m.entry_computation().unwrap();
        assert_eq!(main.instrs.len(), 9);
        assert_eq!(main.root, 8);
        assert_eq!(main.instrs[main.root].op, "multiply");
    }

    #[test]
    fn parses_operands_with_types() {
        let m = parse_module(SAMPLE).unwrap();
        let main = m.entry_computation().unwrap();
        let add = &main.instrs[5];
        assert_eq!(add.op, "add");
        assert_eq!(add.operands, vec!["Arg_0.1", "broadcast.5"]);
    }

    #[test]
    fn parses_attrs_and_constants() {
        let m = parse_module(SAMPLE).unwrap();
        let main = m.entry_computation().unwrap();
        let red = &main.instrs[6];
        assert_eq!(red.attr_dims("dimensions").unwrap(), vec![0]);
        assert_eq!(red.attr_computation("to_apply").unwrap(), "helper.1");
        let c = &main.instrs[3];
        assert_eq!(c.const_text.as_deref(), Some("{1, 2, 3, 4.25}"));
    }

    #[test]
    fn parses_compact_style_without_percent() {
        let text = "HloModule m\n\nENTRY main.3 {\n  x.1 = f32[2]{0} parameter(0)\n  ROOT neg.2 = f32[2]{0} negate(x.1)\n}\n";
        let m = parse_module(text).unwrap();
        let main = m.entry_computation().unwrap();
        assert_eq!(main.instrs[1].operands, vec!["x.1"]);
    }

    #[test]
    fn parses_tuple_shapes_and_tuple_typed_operands() {
        let text = "HloModule m\n\nENTRY e.9 {\n  p.1 = s32[] parameter(0)\n  t.2 = (s32[], s32[]) tuple(s32[] p.1, s32[] p.1)\n  ROOT g.3 = s32[] get-tuple-element((s32[], s32[]) t.2), index=1\n}\n";
        let m = parse_module(text).unwrap();
        let main = m.entry_computation().unwrap();
        assert_eq!(main.instrs[2].operands, vec!["t.2"]);
        assert_eq!(main.instrs[2].attr_i64("index").unwrap(), 1);
        match &main.instrs[1].shape {
            ShapeTy::Tuple(parts) => assert_eq!(parts.len(), 2),
            other => panic!("expected tuple shape, got {other:?}"),
        }
    }

    #[test]
    fn strips_index_comments_in_wide_tuples() {
        let text = "HloModule m\n\nENTRY e.3 {\n  p.1 = (s32[], s32[], s32[], s32[], s32[], /*index=5*/f32[2]{0}) parameter(0)\n  ROOT g.2 = f32[2]{0} get-tuple-element((s32[], s32[], s32[], s32[], s32[], /*index=5*/f32[2]{0}) p.1), index=5\n}\n";
        let m = parse_module(text).unwrap();
        let main = m.entry_computation().unwrap();
        assert_eq!(main.instrs[1].operands, vec!["p.1"]);
        assert_eq!(main.instrs[1].attr_i64("index").unwrap(), 5);
        match &main.instrs[0].shape {
            ShapeTy::Tuple(parts) => assert_eq!(parts.len(), 6),
            other => panic!("expected tuple, got {other:?}"),
        }
    }

    #[test]
    fn parses_slice_attr() {
        let text = "HloModule m\n\nENTRY e.2 {\n  p.1 = f32[4,6]{1,0} parameter(0)\n  ROOT s.2 = f32[2,3]{1,0} slice(f32[4,6]{1,0} p.1), slice={[1:3], [0:6:2]}\n}\n";
        let m = parse_module(text).unwrap();
        let s = &m.entry_computation().unwrap().instrs[1];
        assert_eq!(s.attr("slice").unwrap(), "{[1:3], [0:6:2]}");
    }
}
