//! Chunked data-parallel execution for the compiled lane's big kernels.
//!
//! Kernels never spawn threads directly: they describe their work as a
//! list of independent owned jobs (each job computes one output chunk and
//! reports it over a channel) and hand the list to [`run_jobs`].  The
//! host application may install a runner backed by its own thread pool —
//! the SOMD engine installs one that submits the jobs to its existing
//! `WorkerPool`, so device-lane kernels compete for the same SMP workers
//! as shared-memory invocations (paper §6).  Without an installed runner
//! the default executes the jobs on short-lived scoped threads.
//!
//! Jobs are fully owned (`'static`): chunk workers capture `Arc`-shared
//! tensor data and send their finished chunk back, so no borrow crosses a
//! thread boundary and any `'static` pool can run them.
//!
//! Environment knobs:
//!
//! * `XLA_PAR=0` — disable kernel parallelism entirely (serial lane);
//! * `XLA_PAR_THRESHOLD=N` — minimum output elements before a kernel
//!   goes parallel (default 65536);
//! * `XLA_PAR_THREADS=N` — worker cap for the default scoped runner and
//!   the chunk count (default: available parallelism).

use std::ops::Range;
use std::sync::mpsc;
use std::sync::OnceLock;

/// One owned unit of kernel work (computes a chunk, reports via channel).
pub type ParallelJob = Box<dyn FnOnce() + Send>;

/// Runs a batch of independent jobs to completion (possibly in parallel);
/// must not return before every job has finished.
pub type ParallelRunner = Box<dyn Fn(Vec<ParallelJob>) + Send + Sync>;

static RUNNER: OnceLock<ParallelRunner> = OnceLock::new();

/// Install a process-wide runner for kernel chunks (first caller wins;
/// returns `false` if a runner was already installed).  The SOMD engine
/// installs a `WorkerPool`-backed runner when its device lane starts.
pub fn install_parallel_runner(runner: ParallelRunner) -> bool {
    RUNNER.set(runner).is_ok()
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.parse().ok()
}

/// Minimum output elements before a kernel is chunked.
pub(crate) fn threshold() -> usize {
    static T: OnceLock<usize> = OnceLock::new();
    *T.get_or_init(|| env_usize("XLA_PAR_THRESHOLD").unwrap_or(64 * 1024))
}

/// Worker/chunk cap.
pub(crate) fn max_workers() -> usize {
    static W: OnceLock<usize> = OnceLock::new();
    *W.get_or_init(|| {
        env_usize("XLA_PAR_THREADS").unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

fn enabled() -> bool {
    static E: OnceLock<bool> = OnceLock::new();
    *E.get_or_init(|| std::env::var("XLA_PAR").map(|v| v != "0").unwrap_or(true))
}

/// Should a kernel with `n` output elements run chunked?
pub(crate) fn should_parallelize(n: usize) -> bool {
    enabled() && max_workers() > 1 && n >= threshold()
}

/// Execute the jobs through the installed runner, or on scoped threads.
pub(crate) fn run_jobs(jobs: Vec<ParallelJob>) {
    if jobs.is_empty() {
        return;
    }
    if let Some(r) = RUNNER.get() {
        r(jobs);
        return;
    }
    let w = max_workers().min(jobs.len()).max(1);
    if w <= 1 {
        for j in jobs {
            j();
        }
        return;
    }
    // static round-robin distribution over scoped threads (chunks are
    // near-equal cost by construction)
    let mut buckets: Vec<Vec<ParallelJob>> = (0..w).map(|_| Vec::new()).collect();
    for (i, j) in jobs.into_iter().enumerate() {
        buckets[i % w].push(j);
    }
    std::thread::scope(|s| {
        for b in buckets {
            s.spawn(move || {
                for j in b {
                    j();
                }
            });
        }
    });
}

/// Split `0..n` into near-equal chunk ranges (at most [`max_workers`]
/// chunks, each at least `min_chunk` elements).
pub(crate) fn chunk_ranges(n: usize, min_chunk: usize) -> Vec<Range<usize>> {
    let w = max_workers().max(1);
    let nchunks = w.min(n / min_chunk.max(1)).max(1);
    split_ranges(n, nchunks)
}

/// Split `0..n` into exactly `nchunks` near-equal ranges.
pub(crate) fn split_ranges(n: usize, nchunks: usize) -> Vec<Range<usize>> {
    let nchunks = nchunks.max(1).min(n.max(1));
    let base = n / nchunks;
    let extra = n % nchunks;
    let mut out = Vec::with_capacity(nchunks);
    let mut lo = 0usize;
    for c in 0..nchunks {
        let len = base + usize::from(c < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

/// Build a length-`n` vector by computing chunks (possibly in parallel)
/// and concatenating them in order.  `make` must return exactly
/// `range.len()` elements for each range it is given.
pub(crate) fn build_chunked<T, F>(n: usize, make: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Range<usize>) -> Vec<T> + Send + Sync + Clone + 'static,
{
    build_with_ranges(n, chunk_ranges(n, threshold().max(1) / 2 + 1), make)
}

/// [`build_chunked`] with explicit ranges (testable without env knobs).
/// The ranges need not be in output-element units — `make(range)` returns
/// that chunk's output elements, which are concatenated in range order
/// (the f32 reduce chunks *rows* and returns whole output rows per
/// chunk); `capacity` is only a size hint for the assembled vector.
pub(crate) fn build_with_ranges<T, F>(capacity: usize, ranges: Vec<Range<usize>>, make: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Range<usize>) -> Vec<T> + Send + Sync + Clone + 'static,
{
    if ranges.is_empty() {
        return Vec::new();
    }
    if ranges.len() == 1 {
        return make(ranges[0].clone());
    }
    let (tx, rx) = mpsc::channel::<(usize, Vec<T>)>();
    let jobs: Vec<ParallelJob> = ranges
        .iter()
        .cloned()
        .enumerate()
        .map(|(ci, range)| {
            let make = make.clone();
            let tx = tx.clone();
            Box::new(move || {
                let v = make(range);
                let _ = tx.send((ci, v));
            }) as ParallelJob
        })
        .collect();
    drop(tx);
    run_jobs(jobs);
    let mut parts: Vec<Option<Vec<T>>> = (0..ranges.len()).map(|_| None).collect();
    while let Ok((ci, v)) = rx.recv() {
        parts[ci] = Some(v);
    }
    let mut out = Vec::with_capacity(capacity);
    for p in parts {
        out.extend(p.expect("parallel chunk completed"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for (n, c) in [(10, 3), (7, 7), (5, 1), (0, 4), (100, 8)] {
            let rs = split_ranges(n, c);
            let mut next = 0usize;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn build_with_ranges_matches_serial() {
        let make = |r: Range<usize>| r.map(|i| i * i).collect::<Vec<usize>>();
        let serial = make(0..1000);
        let par = build_with_ranges(1000, split_ranges(1000, 7), make);
        assert_eq!(par, serial);
    }

    #[test]
    fn single_chunk_runs_inline() {
        let got = build_with_ranges(4, vec![0..4], |r| r.collect::<Vec<usize>>());
        assert_eq!(got, vec![0, 1, 2, 3]);
    }
}
