//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no crates.io access), so this vendored
//! shim provides exactly the surface the `somd` crate uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the
//! [`Context`] extension trait.  Semantics follow the real crate where it
//! matters here:
//!
//! * `Error` is a cheap wrapper over a message plus a context chain;
//! * `{:#}` (alternate `Display`) prints `outermost: ...: innermost`,
//!   `{}` prints only the outermost message;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `Error` itself does **not** implement `std::error::Error` (same as
//!   the real crate) so the blanket conversion stays coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: an outermost message plus the chain of causes beneath it.
/// `chain[0]` is the outermost (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` entry point).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message (innermost stays last).
    pub fn context(mut self, msg: impl fmt::Display) -> Self {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: no `impl std::error::Error for Error` — exactly like the real
// anyhow, which is what keeps the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option` (subset of the
/// real trait: enough for `.context(..)` / `.with_context(|| ..)`).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

/// Sealed helper so both `Result<T, E: std::error::Error>` and
/// `Result<T, Error>` get `Context` without overlapping impls.
mod private {
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> super::Error;
    }

    impl<E> IntoAnyhow for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoAnyhow for super::Error {
        fn into_anyhow(self) -> super::Error {
            self
        }
    }
}

impl<T, E: private::IntoAnyhow> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let e = std::fs::read_to_string("/definitely/not/a/real/path/xyz");
        Ok(e.context("reading config")?)
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e = io_fail().unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading config: "), "{s}");
        let outer = format!("{e}");
        assert_eq!(outer, "reading config");
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("artifact '{name}' missing");
        assert_eq!(format!("{e}"), "artifact 'x' missing");
        let e = anyhow!("expects {} inputs, got {}", 2, 3);
        assert_eq!(format!("{e}"), "expects 2 inputs, got 3");
        fn f() -> Result<()> {
            bail!("nope: {}", 7)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope: 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing dtype").unwrap_err();
        assert_eq!(format!("{e}"), "missing dtype");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "zz".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn with_context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner boom")
        }
        let e = inner().with_context(|| "outer frame").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer frame: inner boom");
    }

    #[test]
    fn anyhow_from_displayable_value() {
        let e = anyhow!(std::io::Error::new(std::io::ErrorKind::Other, "disk"));
        assert_eq!(format!("{e}"), "disk");
    }
}
