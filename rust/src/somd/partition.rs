//! Built-in and paper-featured partitioners.
//!
//! * [`Block1D`] — the default block strategy for vectors (copy-free index
//!   ranges, §4.1), with optional halo views and `dim=` selection.
//! * [`Block2D`] — the default (block, block) matrix strategy the paper
//!   credits for SOR's cache-friendliness (§7.2).
//! * [`RowDisjoint`] — SparseMatMult's user-defined strategy: split the
//!   nonzero triplet stream so every partition covers a disjoint row range
//!   (the ~50-line strategy borrowed from JavaGrande, §7.1).
//! * [`TreeDist`] — Listing 12: evenly partition a linked tree across MIs.
//!
//! Since the hybrid co-execution PR every array partitioner also has a
//! **ratio-weighted** form: [`split_fraction`] cuts one index space into
//! an SMP head and a device tail at the scheduler's learned ratio, and
//! [`Block1D::ranges_in`] / [`Block2D::parts_in`] /
//! [`RowDisjoint::split_fraction`] partition *within* such a sub-span so
//! the SMP share still fans out across MIs exactly as a whole invocation
//! would.
//!
//! The device-fleet PR generalizes the two-way cut to **N-way**:
//! [`split_weighted`] cuts one index space into `k + 1` contiguous lane
//! spans (SMP first, then one per device lane) at the scheduler's
//! learned per-lane weights, and [`split_weighted_floor`] additionally
//! applies the `min_device_items` floor — device lanes whose share would
//! be pure launch overhead are starved and their items fold back into
//! the surviving lanes.

use super::distribution::{index_ranges, near_square_grid, Distribution, Range1, Range2, View};
use crate::somd::tree::Tree;

/// Cut `[0, len)` into an SMP head and a device tail, handing the tail
/// `device_fraction` of the items (rounded; clamped to `[0, 1]`).  The
/// head/tail orientation is fixed so hybrid partial results concatenate
/// in rank order through the ordinary array-assembly reduction.
///
/// # Examples
///
/// ```
/// use somd::somd::partition::split_fraction;
/// let (smp, dev) = split_fraction(1000, 0.25);
/// assert_eq!((smp.lo, smp.hi), (0, 750));
/// assert_eq!((dev.lo, dev.hi), (750, 1000));
/// // degenerate splits are valid: 0.0 = pure SMP, 1.0 = pure device
/// assert!(split_fraction(1000, 0.0).1.is_empty());
/// assert!(split_fraction(1000, 1.0).0.is_empty());
/// ```
pub fn split_fraction(len: usize, device_fraction: f64) -> (Range1, Range1) {
    let f = if device_fraction.is_finite() { device_fraction.clamp(0.0, 1.0) } else { 0.0 };
    let dev = (((len as f64) * f).round() as usize).min(len);
    let cut = len - dev;
    (Range1::new(0, cut), Range1::new(cut, len))
}

/// Cut `[0, len)` into `weights.len()` contiguous abutting spans in lane
/// order, lane `i` receiving a share proportional to `weights[i]`
/// (non-finite or negative weights count as zero).  The spans cover the
/// index space exactly and never reorder it, so per-lane partial results
/// concatenate in rank order through the ordinary array-assembly
/// reduction — the N-way generalization of [`split_fraction`]'s
/// head/tail orientation.  When every weight is zero, lane 0 takes the
/// whole space (the SMP lane is the universal fallback, §6).
///
/// # Examples
///
/// ```
/// use somd::somd::partition::split_weighted;
/// let spans = split_weighted(1000, &[0.5, 0.25, 0.25]);
/// assert_eq!((spans[0].lo, spans[0].hi), (0, 500));
/// assert_eq!((spans[1].lo, spans[1].hi), (500, 750));
/// assert_eq!((spans[2].lo, spans[2].hi), (750, 1000));
/// // zero-weight lanes get empty spans at their cut position
/// let spans = split_weighted(10, &[1.0, 0.0, 1.0]);
/// assert!(spans[1].is_empty());
/// assert_eq!((spans[0].len(), spans[2].len()), (5, 5));
/// ```
pub fn split_weighted(len: usize, weights: &[f64]) -> Vec<Range1> {
    if weights.is_empty() {
        return Vec::new();
    }
    let w: Vec<f64> =
        weights.iter().map(|&x| if x.is_finite() && x > 0.0 { x } else { 0.0 }).collect();
    let total: f64 = w.iter().sum();
    if total <= 0.0 {
        // no live weight anywhere: the SMP lane covers everything
        let mut out = Vec::with_capacity(w.len());
        out.push(Range1::new(0, len));
        out.extend((1..w.len()).map(|_| Range1::new(len, len)));
        return out;
    }
    // cumulative rounding: cut points are monotone because the prefix
    // sums are, so spans always abut and cover [0, len) exactly
    let mut out = Vec::with_capacity(w.len());
    let mut acc = 0.0f64;
    let mut lo = 0usize;
    for (i, &wi) in w.iter().enumerate() {
        acc += wi;
        let hi = if i + 1 == w.len() {
            len
        } else {
            ((((len as f64) * (acc / total)).round() as usize).max(lo)).min(len)
        };
        out.push(Range1::new(lo, hi));
        lo = hi;
    }
    out
}

/// [`split_weighted`] under the fleet's `min_device_items` floor: lane 0
/// is the SMP share, lanes `1..` are device lanes.  A device lane whose
/// share would land below `min_items` is *starved* — its weight is
/// zeroed and the space re-split, folding the starved items back into
/// the surviving lanes (ultimately the SMP share) — repeating until
/// every remaining device lane clears the floor.  A device launch over a
/// handful of items is pure overhead, so degrading a lane beats paying
/// for it; when every device lane starves, the SMP lane covers the whole
/// space and the caller should run (and record) a degraded invocation.
///
/// **The floor is deliberately asymmetric: lane 0 is never re-checked.**
/// The SMP share runs on the worker pool the caller already owns — there
/// is no launch or transfer overhead for a micro-span to amortize, so a
/// tiny SMP share is cheap where a tiny device share is not.  Lane 0 is
/// also the designated fallback: every item starved off a device lane
/// (and the cover for every *failed* lane) must land somewhere, and that
/// somewhere is the SMP span.  Zeroing lane 0's weight under the floor
/// would leave nowhere to fold starved items into and turn "shard
/// mostly to devices" into "refuse to shard".  The invariant callers may
/// rely on (pinned by `prop_split_weighted_floor_respects_the_floor` in
/// `tests/proptest_partition.rs`): every **non-empty span at index ≥ 1**
/// has at least `min_items` items; lane 0 may hold any length from 0 to
/// `len`, including a micro-span below the floor.
///
/// # Examples
///
/// ```
/// use somd::somd::partition::split_weighted_floor;
/// // both device lanes clear a floor of 100
/// let spans = split_weighted_floor(1000, &[0.5, 0.25, 0.25], 100);
/// assert_eq!(spans.iter().map(|s| s.len()).sum::<usize>(), 1000);
/// assert!(spans[1].len() >= 100 && spans[2].len() >= 100);
/// // a 2% lane under the floor is starved; its items fold back
/// let spans = split_weighted_floor(1000, &[0.49, 0.49, 0.02], 100);
/// assert!(spans[2].is_empty());
/// assert_eq!(spans[0].len() + spans[1].len(), 1000);
/// // everything starves on a tiny space: SMP covers it all
/// let spans = split_weighted_floor(10, &[0.4, 0.3, 0.3], 100);
/// assert_eq!(spans[0].len(), 10);
/// assert!(spans[1].is_empty() && spans[2].is_empty());
/// ```
pub fn split_weighted_floor(len: usize, weights: &[f64], min_items: usize) -> Vec<Range1> {
    let mut w: Vec<f64> =
        weights.iter().map(|&x| if x.is_finite() && x > 0.0 { x } else { 0.0 }).collect();
    loop {
        let spans = split_weighted(len, &w);
        let mut starved = false;
        for i in 1..spans.len() {
            if w[i] > 0.0 && spans[i].len() < min_items {
                w[i] = 0.0;
                starved = true;
            }
        }
        if !starved {
            return spans;
        }
    }
}

/// Stitch per-request index-space lengths into consecutive sub-spans of
/// the fused space: request `i` of a coalesced batch owns the returned
/// `spans[i]` inside `[0, lens.iter().sum())`.  The serving layer's
/// batcher (and its round-trip tests) use this to cut a fused result
/// back into per-request results — the inverse of the concatenation a
/// [`BatchSpec::compose`](crate::backend::BatchSpec) performs.
///
/// # Examples
///
/// ```
/// use somd::somd::partition::stitched_spans;
/// let spans = stitched_spans(&[3, 0, 4]);
/// assert_eq!((spans[0].lo, spans[0].hi), (0, 3));
/// assert!(spans[1].is_empty());
/// assert_eq!((spans[2].lo, spans[2].hi), (3, 7));
/// ```
pub fn stitched_spans(lens: &[usize]) -> Vec<Range1> {
    let mut out = Vec::with_capacity(lens.len());
    let mut lo = 0usize;
    for &n in lens {
        out.push(Range1::new(lo, lo + n));
        lo += n;
    }
    out
}

/// Block partitioning of `len` indexes (copy-free).
///
/// # Examples
///
/// ```
/// use somd::somd::partition::Block1D;
/// let parts = Block1D::new().ranges(10, 3);
/// assert_eq!(parts.len(), 3);
/// assert_eq!((parts[0].own.lo, parts[0].own.hi), (0, 4));
/// assert_eq!(parts.last().unwrap().own.hi, 10);
/// ```
#[derive(Debug, Clone, Default)]
pub struct Block1D {
    /// Halo view widening each partition's readable window.
    pub view: View,
}

impl Block1D {
    /// The plain block strategy (no halo).
    pub fn new() -> Self {
        Self::default()
    }

    /// `dist(view = <b,a>)`
    pub fn with_view(view: View) -> Self {
        Self { view }
    }

    /// Split `[0, len)` into `n` contiguous owned ranges plus their
    /// halo-widened readable windows.
    pub fn ranges(&self, len: usize, n: usize) -> Vec<BlockPart> {
        self.ranges_in(Range1::new(0, len), len, n)
    }

    /// Ratio-weighted variant: partition only the sub-span `span` of a
    /// logical `[0, len)` index space into `n` ranges.  Owned ranges
    /// stay inside `span`; readable windows may reach outside it (but
    /// never outside `[0, len)`) — an MI at a hybrid cut boundary still
    /// sees its halo exactly as in a whole-space invocation.
    pub fn ranges_in(&self, span: Range1, len: usize, n: usize) -> Vec<BlockPart> {
        assert!(span.hi <= len, "span {span:?} exceeds index space [0, {len})");
        index_ranges(span.len(), n)
            .into_iter()
            .map(|r| {
                let own = Range1::new(span.lo + r.lo, span.lo + r.hi);
                BlockPart { own, readable: own.with_view(self.view, len) }
            })
            .collect()
    }
}

/// A 1-D partition: the indexes the MI owns (writes) and the halo-widened
/// window it may read (paper Figure 4a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPart {
    /// Indexes this MI owns (writes).
    pub own: Range1,
    /// Halo-widened window this MI may read.
    pub readable: Range1,
}

impl Distribution<usize> for Block1D {
    type Part = BlockPart;

    fn distribute(&self, len: &usize, n: usize) -> Vec<BlockPart> {
        self.ranges(*len, n)
    }
}

/// (block, block) partitioning of an `rows x cols` matrix.
///
/// # Examples
///
/// ```
/// use somd::somd::partition::Block2D;
/// let parts = Block2D::new().parts(10, 12, 4); // 2x2 near-square grid
/// let area: usize = parts.iter().map(|p| p.own.rows.len() * p.own.cols.len()).sum();
/// assert_eq!(area, 120);
/// ```
#[derive(Debug, Clone, Default)]
pub struct Block2D {
    /// Halo view widening each partition's readable block.
    pub view: View,
}

/// A 2-D partition with owned block and halo-widened readable block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block2Part {
    /// The (rows x cols) block this MI owns.
    pub own: Range2,
    /// The halo-widened block this MI may read.
    pub readable: Range2,
}

impl Block2D {
    /// The plain (block, block) strategy (no halo).
    pub fn new() -> Self {
        Self::default()
    }

    /// `dist(view = <b,a>,<b,a>)`
    pub fn with_view(view: View) -> Self {
        Self { view }
    }

    /// Split an `rows x cols` matrix into `n` near-square blocks.
    pub fn parts(&self, rows: usize, cols: usize, n: usize) -> Vec<Block2Part> {
        self.parts_in(Range1::new(0, rows), rows, cols, n)
    }

    /// Ratio-weighted variant: partition only the row sub-span
    /// `row_span` (hybrid co-execution splits matrices by rows, so the
    /// two lanes' shares stay contiguous in memory); columns still split
    /// near-square within the span.
    pub fn parts_in(&self, row_span: Range1, rows: usize, cols: usize, n: usize) -> Vec<Block2Part> {
        assert!(row_span.hi <= rows, "row span {row_span:?} exceeds {rows} rows");
        let (pr, pc) = near_square_grid(n);
        let rranges: Vec<Range1> = index_ranges(row_span.len(), pr)
            .into_iter()
            .map(|r| Range1::new(row_span.lo + r.lo, row_span.lo + r.hi))
            .collect();
        let cranges = index_ranges(cols, pc);
        let mut out = Vec::with_capacity(n);
        for r in &rranges {
            for c in &cranges {
                out.push(Block2Part {
                    own: Range2 { rows: *r, cols: *c },
                    readable: Range2 {
                        rows: r.with_view(self.view, rows),
                        cols: c.with_view(self.view, cols),
                    },
                });
            }
        }
        out
    }
}

impl Distribution<(usize, usize)> for Block2D {
    type Part = Block2Part;

    fn distribute(&self, dims: &(usize, usize), n: usize) -> Vec<Block2Part> {
        self.parts(dims.0, dims.1, n)
    }
}

/// Row-major partitioning of `len` rows only on dimension 1 — what the
/// hand-threaded JavaGrande SOR does (outer loop only); kept as the
/// comparison point for the 1D-vs-2D ablation.
#[derive(Debug, Clone, Default)]
pub struct Rows1D {
    /// Halo view widening each partition's readable rows.
    pub view: View,
}

impl Rows1D {
    /// Split `rows` full-width row bands across `n` MIs.
    pub fn parts(&self, rows: usize, cols: usize, n: usize) -> Vec<Block2Part> {
        index_ranges(rows, n)
            .into_iter()
            .map(|r| Block2Part {
                own: Range2 { rows: r, cols: Range1::new(0, cols) },
                readable: Range2 {
                    rows: r.with_view(self.view, rows),
                    cols: Range1::new(0, cols),
                },
            })
            .collect()
    }
}

/// SparseMatMult's strategy: partition the nnz triplet stream (sorted by
/// row) into `n` chunks whose boundaries never split a row, so MIs write
/// disjoint ranges of the result vector.
///
/// # Examples
///
/// ```
/// use somd::somd::partition::RowDisjoint;
/// // rows: 0,0,0,1,1,2,3,3,3,3 — boundaries land on row edges
/// let row = [0u32, 0, 0, 1, 1, 2, 3, 3, 3, 3];
/// let parts = RowDisjoint.parts(&row, 4, 3);
/// assert_eq!(parts.len(), 3);
/// assert_eq!(parts[0].nnz.lo, 0);
/// assert_eq!(parts.last().unwrap().nnz.hi, row.len());
/// ```
#[derive(Debug, Clone, Default)]
pub struct RowDisjoint;

/// Partition descriptor: nnz range plus the (disjoint) row range it feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsePart {
    /// Range of the nonzero triplet stream this MI processes.
    pub nnz: Range1,
    /// The disjoint row range those nonzeros feed.
    pub rows: Range1,
}

impl RowDisjoint {
    /// `row` must be sorted ascending (CSR-by-triplet).
    pub fn parts(&self, row: &[u32], n_rows: usize, n: usize) -> Vec<SparsePart> {
        let nnz = row.len();
        let targets = index_ranges(nnz, n);
        let mut out = Vec::with_capacity(n);
        let mut lo = 0usize;
        for (i, t) in targets.iter().enumerate() {
            let mut hi = t.hi.max(lo);
            if i + 1 == n {
                hi = nnz;
            } else {
                // advance hi to the next row boundary
                while hi > lo && hi < nnz && row[hi] == row[hi - 1] {
                    hi += 1;
                }
            }
            out.push(Self::part_for(row, n_rows, lo, hi));
            lo = hi;
        }
        out
    }

    /// Ratio-weighted two-way split for hybrid co-execution: cut the nnz
    /// stream at the row boundary nearest to `device_fraction` of the
    /// nonzeros, returning the SMP head and device tail.  Both sides keep
    /// the row-disjointness invariant, so their partial `y` contributions
    /// touch disjoint result rows and merge by concatenation.
    pub fn split_fraction(
        &self,
        row: &[u32],
        n_rows: usize,
        device_fraction: f64,
    ) -> (SparsePart, SparsePart) {
        let nnz = row.len();
        let (head, _tail) = split_fraction(nnz, device_fraction);
        let mut cut = head.hi;
        // never split a row across the lanes
        while cut > 0 && cut < nnz && row[cut] == row[cut - 1] {
            cut += 1;
        }
        (Self::part_for(row, n_rows, 0, cut), Self::part_for(row, n_rows, cut, nnz))
    }

    fn part_for(row: &[u32], n_rows: usize, lo: usize, hi: usize) -> SparsePart {
        let nnz = row.len();
        let row_lo = if lo < nnz { row[lo] as usize } else { n_rows };
        let row_hi = if hi > lo { row[hi - 1] as usize + 1 } else { row_lo };
        SparsePart {
            nnz: Range1::new(lo, hi),
            rows: Range1::new(row_lo.min(row_hi), row_hi),
        }
    }
}

/// Listing 12's `TreeDist`: split a binary tree into `n`-level subtrees
/// plus the `n`-level top copy, so MIs process disjoint regions.
#[derive(Debug, Clone, Default)]
pub struct TreeDist {
    /// Number of split levels (2^levels leaf subtrees).  Listing 12 uses
    /// the partition count directly; we default to ceil(log2(n)).
    pub levels: Option<usize>,
}

impl TreeDist {
    /// Split `tree` into the top copy plus the depth-`levels` subtrees.
    pub fn parts<A: Clone + Send + Sync>(&self, tree: &Tree<A>, n: usize) -> Vec<Tree<A>> {
        let levels = self.levels.unwrap_or_else(|| {
            let mut l = 0;
            while (1usize << l) < n {
                l += 1;
            }
            l
        });
        // frontier of subtrees at depth `levels` (Listing 12's double-buffer
        // loop), plus the top `levels` of the original tree.
        let mut frontier: Vec<Tree<A>> = vec![tree.clone()];
        for _ in 0..levels {
            let prev = std::mem::take(&mut frontier);
            for t in prev {
                frontier.push(t.left_or_nil());
                frontier.push(t.right_or_nil());
            }
        }
        let mut out = Vec::with_capacity(frontier.len() + 1);
        out.push(tree.copy_top(levels));
        out.extend(frontier);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::tree::Tree;

    #[test]
    fn block1d_halo() {
        let parts = Block1D::with_view(View::sym(1)).ranges(10, 3);
        assert_eq!(parts[0].own, Range1::new(0, 4));
        assert_eq!(parts[0].readable, Range1::new(0, 5));
        assert_eq!(parts[1].readable, Range1::new(3, 8));
        assert_eq!(parts[2].readable, Range1::new(6, 10));
    }

    #[test]
    fn block2d_covers_matrix() {
        let parts = Block2D::new().parts(10, 12, 4);
        assert_eq!(parts.len(), 4);
        let area: usize = parts.iter().map(|p| p.own.rows.len() * p.own.cols.len()).sum();
        assert_eq!(area, 120);
    }

    #[test]
    fn rows1d_full_width() {
        let parts = Rows1D::default().parts(9, 5, 2);
        assert_eq!(parts.len(), 2);
        assert!(parts.iter().all(|p| p.own.cols.len() == 5));
    }

    #[test]
    fn row_disjoint_never_splits_rows() {
        // rows: 0,0,0,1,1,2,3,3,3,3
        let row = [0u32, 0, 0, 1, 1, 2, 3, 3, 3, 3];
        let parts = RowDisjoint.parts(&row, 4, 3);
        assert_eq!(parts.len(), 3);
        // coverage + disjointness of nnz ranges
        assert_eq!(parts[0].nnz.lo, 0);
        assert_eq!(parts.last().unwrap().nnz.hi, row.len());
        for w in parts.windows(2) {
            assert_eq!(w[0].nnz.hi, w[1].nnz.lo);
            // row disjointness
            assert!(w[0].rows.hi <= w[1].rows.lo || w[1].nnz.is_empty());
        }
        // no boundary splits a row
        for p in &parts {
            if p.nnz.is_empty() {
                continue;
            }
            if p.nnz.hi < row.len() {
                assert_ne!(row[p.nnz.hi], row[p.nnz.hi - 1]);
            }
        }
    }

    #[test]
    fn row_disjoint_more_parts_than_rows() {
        let row = [0u32, 1];
        let parts = RowDisjoint.parts(&row, 2, 4);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.nnz.len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn tree_dist_partitions_node_count() {
        let tree: Tree<i64> = Tree::full(5, 1); // 2^6 - 1 = 63 nodes
        let parts = TreeDist::default().parts(&tree, 4);
        // top copy + 4 subtrees at 2 levels
        assert_eq!(parts.len(), 5);
        let total: usize = parts.iter().map(Tree::count).sum();
        assert_eq!(total, 63);
    }

    // -- ratio-weighted forms (hybrid co-execution) -------------------------

    #[test]
    fn split_fraction_covers_and_clamps() {
        for len in [0usize, 1, 10, 1001] {
            for f in [-0.5, 0.0, 0.25, 0.5, 0.9, 1.0, 2.0, f64::NAN] {
                let (smp, dev) = split_fraction(len, f);
                assert_eq!(smp.lo, 0);
                assert_eq!(smp.hi, dev.lo);
                assert_eq!(dev.hi, len);
            }
        }
        let (smp, dev) = split_fraction(100, 0.3);
        assert_eq!(dev.len(), 30);
        assert_eq!(smp.len(), 70);
    }

    #[test]
    fn stitched_spans_cover_and_abut() {
        let lens = [5usize, 1, 0, 7, 3];
        let spans = stitched_spans(&lens);
        assert_eq!(spans.len(), lens.len());
        assert_eq!(spans[0].lo, 0);
        assert_eq!(spans.last().unwrap().hi, lens.iter().sum::<usize>());
        for (s, &n) in spans.iter().zip(&lens) {
            assert_eq!(s.len(), n);
        }
        for w in spans.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
        assert!(stitched_spans(&[]).is_empty());
    }

    #[test]
    fn ranges_in_refines_the_subspan() {
        let span = Range1::new(300, 701);
        let parts = Block1D::new().ranges_in(span, 1000, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].own.lo, 300);
        assert_eq!(parts.last().unwrap().own.hi, 701);
        for w in parts.windows(2) {
            assert_eq!(w[0].own.hi, w[1].own.lo);
        }
        let sizes: Vec<usize> = parts.iter().map(|p| p.own.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn ranges_in_halo_reaches_outside_the_span() {
        // an MI at the hybrid cut must see the same halo a whole-space
        // partition would: readable crosses the span edge, not the array
        let span = Range1::new(10, 20);
        let parts = Block1D::with_view(View::sym(2)).ranges_in(span, 100, 2);
        assert_eq!(parts[0].readable, Range1::new(8, 17));
        assert_eq!(parts[1].readable, Range1::new(13, 22));
    }

    #[test]
    fn block2d_parts_in_covers_row_span() {
        let span = Range1::new(2, 9);
        let parts = Block2D::new().parts_in(span, 10, 6, 4);
        let area: usize = parts.iter().map(|p| p.own.rows.len() * p.own.cols.len()).sum();
        assert_eq!(area, span.len() * 6);
        assert!(parts.iter().all(|p| p.own.rows.lo >= 2 && p.own.rows.hi <= 9));
    }

    #[test]
    fn split_weighted_covers_abuts_and_orders() {
        for len in [0usize, 1, 10, 1000, 4097] {
            for w in [
                vec![1.0],
                vec![0.5, 0.5],
                vec![0.2, 0.3, 0.5],
                vec![1.0, 0.0, 2.0, 0.0],
                vec![0.25; 7],
            ] {
                let spans = split_weighted(len, &w);
                assert_eq!(spans.len(), w.len());
                assert_eq!(spans[0].lo, 0);
                assert_eq!(spans.last().unwrap().hi, len);
                for win in spans.windows(2) {
                    assert_eq!(win[0].hi, win[1].lo, "len={len} w={w:?}");
                }
            }
        }
    }

    #[test]
    fn split_weighted_is_proportional() {
        let spans = split_weighted(10_000, &[0.1, 0.2, 0.3, 0.4]);
        let lens: Vec<usize> = spans.iter().map(|s| s.len()).collect();
        assert_eq!(lens, vec![1000, 2000, 3000, 4000]);
    }

    #[test]
    fn split_weighted_sanitizes_bad_weights() {
        // NaN / negative / infinite weights count as zero
        let spans = split_weighted(100, &[1.0, f64::NAN, -3.0, f64::INFINITY, 1.0]);
        assert_eq!(spans[0].len(), 50);
        assert!(spans[1].is_empty() && spans[2].is_empty() && spans[3].is_empty());
        assert_eq!(spans[4].len(), 50);
        // all-dead weights: lane 0 takes everything
        let spans = split_weighted(42, &[0.0, f64::NAN, -1.0]);
        assert_eq!(spans[0].len(), 42);
        assert!(spans[1].is_empty() && spans[2].is_empty());
        assert!(split_weighted(10, &[]).is_empty());
    }

    #[test]
    fn split_weighted_one_lane_degenerates_to_whole_space() {
        let spans = split_weighted(123, &[7.0]);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].lo, spans[0].hi), (0, 123));
    }

    #[test]
    fn split_weighted_two_way_matches_split_fraction() {
        // The N-way form at N=2 must agree with the hybrid cut wherever
        // the cut is unambiguous.  (At an exact half-item the two round
        // from opposite ends — split_fraction rounds the tail,
        // split_weighted the cumulative prefix — so the comparison uses
        // lengths where every tested fraction lands on a whole item.)
        for len in [0usize, 8, 1000, 4096] {
            for f in [0.0, 0.25, 0.5, 0.75, 1.0] {
                let (smp, dev) = split_fraction(len, f);
                let spans = split_weighted(len, &[1.0 - f, f]);
                assert_eq!(spans[0], smp, "len={len} f={f}");
                assert_eq!(spans[1], dev, "len={len} f={f}");
            }
        }
        // and off the exact-multiple grid both forms still cover and abut
        let spans = split_weighted(10, &[0.75, 0.25]);
        assert_eq!(spans[0].hi, spans[1].lo);
        assert_eq!(spans[1].hi, 10);
    }

    #[test]
    fn split_weighted_floor_starves_small_device_lanes() {
        // a lane under the floor degrades; its items fold back into the
        // surviving lanes, never vanishing
        let spans = split_weighted_floor(1000, &[0.49, 0.49, 0.02], 100);
        assert!(spans[2].is_empty());
        assert_eq!(spans.iter().map(|s| s.len()).sum::<usize>(), 1000);
        assert!(spans[1].len() >= 100);
        // cascading starvation: once the big lane absorbs everything,
        // re-splitting must not resurrect the starved one
        let spans = split_weighted_floor(150, &[0.1, 0.45, 0.45], 100);
        let covered: usize = spans.iter().map(|s| s.len()).sum();
        assert_eq!(covered, 150);
        for (i, s) in spans.iter().enumerate().skip(1) {
            assert!(s.is_empty() || s.len() >= 100, "lane {i}: {s:?}");
        }
    }

    #[test]
    fn split_weighted_floor_smp_lane_is_exempt() {
        // the floor applies to device lanes only — a small SMP share is
        // fine (SMP pays no launch cost)
        let spans = split_weighted_floor(1000, &[0.01, 0.99], 100);
        assert_eq!(spans[0].len(), 10);
        assert_eq!(spans[1].len(), 990);
    }

    #[test]
    fn split_weighted_floor_total_starvation_degrades_to_smp() {
        let spans = split_weighted_floor(50, &[0.34, 0.33, 0.33], 1024);
        assert_eq!(spans[0].len(), 50);
        assert!(spans[1..].iter().all(|s| s.is_empty()));
    }

    #[test]
    fn row_disjoint_split_fraction_respects_row_boundaries() {
        let row = [0u32, 0, 0, 1, 1, 2, 3, 3, 3, 3];
        for f in [0.0, 0.3, 0.5, 0.8, 1.0] {
            let (head, tail) = RowDisjoint.split_fraction(&row, 4, f);
            assert_eq!(head.nnz.lo, 0);
            assert_eq!(head.nnz.hi, tail.nnz.lo);
            assert_eq!(tail.nnz.hi, row.len());
            let cut = head.nnz.hi;
            if cut > 0 && cut < row.len() {
                assert_ne!(row[cut], row[cut - 1], "cut splits row at f={f}");
            }
            // the two sides feed disjoint result rows
            if !head.nnz.is_empty() && !tail.nnz.is_empty() {
                assert!(head.rows.hi <= tail.rows.lo);
            }
        }
    }
}
