//! Built-in and paper-featured partitioners.
//!
//! * [`Block1D`] — the default block strategy for vectors (copy-free index
//!   ranges, §4.1), with optional halo views and `dim=` selection.
//! * [`Block2D`] — the default (block, block) matrix strategy the paper
//!   credits for SOR's cache-friendliness (§7.2).
//! * [`RowDisjoint`] — SparseMatMult's user-defined strategy: split the
//!   nonzero triplet stream so every partition covers a disjoint row range
//!   (the ~50-line strategy borrowed from JavaGrande, §7.1).
//! * [`TreeDist`] — Listing 12: evenly partition a linked tree across MIs.

use super::distribution::{index_ranges, near_square_grid, Distribution, Range1, Range2, View};
use crate::somd::tree::Tree;

/// Block partitioning of `len` indexes (copy-free).
#[derive(Debug, Clone, Default)]
pub struct Block1D {
    pub view: View,
}

impl Block1D {
    pub fn new() -> Self {
        Self::default()
    }

    /// `dist(view = <b,a>)`
    pub fn with_view(view: View) -> Self {
        Self { view }
    }

    pub fn ranges(&self, len: usize, n: usize) -> Vec<BlockPart> {
        index_ranges(len, n)
            .into_iter()
            .map(|own| BlockPart { own, readable: own.with_view(self.view, len) })
            .collect()
    }
}

/// A 1-D partition: the indexes the MI owns (writes) and the halo-widened
/// window it may read (paper Figure 4a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPart {
    pub own: Range1,
    pub readable: Range1,
}

impl Distribution<usize> for Block1D {
    type Part = BlockPart;

    fn distribute(&self, len: &usize, n: usize) -> Vec<BlockPart> {
        self.ranges(*len, n)
    }
}

/// (block, block) partitioning of an `rows x cols` matrix.
#[derive(Debug, Clone, Default)]
pub struct Block2D {
    pub view: View,
}

/// A 2-D partition with owned block and halo-widened readable block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block2Part {
    pub own: Range2,
    pub readable: Range2,
}

impl Block2D {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_view(view: View) -> Self {
        Self { view }
    }

    pub fn parts(&self, rows: usize, cols: usize, n: usize) -> Vec<Block2Part> {
        let (pr, pc) = near_square_grid(n);
        let rranges = index_ranges(rows, pr);
        let cranges = index_ranges(cols, pc);
        let mut out = Vec::with_capacity(n);
        for r in &rranges {
            for c in &cranges {
                out.push(Block2Part {
                    own: Range2 { rows: *r, cols: *c },
                    readable: Range2 {
                        rows: r.with_view(self.view, rows),
                        cols: c.with_view(self.view, cols),
                    },
                });
            }
        }
        out
    }
}

impl Distribution<(usize, usize)> for Block2D {
    type Part = Block2Part;

    fn distribute(&self, dims: &(usize, usize), n: usize) -> Vec<Block2Part> {
        self.parts(dims.0, dims.1, n)
    }
}

/// Row-major partitioning of `len` rows only on dimension 1 — what the
/// hand-threaded JavaGrande SOR does (outer loop only); kept as the
/// comparison point for the 1D-vs-2D ablation.
#[derive(Debug, Clone, Default)]
pub struct Rows1D {
    pub view: View,
}

impl Rows1D {
    pub fn parts(&self, rows: usize, cols: usize, n: usize) -> Vec<Block2Part> {
        index_ranges(rows, n)
            .into_iter()
            .map(|r| Block2Part {
                own: Range2 { rows: r, cols: Range1::new(0, cols) },
                readable: Range2 {
                    rows: r.with_view(self.view, rows),
                    cols: Range1::new(0, cols),
                },
            })
            .collect()
    }
}

/// SparseMatMult's strategy: partition the nnz triplet stream (sorted by
/// row) into `n` chunks whose boundaries never split a row, so MIs write
/// disjoint ranges of the result vector.
#[derive(Debug, Clone, Default)]
pub struct RowDisjoint;

/// Partition descriptor: nnz range plus the (disjoint) row range it feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsePart {
    pub nnz: Range1,
    pub rows: Range1,
}

impl RowDisjoint {
    /// `row` must be sorted ascending (CSR-by-triplet).
    pub fn parts(&self, row: &[u32], n_rows: usize, n: usize) -> Vec<SparsePart> {
        let nnz = row.len();
        let targets = index_ranges(nnz, n);
        let mut out = Vec::with_capacity(n);
        let mut lo = 0usize;
        for (i, t) in targets.iter().enumerate() {
            let mut hi = t.hi.max(lo);
            if i + 1 == n {
                hi = nnz;
            } else {
                // advance hi to the next row boundary
                while hi > lo && hi < nnz && row[hi] == row[hi - 1] {
                    hi += 1;
                }
            }
            let row_lo = if lo < nnz { row[lo] as usize } else { n_rows };
            let row_hi = if hi > lo { row[hi - 1] as usize + 1 } else { row_lo };
            out.push(SparsePart {
                nnz: Range1::new(lo, hi),
                rows: Range1::new(row_lo.min(row_hi), row_hi),
            });
            lo = hi;
        }
        out
    }
}

/// Listing 12's `TreeDist`: split a binary tree into `n`-level subtrees
/// plus the `n`-level top copy, so MIs process disjoint regions.
#[derive(Debug, Clone, Default)]
pub struct TreeDist {
    /// Number of split levels (2^levels leaf subtrees).  Listing 12 uses
    /// the partition count directly; we default to ceil(log2(n)).
    pub levels: Option<usize>,
}

impl TreeDist {
    pub fn parts<A: Clone + Send + Sync>(&self, tree: &Tree<A>, n: usize) -> Vec<Tree<A>> {
        let levels = self.levels.unwrap_or_else(|| {
            let mut l = 0;
            while (1usize << l) < n {
                l += 1;
            }
            l
        });
        // frontier of subtrees at depth `levels` (Listing 12's double-buffer
        // loop), plus the top `levels` of the original tree.
        let mut frontier: Vec<Tree<A>> = vec![tree.clone()];
        for _ in 0..levels {
            let prev = std::mem::take(&mut frontier);
            for t in prev {
                frontier.push(t.left_or_nil());
                frontier.push(t.right_or_nil());
            }
        }
        let mut out = Vec::with_capacity(frontier.len() + 1);
        out.push(tree.copy_top(levels));
        out.extend(frontier);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::tree::Tree;

    #[test]
    fn block1d_halo() {
        let parts = Block1D::with_view(View::sym(1)).ranges(10, 3);
        assert_eq!(parts[0].own, Range1::new(0, 4));
        assert_eq!(parts[0].readable, Range1::new(0, 5));
        assert_eq!(parts[1].readable, Range1::new(3, 8));
        assert_eq!(parts[2].readable, Range1::new(6, 10));
    }

    #[test]
    fn block2d_covers_matrix() {
        let parts = Block2D::new().parts(10, 12, 4);
        assert_eq!(parts.len(), 4);
        let area: usize = parts.iter().map(|p| p.own.rows.len() * p.own.cols.len()).sum();
        assert_eq!(area, 120);
    }

    #[test]
    fn rows1d_full_width() {
        let parts = Rows1D::default().parts(9, 5, 2);
        assert_eq!(parts.len(), 2);
        assert!(parts.iter().all(|p| p.own.cols.len() == 5));
    }

    #[test]
    fn row_disjoint_never_splits_rows() {
        // rows: 0,0,0,1,1,2,3,3,3,3
        let row = [0u32, 0, 0, 1, 1, 2, 3, 3, 3, 3];
        let parts = RowDisjoint.parts(&row, 4, 3);
        assert_eq!(parts.len(), 3);
        // coverage + disjointness of nnz ranges
        assert_eq!(parts[0].nnz.lo, 0);
        assert_eq!(parts.last().unwrap().nnz.hi, row.len());
        for w in parts.windows(2) {
            assert_eq!(w[0].nnz.hi, w[1].nnz.lo);
            // row disjointness
            assert!(w[0].rows.hi <= w[1].rows.lo || w[1].nnz.is_empty());
        }
        // no boundary splits a row
        for p in &parts {
            if p.nnz.is_empty() {
                continue;
            }
            if p.nnz.hi < row.len() {
                assert_ne!(row[p.nnz.hi], row[p.nnz.hi - 1]);
            }
        }
    }

    #[test]
    fn row_disjoint_more_parts_than_rows() {
        let row = [0u32, 1];
        let parts = RowDisjoint.parts(&row, 2, 4);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.nnz.len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn tree_dist_partitions_node_count() {
        let tree: Tree<i64> = Tree::full(5, 1); // 2^6 - 1 = 63 nodes
        let parts = TreeDist::default().parts(&tree, 4);
        // top copy + 4 subtrees at 2 levels
        assert_eq!(parts.len(), 5);
        let total: usize = parts.iter().map(Tree::count).sum();
        assert_eq!(total, 63);
    }
}
