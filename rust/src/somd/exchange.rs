//! Intermediate reductions (paper §3.1, Figure 3): a reduction invoked
//! *inside* a SOMD method body is applied across all MIs — an all-reduce.
//!
//! The paper has one MI compute the operation and disseminate the result.
//! On shared memory we let every MI fold the same rank-ordered value list
//! (deterministic, so all copies are identical) — equivalent observable
//! behaviour without a second dissemination phase; the distributed
//! realization (out of scope, §4.2) is where the leader variant matters.
//!
//! Epoch-indexed slots make the exchange reusable: each MI deposits at its
//! own call-count epoch, so back-to-back all-reduces never race a slower
//! rank still reading the previous epoch's slots.

use std::any::Any;
use std::collections::HashMap;
use std::sync::Mutex;

use super::phaser::Phaser;
use super::reduction::Reduction;

/// The per-invocation all-reduce rendezvous (one slot row per MI).
pub struct Exchange {
    slots: Vec<Mutex<HashMap<u64, Box<dyn Any + Send>>>>,
    phaser: Phaser,
}

impl Exchange {
    /// An exchange for `parties` MIs.
    pub fn new(parties: usize) -> Self {
        Self {
            slots: (0..parties).map(|_| Mutex::new(HashMap::new())).collect(),
            phaser: Phaser::new(parties),
        }
    }

    /// Registered MI count.
    pub fn parties(&self) -> usize {
        self.slots.len()
    }

    /// All-reduce `v` across every MI.  `epoch` must be the caller's own
    /// monotone call counter (managed by [`crate::somd::mi::MiCtx`]).
    pub fn allreduce<T, Rd>(&self, rank: usize, epoch: u64, v: T, red: &Rd) -> T
    where
        T: Clone + Send + 'static,
        Rd: Reduction<T> + ?Sized,
    {
        self.slots[rank].lock().unwrap().insert(epoch, Box::new(v));
        self.phaser.arrive_and_wait();
        let vals: Vec<T> = (0..self.parties())
            .map(|r| {
                let slot = self.slots[r].lock().unwrap();
                slot.get(&epoch)
                    .expect("missing all-reduce deposit — divergent MI control flow?")
                    .downcast_ref::<T>()
                    .expect("all-reduce type mismatch across MIs")
                    .clone()
            })
            .collect();
        let result = red.reduce(vals);
        self.phaser.arrive_and_wait();
        self.slots[rank].lock().unwrap().remove(&epoch);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::reduction;
    use std::sync::Arc;

    fn run_allreduce(n: usize, rounds: usize) -> Vec<Vec<f64>> {
        let ex = Arc::new(Exchange::new(n));
        let mut handles = Vec::new();
        for rank in 0..n {
            let ex = ex.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for round in 0..rounds {
                    let v = (rank + 1) as f64 * (round + 1) as f64;
                    out.push(ex.allreduce(rank, round as u64, v, &reduction::sum::<f64>()));
                }
                out
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_ranks_get_same_sum() {
        let results = run_allreduce(4, 1);
        for r in &results {
            assert_eq!(r[0], 1.0 + 2.0 + 3.0 + 4.0);
        }
    }

    #[test]
    fn repeated_allreduces_do_not_cross_epochs() {
        let results = run_allreduce(3, 20);
        for round in 0..20 {
            let want = (1.0 + 2.0 + 3.0) * (round + 1) as f64;
            for r in &results {
                assert_eq!(r[round], want);
            }
        }
    }

    #[test]
    fn vector_payloads() {
        let ex = Arc::new(Exchange::new(2));
        let mut handles = Vec::new();
        for rank in 0..2 {
            let ex = ex.clone();
            handles.push(std::thread::spawn(move || {
                let v = vec![rank as i64; 3];
                ex.allreduce(rank, 0, v, &reduction::sum::<i64>().into_vec_elementwise())
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![1, 1, 1]);
        }
    }
}
