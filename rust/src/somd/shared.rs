//! Shared scalars (paper §3.1 "Shared scalars", Listing 14).
//!
//! A [`Shared<T>`] gives every MI its own local copy; consistency is only
//! re-established inside `sync reduce(op)(x) { … }` blocks
//! ([`crate::somd::mi::MiCtx::sync_reduce`]), which fold the local copies
//! into a single global value and write it back to every copy — the
//! paper's "syntactic sugar for an intermediate reduction".

use std::sync::Mutex;

/// A `shared` scalar: one local copy per MI (see the module docs).
pub struct Shared<T> {
    locals: Vec<Mutex<T>>,
}

impl<T: Clone> Shared<T> {
    /// One local copy per MI, all starting from the declared initial value.
    pub fn new(parties: usize, init: T) -> Self {
        Self { locals: (0..parties).map(|_| Mutex::new(init.clone())).collect() }
    }

    /// Number of per-MI copies.
    pub fn parties(&self) -> usize {
        self.locals.len()
    }

    /// Read this MI's local copy.
    pub fn get(&self, rank: usize) -> T {
        self.locals[rank].lock().unwrap().clone()
    }

    /// Overwrite this MI's local copy.
    pub fn set(&self, rank: usize, v: T) {
        *self.locals[rank].lock().unwrap() = v;
    }

    /// Mutate this MI's local copy in place.
    pub fn update(&self, rank: usize, f: impl FnOnce(&mut T)) {
        f(&mut self.locals[rank].lock().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locals_are_independent() {
        let s = Shared::new(3, 0i64);
        s.set(0, 10);
        s.update(1, |v| *v += 5);
        assert_eq!(s.get(0), 10);
        assert_eq!(s.get(1), 5);
        assert_eq!(s.get(2), 0);
    }

    #[test]
    fn initial_value_cloned_to_all() {
        let s = Shared::new(4, vec![1, 2]);
        for r in 0..4 {
            assert_eq!(s.get(r), vec![1, 2]);
        }
    }
}
