//! Elina-like worker pool (paper §6): SOMD execution requests may be
//! submitted concurrently and compete for a pool of threads managed by the
//! runtime system.
//!
//! The pool schedules *invocations* (whole SOMD calls); within one
//! invocation the master spawns its MIs with scoped threads so that
//! barrier-coupled MI groups can never deadlock on pool capacity (the MIs
//! of one method must be co-scheduled — same reason the paper sizes its
//! thread pool to the MI count).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<(VecDeque<Job>, bool)>, // (queue, shutting_down)
    cv: Condvar,
}

/// Fixed-size thread pool with FIFO scheduling.
pub struct WorkerPool {
    queue: Arc<Queue>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Handle to a submitted job's result.
pub struct JobHandle<R> {
    rx: mpsc::Receiver<std::thread::Result<R>>,
}

impl<R> JobHandle<R> {
    /// Block for the result; re-panics if the job panicked.
    pub fn join(self) -> R {
        match self.rx.recv().expect("worker pool dropped job") {
            Ok(r) => r,
            Err(e) => std::panic::resume_unwind(e),
        }
    }

    /// Non-blocking poll: `Some(result)` once the job finished.
    pub fn try_join(&self) -> Option<std::thread::Result<R>> {
        self.rx.try_recv().ok()
    }

    /// A handle fed by an external executor (the engine's device master
    /// thread submits results through the returned sender).
    pub(crate) fn pair() -> (mpsc::Sender<std::thread::Result<R>>, JobHandle<R>) {
        let (tx, rx) = mpsc::channel();
        (tx, JobHandle { rx })
    }
}

impl WorkerPool {
    /// A pool with `workers` threads (panics on 0).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let queue = Arc::new(Queue { jobs: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() });
        let handles = (0..workers)
            .map(|i| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("somd-worker-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("spawn worker")
            })
            .collect();
        Self { queue, handles }
    }

    /// The pool's thread count.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job; returns a handle to its result.
    pub fn submit<R: Send + 'static>(
        &self,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> JobHandle<R> {
        let (tx, rx) = mpsc::channel();
        let wrapped: Job = Box::new(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            let _ = tx.send(r);
        });
        {
            let mut g = self.queue.jobs.lock().unwrap();
            assert!(!g.1, "submit after shutdown");
            g.0.push_back(wrapped);
        }
        self.queue.cv.notify_one();
        JobHandle { rx }
    }
}

fn worker_loop(q: &Queue) {
    loop {
        let job = {
            let mut g = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = g.0.pop_front() {
                    break j;
                }
                if g.1 {
                    return;
                }
                g = q.cv.wait(g).unwrap();
            }
        };
        job();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.jobs.lock().unwrap().1 = true;
        self.queue.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs_and_returns_results() {
        let pool = WorkerPool::new(2);
        let hs: Vec<_> = (0..10).map(|i| pool.submit(move || i * i)).collect();
        let got: Vec<i32> = hs.into_iter().map(JobHandle::join).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let pool = Arc::new(WorkerPool::new(3));
        let count = Arc::new(AtomicUsize::new(0));
        let mut outer = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let count = count.clone();
            outer.push(std::thread::spawn(move || {
                let hs: Vec<_> = (0..8)
                    .map(|_| {
                        let c = count.clone();
                        pool.submit(move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        })
                    })
                    .collect();
                hs.into_iter().for_each(|h| h.join());
            }));
        }
        for h in outer {
            h.join().unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn job_panic_propagates_on_join() {
        let pool = WorkerPool::new(1);
        let h = pool.submit(|| panic!("job failed"));
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.join())).is_err());
        // pool survives the panic
        assert_eq!(pool.submit(|| 7).join(), 7);
    }

    #[test]
    fn drop_drains_gracefully() {
        let pool = WorkerPool::new(2);
        let h = pool.submit(|| 1);
        drop(pool);
        assert_eq!(h.join(), 1);
    }
}
