//! Reduction strategies (the paper's `reduce` qualifier, §3.1).
//!
//! Built-ins mirror the paper: primitive operations (`+`, `-`, `*`, plus
//! min/max), the default array-assembly reduction for methods returning
//! arrays, and user-defined strategies via [`Reduction`] implementations or
//! [`FnReduce`] closures.  Reductions are applied *sequentially and
//! deterministically* to the rank-ordered list of MI results (§3.1 — the
//! prototype does not validate associativity/commutativity; that contract
//! is the programmer's, exactly as in the paper).

/// A reduction `List<R> -> R` applied to the rank-ordered partial results.
pub trait Reduction<R>: Send + Sync {
    /// Fold the rank-ordered partials into the method's result.
    fn reduce(&self, parts: Vec<R>) -> R;
}

/// Fold with a binary op, left-to-right in rank order.
pub struct Fold<F> {
    op: F,
}

impl<F> Fold<F> {
    /// A fold over the given binary op.
    pub fn new(op: F) -> Self {
        Self { op }
    }
}

impl<R, F> Reduction<R> for Fold<F>
where
    F: Fn(R, R) -> R + Send + Sync,
{
    fn reduce(&self, parts: Vec<R>) -> R {
        let mut it = parts.into_iter();
        let first = it.next().expect("reduction over zero partial results");
        it.fold(first, |a, b| (self.op)(a, b))
    }
}

/// `reduce(+)`
pub fn sum<R: std::ops::Add<Output = R> + Send>() -> Fold<impl Fn(R, R) -> R + Send + Sync> {
    Fold::new(|a: R, b: R| a + b)
}

/// `reduce(-)`
pub fn sub<R: std::ops::Sub<Output = R> + Send>() -> Fold<impl Fn(R, R) -> R + Send + Sync> {
    Fold::new(|a: R, b: R| a - b)
}

/// `reduce(*)`
pub fn prod<R: std::ops::Mul<Output = R> + Send>() -> Fold<impl Fn(R, R) -> R + Send + Sync> {
    Fold::new(|a: R, b: R| a * b)
}

/// `reduce(min)` over f64.
pub fn min_f64() -> Fold<impl Fn(f64, f64) -> f64 + Send + Sync> {
    Fold::new(f64::min)
}

/// `reduce(max)` over f64.
pub fn max_f64() -> Fold<impl Fn(f64, f64) -> f64 + Send + Sync> {
    Fold::new(f64::max)
}

/// The default reduction when the method returns an array (§3.1): assemble
/// the partially computed arrays by rank-order concatenation.
pub struct Assemble;

impl<T: Send> Reduction<Vec<T>> for Assemble {
    fn reduce(&self, parts: Vec<Vec<T>>) -> Vec<T> {
        let total = parts.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            out.extend(p);
        }
        out
    }
}

/// Elementwise lift of a binary fold onto vectors (`reduce(+)` applied to
/// an array-valued method: combine rank results element by element).
pub struct ElementwiseVec<F> {
    op: F,
}

impl<T, F> Reduction<Vec<T>> for ElementwiseVec<F>
where
    T: Send,
    F: Fn(T, T) -> T + Send + Sync,
{
    fn reduce(&self, parts: Vec<Vec<T>>) -> Vec<T> {
        let mut it = parts.into_iter();
        let mut acc = it.next().expect("reduction over zero partial results");
        for p in it {
            assert_eq!(acc.len(), p.len(), "elementwise reduction length mismatch");
            acc = acc.into_iter().zip(p).map(|(a, b)| (self.op)(a, b)).collect();
        }
        acc
    }
}

impl<F> Fold<F> {
    /// Lift this fold to vectors, combining element by element.
    pub fn into_vec_elementwise(self) -> ElementwiseVec<F> {
        ElementwiseVec { op: self.op }
    }
}

/// User-defined reduction from a whole-list closure.
pub struct FnReduce<F> {
    f: F,
}

impl<F> FnReduce<F> {
    /// A reduction from a whole-list closure.
    pub fn new(f: F) -> Self {
        Self { f }
    }
}

impl<R, F> Reduction<R> for FnReduce<F>
where
    F: Fn(Vec<R>) -> R + Send + Sync,
{
    fn reduce(&self, parts: Vec<R>) -> R {
        (self.f)(parts)
    }
}

/// `reduce(self)` (§3.1 self-reductions): re-apply the method body itself
/// to the list of partial results.  The caller supplies the body as a
/// closure over the collected parts.
pub fn self_reduction<R, F>(body: F) -> FnReduce<F>
where
    F: Fn(Vec<R>) -> R + Send + Sync,
{
    FnReduce::new(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_folds_in_rank_order() {
        assert_eq!(sum::<i64>().reduce(vec![1, 2, 3, 4]), 10);
    }

    #[test]
    fn sub_is_left_fold() {
        // determinism matters for non-commutative ops
        assert_eq!(sub::<i64>().reduce(vec![10, 1, 2]), 7);
    }

    #[test]
    fn prod_works() {
        assert_eq!(prod::<i64>().reduce(vec![2, 3, 4]), 24);
    }

    #[test]
    fn assemble_concatenates_by_rank() {
        let out = Assemble.reduce(vec![vec![1, 2], vec![3], vec![], vec![4, 5]]);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn self_reduction_reapplies_body() {
        // sum method: body over a list of partial sums is itself a sum
        let r = self_reduction(|parts: Vec<i64>| parts.iter().sum());
        assert_eq!(r.reduce(vec![3, 4, 5]), 12);
    }

    #[test]
    #[should_panic]
    fn empty_reduction_panics() {
        let _ = sum::<i64>().reduce(vec![]);
    }
}
