//! Method pipelines: execution plans of chained stages with
//! **device-resident intermediates** (the top ROADMAP open item; HSTREAM
//! and the TornadoVM task-graph line are the precedents — see
//! `docs/PIPELINES.md` for the full walkthrough).
//!
//! The paper's SOMD model (§6) treats every invocation as an isolated
//! host round-trip, yet its own evaluation workloads chain methods —
//! SOR step → sum, crypt encrypt → decrypt — paying a full D2H+H2D on
//! every hop.  An [`ExecutionPlan`] chains stages (each described by a
//! [`PipelineSpec`], attached to its method via
//! [`HeteroMethod::with_pipeline`]) so that when consecutive stages
//! resolve to the device lane, the upstream outputs *stay resident* as
//! the downstream inputs:
//!
//! * **residency** — a fused device→device hop moves zero bytes; the
//!   skipped round trip is counted explicitly in
//!   [`DeviceStats::h2d_skipped`]/[`DeviceStats::d2h_skipped`] (and fed
//!   to the scheduler as a *resident run*, never diluting
//!   `transfer_bytes_per_run`);
//! * **memoized uploads** — host inputs enter through
//!   [`DeviceSession::put_cached`]: a content-hash match on an
//!   already-resident upload pins and reuses it (refcounted buffers),
//!   observable through [`Engine::device_counters`];
//! * **overlap** — with a fused plan, stage `i+1`'s H2D rides under
//!   stage `i`'s modeled compute (double-buffering;
//!   `SOMD_PIPELINE_OVERLAP=off` disables);
//! * **fallback** — a failing device stage re-runs on SMP *from the
//!   stage's pinned inputs* and downstream stages see correct host data:
//!   no stale resident buffer can leak forward (§6's fallback
//!   discipline, extended to plans).
//!
//! With a device fleet attached, all device stages of one plan run are
//! pinned to a single lane through [`Engine::run_on_lane`] (FIFO per
//! lane keeps the warm session's buffers valid across jobs); without a
//! fleet, a plan-local [`DeviceSession`] over the caller's registry
//! plays the same role.  `run(.., fused=false)` executes the identical
//! plan as isolated per-stage round-trips — the reference path every
//! pipeline test compares against, bitwise.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{HeteroMethod, PipelineSpec};
use crate::device::{BufId, DeviceProfile, DeviceSession, DeviceStats};
use crate::runtime::{HostTensor, Registry};

use super::config::Target;
use super::engine::Engine;

/// Default fixed device fraction for pipeline hybrid stages
/// (overridden by `SOMD_PIPELINE_HYBRID_FRACTION`).
pub const DEFAULT_PIPELINE_HYBRID_FRACTION: f64 = 0.5;

/// The fixed device fraction pipeline hybrid stages split at.  Fixed —
/// not the scheduler's learned ratio — because the fused and reference
/// runs must split identically for order-sensitive float reductions to
/// stay bitwise equal.
pub fn hybrid_fraction_from_env() -> f64 {
    std::env::var("SOMD_PIPELINE_HYBRID_FRACTION")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|f| f.is_finite() && *f > 0.0 && *f < 1.0)
        .unwrap_or(DEFAULT_PIPELINE_HYBRID_FRACTION)
}

/// Whether fused plans overlap stage `i+1` H2D with stage `i` compute
/// (`SOMD_PIPELINE_OVERLAP=0|off|false` disables; default on).
pub fn overlap_from_env() -> bool {
    !matches!(
        std::env::var("SOMD_PIPELINE_OVERLAP").as_deref().map(str::trim),
        Ok("0") | Ok("off") | Ok("false")
    )
}

/// One stage of an [`ExecutionPlan`]: a method name (resolved against
/// the engine's rules/history like any invocation) plus its type-erased
/// stage evaluators.
struct PlanStage {
    name: String,
    spec: Arc<PipelineSpec>,
}

/// An ordered chain of stages executed with device-resident
/// intermediates (see the module docs).  Build with
/// [`ExecutionPlan::stage`]/[`ExecutionPlan::then_method`], execute with
/// [`ExecutionPlan::run`].
#[derive(Default)]
pub struct ExecutionPlan {
    stages: Vec<PlanStage>,
}

/// Which lane one stage of a plan run actually used (after §6 fallback
/// resolution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageLane {
    /// The shared-memory pool (preference, fallback, or failure cover).
    Smp,
    /// The device lane, inputs/outputs resident.
    Device,
    /// Fixed-fraction co-execution across SMP + device.
    Hybrid,
}

/// Per-stage execution report of one plan run.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// The stage's method name.
    pub name: String,
    /// The lane the stage actually ran on.
    pub lane: StageLane,
    /// Device profile (device-lane stages only).
    pub profile: Option<String>,
    /// Whether the stage consumed its inputs device-resident (a fused
    /// hop from the previous stage — the boundary moved zero D2H bytes).
    pub resident_in: bool,
    /// D2H bytes paid materializing this stage's *outputs* to the host
    /// (0 while they stay resident for the next stage).
    pub exit_d2h_bytes: usize,
    /// Whether the stage fell back to SMP after a device/hybrid failure.
    pub fell_back: bool,
    /// The failure that triggered the fallback, if any.
    pub error: Option<String>,
    /// Stage wall seconds (evaluator only; entry/exit transfers charge
    /// the modeled clock in `stats`).
    pub secs: f64,
    /// Device accounting delta for this stage (device-lane stages and
    /// any materialization charged to them).
    pub stats: Option<DeviceStats>,
}

/// The outcome of one [`ExecutionPlan::run`].
#[derive(Debug)]
pub struct PipelineReport {
    /// Per-stage execution reports, in plan order.
    pub stages: Vec<StageReport>,
    /// The final stage's outputs, materialized to the host.
    pub outputs: Vec<HostTensor>,
    /// Stage boundaries that stayed device-resident: the downstream
    /// stage consumed resident inputs *and* the upstream stage paid zero
    /// exit D2H bytes — the provably-free hops.
    pub resident_boundaries: usize,
    /// Wall seconds for the whole run.
    pub wall_secs: f64,
    /// Modeled seconds: device-stage modeled clocks (transfers, launch
    /// overheads, scaled compute) plus host-lane stage wall time — the
    /// quantity the `somd bench pipeline` gate compares.
    pub modeled_secs: f64,
}

/// Intermediate data flowing between stages.
enum StageData {
    /// Host tensors (plan inputs, host-lane stage outputs, or
    /// materialized device outputs).
    Host(Vec<HostTensor>),
    /// Device-resident buffers (fused device-stage outputs).
    Resident(Vec<BufId>),
}

/// Outcome of one device-stage attempt (crosses back from a lane job,
/// so everything is owned and `Send`).
struct DevOutcome {
    /// `Ok`: resident outputs.  `Err`: the stage's inputs, downloaded
    /// from their pinned buffers for the SMP fallback, plus the error.
    result: std::result::Result<Vec<BufId>, (Vec<HostTensor>, String)>,
    delta: DeviceStats,
    secs: f64,
    resident_in: bool,
}

/// Run one device stage on `session`.  Host inputs enter through the
/// memo cache when `memoize` (fused plans); resident inputs are handed
/// over in place with the skipped round-trip counted.  Inputs are pinned
/// across the evaluator call so a failure can still download them for
/// the SMP fallback — no stale resident buffer survives a failed stage.
fn device_stage_on(
    session: &mut DeviceSession<'_>,
    spec: &PipelineSpec,
    data: StageData,
    memoize: bool,
    overlap: bool,
) -> Result<DevOutcome> {
    session.set_overlap(overlap);
    let (ids, resident_in) = match data {
        StageData::Host(ts) => {
            let mut ids = Vec::with_capacity(ts.len());
            for t in &ts {
                ids.push(if memoize { session.put_cached(t)? } else { session.put(t)? });
            }
            (ids, false)
        }
        StageData::Resident(ids) => {
            for id in &ids {
                let bytes = session.memory().bytes_of(*id)?;
                session.note_resident_handoff(bytes);
            }
            (ids, true)
        }
    };
    for id in &ids {
        session.retain(*id)?;
    }
    let before = session.stats();
    let t0 = Instant::now();
    let dev = spec.device.as_ref().ok_or_else(|| anyhow!("stage has no device evaluator"))?;
    let out = dev(session, ids.clone());
    let secs = t0.elapsed().as_secs_f64();
    match out {
        Ok(outs) => {
            let delta = session.stats().delta_since(&before);
            for id in &ids {
                session.free(*id)?; // drop the fallback pins
            }
            Ok(DevOutcome { result: Ok(outs), delta, secs, resident_in })
        }
        Err(e) => {
            // the evaluator's own input references are in an unknown
            // state, but the pins still hold the data: download it so
            // the SMP fallback re-runs the stage from correct inputs
            let mut host = Vec::with_capacity(ids.len());
            for id in &ids {
                host.push(session.get(*id)?);
                session.free(*id)?;
            }
            let delta = session.stats().delta_since(&before);
            Ok(DevOutcome { result: Err((host, e.to_string())), delta, secs, resident_in })
        }
    }
}

/// Download `ids` to the host and free them; returns the tensors plus
/// the accounting delta (its `bytes_d2h` is the hop's exit cost).
fn materialize_on(
    session: &mut DeviceSession<'_>,
    ids: Vec<BufId>,
) -> Result<(Vec<HostTensor>, DeviceStats)> {
    let before = session.stats();
    let mut out = Vec::with_capacity(ids.len());
    for id in ids {
        out.push(session.get(id)?);
        session.free(id)?;
    }
    Ok((out, session.stats().delta_since(&before)))
}

/// Where a plan run's device stages execute: pinned to one fleet lane's
/// warm session (fleet attached) or on a plan-local session over the
/// caller's registry (no fleet).  Either way, one session spans the
/// whole run — the residency/memo substrate.
enum Exec<'e, 'r> {
    Lane { engine: &'e Engine, lane: usize },
    Local { session: Option<DeviceSession<'r>>, registry: &'r Registry },
}

impl<'e, 'r> Exec<'e, 'r> {
    fn device_stage(
        &mut self,
        spec: &Arc<PipelineSpec>,
        data: StageData,
        profile: &str,
        memoize: bool,
        overlap: bool,
    ) -> Result<DevOutcome> {
        match self {
            Exec::Lane { engine, lane } => {
                let spec = spec.clone();
                let profile = profile.to_string();
                engine.run_on_lane(*lane, move |ctx| -> Result<DevOutcome> {
                    let session = ctx.session(&profile)?;
                    device_stage_on(session, &spec, data, memoize, overlap)
                })?
            }
            Exec::Local { session, registry } => {
                if session.is_none() {
                    let p = DeviceProfile::by_name(profile)
                        .ok_or_else(|| anyhow!("unknown device profile '{profile}'"))?;
                    *session = Some(DeviceSession::new(registry, p));
                }
                let s = session.as_mut().expect("session just initialized");
                device_stage_on(s, spec, data, memoize, overlap)
            }
        }
    }

    fn materialize(
        &mut self,
        ids: Vec<BufId>,
        profile: &str,
    ) -> Result<(Vec<HostTensor>, DeviceStats)> {
        match self {
            Exec::Lane { engine, lane } => {
                let profile = profile.to_string();
                engine.run_on_lane(*lane, move |ctx| -> Result<(Vec<HostTensor>, DeviceStats)> {
                    let session = ctx.session(&profile)?;
                    materialize_on(session, ids)
                })?
            }
            Exec::Local { session, .. } => {
                let s = session
                    .as_mut()
                    .ok_or_else(|| anyhow!("resident data without a device session"))?;
                materialize_on(s, ids)
            }
        }
    }

    /// Reset overlap on the session the run used (warm lane sessions
    /// outlive the plan; leave them in the default state).
    fn finish(&mut self, profile: &str) {
        match self {
            Exec::Lane { engine, lane } => {
                if !profile.is_empty() {
                    let profile = profile.to_string();
                    let _ = engine.run_on_lane(*lane, move |ctx| {
                        if let Ok(s) = ctx.session(&profile) {
                            s.set_overlap(false);
                        }
                    });
                }
            }
            Exec::Local { session, .. } => {
                if let Some(s) = session {
                    s.set_overlap(false);
                }
            }
        }
    }
}

impl ExecutionPlan {
    /// An empty plan.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a stage (builder style): `name` resolves against the
    /// engine's rules/history exactly like a plain invocation of that
    /// method would.
    pub fn stage(mut self, name: impl Into<String>, spec: PipelineSpec) -> Self {
        self.stages.push(PlanStage { name: name.into(), spec: Arc::new(spec) });
        self
    }

    /// Append a stage from a method's attached [`PipelineSpec`] (set via
    /// [`HeteroMethod::with_pipeline`]); the plan takes ownership of the
    /// stage evaluators.  Errors when the method has none.
    pub fn then_method<I: ?Sized + Sync, P: Send + Sync, E: Sync, R: Send>(
        self,
        method: &mut HeteroMethod<I, P, E, R>,
    ) -> Result<Self> {
        let spec = method
            .take_pipeline()
            .ok_or_else(|| anyhow!("method '{}' has no pipeline spec", method.name()))?;
        let name = method.name().to_string();
        Ok(self.stage(name, spec))
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// Whether the plan has no stages.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// The stage method names, in plan order.
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.name.as_str()).collect()
    }

    /// Execute the plan over `inputs`.
    ///
    /// `fused = true` keeps intermediates device-resident across
    /// consecutive device stages (memoized uploads, overlap, skipped
    /// round-trips); `fused = false` is the per-stage reference path —
    /// every stage round-trips host memory through plain `put`/`get`,
    /// exactly as isolated invocations would.  Both paths resolve each
    /// stage through the same §6 ladder, so for a given engine they run
    /// on the same lanes and their outputs must be bitwise identical.
    pub fn run(
        &self,
        engine: &Engine,
        registry: &Registry,
        inputs: Vec<HostTensor>,
        fused: bool,
    ) -> Result<PipelineReport> {
        if self.stages.is_empty() {
            return Err(anyhow!("empty execution plan"));
        }
        let overlap = fused && overlap_from_env();
        let t_run = Instant::now();
        let tctx = engine.tracer().begin();
        let mut root = tctx.span("pipeline.run", None);
        root.field_u64("stages", self.stages.len() as u64);
        root.field_str("mode", if fused { "fused" } else { "per-stage" });

        let mut exec = if engine.device_ready() {
            let pending = engine.device_lane_pending();
            let lane = pending
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| **p)
                .map(|(i, _)| i)
                .unwrap_or(0);
            Exec::Lane { engine, lane }
        } else {
            Exec::Local { session: None, registry }
        };
        // all device stages of one run share one profile (and with it
        // one session), fixed by the first device-resolved stage —
        // resident handles are meaningless across sessions
        let mut plan_profile = String::new();

        let mut data = StageData::Host(inputs);
        let mut reports: Vec<StageReport> = Vec::new();
        let mut modeled = 0.0f64;

        for stage in &self.stages {
            let mut sspan = tctx.span("pipeline.stage", Some(root.id()));
            sspan.field_str("stage", stage.name.clone());
            let applicable =
                |p: &str| stage.spec.has_device() && DeviceProfile::by_name(p).is_some();
            let hybrid_ok = stage.spec.has_hybrid()
                && DeviceProfile::by_name(engine.auto_profile()).is_some();
            let target = engine.resolve_target(&stage.name, &applicable, hybrid_ok, 0);

            // take the flowing data; the arms put the stage output back
            let taken = std::mem::replace(&mut data, StageData::Host(Vec::new()));

            match target {
                Target::Device(p) => {
                    if plan_profile.is_empty() {
                        plan_profile = p;
                    }
                    let outcome = exec.device_stage(
                        &stage.spec,
                        taken,
                        &plan_profile,
                        fused,
                        overlap,
                    )?;
                    modeled += outcome.delta.device_time.as_secs_f64();
                    match outcome.result {
                        Ok(outs) => {
                            engine.scheduler().record_device(
                                &stage.name,
                                Duration::from_secs_f64(outcome.secs),
                                &outcome.delta,
                            );
                            reports.push(StageReport {
                                name: stage.name.clone(),
                                lane: StageLane::Device,
                                profile: Some(plan_profile.clone()),
                                resident_in: outcome.resident_in,
                                exit_d2h_bytes: 0,
                                fell_back: false,
                                error: None,
                                secs: outcome.secs,
                                stats: Some(outcome.delta),
                            });
                            if fused {
                                data = StageData::Resident(outs);
                            } else {
                                // reference path: round-trip every hop
                                let (host, d) = exec.materialize(outs, &plan_profile)?;
                                modeled += d.device_time.as_secs_f64();
                                let last = reports.last_mut().expect("stage just pushed");
                                last.exit_d2h_bytes += d.bytes_d2h;
                                if let Some(st) = &mut last.stats {
                                    st.absorb(&d);
                                }
                                data = StageData::Host(host);
                            }
                        }
                        Err((host_inputs, msg)) => {
                            engine.scheduler().record_device_failure(&stage.name);
                            let t0 = Instant::now();
                            let outs = (stage.spec.smp)(&host_inputs)?;
                            let secs = t0.elapsed();
                            engine.scheduler().record_smp(&stage.name, secs);
                            modeled += secs.as_secs_f64();
                            reports.push(StageReport {
                                name: stage.name.clone(),
                                lane: StageLane::Smp,
                                profile: None,
                                resident_in: outcome.resident_in,
                                exit_d2h_bytes: 0,
                                fell_back: true,
                                error: Some(msg),
                                secs: secs.as_secs_f64(),
                                stats: Some(outcome.delta),
                            });
                            data = StageData::Host(outs);
                        }
                    }
                }
                Target::Hybrid | Target::Sharded if stage.spec.has_hybrid() => {
                    let host = self.to_host(&mut exec, taken, &plan_profile, &mut reports, &mut modeled)?;
                    let hybrid =
                        stage.spec.hybrid.as_ref().expect("hybrid_ok implies evaluator");
                    let t0 = Instant::now();
                    match hybrid(engine, registry, &host) {
                        Ok(outs) => {
                            let secs = t0.elapsed().as_secs_f64();
                            modeled += secs;
                            reports.push(StageReport {
                                name: stage.name.clone(),
                                lane: StageLane::Hybrid,
                                profile: None,
                                resident_in: false,
                                exit_d2h_bytes: 0,
                                fell_back: false,
                                error: None,
                                secs,
                                stats: None,
                            });
                            data = StageData::Host(outs);
                        }
                        Err(e) => {
                            // the evaluator records its own failure; the
                            // stage still completes on SMP
                            let t1 = Instant::now();
                            let outs = (stage.spec.smp)(&host)?;
                            let secs = t1.elapsed();
                            engine.scheduler().record_smp(&stage.name, secs);
                            modeled += secs.as_secs_f64();
                            reports.push(StageReport {
                                name: stage.name.clone(),
                                lane: StageLane::Smp,
                                profile: None,
                                resident_in: false,
                                exit_d2h_bytes: 0,
                                fell_back: true,
                                error: Some(e.to_string()),
                                secs: secs.as_secs_f64(),
                                stats: None,
                            });
                            data = StageData::Host(outs);
                        }
                    }
                }
                _ => {
                    let host = self.to_host(&mut exec, taken, &plan_profile, &mut reports, &mut modeled)?;
                    let t0 = Instant::now();
                    let outs = (stage.spec.smp)(&host)?;
                    let secs = t0.elapsed();
                    engine.scheduler().record_smp(&stage.name, secs);
                    modeled += secs.as_secs_f64();
                    reports.push(StageReport {
                        name: stage.name.clone(),
                        lane: StageLane::Smp,
                        profile: None,
                        resident_in: false,
                        exit_d2h_bytes: 0,
                        fell_back: false,
                        error: None,
                        secs: secs.as_secs_f64(),
                        stats: None,
                    });
                    data = StageData::Host(outs);
                }
            }
            // the arms each push exactly one report for this stage
            if let Some(rep) = reports.last() {
                sspan.field_str(
                    "lane",
                    match rep.lane {
                        StageLane::Smp => "smp",
                        StageLane::Device => "device",
                        StageLane::Hybrid => "hybrid",
                    },
                );
                sspan.field_f64("stage_secs", rep.secs);
                sspan.field_u64("fell_back", rep.fell_back as u64);
                if let Some(st) = &rep.stats {
                    sspan.field_u64("bytes_h2d", st.bytes_h2d as u64);
                    sspan.field_u64("bytes_d2h", st.bytes_d2h as u64);
                }
            }
            sspan.finish();
        }

        // the plan's outputs always land on the host (both paths pay
        // this final download, so the comparison stays fair)
        let outputs = match data {
            StageData::Host(ts) => ts,
            StageData::Resident(ids) => {
                let (host, d) = exec.materialize(ids, &plan_profile)?;
                modeled += d.device_time.as_secs_f64();
                if let Some(last) = reports.last_mut() {
                    last.exit_d2h_bytes += d.bytes_d2h;
                    if let Some(st) = &mut last.stats {
                        st.absorb(&d);
                    }
                }
                host
            }
        };
        exec.finish(&plan_profile);

        // a boundary is provably resident when the downstream stage took
        // resident inputs AND the upstream stage paid zero exit D2H —
        // a stage that fell back re-downloaded its inputs, so its entry
        // hop does not count even though it started resident
        let resident_boundaries = reports
            .windows(2)
            .filter(|w| w[1].resident_in && !w[1].fell_back && w[0].exit_d2h_bytes == 0)
            .count();

        Ok(PipelineReport {
            stages: reports,
            outputs,
            resident_boundaries,
            wall_secs: t_run.elapsed().as_secs_f64(),
            modeled_secs: modeled,
        })
    }

    /// Materialize `data` to host tensors for a host-lane stage,
    /// charging any exit D2H to the previous stage's report.
    fn to_host(
        &self,
        exec: &mut Exec<'_, '_>,
        data: StageData,
        profile: &str,
        reports: &mut Vec<StageReport>,
        modeled: &mut f64,
    ) -> Result<Vec<HostTensor>> {
        match data {
            StageData::Host(ts) => Ok(ts),
            StageData::Resident(ids) => {
                let (host, d) = exec.materialize(ids, profile)?;
                *modeled += d.device_time.as_secs_f64();
                if let Some(last) = reports.last_mut() {
                    last.exit_d2h_bytes += d.bytes_d2h;
                    if let Some(st) = &mut last.stats {
                        st.absorb(&d);
                    }
                }
                Ok(host)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::reduction;
    use crate::somd::{Block1D, SomdMethod};

    fn reg() -> Registry {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Registry::load(dir).unwrap()
    }

    fn double_spec() -> PipelineSpec {
        PipelineSpec::new(|ts: &[HostTensor]| {
            let v = ts[0].as_f32()?;
            Ok(vec![HostTensor::vec_f32(v.iter().map(|x| x * 2.0).collect())])
        })
    }

    #[test]
    fn empty_plan_rejected_and_builder_reports_shape() {
        let engine = Engine::new(2);
        let r = reg();
        let plan = ExecutionPlan::new();
        assert!(plan.is_empty());
        assert!(plan.run(&engine, &r, vec![], true).is_err());
        let plan = plan.stage("A.a", double_spec()).stage("B.b", double_spec());
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.stage_names(), vec!["A.a", "B.b"]);
    }

    #[test]
    fn smp_only_plan_chains_host_stages() {
        let engine = Engine::new(2);
        let r = reg();
        let plan = ExecutionPlan::new()
            .stage("Pipe.double", double_spec())
            .stage("Pipe.double2", double_spec());
        let input = HostTensor::vec_f32(vec![1.0, 2.0, 3.0]);
        let rep = plan.run(&engine, &r, vec![input], true).unwrap();
        assert_eq!(rep.outputs[0].as_f32().unwrap(), &[4.0, 8.0, 12.0]);
        assert_eq!(rep.stages.len(), 2);
        assert!(rep.stages.iter().all(|s| s.lane == StageLane::Smp && !s.fell_back));
        assert_eq!(rep.resident_boundaries, 0);
        // both stages fed the scheduler history
        assert!(engine.scheduler().history("Pipe.double").is_some());
    }

    #[test]
    fn then_method_takes_the_attached_spec() {
        let smp = SomdMethod::new(
            "Pipe.m",
            |inp: &Vec<f32>, n| Block1D::new().ranges(inp.len(), n),
            |_, _| (),
            |_, _, _, _| 0.0f64,
            reduction::sum::<f64>(),
        );
        let mut m = HeteroMethod::smp_only(smp).with_pipeline(double_spec());
        assert!(m.has_pipeline_version());
        let plan = ExecutionPlan::new().then_method(&mut m).unwrap();
        assert_eq!(plan.stage_names(), vec!["Pipe.m"]);
        assert!(!m.has_pipeline_version());
        // a second take has nothing left
        assert!(ExecutionPlan::new().then_method(&mut m).is_err());
    }

    #[test]
    fn env_knob_parsers_have_sane_defaults() {
        // no env set in the test harness: defaults
        assert!(overlap_from_env());
        let f = hybrid_fraction_from_env();
        assert!(f > 0.0 && f < 1.0);
    }
}
