//! Per-MI execution context (the compiler-generated parameters of
//! Algorithm 1: rank, fence phaser, results slot, shared environment).

use std::cell::Cell;

use super::exchange::Exchange;
use super::phaser::Phaser;
use super::reduction::Reduction;
use super::shared::Shared;

/// Handed to every method instance; owns nothing, borrows the invocation
/// environment created by the master.
pub struct MiCtx<'a> {
    rank: usize,
    parts: usize,
    fence: &'a Phaser,
    exchange: &'a Exchange,
    epoch: Cell<u64>,
    barriers: Cell<u64>,
}

impl<'a> MiCtx<'a> {
    pub(crate) fn new(rank: usize, parts: usize, fence: &'a Phaser, exchange: &'a Exchange) -> Self {
        Self { rank, parts, fence, exchange, epoch: Cell::new(0), barriers: Cell::new(0) }
    }

    /// This MI's rank in `[0, parts)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Total number of MIs in this invocation.
    pub fn parts(&self) -> usize {
        self.parts
    }

    /// `sync { … }` (§3.1): run the block, then fence — on shared memory a
    /// barrier under the strict memory model (§4.1/§5.1).
    pub fn sync<R>(&self, block: impl FnOnce() -> R) -> R {
        let r = block();
        self.fence.arrive_and_wait();
        self.barriers.set(self.barriers.get() + 1);
        r
    }

    /// A bare fence (used by generated code that needs phase alignment
    /// without a block, e.g. double-buffer swaps).
    pub fn fence(&self) {
        self.fence.arrive_and_wait();
        self.barriers.set(self.barriers.get() + 1);
    }

    /// Intermediate reduction (§3.1, Figure 3): all-reduce `v` across MIs.
    pub fn allreduce<T, Rd>(&self, v: T, red: &Rd) -> T
    where
        T: Clone + Send + 'static,
        Rd: Reduction<T> + ?Sized,
    {
        let e = self.epoch.get();
        self.epoch.set(e + 1);
        self.exchange.allreduce(self.rank, e, v, red)
    }

    /// `sync reduce(op)(x) { … }` (Listing 14): run the block (which may
    /// update the MI's local copy of `x`), then fold all local copies and
    /// write the folded value back into every local copy.
    pub fn sync_reduce<T, Rd>(&self, shared: &Shared<T>, red: &Rd, block: impl FnOnce())
    where
        T: Clone + Send + 'static,
        Rd: Reduction<T> + ?Sized,
    {
        block();
        let v = shared.get(self.rank);
        let folded = self.allreduce(v, red);
        shared.set(self.rank, folded);
    }

    /// Barriers this MI has crossed (observability/testing).
    pub fn barrier_count(&self) -> u64 {
        self.barriers.get()
    }

    /// The `single` construct (paper §7.5, proposed future work): the
    /// enclosed block executes on exactly one MI (rank 0); its result is
    /// broadcast to every MI, with fences on both sides so the block sees
    /// a consistent pre-state and all MIs see its effects.
    ///
    /// This is what lets an iterative algorithm (LUFact) keep its MIs
    /// alive across outer iterations instead of paying a split-join per
    /// iteration — quantified in `benches/ablations.rs`.
    pub fn single<T, F>(&self, block: F) -> T
    where
        T: Clone + Send + 'static,
        F: FnOnce() -> T,
    {
        self.fence.arrive_and_wait();
        self.barriers.set(self.barriers.get() + 1);
        let v = if self.rank == 0 { Some(block()) } else { None };
        // broadcast: reuse the exchange; rank 0's value wins
        let e = self.epoch.get();
        self.epoch.set(e + 1);
        self.exchange
            .allreduce(
                self.rank,
                e,
                v,
                &crate::somd::reduction::FnReduce::new(|parts: Vec<Option<T>>| {
                    // rank order: element 0 is rank 0's Some(value)
                    parts.into_iter().next().expect("at least one MI")
                }),
            )
            .expect("rank 0 must produce the single block's value")
    }
}
