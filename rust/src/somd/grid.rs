//! Shared 2-D arrays for MI-visible matrix data (paper §3.1 "Shared Array
//! Positions").
//!
//! [`SharedGrid`] is a PGAS-style shared plane: every MI may read anywhere
//! inside its halo-widened view, but must only write inside its owned
//! partition; cross-MI visibility is only guaranteed after a `sync` fence.
//! That contract is the paper's relaxed-consistency shared array; it is
//! what makes the interior-disjoint writes below sound (see the `unsafe`
//! note).  [`DoubleGrid`] packages the front/back planes used by the
//! Jacobi-style SOR sweep.

use std::cell::UnsafeCell;

/// Row-major `rows x cols` matrix writable by multiple MIs at disjoint
/// positions.
pub struct SharedGrid {
    rows: usize,
    cols: usize,
    // one UnsafeCell per element: same layout as f64 (repr(transparent)),
    // so row views can be cast to &[f64] under the fencing contract.
    data: Vec<UnsafeCell<f64>>,
}

// SAFETY: MIs write only inside their owned (disjoint) partitions and read
// across partitions only between `sync` fences, which impose a
// happens-before edge (Mutex+Condvar in Phaser). This is the same contract
// the paper's generated Java code relies on.
unsafe impl Sync for SharedGrid {}
unsafe impl Send for SharedGrid {}

impl SharedGrid {
    /// A `rows x cols` grid filled with `init`.
    pub fn new(rows: usize, cols: usize, init: f64) -> Self {
        Self { rows, cols, data: (0..rows * cols).map(|_| UnsafeCell::new(init)).collect() }
    }

    /// A grid adopting `data` (row-major, length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data: data.into_iter().map(UnsafeCell::new).collect() }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Read one element (fenced by the SOMD sync contract).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        unsafe { *self.data.get_unchecked(r * self.cols + c).get() }
    }

    /// Write one element the caller's MI owns for this phase.
    #[inline]
    pub fn set(&self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        unsafe { *self.data.get_unchecked(r * self.cols + c).get() = v }
    }

    /// Immutable row slice (valid under the same fencing contract).
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        debug_assert!(r < self.rows);
        // SAFETY: UnsafeCell<f64> is repr(transparent) over f64; reads are
        // fenced by the SOMD sync contract.
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr().add(r * self.cols).cast::<f64>(),
                self.cols,
            )
        }
    }

    /// Raw mutable row access for an MI that owns row `r`.
    ///
    /// # Safety
    /// The caller must own row `r` exclusively for the current phase.
    #[inline]
    pub unsafe fn row_mut(&self, r: usize) -> &mut [f64] {
        debug_assert!(r < self.rows);
        std::slice::from_raw_parts_mut(
            self.data.as_ptr().add(r * self.cols).cast::<f64>().cast_mut(),
            self.cols,
        )
    }

    /// Snapshot to an owned Vec (master-side, after join).
    pub fn to_vec(&self) -> Vec<f64> {
        (0..self.rows * self.cols).map(|i| unsafe { *self.data[i].get() }).collect()
    }
}

/// Front/back planes for out-of-place iterative stencils: MIs read from
/// `src(iter)` and write to `dst(iter)`, flipping parity every iteration
/// (the flip is implicit — no shared mutable state to coordinate).
pub struct DoubleGrid {
    planes: [SharedGrid; 2],
}

impl DoubleGrid {
    /// Both planes initialized from `data` (row-major).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        let a = SharedGrid::from_vec(rows, cols, data.clone());
        let b = SharedGrid::from_vec(rows, cols, data);
        Self { planes: [a, b] }
    }

    /// The plane read during iteration `iter`.
    pub fn src(&self, iter: usize) -> &SharedGrid {
        &self.planes[iter % 2]
    }

    /// The plane written during iteration `iter`.
    pub fn dst(&self, iter: usize) -> &SharedGrid {
        &self.planes[(iter + 1) % 2]
    }

    /// The plane holding the result after `iters` completed iterations.
    pub fn final_plane(&self, iters: usize) -> &SharedGrid {
        &self.planes[iters % 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let g = SharedGrid::new(3, 4, 0.0);
        g.set(2, 3, 7.5);
        assert_eq!(g.get(2, 3), 7.5);
        assert_eq!(g.row(2)[3], 7.5);
    }

    #[test]
    fn disjoint_parallel_writes() {
        let g = SharedGrid::new(8, 100, 0.0);
        std::thread::scope(|s| {
            for r in 0..8 {
                let g = &g;
                s.spawn(move || {
                    for c in 0..100 {
                        g.set(r, c, (r * 100 + c) as f64);
                    }
                });
            }
        });
        for r in 0..8 {
            for c in 0..100 {
                assert_eq!(g.get(r, c), (r * 100 + c) as f64);
            }
        }
    }

    #[test]
    fn double_grid_parity() {
        let d = DoubleGrid::from_vec(2, 2, vec![1.0; 4]);
        assert!(std::ptr::eq(d.src(0), d.dst(1)));
        assert!(std::ptr::eq(d.src(1), d.dst(0)));
        assert!(std::ptr::eq(d.final_plane(2), d.src(0)));
    }
}
