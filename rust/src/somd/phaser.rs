//! A `java.util.concurrent.Phaser`-like synchronization primitive.
//!
//! The paper's compilation scheme (§5.1, Algorithm 1) uses two phasers:
//! `fence` encodes the `sync` construct (all MIs advance together, strict
//! memory model) and `completed` synchronizes task completion with the
//! master.  This implementation supports exactly those uses: a fixed party
//! count, `arrive` (non-blocking notification) and `arrive_and_wait`
//! (barrier), plus a `wait_for` used by the master on `completed`.

use std::sync::{Condvar, Mutex};

#[derive(Debug)]
struct State {
    parties: usize,
    arrived: usize,
    generation: u64,
}

/// A reusable multi-generation barrier.
#[derive(Debug)]
pub struct Phaser {
    state: Mutex<State>,
    cond: Condvar,
}

impl Phaser {
    /// A phaser with `parties` registered participants.
    pub fn new(parties: usize) -> Self {
        assert!(parties > 0, "phaser needs at least one party");
        Self {
            state: Mutex::new(State { parties, arrived: 0, generation: 0 }),
            cond: Condvar::new(),
        }
    }

    /// Registered party count.
    pub fn parties(&self) -> usize {
        self.state.lock().unwrap().parties
    }

    /// Completed barrier generations so far.
    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().generation
    }

    /// Arrive without waiting (the MI -> master completion signal).
    pub fn arrive(&self) {
        let mut s = self.state.lock().unwrap();
        s.arrived += 1;
        if s.arrived >= s.parties {
            s.arrived = 0;
            s.generation += 1;
            self.cond.notify_all();
        }
    }

    /// Arrive and block until every registered party has arrived
    /// (the `sync` fence of §5.1).
    pub fn arrive_and_wait(&self) {
        let mut s = self.state.lock().unwrap();
        let gen = s.generation;
        s.arrived += 1;
        if s.arrived >= s.parties {
            s.arrived = 0;
            s.generation += 1;
            self.cond.notify_all();
            return;
        }
        while s.generation == gen {
            s = self.cond.wait(s).unwrap();
        }
    }

    /// Block until generation `gen` has completed (master-side join on the
    /// `completed` phaser: master is NOT a registered party).
    pub fn wait_for_generation(&self, gen: u64) {
        let mut s = self.state.lock().unwrap();
        while s.generation <= gen {
            s = self.cond.wait(s).unwrap();
        }
    }

    /// Convenience: wait until the first generation completes.
    pub fn await_advance(&self) {
        self.wait_for_generation(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn single_party_never_blocks() {
        let p = Phaser::new(1);
        for _ in 0..10 {
            p.arrive_and_wait();
        }
        assert_eq!(p.generation(), 10);
    }

    #[test]
    fn barrier_orders_phases() {
        // Every thread must observe all phase-0 increments before phase 1.
        let p = Arc::new(Phaser::new(4));
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = p.clone();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                p.arrive_and_wait();
                assert_eq!(c.load(Ordering::SeqCst), 4);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn master_waits_for_completion() {
        let p = Arc::new(Phaser::new(3));
        for _ in 0..3 {
            let p = p.clone();
            std::thread::spawn(move || p.arrive());
        }
        p.await_advance();
        assert_eq!(p.generation(), 1);
    }

    #[test]
    fn reusable_across_generations() {
        let p = Arc::new(Phaser::new(2));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    p.arrive_and_wait();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.generation(), 50);
    }

    #[test]
    #[should_panic]
    fn zero_parties_rejected() {
        let _ = Phaser::new(0);
    }
}
