//! Adaptive target selection (the loop paper §6 leaves to the runtime).
//!
//! The paper's Elina runtime obeys static `method:target` rules and
//! reverts to shared memory when a preference is inapplicable; automatic
//! version selection is explicitly delegated to the compiler/runtime
//! ("empowering the compiler to generate code for multiple architectures
//! from the same source").  This module closes that loop: a per-method
//! execution-history store feeds a cost model that resolves the
//! [`Target::Auto`](crate::somd::Target::Auto) rules variant at
//! invocation time.
//!
//! Recorded signals per method:
//!
//! * **SMP** — observed wall time of shared-memory invocations;
//! * **device** — the *measured* per-invocation execute time on the
//!   device lane (wall time from job start to completion on the device
//!   master, excluding queue wait), plus transfer-byte and launch totals
//!   from [`DeviceStats`](crate::device::DeviceStats).  Earlier revisions
//!   recorded the *modeled* device time here, which poisoned `auto`
//!   decisions with cost-model assumptions instead of observed cost; the
//!   modeled clock still lives in `DeviceStats` for the paper-figure
//!   reports.
//! * **hybrid** — since the hybrid co-execution PR, one invocation may be
//!   *split* across both lanes ([`Choice::Hybrid`]).  Each hybrid run
//!   records the wall time of the slower side plus a per-side
//!   **throughput** observation (index-space items per second), from
//!   which the learned split ratio converges toward the
//!   throughput-proportional equilibrium (see [`Scheduler::record_hybrid`]).
//! * **sharded** — since the device-fleet PR, one invocation may be split
//!   N-way across the SMP pool *and every attached device lane* at once
//!   ([`Choice::Sharded`]).  Each sharded run records the wall of the
//!   slowest lane plus a throughput observation per participating lane
//!   (windows keyed by `(method, device_id)`), and the learned per-lane
//!   weight vector converges toward the N-way throughput-proportional
//!   equilibrium `w_i = T_i / Σ T` — the direct generalization of the
//!   two-way `device_fraction` logic, under the same deadband discipline
//!   (see [`Scheduler::record_sharded`]).
//!
//! The decision rule is deliberately simple and deterministic:
//! explore each applicable side until it has `min_samples` observations
//! (SMP first — it is always applicable), then pick the side with the
//! lower trailing-window mean, with a hysteresis factor so the choice
//! only flips when the other side is *clearly* faster.  Histories
//! serialize to JSON so deployments can persist what they learned.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::device::DeviceStats;
use crate::util::json::Json;

/// The split ratio used before any hybrid throughput has been observed
/// for a method (an even split: no evidence favors either side yet).
pub const DEFAULT_DEVICE_FRACTION: f64 = 0.5;

/// Penalty recorded for a failed lane so exploration completes and the
/// broken lane loses the mean comparison.  Later successes slide the
/// penalty out of the trailing window.
const PENALTY_SECS: f64 = 1e6;

/// Hybrid fractions are clamped away from the degenerate endpoints so a
/// learned split always keeps both lanes alive (a lane at exactly 0 would
/// never produce new throughput samples to recover from).
const FRACTION_MIN: f64 = 0.05;
/// Upper clamp counterpart of [`FRACTION_MIN`].
const FRACTION_MAX: f64 = 0.95;

/// N-way counterpart of [`FRACTION_MIN`]: every learned lane weight is
/// floored here (then renormalized, so the effective floor is
/// approximate) — a lane weighted to exactly 0 would never produce new
/// throughput samples to recover from.
const WEIGHT_MIN: f64 = 0.05;

/// Which lane(s) the cost model picked for one invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Choice {
    /// Run the whole invocation on the shared-memory worker pool.
    Smp,
    /// Offload the whole invocation to the device lane.
    Device,
    /// Split the invocation's index space across both lanes at once
    /// (hybrid co-execution): the SMP side takes the leading share, the
    /// device side the trailing `device_fraction` share, and the partial
    /// results merge through the method's ordinary reduction.
    Hybrid {
        /// Learned share of the index space handed to the device side,
        /// in `(0, 1)`.
        device_fraction: f64,
    },
    /// Shard the invocation's index space N-way across the SMP pool and
    /// *every* device lane of the fleet at once: the SMP side takes the
    /// leading span, each device lane one contiguous span in lane order,
    /// and the partial results merge through the method's ordinary
    /// reduction.  The learned weight vector itself is fetched separately
    /// via [`Scheduler::sharded_weights`] (exactly as the engine fetches
    /// [`Scheduler::hybrid_fraction`] at fork time), keeping this enum
    /// `Copy`.
    Sharded {
        /// Device-lane count of the fleet this decision targets (the
        /// weight vector has `lanes + 1` entries: SMP first).
        lanes: usize,
    },
}

impl Choice {
    /// Whether two choices pick the same lane *kind*, ignoring the hybrid
    /// split ratio (used for hysteresis: a ratio refinement is not a flip).
    pub fn same_lane(&self, other: &Choice) -> bool {
        matches!(
            (self, other),
            (Choice::Smp, Choice::Smp)
                | (Choice::Device, Choice::Device)
                | (Choice::Hybrid { .. }, Choice::Hybrid { .. })
                | (Choice::Sharded { .. }, Choice::Sharded { .. })
        )
    }
}

/// Why the cost model returned its [`Choice`]: the decision-explain
/// payload the tracing layer attaches to every `resolve` span (see
/// `docs/OBSERVABILITY.md`).  Produced by the `decide_*_explained`
/// entry points alongside the decision itself, from the same history
/// granularity the ladder ran on.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionExplain {
    /// The decision.
    pub choice: Choice,
    /// Which ladder rung produced it: `explore-smp` / `explore-device`
    /// / `explore-hybrid` / `explore-sharded` (a lane still collecting
    /// its minimum samples), `incumbent-held` (hysteresis kept the last
    /// choice), `hysteresis-flip` (a challenger beat the incumbent by
    /// the configured factor), or `best-mean` (no incumbent — lowest
    /// trailing mean wins).  A payload from
    /// [`Scheduler::explain_forced`] instead carries `rule-forced`: the
    /// lane came from the rules table, not the ladder.
    pub reason: &'static str,
    /// Trailing-window mean SMP seconds at decision time, if observed.
    pub smp_est: Option<f64>,
    /// Trailing-window mean measured device seconds, if observed.
    pub device_est: Option<f64>,
    /// Trailing-window mean hybrid wall seconds, if observed.
    pub hybrid_est: Option<f64>,
    /// Trailing-window mean sharded wall seconds, if observed.
    pub sharded_est: Option<f64>,
    /// The incumbent (`last_choice` of the granularity the ladder ran
    /// on) *before* this decision replaced it.
    pub incumbent: Option<Choice>,
    /// The hysteresis factor the incumbent was defended with.
    pub hysteresis: f64,
    /// The size bucket the decision ran in (`None` = all-sizes ladder).
    pub bucket: Option<u32>,
}

impl DecisionExplain {
    /// Short lane spelling of the decision (`smp` / `device` / `hybrid`
    /// / `sharded`), for span fields and logs.
    pub fn choice_name(&self) -> &'static str {
        choice_name(&self.choice)
    }
}

/// Short lane spelling of a [`Choice`].
pub fn choice_name(c: &Choice) -> &'static str {
    match c {
        Choice::Smp => "smp",
        Choice::Device => "device",
        Choice::Hybrid { .. } => "hybrid",
        Choice::Sharded { .. } => "sharded",
    }
}

/// Tunables for the cost model.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Trailing samples kept per side.
    pub window: usize,
    /// Observations required per side before the means are compared.
    pub min_samples: usize,
    /// The challenger must be at least this factor faster to flip the
    /// previous choice (1.0 = no hysteresis).
    pub hysteresis: f64,
    /// Deadband for the learned hybrid split: the stored `device_fraction`
    /// only moves when the freshly computed equilibrium differs from it by
    /// more than this amount (the ratio counterpart of `hysteresis` —
    /// prevents the split from chasing per-run noise).
    pub ratio_deadband: f64,
    /// Minimum index-space items the device share of a hybrid split must
    /// receive; below it the invocation runs pure-SMP instead (a device
    /// launch over a handful of items is pure overhead).
    pub min_device_items: usize,
    /// Condition decisions on input size: every `(method, lane)` window
    /// is additionally bucketed by `log2(items)` (see [`bucket_of`]), and
    /// the `*_sized` entry points explore/decide per bucket — a lane that
    /// wins at 1M items can lose at 10K without the windows fighting.
    /// Defaults from the `SOMD_SCHED_SIZE_BUCKETS` env knob (off unless
    /// set to `1`/`on`/`true`/`yes`).
    pub size_buckets: bool,
}

/// Whether `SOMD_SCHED_SIZE_BUCKETS` enables per-size histories.
fn size_buckets_env() -> bool {
    match std::env::var("SOMD_SCHED_SIZE_BUCKETS") {
        Ok(v) => matches!(v.to_ascii_lowercase().as_str(), "1" | "on" | "true" | "yes"),
        Err(_) => false,
    }
}

/// The size bucket an invocation over `items` index-space items falls
/// into: `floor(log2(items))`, with 0 items clamped to bucket 0.  Every
/// bucket spans one power of two — coarse enough that repeated runs of
/// one workload share a window, fine enough that 10K- and 1M-item
/// invocations never mix.
pub fn bucket_of(items: u64) -> u32 {
    items.max(1).ilog2()
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            window: 8,
            min_samples: 2,
            hysteresis: 1.15,
            ratio_deadband: 0.05,
            min_device_items: 1024,
            size_buckets: size_buckets_env(),
        }
    }
}

/// One side's contribution to a hybrid invocation, as fed back to the
/// ratio learner: how many index-space items the side processed and how
/// long its own execute phase took (each side clocked independently, so
/// queue wait on the other side never pollutes the throughput estimate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridSample {
    /// Index-space items this side processed (0 for a degenerate share).
    pub items: usize,
    /// Wall seconds this side spent executing its share.
    pub secs: f64,
}

/// Execution history of one method.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodHistory {
    /// Trailing SMP wall times (seconds).
    pub smp_secs: Vec<f64>,
    /// Trailing *measured* device execute times (seconds, queue wait
    /// excluded).
    pub device_secs: Vec<f64>,
    /// Trailing hybrid invocation wall times (seconds; the slower side's
    /// own execute time — the two sides run concurrently, so the slower
    /// one bounds the invocation).
    pub hybrid_secs: Vec<f64>,
    /// Trailing SMP-side throughput observations from hybrid runs
    /// (index-space items per second).
    pub smp_items_per_sec: Vec<f64>,
    /// Trailing device-side throughput observations from hybrid runs
    /// (index-space items per second).
    pub device_items_per_sec: Vec<f64>,
    /// Trailing sharded (N-way fleet) invocation wall times (seconds;
    /// the slowest lane bounds the invocation).
    pub sharded_secs: Vec<f64>,
    /// Trailing device-master queue waits (seconds spent between a
    /// job's enqueue on the master and its dequeue).  Deliberately kept
    /// out of `device_secs` — the execute window must stay queue-free
    /// so `auto` compares compute against compute — but surfaced here
    /// so reports and the metrics hub can see lane contention build.
    /// Only runs that crossed a device-master queue contribute (inline
    /// session executions record no wait).
    pub device_queue_wait_secs: Vec<f64>,
    /// Per-device-lane throughput windows from sharded runs, indexed by
    /// `device_id` (the lane's position in the fleet) — the
    /// `(method, device_id)` keying of the fleet scheduler.  The SMP
    /// side's sharded throughput shares [`MethodHistory::smp_items_per_sec`]
    /// with the hybrid lane (it is the same physical signal).
    pub device_lane_items_per_sec: Vec<Vec<f64>>,
    /// Lifetime SMP invocations (not windowed).
    pub smp_runs: u64,
    /// Lifetime device invocations (not windowed).
    pub device_runs: u64,
    /// Lifetime failed device invocations.
    pub device_failures: u64,
    /// Lifetime hybrid invocations (including ones whose device half
    /// failed and fell back to SMP).
    pub hybrid_runs: u64,
    /// Hybrid invocations whose device half failed.
    pub hybrid_failures: u64,
    /// Lifetime sharded invocations (including degraded ones whose every
    /// device share starved under the floor).
    pub sharded_runs: u64,
    /// Sharded invocations in which at least one device lane failed.
    pub sharded_failures: u64,
    /// Runs that actually recorded transfer/launch accounting (successful
    /// device + hybrid runs) — the denominator of
    /// [`MethodHistory::transfer_bytes_per_run`].  Failed and degraded
    /// runs increment the lifetime counters but move no bytes, so they
    /// must not dilute the bus-pressure signal.
    pub transfer_runs: u64,
    /// Device-touching runs that kept at least one intermediate
    /// device-resident (pipeline stages: memoized-upload hits or resident
    /// stage handoffs).  Counted *separately* from `transfer_runs` — a
    /// resident run's near-zero bus traffic reflects residency, not a
    /// cheap workload, and folding it into the mean would dilute the
    /// §7.3 bus-pressure signal.
    pub resident_runs: u64,
    /// Bytes actually moved during resident runs (still part of the
    /// `bytes_h2d`/`bytes_d2h` lifetime totals; excluded from the
    /// per-transfer-run mean).
    pub resident_bytes: u64,
    /// Bytes that stayed device-resident instead of crossing the bus
    /// (both directions), summed over resident runs.
    pub skipped_bytes: u64,
    /// The learned device share of a hybrid split; `None` until the first
    /// hybrid run produced throughput observations for both sides.
    pub device_fraction: Option<f64>,
    /// The learned per-lane weight vector of a sharded split (`lanes + 1`
    /// entries, SMP first, summing to 1); `None` until every lane has
    /// produced at least one throughput observation.
    pub lane_weights: Option<Vec<f64>>,
    /// Lifetime host→device bytes (device + hybrid runs).
    pub bytes_h2d: u64,
    /// Lifetime device→host bytes (device + hybrid runs).
    pub bytes_d2h: u64,
    /// Lifetime kernel launches (device + hybrid runs).
    pub launches: u64,
    /// Trailing client-requests-per-fused-invocation observations from
    /// the serving layer's micro-batcher (1.0 = an unbatched launch).
    pub batch_requests_per_invocation: Vec<f64>,
    /// Lifetime fused invocations submitted through the batched path.
    pub batched_invocations: u64,
    /// Lifetime client requests coalesced into those invocations.
    pub batched_requests: u64,
    /// Lifetime index-space items carried by the batched path.
    pub batched_items: u64,
    /// The last decision, for hysteresis.
    pub last_choice: Option<Choice>,
    /// Per-size sub-histories keyed by `log2(items)` (see [`bucket_of`]),
    /// populated by the `*_sized` record paths when
    /// [`SchedulerConfig::size_buckets`] is on.  Each bucket is a full
    /// [`MethodHistory`] restricted to invocations of that size (its own
    /// `size_buckets` stays empty — one level only).  This top-level
    /// history remains the all-sizes aggregate, which is also how legacy
    /// snapshots load: everything in one all-sizes "bucket".
    pub size_buckets: BTreeMap<u32, MethodHistory>,
    /// Smallest index-space item count observed by a sized record (the
    /// leak check: a bucket's whole `[items_min, items_max]` range must
    /// hash to that bucket).
    pub items_min: Option<u64>,
    /// Largest index-space item count observed by a sized record.
    pub items_max: Option<u64>,
}

impl MethodHistory {
    fn push(buf: &mut Vec<f64>, v: f64, window: usize) {
        buf.push(v);
        if buf.len() > window {
            buf.remove(0);
        }
    }

    fn mean(buf: &[f64]) -> Option<f64> {
        if buf.is_empty() {
            None
        } else {
            Some(buf.iter().sum::<f64>() / buf.len() as f64)
        }
    }

    /// Trailing-window mean SMP seconds.
    pub fn smp_estimate(&self) -> Option<f64> {
        Self::mean(&self.smp_secs)
    }

    /// Trailing-window mean measured device seconds.
    pub fn device_estimate(&self) -> Option<f64> {
        Self::mean(&self.device_secs)
    }

    /// Trailing-window mean hybrid wall seconds.
    pub fn hybrid_estimate(&self) -> Option<f64> {
        Self::mean(&self.hybrid_secs)
    }

    /// Trailing-window mean SMP-side throughput (items/s) from hybrid runs.
    pub fn smp_throughput(&self) -> Option<f64> {
        Self::mean(&self.smp_items_per_sec)
    }

    /// Trailing-window mean device-side throughput (items/s) from hybrid
    /// runs.
    pub fn device_throughput(&self) -> Option<f64> {
        Self::mean(&self.device_items_per_sec)
    }

    /// Trailing-window mean sharded wall seconds.
    pub fn sharded_estimate(&self) -> Option<f64> {
        Self::mean(&self.sharded_secs)
    }

    /// Trailing-window mean device-master queue wait (seconds); `None`
    /// until a run crossed a device-master queue.
    pub fn mean_device_queue_wait(&self) -> Option<f64> {
        Self::mean(&self.device_queue_wait_secs)
    }

    /// Trailing-window mean throughput (items/s) of device lane
    /// `device_id` from sharded runs; `None` until the lane has produced
    /// a sample.
    pub fn device_lane_throughput(&self, device_id: usize) -> Option<f64> {
        self.device_lane_items_per_sec.get(device_id).and_then(|w| Self::mean(w))
    }

    /// The throughput-proportional equilibrium split: with per-side
    /// throughputs `T_smp` and `T_dev`, handing the device the fraction
    /// `T_dev / (T_smp + T_dev)` makes both sides finish at the same time
    /// (the HSTREAM-style balance point).  `None` until both sides have
    /// at least one throughput observation.
    pub fn equilibrium_fraction(&self) -> Option<f64> {
        let s = self.smp_throughput()?;
        let d = self.device_throughput()?;
        if s + d > 0.0 {
            Some(d / (s + d))
        } else {
            None
        }
    }

    /// The N-way throughput-proportional equilibrium over a `lanes`-device
    /// fleet: with per-lane mean throughputs `T_smp, T_0, …, T_{k-1}`,
    /// handing lane `i` the weight `T_i / Σ T` makes every lane finish at
    /// the same time — the direct generalization of
    /// [`MethodHistory::equilibrium_fraction`].  `None` until the SMP
    /// side *and every device lane* have at least one throughput
    /// observation (a lane without evidence cannot be weighted honestly).
    pub fn equilibrium_weights(&self, lanes: usize) -> Option<Vec<f64>> {
        let mut t = Vec::with_capacity(lanes + 1);
        t.push(self.smp_throughput()?);
        for i in 0..lanes {
            t.push(self.device_lane_throughput(i)?);
        }
        let total: f64 = t.iter().sum();
        if total > 0.0 {
            Some(t.into_iter().map(|x| x / total).collect())
        } else {
            None
        }
    }

    /// Trailing-window mean client requests per fused invocation, `None`
    /// until the serving layer submitted a batch for this method.  Lane
    /// estimates stay wall-time-based — this surfaces *occupancy*, so a
    /// report can tell whether a method's history was learned from
    /// coalesced traffic (big fused index spaces) or singleton calls.
    pub fn mean_batch_requests(&self) -> Option<f64> {
        Self::mean(&self.batch_requests_per_invocation)
    }

    /// Mean transfer bytes per device-touching run (the §7.3 "Crypt loses
    /// on the bus" signal, surfaced for reports).  Only runs that
    /// recorded transfer accounting count — failed/degraded runs moved
    /// nothing across the bus and must not dilute the mean.
    pub fn transfer_bytes_per_run(&self) -> f64 {
        if self.transfer_runs == 0 {
            0.0
        } else {
            // resident runs' (small) residual traffic is excluded: the
            // mean characterizes what a *round-tripping* run costs
            let moved =
                (self.bytes_h2d + self.bytes_d2h).saturating_sub(self.resident_bytes);
            moved as f64 / self.transfer_runs as f64
        }
    }
}

/// One row of the decision table (bench/report surface).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRow {
    /// Method name (the rules-file key).
    pub method: String,
    /// Trailing-window mean SMP seconds, if observed.
    pub smp_secs: Option<f64>,
    /// Trailing-window mean measured device seconds, if observed.
    pub device_secs: Option<f64>,
    /// Trailing-window mean hybrid wall seconds, if observed.
    pub hybrid_secs: Option<f64>,
    /// Trailing-window mean sharded (N-way fleet) wall seconds, if
    /// observed.
    pub sharded_secs: Option<f64>,
    /// The learned hybrid split, if any hybrid run happened.
    pub device_fraction: Option<f64>,
    /// The learned per-lane fleet weights, if any sharded run converged
    /// them (SMP first).
    pub lane_weights: Option<Vec<f64>>,
    /// Mean bus bytes per device-touching run.
    pub transfer_bytes_per_run: f64,
    /// Trailing mean client requests per fused invocation, if the serving
    /// layer batched this method.
    pub mean_batch_requests: Option<f64>,
    /// `None` for the all-sizes aggregate row; `Some(b)` for a per-size
    /// row covering inputs with `⌊log2(items)⌋ == b` (size bucketing on).
    pub bucket_log2_items: Option<u32>,
    /// What the cost model would pick next for this method.
    pub choice: Choice,
}

/// The history store + cost model.  Thread-safe; one per [`Engine`]
/// (shared with its device master thread).
///
/// [`Engine`]: crate::somd::Engine
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    histories: Mutex<BTreeMap<String, MethodHistory>>,
}

impl Scheduler {
    /// A scheduler with the given tunables and an empty history store.
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, histories: Mutex::new(BTreeMap::new()) }
    }

    /// The tunables this scheduler was built with.
    pub fn config(&self) -> SchedulerConfig {
        self.cfg
    }

    /// Widen the observed item range of a history.
    fn note_items(e: &mut MethodHistory, items: u64) {
        e.items_min = Some(e.items_min.map_or(items, |m| m.min(items)));
        e.items_max = Some(e.items_max.map_or(items, |m| m.max(items)));
    }

    /// Run `f` against the all-sizes history and — when size bucketing is
    /// on and the caller knew the item count — against that size's bucket
    /// too, so every sized record feeds both granularities.
    fn for_each_granularity(
        &self,
        method: &str,
        items: Option<u64>,
        mut f: impl FnMut(&SchedulerConfig, &mut MethodHistory),
    ) {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        f(&self.cfg, e);
        if let Some(items) = items {
            Self::note_items(e, items);
            if self.cfg.size_buckets {
                let b = e.size_buckets.entry(bucket_of(items)).or_default();
                f(&self.cfg, b);
                Self::note_items(b, items);
            }
        }
    }

    /// Record an SMP invocation's wall time.
    pub fn record_smp(&self, method: &str, wall: Duration) {
        self.record_smp_impl(method, wall, None);
    }

    /// Record an SMP invocation's wall time together with its index-space
    /// item count, feeding the size bucket as well as the all-sizes
    /// window (see [`SchedulerConfig::size_buckets`]).
    pub fn record_smp_sized(&self, method: &str, wall: Duration, items: u64) {
        self.record_smp_impl(method, wall, Some(items));
    }

    fn record_smp_impl(&self, method: &str, wall: Duration, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.smp_secs, wall.as_secs_f64(), cfg.window);
            e.smp_runs += 1;
        });
    }

    /// Record a device invocation: `measured` is the observed execute
    /// wall time of the job itself (clock started after dequeue, so queue
    /// wait is excluded); `stats` contributes the transfer/launch totals.
    /// The trailing window holds *measured* seconds — the modeled
    /// `stats.device_time` is deliberately NOT recorded here, so `auto`
    /// compares like with like (observed SMP wall vs observed device
    /// wall).
    pub fn record_device(&self, method: &str, measured: Duration, stats: &DeviceStats) {
        self.record_device_impl(method, measured, stats, None);
    }

    /// Sized counterpart of [`Scheduler::record_device`]: also feeds the
    /// invocation's size bucket (including its transfer accounting, so
    /// per-size rows can surface bus pressure at that size).
    pub fn record_device_sized(
        &self,
        method: &str,
        measured: Duration,
        stats: &DeviceStats,
        items: u64,
    ) {
        self.record_device_impl(method, measured, stats, Some(items));
    }

    fn record_device_impl(
        &self,
        method: &str,
        measured: Duration,
        stats: &DeviceStats,
        items: Option<u64>,
    ) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.device_secs, measured.as_secs_f64(), cfg.window);
            e.device_runs += 1;
            Self::account_transfers(e, stats, cfg.window);
        });
    }

    /// Fold one run's transfer accounting into a history entry.  Runs
    /// that skipped transfers via residency are recorded as
    /// `resident_runs` — never as `transfer_runs` — so resident
    /// pipeline stages don't dilute `transfer_bytes_per_run`.  A run
    /// that crossed a device-master queue also contributes its queue
    /// wait to the (windowed) wait signal here.
    fn account_transfers(e: &mut MethodHistory, stats: &DeviceStats, window: usize) {
        if stats.skipped_transfers() > 0 {
            e.resident_runs += 1;
            e.resident_bytes += stats.total_transfer_bytes() as u64;
            e.skipped_bytes += stats.skipped_transfer_bytes() as u64;
        } else {
            e.transfer_runs += 1;
        }
        e.bytes_h2d += stats.bytes_h2d as u64;
        e.bytes_d2h += stats.bytes_d2h as u64;
        e.launches += stats.launches as u64;
        if stats.queue_wait > Duration::ZERO {
            MethodHistory::push(
                &mut e.device_queue_wait_secs,
                stats.queue_wait.as_secs_f64(),
                window,
            );
        }
    }

    /// Record a *failed* device invocation as a large penalty sample.
    /// Without this, a method whose device version always errors would
    /// never accumulate device samples, so the exploration phase would
    /// keep resolving `auto` to the broken lane forever; the penalty
    /// completes exploration and steers the method back to SMP.  Later
    /// successes slide the penalty out of the trailing window.
    pub fn record_device_failure(&self, method: &str) {
        self.record_device_failure_impl(method, None);
    }

    /// Sized counterpart of [`Scheduler::record_device_failure`]: the
    /// penalty lands in the size bucket too, so a per-size ladder that
    /// chose the device also learns the lane is broken at that size.
    pub fn record_device_failure_sized(&self, method: &str, items: u64) {
        self.record_device_failure_impl(method, Some(items));
    }

    fn record_device_failure_impl(&self, method: &str, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.device_secs, PENALTY_SECS, cfg.window);
            e.device_runs += 1;
            e.device_failures += 1;
        });
    }

    /// Record one completed hybrid invocation.
    ///
    /// Besides the hybrid wall sample (the slower side bounds the
    /// invocation), each side contributes a throughput observation, and
    /// the learned `device_fraction` moves to the fresh
    /// [equilibrium](MethodHistory::equilibrium_fraction) whenever it
    /// falls outside the configured `ratio_deadband` around the current
    /// value — the same keep-unless-clearly-better discipline the lane
    /// decision applies through `hysteresis`.
    ///
    /// Degenerate shares (`items == 0` or a non-positive clock) do not
    /// produce throughput samples, so 0.0/1.0 experiment splits cannot
    /// poison the learned ratio.
    ///
    /// One invocation's item count is always known to a co-execution
    /// record (the samples carry per-side shares), so the size bucket is
    /// fed automatically whenever bucketing is on — per-size windows AND
    /// per-size learned fractions/weights, with the same deadbands.
    pub fn record_hybrid(
        &self,
        method: &str,
        smp: HybridSample,
        device: HybridSample,
        stats: &DeviceStats,
    ) {
        let items = (smp.items + device.items) as u64;
        self.for_each_granularity(method, Some(items), |cfg, e| {
            MethodHistory::push(&mut e.hybrid_secs, smp.secs.max(device.secs), cfg.window);
            if smp.items > 0 && smp.secs > 0.0 {
                MethodHistory::push(
                    &mut e.smp_items_per_sec,
                    smp.items as f64 / smp.secs,
                    cfg.window,
                );
            }
            if device.items > 0 && device.secs > 0.0 {
                MethodHistory::push(
                    &mut e.device_items_per_sec,
                    device.items as f64 / device.secs,
                    cfg.window,
                );
            }
            e.hybrid_runs += 1;
            Self::account_transfers(e, stats, cfg.window);
            if let Some(f_star) = e.equilibrium_fraction() {
                let f_star = f_star.clamp(FRACTION_MIN, FRACTION_MAX);
                match e.device_fraction {
                    Some(cur) if (f_star - cur).abs() <= cfg.ratio_deadband => {}
                    _ => e.device_fraction = Some(f_star),
                }
            }
        });
    }

    /// Record a hybrid invocation whose device half failed (the SMP side
    /// covered the device share, so the caller still got a result).  The
    /// penalty sample steers the lane decision away from hybrid until the
    /// device side proves itself again.
    pub fn record_hybrid_failure(&self, method: &str) {
        self.record_hybrid_failure_impl(method, None);
    }

    /// [`Scheduler::record_hybrid_failure`] with the invocation's item
    /// count, so the penalty also lands in the size bucket — without it a
    /// per-bucket ladder whose hybrid rung always fails would re-explore
    /// hybrid forever at that size.
    pub fn record_hybrid_failure_sized(&self, method: &str, items: u64) {
        self.record_hybrid_failure_impl(method, Some(items));
    }

    fn record_hybrid_failure_impl(&self, method: &str, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.hybrid_secs, PENALTY_SECS, cfg.window);
            e.hybrid_runs += 1;
            e.hybrid_failures += 1;
        });
    }

    /// Record one fused invocation submitted by the serving layer's
    /// micro-batcher: `requests` client calls were coalesced into a
    /// single launch covering `items` index-space items.  The wall/stats
    /// samples of the launch itself still arrive through the ordinary
    /// lane records (the fused invocation runs through the same
    /// SMP/device/hybrid paths), so lane and ratio learning keep
    /// converging on coalesced traffic; this record adds the *occupancy*
    /// signal — how many requests and items each launch amortized —
    /// which reports and capacity planning read back through
    /// [`MethodHistory::mean_batch_requests`].
    pub fn record_batch(&self, method: &str, requests: usize, items: usize) {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        MethodHistory::push(
            &mut e.batch_requests_per_invocation,
            requests as f64,
            self.cfg.window,
        );
        e.batched_invocations += 1;
        e.batched_requests += requests as u64;
        e.batched_items += items as u64;
    }

    /// Record one completed sharded (N-way fleet) invocation.
    ///
    /// `devices[i]` is device lane `i`'s sample; a lane that was starved
    /// under the floor (or otherwise produced no work) passes
    /// `items == 0` and contributes no throughput observation — exactly
    /// the degenerate-share discipline of [`Scheduler::record_hybrid`],
    /// per lane.  Besides the wall sample (the slowest lane bounds the
    /// invocation), the learned `lane_weights` move to the fresh
    /// [N-way equilibrium](MethodHistory::equilibrium_weights) whenever
    /// any component drifts outside the configured `ratio_deadband`
    /// (L∞, the vector counterpart of the two-way deadband), with every
    /// weight floored near 0.05 (then renormalized) so no lane is starved
    /// out of producing recovery evidence.
    pub fn record_sharded(
        &self,
        method: &str,
        smp: HybridSample,
        devices: &[HybridSample],
        stats: &DeviceStats,
    ) {
        let items = (smp.items + devices.iter().map(|d| d.items).sum::<usize>()) as u64;
        self.for_each_granularity(method, Some(items), |cfg, e| {
            let slowest = devices.iter().map(|d| d.secs).fold(smp.secs, f64::max);
            MethodHistory::push(&mut e.sharded_secs, slowest, cfg.window);
            if smp.items > 0 && smp.secs > 0.0 {
                MethodHistory::push(
                    &mut e.smp_items_per_sec,
                    smp.items as f64 / smp.secs,
                    cfg.window,
                );
            }
            // Resize in BOTH directions: a fleet that *shrank* between runs
            // (or since a persisted snapshot was taken) must not keep stale
            // extra-lane windows alive — they would keep steering
            // `sharded_weights` and the decision table toward lanes that no
            // longer exist.  `Vec::resize` truncates when shrinking.
            if e.device_lane_items_per_sec.len() != devices.len() {
                e.device_lane_items_per_sec.resize(devices.len(), Vec::new());
            }
            // Learned weights from a different fleet size are meaningless for
            // this one; drop them so `sharded_weights` falls back to its
            // hybrid/even-split ladder until a fresh equilibrium is learned.
            if e.lane_weights.as_ref().is_some_and(|w| w.len() != devices.len() + 1) {
                e.lane_weights = None;
            }
            for (i, d) in devices.iter().enumerate() {
                if d.items > 0 && d.secs > 0.0 {
                    MethodHistory::push(
                        &mut e.device_lane_items_per_sec[i],
                        d.items as f64 / d.secs,
                        cfg.window,
                    );
                }
            }
            e.sharded_runs += 1;
            Self::account_transfers(e, stats, cfg.window);
            if let Some(w_star) = e.equilibrium_weights(devices.len()) {
                let floored: Vec<f64> = w_star.iter().map(|w| w.max(WEIGHT_MIN)).collect();
                let total: f64 = floored.iter().sum();
                let w_star: Vec<f64> = floored.into_iter().map(|w| w / total).collect();
                let keep = match &e.lane_weights {
                    Some(cur) if cur.len() == w_star.len() => cur
                        .iter()
                        .zip(&w_star)
                        .all(|(a, b)| (a - b).abs() <= cfg.ratio_deadband),
                    _ => false,
                };
                if !keep {
                    e.lane_weights = Some(w_star);
                }
            }
        });
    }

    /// Record a sharded invocation in which at least one device lane
    /// failed (the SMP side covered the failed spans, so the caller still
    /// got a complete result).  The penalty sample steers the lane
    /// decision away from sharding until the fleet proves itself again.
    pub fn record_sharded_failure(&self, method: &str) {
        self.record_sharded_failure_impl(method, None);
    }

    /// [`Scheduler::record_sharded_failure`] with the invocation's item
    /// count, so the penalty also lands in the size bucket.
    pub fn record_sharded_failure_sized(&self, method: &str, items: u64) {
        self.record_sharded_failure_impl(method, Some(items));
    }

    fn record_sharded_failure_impl(&self, method: &str, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.sharded_secs, PENALTY_SECS, cfg.window);
            e.sharded_runs += 1;
            e.sharded_failures += 1;
        });
    }

    /// Record a sharded invocation that degraded to pure SMP because
    /// *every* device lane's share underflowed `min_device_items` — the
    /// N-way counterpart of [`Scheduler::record_hybrid_degraded`], and
    /// for the same reason: the SMP wall IS the sharded lane's honest
    /// cost at this input size, so recording it completes the sharded
    /// exploration rung instead of re-resolving forever.
    pub fn record_sharded_degraded(&self, method: &str, wall: Duration) {
        self.record_sharded_degraded_impl(method, wall, None);
    }

    /// [`Scheduler::record_sharded_degraded`] with the invocation's item
    /// count.  Degraded runs MUST reach the size bucket: a per-bucket
    /// ladder at a size too small to shard would otherwise return
    /// [`Choice::Sharded`] forever — the exact pathology the unsized
    /// degraded record fixed, recurring per bucket.
    pub fn record_sharded_degraded_sized(&self, method: &str, wall: Duration, items: u64) {
        self.record_sharded_degraded_impl(method, wall, Some(items));
    }

    fn record_sharded_degraded_impl(&self, method: &str, wall: Duration, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.sharded_secs, wall.as_secs_f64(), cfg.window);
            e.sharded_runs += 1;
        });
    }

    /// The per-lane weight vector a sharded invocation of `method` over a
    /// `lanes`-device fleet should use right now (`lanes + 1` entries,
    /// SMP first):
    ///
    /// 1. the learned [`MethodHistory::lane_weights`] when their lane
    ///    count matches the fleet's;
    /// 2. for a 1-device fleet with only two-way history, the learned
    ///    hybrid split `[1 - f, f]` — this is also how **legacy
    ///    snapshots** (persisted before the fleet existed) load: their
    ///    `device_fraction` is reinterpreted as a 1-device fleet's weight
    ///    vector;
    /// 3. otherwise the even split `1 / (lanes + 1)` per lane (no
    ///    evidence favors anyone yet — the N-way counterpart of
    ///    [`DEFAULT_DEVICE_FRACTION`]).
    pub fn sharded_weights(&self, method: &str, lanes: usize) -> Vec<f64> {
        let h = self.histories.lock().unwrap();
        if let Some(e) = h.get(method) {
            if let Some(w) = Self::weights_from(e, lanes) {
                return w;
            }
        }
        vec![1.0 / (lanes + 1) as f64; lanes + 1]
    }

    /// [`Scheduler::sharded_weights`] conditioned on input size: the size
    /// bucket's learned vector wins (when bucketing is on), then the
    /// all-sizes vector, then the even split — per-size fleet weights
    /// without a separate learning path, since every sharded record
    /// already feeds the bucket.
    pub fn sharded_weights_sized(&self, method: &str, lanes: usize, items: u64) -> Vec<f64> {
        let h = self.histories.lock().unwrap();
        if let Some(e) = h.get(method) {
            if self.cfg.size_buckets {
                if let Some(w) =
                    e.size_buckets.get(&bucket_of(items)).and_then(|b| Self::weights_from(b, lanes))
                {
                    return w;
                }
            }
            if let Some(w) = Self::weights_from(e, lanes) {
                return w;
            }
        }
        vec![1.0 / (lanes + 1) as f64; lanes + 1]
    }

    /// The weight ladder's evidence-bearing rungs for one history
    /// granularity (learned N-way vector, then a 1-device fleet's
    /// reinterpreted hybrid split); `None` means "no evidence here" so
    /// callers can fall through to a coarser granularity.
    fn weights_from(e: &MethodHistory, lanes: usize) -> Option<Vec<f64>> {
        if let Some(w) = &e.lane_weights {
            if w.len() == lanes + 1 {
                return Some(w.clone());
            }
        }
        if lanes == 1 {
            if let Some(f) = e.device_fraction {
                return Some(vec![1.0 - f, f]);
            }
        }
        None
    }

    /// Pin the learned weight vector for `method` (experiments, the
    /// correctness suite's skewed splits, deployments that want a fixed
    /// shard plan).  Weights are sanitized (non-finite / negative → 0)
    /// and normalized; an all-zero vector is ignored.
    pub fn set_sharded_weights(&self, method: &str, weights: &[f64]) {
        let w: Vec<f64> =
            weights.iter().map(|&x| if x.is_finite() && x > 0.0 { x } else { 0.0 }).collect();
        let total: f64 = w.iter().sum();
        if total <= 0.0 {
            return;
        }
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        e.lane_weights = Some(w.into_iter().map(|x| x / total).collect());
    }

    /// Record a hybrid invocation that *degraded* to pure SMP because the
    /// device share underflowed `min_device_items`.  The SMP wall IS the
    /// hybrid lane's honest cost at this input size, so recording it here
    /// (alongside the ordinary SMP sample) completes the hybrid
    /// exploration rung — without this, an `auto` method whose inputs are
    /// too small to split would return [`Choice::Hybrid`] forever, each
    /// submission degrading without ever accruing a hybrid sample, and
    /// the decision could never settle on a faster pure lane.
    pub fn record_hybrid_degraded(&self, method: &str, wall: Duration) {
        self.record_hybrid_degraded_impl(method, wall, None);
    }

    /// [`Scheduler::record_hybrid_degraded`] with the invocation's item
    /// count, completing the *bucket's* hybrid exploration rung too (see
    /// [`Scheduler::record_sharded_degraded_sized`] for why that matters).
    pub fn record_hybrid_degraded_sized(&self, method: &str, wall: Duration, items: u64) {
        self.record_hybrid_degraded_impl(method, wall, Some(items));
    }

    fn record_hybrid_degraded_impl(&self, method: &str, wall: Duration, items: Option<u64>) {
        self.for_each_granularity(method, items, |cfg, e| {
            MethodHistory::push(&mut e.hybrid_secs, wall.as_secs_f64(), cfg.window);
            e.hybrid_runs += 1;
        });
    }

    /// The split ratio a hybrid invocation of `method` should use right
    /// now: the learned equilibrium if one exists, otherwise
    /// [`DEFAULT_DEVICE_FRACTION`].
    pub fn hybrid_fraction(&self, method: &str) -> f64 {
        self.histories
            .lock()
            .unwrap()
            .get(method)
            .and_then(|e| e.device_fraction)
            .unwrap_or(DEFAULT_DEVICE_FRACTION)
    }

    /// [`Scheduler::hybrid_fraction`] conditioned on input size: the
    /// bucket's learned equilibrium when size bucketing is on and the
    /// bucket has one, else the all-sizes fraction, else the default —
    /// a small input's split no longer dragged toward the ratio a huge
    /// input converged to.
    pub fn hybrid_fraction_sized(&self, method: &str, items: u64) -> f64 {
        let h = self.histories.lock().unwrap();
        let Some(e) = h.get(method) else { return DEFAULT_DEVICE_FRACTION };
        if self.cfg.size_buckets {
            if let Some(f) =
                e.size_buckets.get(&bucket_of(items)).and_then(|b| b.device_fraction)
            {
                return f;
            }
        }
        e.device_fraction.unwrap_or(DEFAULT_DEVICE_FRACTION)
    }

    /// Resolve `Target::Auto` for a method whose device version IS
    /// applicable (the caller has already checked applicability; an
    /// inapplicable device reverts to SMP before ever reaching here).
    ///
    /// This is the *binary* decision — methods without a hybrid spec can
    /// only run whole-invocation on one lane.  Callers whose method
    /// supports co-execution use [`Scheduler::decide_hybrid`] instead.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::time::Duration;
    /// use somd::somd::{Choice, Scheduler, SchedulerConfig};
    ///
    /// let s = Scheduler::new(SchedulerConfig::default());
    /// // exploration: SMP is measured first (it is always applicable)
    /// assert_eq!(s.decide("Series.coefficients"), Choice::Smp);
    /// s.record_smp("Series.coefficients", Duration::from_millis(200));
    /// s.record_smp("Series.coefficients", Duration::from_millis(200));
    /// // then the device side gets its minimum samples
    /// assert_eq!(s.decide("Series.coefficients"), Choice::Device);
    /// ```
    pub fn decide(&self, method: &str) -> Choice {
        self.decide_explained(method, None).choice
    }

    /// [`Scheduler::decide`] (or, with `items`, [`Scheduler::decide_sized`])
    /// returning the full [`DecisionExplain`] payload — same decision,
    /// same state transitions, plus the why.
    pub fn decide_explained(&self, method: &str, items: Option<u64>) -> DecisionExplain {
        self.decide_impl_explained(method, items, Self::decide_history_explained)
    }

    /// [`Scheduler::decide`] conditioned on input size: when size
    /// bucketing is on, the exploration ladder and incumbent hysteresis
    /// run *per bucket*, so a method can settle on the device for large
    /// inputs and SMP for small ones simultaneously.  Each bucket
    /// explores from scratch — seeding it from the all-sizes decision
    /// would starve the unchosen lane of samples (records follow the
    /// chosen lane) and the bucket could never diverge from the
    /// aggregate.  With bucketing off this is exactly `decide`.
    pub fn decide_sized(&self, method: &str, items: u64) -> Choice {
        self.decide_explained(method, Some(items)).choice
    }

    /// Shared decide plumbing: run `ladder` on the size bucket when one
    /// applies (bucketing on AND the caller knows the item count), else
    /// on the all-sizes history.  The bucket's incumbent is its own
    /// `last_choice`; the top-level `last_choice` still tracks the most
    /// recent decision of *any* size so unsized callers and the decision
    /// table keep their meaning.
    fn decide_impl_explained(
        &self,
        method: &str,
        items: Option<u64>,
        ladder: impl Fn(&SchedulerConfig, &MethodHistory) -> (Choice, &'static str),
    ) -> DecisionExplain {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        let explain = match items {
            Some(items) if self.cfg.size_buckets => {
                let bucket = bucket_of(items);
                let b = e.size_buckets.entry(bucket).or_default();
                let incumbent = b.last_choice;
                let (choice, reason) = ladder(&self.cfg, b);
                let explain = DecisionExplain {
                    choice,
                    reason,
                    smp_est: b.smp_estimate(),
                    device_est: b.device_estimate(),
                    hybrid_est: b.hybrid_estimate(),
                    sharded_est: b.sharded_estimate(),
                    incumbent,
                    hysteresis: self.cfg.hysteresis,
                    bucket: Some(bucket),
                };
                b.last_choice = Some(choice);
                explain
            }
            _ => {
                let incumbent = e.last_choice;
                let (choice, reason) = ladder(&self.cfg, e);
                DecisionExplain {
                    choice,
                    reason,
                    smp_est: e.smp_estimate(),
                    device_est: e.device_estimate(),
                    hybrid_est: e.hybrid_estimate(),
                    sharded_est: e.sharded_estimate(),
                    incumbent,
                    hysteresis: self.cfg.hysteresis,
                    bucket: None,
                }
            }
        };
        e.last_choice = Some(explain.choice);
        explain
    }

    /// A read-only [`DecisionExplain`] for a resolution the scheduler
    /// did *not* make: the lane was forced by a rules-table entry, but
    /// the `resolve` span still wants the payload — what the histories
    /// would have predicted, and which incumbent the rule overrode.
    /// Reads the same granularity the ladder would have run on (the
    /// size bucket when bucketing is on and `items` is known, else the
    /// all-sizes history) without touching `last_choice`: a forced run
    /// is not a scheduler decision and must not seed hysteresis.  The
    /// reason is always `rule-forced`.
    pub fn explain_forced(
        &self,
        method: &str,
        choice: Choice,
        items: Option<u64>,
    ) -> DecisionExplain {
        let h = self.histories.lock().unwrap();
        let fresh = MethodHistory::default();
        let e = h.get(method).unwrap_or(&fresh);
        let (g, bucket): (&MethodHistory, Option<u32>) = match items {
            Some(items) if self.cfg.size_buckets => {
                let bucket = bucket_of(items);
                (e.size_buckets.get(&bucket).unwrap_or(&fresh), Some(bucket))
            }
            _ => (e, None),
        };
        DecisionExplain {
            choice,
            reason: "rule-forced",
            smp_est: g.smp_estimate(),
            device_est: g.device_estimate(),
            hybrid_est: g.hybrid_estimate(),
            sharded_est: g.sharded_estimate(),
            incumbent: g.last_choice,
            hysteresis: self.cfg.hysteresis,
            bucket,
        }
    }

    /// Resolve `Target::Auto` for a method that supports hybrid
    /// co-execution: explore SMP, then the device, then the hybrid split,
    /// and settle on the lane with the lowest trailing-window mean —
    /// the incumbent keeps the method unless a challenger beats it by the
    /// hysteresis factor.  A returned [`Choice::Hybrid`] carries the
    /// current learned split ratio.
    pub fn decide_hybrid(&self, method: &str) -> Choice {
        self.decide_hybrid_explained(method, None).choice
    }

    /// [`Scheduler::decide_hybrid`] (or, with `items`,
    /// [`Scheduler::decide_hybrid_sized`]) returning the full
    /// [`DecisionExplain`] payload.
    pub fn decide_hybrid_explained(&self, method: &str, items: Option<u64>) -> DecisionExplain {
        self.decide_impl_explained(method, items, Self::decide_history_hybrid_explained)
    }

    /// [`Scheduler::decide_hybrid`] conditioned on input size — the
    /// per-bucket ladder of [`Scheduler::decide_sized`], with the hybrid
    /// rung; a returned [`Choice::Hybrid`] carries the *bucket's* learned
    /// split ratio.
    pub fn decide_hybrid_sized(&self, method: &str, items: u64) -> Choice {
        self.decide_hybrid_explained(method, Some(items)).choice
    }

    /// Resolve `Target::Auto` for a co-execution-capable method over a
    /// `lanes`-device fleet: explore SMP, then the device lane, then the
    /// N-way shard, and settle on the lane kind with the lowest
    /// trailing-window mean under the usual hysteresis — the fleet
    /// generalization of [`Scheduler::decide_hybrid`] (which the engine
    /// still uses for 1-device fleets, keeping the two-way behavior
    /// bit-for-bit).  An incumbent [`Choice::Hybrid`] counts as the
    /// co-execution incumbent here, so a snapshot learned on a 1-device
    /// fleet does not forfeit its hysteresis when the fleet grows.
    pub fn decide_sharded(&self, method: &str, lanes: usize) -> Choice {
        self.decide_sharded_explained(method, lanes, None).choice
    }

    /// [`Scheduler::decide_sharded`] (or, with `items`,
    /// [`Scheduler::decide_sharded_sized`]) returning the full
    /// [`DecisionExplain`] payload.
    pub fn decide_sharded_explained(
        &self,
        method: &str,
        lanes: usize,
        items: Option<u64>,
    ) -> DecisionExplain {
        self.decide_impl_explained(method, items, |cfg, e| {
            Self::decide_history_sharded_explained(cfg, e, lanes)
        })
    }

    /// [`Scheduler::decide_sharded`] conditioned on input size — the
    /// per-bucket ladder of [`Scheduler::decide_sized`], with the sharded
    /// rung.
    pub fn decide_sharded_sized(&self, method: &str, lanes: usize, items: u64) -> Choice {
        self.decide_sharded_explained(method, lanes, Some(items)).choice
    }

    fn decide_history(cfg: &SchedulerConfig, e: &MethodHistory) -> Choice {
        Self::decide_history_explained(cfg, e).0
    }

    fn decide_history_explained(
        cfg: &SchedulerConfig,
        e: &MethodHistory,
    ) -> (Choice, &'static str) {
        // explore first: SMP is always applicable, measure it first, then
        // give the device its minimum samples
        if e.smp_secs.len() < cfg.min_samples {
            return (Choice::Smp, "explore-smp");
        }
        if e.device_secs.len() < cfg.min_samples {
            return (Choice::Device, "explore-device");
        }
        let smp = e.smp_estimate().expect("smp samples present");
        let dev = e.device_estimate().expect("device samples present");
        match e.last_choice {
            // hysteresis: the incumbent keeps the method unless the
            // challenger beats it by the configured factor
            Some(Choice::Smp) => {
                if smp > dev * cfg.hysteresis {
                    (Choice::Device, "hysteresis-flip")
                } else {
                    (Choice::Smp, "incumbent-held")
                }
            }
            Some(Choice::Device) => {
                if dev > smp * cfg.hysteresis {
                    (Choice::Smp, "hysteresis-flip")
                } else {
                    (Choice::Device, "incumbent-held")
                }
            }
            // a hybrid/sharded incumbent can only appear when the caller
            // switched entry points; fall back to the no-incumbent
            // comparison
            Some(Choice::Hybrid { .. }) | Some(Choice::Sharded { .. }) | None => {
                if dev < smp {
                    (Choice::Device, "best-mean")
                } else {
                    (Choice::Smp, "best-mean")
                }
            }
        }
    }

    fn decide_history_hybrid(cfg: &SchedulerConfig, e: &MethodHistory) -> Choice {
        Self::decide_history_hybrid_explained(cfg, e).0
    }

    fn decide_history_hybrid_explained(
        cfg: &SchedulerConfig,
        e: &MethodHistory,
    ) -> (Choice, &'static str) {
        // exploration ladder: SMP → device → hybrid, each to min_samples
        if e.smp_secs.len() < cfg.min_samples {
            return (Choice::Smp, "explore-smp");
        }
        if e.device_secs.len() < cfg.min_samples {
            return (Choice::Device, "explore-device");
        }
        let fraction = e.device_fraction.unwrap_or(DEFAULT_DEVICE_FRACTION);
        if e.hybrid_secs.len() < cfg.min_samples {
            return (Choice::Hybrid { device_fraction: fraction }, "explore-hybrid");
        }
        let smp = e.smp_estimate().expect("smp samples present");
        let dev = e.device_estimate().expect("device samples present");
        let hyb = e.hybrid_estimate().expect("hybrid samples present");
        let cost = |c: Choice| match c {
            Choice::Smp => smp,
            Choice::Device => dev,
            // a sharded incumbent (snapshot from a fleet engine) costs as
            // the co-execution lane — both split one invocation
            Choice::Hybrid { .. } | Choice::Sharded { .. } => hyb,
        };
        let mut best = Choice::Smp;
        for c in [Choice::Device, Choice::Hybrid { device_fraction: fraction }] {
            if cost(c) < cost(best) {
                best = c;
            }
        }
        match e.last_choice {
            Some(inc) => {
                // an incumbent hybrid keeps running at the *current*
                // learned ratio — a ratio refinement is not a lane flip
                let inc = match inc {
                    Choice::Hybrid { .. } | Choice::Sharded { .. } => {
                        Choice::Hybrid { device_fraction: fraction }
                    }
                    other => other,
                };
                if cost(inc) > cost(best) * cfg.hysteresis {
                    (best, "hysteresis-flip")
                } else {
                    (inc, "incumbent-held")
                }
            }
            None => (best, "best-mean"),
        }
    }

    /// The N-way exploration/decision ladder: SMP → device → sharded,
    /// each to `min_samples`, then the lowest trailing mean wins under
    /// hysteresis.  The hybrid rung is *replaced* by the sharded rung on
    /// multi-device fleets — sharding subsumes the two-way split — but
    /// hybrid history (from 1-device snapshots) still costs the
    /// co-execution incumbent honestly.
    fn decide_history_sharded(cfg: &SchedulerConfig, e: &MethodHistory, lanes: usize) -> Choice {
        Self::decide_history_sharded_explained(cfg, e, lanes).0
    }

    fn decide_history_sharded_explained(
        cfg: &SchedulerConfig,
        e: &MethodHistory,
        lanes: usize,
    ) -> (Choice, &'static str) {
        if e.smp_secs.len() < cfg.min_samples {
            return (Choice::Smp, "explore-smp");
        }
        if e.device_secs.len() < cfg.min_samples {
            return (Choice::Device, "explore-device");
        }
        if e.sharded_secs.len() < cfg.min_samples {
            return (Choice::Sharded { lanes }, "explore-sharded");
        }
        let smp = e.smp_estimate().expect("smp samples present");
        let dev = e.device_estimate().expect("device samples present");
        let shd = e.sharded_estimate().expect("sharded samples present");
        let cost = |c: Choice| match c {
            Choice::Smp => smp,
            Choice::Device => dev,
            // a hybrid incumbent (two-way snapshot) costs as its own
            // window when present, else as the sharded lane
            Choice::Hybrid { .. } => e.hybrid_estimate().unwrap_or(shd),
            Choice::Sharded { .. } => shd,
        };
        let mut best = Choice::Smp;
        for c in [Choice::Device, Choice::Sharded { lanes }] {
            if cost(c) < cost(best) {
                best = c;
            }
        }
        match e.last_choice {
            Some(inc) => {
                // a weight refinement is not a lane flip; a two-way
                // hybrid incumbent carries its hysteresis into the fleet
                let inc = match inc {
                    Choice::Sharded { .. } | Choice::Hybrid { .. } => Choice::Sharded { lanes },
                    other => other,
                };
                if cost(inc) > cost(best) * cfg.hysteresis {
                    (best, "hysteresis-flip")
                } else {
                    (inc, "incumbent-held")
                }
            }
            None => (best, "best-mean"),
        }
    }

    /// Peek at the binary decision without recording it (reports).
    pub fn predict(&self, method: &str) -> Choice {
        let h = self.histories.lock().unwrap();
        match h.get(method) {
            Some(e) => Self::decide_history(&self.cfg, e),
            None => Choice::Smp,
        }
    }

    /// Snapshot one method's history.
    pub fn history(&self, method: &str) -> Option<MethodHistory> {
        self.histories.lock().unwrap().get(method).cloned()
    }

    /// Snapshot one method's history for a single size bucket (None when
    /// the method or bucket has never been fed a sized sample).
    pub fn bucket_history(&self, method: &str, bucket: u32) -> Option<MethodHistory> {
        self.histories
            .lock()
            .unwrap()
            .get(method)
            .and_then(|e| e.size_buckets.get(&bucket))
            .cloned()
    }

    /// Structural invariant check over every size bucket: a bucket keyed
    /// `b` may only hold samples whose item counts map to `b` (verified
    /// through the `items_min`/`items_max` extremes every sized record
    /// maintains), and buckets never nest.  The scheduler-history suite
    /// runs this after mixed-size workloads to prove windows don't leak
    /// across buckets.
    pub fn check_buckets(&self) -> Result<(), String> {
        let h = self.histories.lock().unwrap();
        for (name, e) in h.iter() {
            for (&b, bucket) in &e.size_buckets {
                for items in [bucket.items_min, bucket.items_max].into_iter().flatten() {
                    if bucket_of(items) != b {
                        return Err(format!(
                            "method '{name}': bucket {b} holds a sample of {items} items \
                             (belongs to bucket {})",
                            bucket_of(items)
                        ));
                    }
                }
                if !bucket.size_buckets.is_empty() {
                    return Err(format!("method '{name}': bucket {b} has nested buckets"));
                }
            }
        }
        Ok(())
    }

    /// The full decision table: one all-sizes row per known method, plus
    /// (when size bucketing has populated them) one row per size bucket.
    /// Methods with sharded history report the fleet decision, methods
    /// with hybrid history the three-way one; pure two-lane methods keep
    /// the binary one (so a method that never co-executed is never
    /// *reported* as hybrid- or fleet-bound).
    pub fn decision_table(&self) -> Vec<DecisionRow> {
        let row_from = |name: &str, e: &MethodHistory, bucket: Option<u32>| DecisionRow {
            method: name.to_string(),
            smp_secs: e.smp_estimate(),
            device_secs: e.device_estimate(),
            hybrid_secs: e.hybrid_estimate(),
            sharded_secs: e.sharded_estimate(),
            device_fraction: e.device_fraction,
            lane_weights: e.lane_weights.clone(),
            transfer_bytes_per_run: e.transfer_bytes_per_run(),
            mean_batch_requests: e.mean_batch_requests(),
            bucket_log2_items: bucket,
            choice: if e.sharded_runs > 0 {
                let lanes = e.device_lane_items_per_sec.len().max(1);
                Self::decide_history_sharded(&self.cfg, e, lanes)
            } else if e.hybrid_runs > 0 {
                Self::decide_history_hybrid(&self.cfg, e)
            } else {
                Self::decide_history(&self.cfg, e)
            },
        };
        let h = self.histories.lock().unwrap();
        let mut rows = Vec::new();
        for (name, e) in h.iter() {
            rows.push(row_from(name, e, None));
            for (&b, bucket) in &e.size_buckets {
                rows.push(row_from(name, bucket, Some(b)));
            }
        }
        rows
    }

    // -- serialization ------------------------------------------------------

    /// Serialize every history to JSON (decision state round-trips,
    /// size buckets included).
    pub fn to_json(&self) -> Json {
        let h = self.histories.lock().unwrap();
        let mut top = BTreeMap::new();
        for (name, e) in h.iter() {
            top.insert(name.clone(), Self::history_json(e));
        }
        Json::Obj(top)
    }

    /// One history granularity as a JSON object — called once per method
    /// and recursively per size bucket (buckets serialize with the same
    /// schema as the all-sizes history, minus further nesting).
    fn history_json(e: &MethodHistory) -> Json {
        let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        let mut m = BTreeMap::new();
        m.insert("smp_secs".to_string(), arr(&e.smp_secs));
        m.insert("device_secs".to_string(), arr(&e.device_secs));
        m.insert("hybrid_secs".to_string(), arr(&e.hybrid_secs));
        m.insert("smp_items_per_sec".to_string(), arr(&e.smp_items_per_sec));
        m.insert("device_items_per_sec".to_string(), arr(&e.device_items_per_sec));
        m.insert("sharded_secs".to_string(), arr(&e.sharded_secs));
        m.insert("device_queue_wait_secs".to_string(), arr(&e.device_queue_wait_secs));
        m.insert(
            "device_lane_items_per_sec".to_string(),
            Json::Arr(e.device_lane_items_per_sec.iter().map(|w| arr(w)).collect()),
        );
        m.insert("smp_runs".to_string(), Json::Num(e.smp_runs as f64));
        m.insert("device_runs".to_string(), Json::Num(e.device_runs as f64));
        m.insert("device_failures".to_string(), Json::Num(e.device_failures as f64));
        m.insert("hybrid_runs".to_string(), Json::Num(e.hybrid_runs as f64));
        m.insert("hybrid_failures".to_string(), Json::Num(e.hybrid_failures as f64));
        m.insert("sharded_runs".to_string(), Json::Num(e.sharded_runs as f64));
        m.insert("sharded_failures".to_string(), Json::Num(e.sharded_failures as f64));
        m.insert("transfer_runs".to_string(), Json::Num(e.transfer_runs as f64));
        m.insert("resident_runs".to_string(), Json::Num(e.resident_runs as f64));
        m.insert("resident_bytes".to_string(), Json::Num(e.resident_bytes as f64));
        m.insert("skipped_bytes".to_string(), Json::Num(e.skipped_bytes as f64));
        m.insert(
            "device_fraction".to_string(),
            match e.device_fraction {
                Some(f) => Json::Num(f),
                None => Json::Null,
            },
        );
        m.insert(
            "lane_weights".to_string(),
            match &e.lane_weights {
                Some(w) => arr(w),
                None => Json::Null,
            },
        );
        m.insert("bytes_h2d".to_string(), Json::Num(e.bytes_h2d as f64));
        m.insert("bytes_d2h".to_string(), Json::Num(e.bytes_d2h as f64));
        m.insert("launches".to_string(), Json::Num(e.launches as f64));
        m.insert(
            "batch_requests_per_invocation".to_string(),
            arr(&e.batch_requests_per_invocation),
        );
        m.insert("batched_invocations".to_string(), Json::Num(e.batched_invocations as f64));
        m.insert("batched_requests".to_string(), Json::Num(e.batched_requests as f64));
        m.insert("batched_items".to_string(), Json::Num(e.batched_items as f64));
        m.insert(
            "last_choice".to_string(),
            match e.last_choice {
                Some(Choice::Smp) => Json::Str("smp".to_string()),
                Some(Choice::Device) => Json::Str("device".to_string()),
                Some(Choice::Hybrid { .. }) => Json::Str("hybrid".to_string()),
                Some(Choice::Sharded { .. }) => Json::Str("sharded".to_string()),
                None => Json::Null,
            },
        );
        let opt_num = |v: Option<u64>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        m.insert("items_min".to_string(), opt_num(e.items_min));
        m.insert("items_max".to_string(), opt_num(e.items_max));
        // emitted only when populated, so unbucketed snapshots keep the
        // exact pre-bucket schema (and legacy loaders stay unconfused)
        if !e.size_buckets.is_empty() {
            let mut buckets = BTreeMap::new();
            for (&b, bucket) in &e.size_buckets {
                buckets.insert(b.to_string(), Self::history_json(bucket));
            }
            m.insert("size_buckets".to_string(), Json::Obj(buckets));
        }
        Json::Obj(m)
    }

    /// Rebuild a scheduler from [`Scheduler::to_json`] output.  Histories
    /// persisted before the hybrid lane existed load cleanly (the hybrid
    /// fields default to empty), and snapshots persisted before size
    /// bucketing load as a single all-sizes history with no buckets —
    /// exactly the "everything in one bucket" semantics they were
    /// recorded under.
    pub fn from_json(cfg: SchedulerConfig, json: &Json) -> Result<Scheduler, String> {
        let obj = match json {
            Json::Obj(m) => m,
            _ => return Err("scheduler state must be a JSON object".to_string()),
        };
        let mut histories = BTreeMap::new();
        for (name, v) in obj {
            histories.insert(name.clone(), Self::history_from(name, v)?);
        }
        Ok(Scheduler { cfg, histories: Mutex::new(histories) })
    }

    /// Parse one history granularity — called per method and recursively
    /// per size bucket (nesting below one level is discarded; buckets
    /// never hold buckets).
    fn history_from(name: &str, v: &Json) -> Result<MethodHistory, String> {
        let secs = |key: &str| -> Result<Vec<f64>, String> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("method '{name}': missing '{key}'"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| format!("bad number in '{key}'")))
                .collect()
        };
        // fields added by the hybrid lane: absent in old snapshots
        let secs_opt = |key: &str| -> Result<Vec<f64>, String> {
            match v.get(key).and_then(Json::as_arr) {
                None => Ok(Vec::new()),
                Some(a) => a
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| format!("bad number in '{key}'")))
                    .collect(),
            }
        };
        let num = |key: &str| -> u64 { v.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64 };
        let device_fraction = v.get("device_fraction").and_then(Json::as_f64);
        // fields added by the device-fleet PR: absent in older
        // snapshots, which then load as a 1-device fleet (their
        // two-way `device_fraction` keeps steering `sharded_weights`)
        let lane_weights: Option<Vec<f64>> = v
            .get("lane_weights")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|x| x.as_f64().ok_or_else(|| "bad number in 'lane_weights'".to_string()))
                    .collect::<Result<Vec<f64>, String>>()
            })
            .transpose()?;
        let device_lane_items_per_sec: Vec<Vec<f64>> =
            match v.get("device_lane_items_per_sec").and_then(Json::as_arr) {
                None => Vec::new(),
                Some(lanes) => lanes
                    .iter()
                    .map(|lane| {
                        lane.as_arr()
                            .ok_or_else(|| {
                                "bad lane window in 'device_lane_items_per_sec'".to_string()
                            })?
                            .iter()
                            .map(|x| {
                                x.as_f64().ok_or_else(|| {
                                    "bad number in 'device_lane_items_per_sec'".to_string()
                                })
                            })
                            .collect::<Result<Vec<f64>, String>>()
                    })
                    .collect::<Result<Vec<Vec<f64>>, String>>()?,
            };
        // pre-hybrid snapshots lack the field; their only
        // transfer-accounted runs were device runs (old denominator)
        let transfer_runs = match v.get("transfer_runs").and_then(Json::as_f64) {
            Some(n) => n as u64,
            None => num("device_runs"),
        };
        let last_choice = match v.get("last_choice").and_then(Json::as_str) {
            Some("smp") => Some(Choice::Smp),
            Some("device") => Some(Choice::Device),
            Some("hybrid") => Some(Choice::Hybrid {
                device_fraction: device_fraction.unwrap_or(DEFAULT_DEVICE_FRACTION),
            }),
            Some("sharded") => Some(Choice::Sharded {
                lanes: lane_weights
                    .as_ref()
                    .map(|w| w.len().saturating_sub(1))
                    .filter(|&l| l > 0)
                    .unwrap_or_else(|| device_lane_items_per_sec.len().max(1)),
            }),
            _ => None,
        };
        // pre-bucket snapshots lack the key → no buckets (all-sizes only)
        let mut size_buckets = BTreeMap::new();
        if let Some(Json::Obj(bm)) = v.get("size_buckets") {
            for (key, bv) in bm {
                let b: u32 = key
                    .parse()
                    .map_err(|_| format!("method '{name}': bad size bucket key '{key}'"))?;
                let mut bucket = Self::history_from(name, bv)?;
                bucket.size_buckets = BTreeMap::new();
                size_buckets.insert(b, bucket);
            }
        }
        let item_bound =
            |key: &str| -> Option<u64> { v.get(key).and_then(Json::as_f64).map(|x| x as u64) };
        Ok(MethodHistory {
            smp_secs: secs("smp_secs")?,
            device_secs: secs("device_secs")?,
            hybrid_secs: secs_opt("hybrid_secs")?,
            smp_items_per_sec: secs_opt("smp_items_per_sec")?,
            device_items_per_sec: secs_opt("device_items_per_sec")?,
            sharded_secs: secs_opt("sharded_secs")?,
            // observability PR field: absent in older snapshots
            device_queue_wait_secs: secs_opt("device_queue_wait_secs")?,
            device_lane_items_per_sec,
            smp_runs: num("smp_runs"),
            device_runs: num("device_runs"),
            device_failures: num("device_failures"),
            hybrid_runs: num("hybrid_runs"),
            hybrid_failures: num("hybrid_failures"),
            sharded_runs: num("sharded_runs"),
            sharded_failures: num("sharded_failures"),
            transfer_runs,
            // pre-pipeline snapshots lack the resident-run fields
            resident_runs: num("resident_runs"),
            resident_bytes: num("resident_bytes"),
            skipped_bytes: num("skipped_bytes"),
            device_fraction,
            lane_weights,
            bytes_h2d: num("bytes_h2d"),
            bytes_d2h: num("bytes_d2h"),
            launches: num("launches"),
            // fields added by the serving layer: absent in
            // pre-serve snapshots
            batch_requests_per_invocation: secs_opt("batch_requests_per_invocation")?,
            batched_invocations: num("batched_invocations"),
            batched_requests: num("batched_requests"),
            batched_items: num("batched_items"),
            size_buckets,
            items_min: item_bound("items_min"),
            items_max: item_bound("items_max"),
            last_choice,
        })
    }

    /// Persist the full history store to `path` (the
    /// [`Scheduler::to_json`] text).  The serving layer calls this on
    /// drain when `SOMD_SCHED_SNAPSHOT` is set, so a restarted process
    /// warm-starts its lane/ratio learning instead of re-exploring.
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().dump())
            .map_err(|e| format!("writing scheduler snapshot {}: {e}", path.display()))
    }

    /// Rebuild a scheduler from a file written by [`Scheduler::save`]
    /// (snapshots from any earlier history layout load cleanly — see
    /// [`Scheduler::from_json`]).
    pub fn load(path: &std::path::Path, cfg: SchedulerConfig) -> Result<Scheduler, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading scheduler snapshot {}: {e}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| format!("parsing scheduler snapshot {}: {e}", path.display()))?;
        Self::from_json(cfg, &json)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev_stats(secs: f64, bytes: usize) -> DeviceStats {
        DeviceStats {
            launches: 1,
            bytes_h2d: bytes,
            device_time: Duration::from_secs_f64(secs),
            ..DeviceStats::default()
        }
    }

    /// Record a device run whose measured wall equals `secs`.
    fn rec_dev(s: &Scheduler, m: &str, secs: f64, bytes: usize) {
        s.record_device(m, Duration::from_secs_f64(secs), &dev_stats(secs, bytes));
    }

    /// Record a hybrid run: both sides clocked at `secs`, with the given
    /// per-side item shares.
    fn rec_hyb(s: &Scheduler, m: &str, smp_items: usize, dev_items: usize, secs: f64) {
        s.record_hybrid(
            m,
            HybridSample { items: smp_items, secs },
            HybridSample { items: dev_items, secs },
            &DeviceStats::default(),
        );
    }

    #[test]
    fn explores_smp_then_device() {
        let s = Scheduler::new(SchedulerConfig::default());
        assert_eq!(s.decide("M.m"), Choice::Smp);
        s.record_smp("M.m", Duration::from_millis(10));
        s.record_smp("M.m", Duration::from_millis(10));
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn picks_faster_side_after_exploration() {
        let s = Scheduler::new(SchedulerConfig { hysteresis: 1.0, ..Default::default() });
        for _ in 0..3 {
            s.record_smp("M.m", Duration::from_millis(50));
            rec_dev(&s, "M.m", 0.005, 1000);
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn hysteresis_prevents_flapping_on_noise() {
        let s = Scheduler::new(SchedulerConfig {
            window: 4,
            min_samples: 2,
            hysteresis: 1.5,
            ..Default::default()
        });
        for _ in 0..4 {
            s.record_smp("M.m", Duration::from_millis(10));
            rec_dev(&s, "M.m", 0.011, 0);
        }
        // smp incumbent; device is 10% faster? no: device is slower here.
        assert_eq!(s.decide("M.m"), Choice::Smp);
        // device becomes slightly faster, but within the hysteresis band
        for _ in 0..4 {
            rec_dev(&s, "M.m", 0.009, 0);
        }
        assert_eq!(s.decide("M.m"), Choice::Smp);
        // device becomes clearly faster — now it flips
        for _ in 0..4 {
            rec_dev(&s, "M.m", 0.004, 0);
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
        // and stays flipped on repeated decisions (stable boundary)
        for _ in 0..10 {
            assert_eq!(s.decide("M.m"), Choice::Device);
        }
    }

    #[test]
    fn failing_device_lane_steers_back_to_smp() {
        let s = Scheduler::new(SchedulerConfig::default());
        s.record_smp("M.m", Duration::from_millis(10));
        s.record_smp("M.m", Duration::from_millis(10));
        // exploration would now pick the device; it fails every time
        assert_eq!(s.decide("M.m"), Choice::Device);
        s.record_device_failure("M.m");
        assert_eq!(s.decide("M.m"), Choice::Device); // still exploring (1 < 2)
        s.record_device_failure("M.m");
        // penalties complete exploration and the broken lane loses
        assert_eq!(s.decide("M.m"), Choice::Smp);
        let h = s.history("M.m").unwrap();
        assert_eq!(h.device_failures, 2);
        // a recovered device (fast successes) can win the method back
        for _ in 0..8 {
            s.record_device("M.m", Duration::from_micros(100), &DeviceStats::default());
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn json_roundtrip_preserves_decisions() {
        let cfg = SchedulerConfig::default();
        let s = Scheduler::new(cfg);
        for i in 0..5 {
            s.record_smp("A.a", Duration::from_millis(3 + i));
            rec_dev(&s, "A.a", 0.050, 1 << 20);
            s.record_smp("B.b", Duration::from_millis(80));
            rec_dev(&s, "B.b", 0.002, 64);
        }
        let a = s.decide("A.a");
        let b = s.decide("B.b");
        let restored = Scheduler::from_json(cfg, &s.to_json()).unwrap();
        assert_eq!(restored.decide("A.a"), a);
        assert_eq!(restored.decide("B.b"), b);
        assert_eq!(restored.history("A.a"), s.history("A.a"));
    }

    #[test]
    fn transfer_heavy_method_steers_to_smp() {
        // Crypt-shaped: device time dominated by transfers exceeds SMP
        let s = Scheduler::new(SchedulerConfig::default());
        for _ in 0..3 {
            s.record_smp("Crypt.pass", Duration::from_millis(8));
            rec_dev(&s, "Crypt.pass", 0.120, 50_000_000);
        }
        assert_eq!(s.decide("Crypt.pass"), Choice::Smp);
        // Series-shaped: compute dense, tiny transfers
        for _ in 0..3 {
            s.record_smp("Series.coefficients", Duration::from_millis(200));
            rec_dev(&s, "Series.coefficients", 0.004, 8_000);
        }
        assert_eq!(s.decide("Series.coefficients"), Choice::Device);
        let table = s.decision_table();
        assert_eq!(table.len(), 2);
        assert!(table[0].transfer_bytes_per_run > table[1].transfer_bytes_per_run);
    }

    // -- hybrid co-execution ------------------------------------------------

    #[test]
    fn hybrid_exploration_ladder() {
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "Series.coefficients";
        // phase 1: SMP
        assert_eq!(s.decide_hybrid(m), Choice::Smp);
        s.record_smp(m, Duration::from_millis(10));
        s.record_smp(m, Duration::from_millis(10));
        // phase 2: device
        assert_eq!(s.decide_hybrid(m), Choice::Device);
        rec_dev(&s, m, 0.010, 0);
        rec_dev(&s, m, 0.010, 0);
        // phase 3: hybrid at the default split
        match s.decide_hybrid(m) {
            Choice::Hybrid { device_fraction } => {
                assert!((device_fraction - DEFAULT_DEVICE_FRACTION).abs() < 1e-12)
            }
            other => panic!("expected hybrid exploration, got {other:?}"),
        }
        // a faster hybrid wins the method and stays
        rec_hyb(&s, m, 500, 500, 0.005);
        rec_hyb(&s, m, 500, 500, 0.005);
        assert!(matches!(s.decide_hybrid(m), Choice::Hybrid { .. }));
        for _ in 0..5 {
            assert!(matches!(s.decide_hybrid(m), Choice::Hybrid { .. }));
        }
        // hybrid degrades badly: the method flips back to a single lane
        for _ in 0..8 {
            rec_hyb(&s, m, 500, 500, 0.500);
        }
        assert!(!matches!(s.decide_hybrid(m), Choice::Hybrid { .. }));
    }

    #[test]
    fn ratio_converges_to_throughput_proportional_equilibrium() {
        let s = Scheduler::new(SchedulerConfig::default());
        // device side processes 3x the items in the same time => 3x the
        // throughput => equilibrium fraction 0.75
        for _ in 0..6 {
            rec_hyb(&s, "M.m", 250, 750, 1.0);
        }
        let f = s.hybrid_fraction("M.m");
        assert!((f - 0.75).abs() < 1e-9, "fraction {f}");
        let h = s.history("M.m").unwrap();
        assert!((h.equilibrium_fraction().unwrap() - 0.75).abs() < 1e-9);
        assert_eq!(h.hybrid_runs, 6);
    }

    #[test]
    fn ratio_deadband_absorbs_noise() {
        let s = Scheduler::new(SchedulerConfig {
            window: 2,
            ratio_deadband: 0.10,
            ..Default::default()
        });
        rec_hyb(&s, "M.m", 500, 500, 1.0); // equilibrium 0.5
        let f0 = s.hybrid_fraction("M.m");
        assert!((f0 - 0.5).abs() < 1e-9);
        // small imbalance within the deadband: the stored ratio holds
        rec_hyb(&s, "M.m", 480, 520, 1.0);
        rec_hyb(&s, "M.m", 480, 520, 1.0);
        assert!((s.hybrid_fraction("M.m") - f0).abs() < 1e-9);
        // a clear shift moves it
        rec_hyb(&s, "M.m", 200, 800, 1.0);
        rec_hyb(&s, "M.m", 200, 800, 1.0);
        assert!((s.hybrid_fraction("M.m") - 0.8).abs() < 1e-6);
    }

    #[test]
    fn degenerate_shares_do_not_poison_the_ratio() {
        let s = Scheduler::new(SchedulerConfig::default());
        // an all-device experiment split: no SMP throughput sample
        s.record_hybrid(
            "M.m",
            HybridSample { items: 0, secs: 0.0 },
            HybridSample { items: 1000, secs: 1.0 },
            &DeviceStats::default(),
        );
        let h = s.history("M.m").unwrap();
        assert!(h.smp_items_per_sec.is_empty());
        assert_eq!(h.device_items_per_sec.len(), 1);
        assert_eq!(h.device_fraction, None, "one-sided evidence must not set a ratio");
        assert_eq!(s.hybrid_fraction("M.m"), DEFAULT_DEVICE_FRACTION);
    }

    #[test]
    fn hybrid_failures_penalize_the_hybrid_lane() {
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "M.m";
        for _ in 0..2 {
            s.record_smp(m, Duration::from_millis(10));
            rec_dev(&s, m, 0.008, 0);
        }
        s.record_hybrid_failure(m);
        s.record_hybrid_failure(m);
        // both failures recorded; the hybrid lane cannot win the decision
        let h = s.history(m).unwrap();
        assert_eq!(h.hybrid_failures, 2);
        assert!(!matches!(s.decide_hybrid(m), Choice::Hybrid { .. }));
    }

    #[test]
    fn hybrid_state_survives_json_text_roundtrip() {
        let cfg = SchedulerConfig::default();
        let s = Scheduler::new(cfg);
        for _ in 0..3 {
            s.record_smp("M.m", Duration::from_millis(20));
            rec_dev(&s, "M.m", 0.020, 4096);
            rec_hyb(&s, "M.m", 300, 700, 0.008);
        }
        let first = s.decide_hybrid("M.m");
        let text = s.to_json().dump();
        let parsed = Json::parse(&text).expect("scheduler state parses");
        let restored = Scheduler::from_json(cfg, &parsed).expect("state restores");
        assert_eq!(restored.history("M.m"), s.history("M.m"));
        assert_eq!(restored.hybrid_fraction("M.m"), s.hybrid_fraction("M.m"));
        assert!(restored.decide_hybrid("M.m").same_lane(&first));
    }

    #[test]
    fn failed_and_degraded_runs_do_not_dilute_transfer_bytes_per_run() {
        // regression (review finding): byte-less runs must not shrink the
        // §7.3 bus-pressure signal
        let s = Scheduler::new(SchedulerConfig::default());
        rec_dev(&s, "M.m", 0.010, 1_000_000); // 1 MB across the bus
        s.record_device_failure("M.m");
        s.record_hybrid_failure("M.m");
        for _ in 0..5 {
            s.record_hybrid_degraded("M.m", Duration::from_millis(10));
        }
        let h = s.history("M.m").unwrap();
        assert_eq!(h.transfer_runs, 1);
        assert!((h.transfer_bytes_per_run() - 1_000_000.0).abs() < 1e-9);
    }

    #[test]
    fn resident_runs_recorded_distinctly_from_transfer_runs() {
        // a pipeline stage that kept its input resident moves almost no
        // bytes; folding it into the mean would fake a cheap bus
        let s = Scheduler::new(SchedulerConfig::default());
        rec_dev(&s, "M.m", 0.010, 1_000_000); // an honest round-trip run
        let mut st = dev_stats(0.004, 64); // residual traffic only
        st.h2d_skipped = 1;
        st.d2h_skipped = 1;
        st.bytes_h2d_skipped = 1_000_000;
        st.bytes_d2h_skipped = 1_000_000;
        s.record_device("M.m", Duration::from_secs_f64(0.004), &st);
        let h = s.history("M.m").unwrap();
        assert_eq!(h.transfer_runs, 1);
        assert_eq!(h.resident_runs, 1);
        assert_eq!(h.resident_bytes, 64);
        assert_eq!(h.skipped_bytes, 2_000_000);
        // the mean still reads 1 MB/run, not (1 MB + 64 B) / 2
        assert!((h.transfer_bytes_per_run() - 1_000_000.0).abs() < 1e-9);
    }

    #[test]
    fn degraded_runs_complete_exploration_so_auto_can_settle() {
        // regression (review finding): an auto method whose inputs are too
        // small to split must not sit in the hybrid exploration rung
        // forever — the degraded SMP wall counts as the hybrid sample
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "Tiny.m";
        for _ in 0..2 {
            s.record_smp(m, Duration::from_millis(10));
            rec_dev(&s, m, 0.001, 64); // device clearly faster
        }
        // exploration now wants hybrid…
        assert!(matches!(s.decide_hybrid(m), Choice::Hybrid { .. }));
        // …but every attempt degrades (device share under the floor)
        s.record_hybrid_degraded(m, Duration::from_millis(10));
        s.record_hybrid_degraded(m, Duration::from_millis(10));
        // exploration is complete and the faster pure lane wins
        assert_eq!(s.decide_hybrid(m), Choice::Device);
        let h = s.history(m).unwrap();
        assert_eq!(h.hybrid_runs, 2);
        assert_eq!(h.hybrid_failures, 0);
    }

    #[test]
    fn batch_records_accumulate_and_round_trip() {
        let cfg = SchedulerConfig::default();
        let s = Scheduler::new(cfg);
        s.record_smp("Serve.m", Duration::from_millis(5));
        s.record_batch("Serve.m", 8, 8000);
        s.record_batch("Serve.m", 4, 4000);
        s.record_batch("Serve.m", 1, 500);
        let h = s.history("Serve.m").unwrap();
        assert_eq!(h.batched_invocations, 3);
        assert_eq!(h.batched_requests, 13);
        assert_eq!(h.batched_items, 12_500);
        assert!((h.mean_batch_requests().unwrap() - 13.0 / 3.0).abs() < 1e-12);
        // occupancy must not perturb the lane decision inputs
        assert_eq!(h.smp_secs.len(), 1);
        assert_eq!(h.device_secs.len(), 0);
        // and it round-trips through serialized text
        let text = s.to_json().dump();
        let restored = Scheduler::from_json(cfg, &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(restored.history("Serve.m"), s.history("Serve.m"));
        let row = &restored.decision_table()[0];
        assert!((row.mean_batch_requests.unwrap() - 13.0 / 3.0).abs() < 1e-12);
    }

    // -- sharded fleet co-execution -----------------------------------------

    /// Record a sharded run: every lane clocked at `secs`, with the given
    /// per-lane item shares (smp first).
    fn rec_shd(s: &Scheduler, m: &str, smp_items: usize, dev_items: &[usize], secs: f64) {
        let devices: Vec<HybridSample> =
            dev_items.iter().map(|&items| HybridSample { items, secs }).collect();
        s.record_sharded(
            m,
            HybridSample { items: smp_items, secs },
            &devices,
            &DeviceStats::default(),
        );
    }

    #[test]
    fn sharded_exploration_ladder() {
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "Series.coefficients";
        assert_eq!(s.decide_sharded(m, 2), Choice::Smp);
        s.record_smp(m, Duration::from_millis(10));
        s.record_smp(m, Duration::from_millis(10));
        assert_eq!(s.decide_sharded(m, 2), Choice::Device);
        rec_dev(&s, m, 0.010, 0);
        rec_dev(&s, m, 0.010, 0);
        assert_eq!(s.decide_sharded(m, 2), Choice::Sharded { lanes: 2 });
        // a faster shard wins the method and stays
        rec_shd(&s, m, 300, &[350, 350], 0.004);
        rec_shd(&s, m, 300, &[350, 350], 0.004);
        for _ in 0..5 {
            assert!(matches!(s.decide_sharded(m, 2), Choice::Sharded { lanes: 2 }));
        }
        // the shard degrades badly: the method flips back to a pure lane
        for _ in 0..8 {
            rec_shd(&s, m, 300, &[350, 350], 0.500);
        }
        assert!(!matches!(s.decide_sharded(m, 2), Choice::Sharded { .. }));
    }

    #[test]
    fn weights_converge_to_throughput_proportional_equilibrium() {
        let s = Scheduler::new(SchedulerConfig::default());
        // same clock, items 1:2:5 => throughputs 1:2:5 => weights .125/.25/.625
        for _ in 0..6 {
            rec_shd(&s, "M.m", 125, &[250, 625], 1.0);
        }
        let w = s.sharded_weights("M.m", 2);
        assert_eq!(w.len(), 3);
        assert!((w[0] - 0.125).abs() < 1e-9, "weights {w:?}");
        assert!((w[1] - 0.250).abs() < 1e-9, "weights {w:?}");
        assert!((w[2] - 0.625).abs() < 1e-9, "weights {w:?}");
        let h = s.history("M.m").unwrap();
        assert_eq!(h.sharded_runs, 6);
        assert_eq!(h.device_lane_items_per_sec.len(), 2);
        let eq = h.equilibrium_weights(2).unwrap();
        assert!((eq.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weight_deadband_absorbs_noise() {
        let s = Scheduler::new(SchedulerConfig {
            window: 2,
            ratio_deadband: 0.10,
            ..Default::default()
        });
        rec_shd(&s, "M.m", 500, &[250, 250], 1.0);
        let w0 = s.sharded_weights("M.m", 2);
        assert!((w0[0] - 0.5).abs() < 1e-9);
        // a small imbalance inside the deadband: the stored weights hold
        rec_shd(&s, "M.m", 480, &[270, 250], 1.0);
        rec_shd(&s, "M.m", 480, &[270, 250], 1.0);
        assert_eq!(s.sharded_weights("M.m", 2), w0);
        // a clear shift moves every component
        rec_shd(&s, "M.m", 200, &[600, 200], 1.0);
        rec_shd(&s, "M.m", 200, &[600, 200], 1.0);
        let w = s.sharded_weights("M.m", 2);
        assert!((w[1] - 0.6).abs() < 1e-6, "weights {w:?}");
    }

    #[test]
    fn lane_without_evidence_blocks_the_weight_update() {
        let s = Scheduler::new(SchedulerConfig::default());
        // lane 1 starved (0 items): no throughput sample, no equilibrium
        rec_shd(&s, "M.m", 500, &[500, 0], 1.0);
        let h = s.history("M.m").unwrap();
        assert_eq!(h.device_lane_items_per_sec.len(), 2);
        assert!(h.device_lane_items_per_sec[1].is_empty());
        assert_eq!(h.lane_weights, None, "one-sided evidence must not set weights");
        // the default is the even split
        let w = s.sharded_weights("M.m", 2);
        assert!(w.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-12));
        // once the lane produces evidence, the equilibrium engages
        rec_shd(&s, "M.m", 500, &[500, 500], 1.0);
        assert!(s.history("M.m").unwrap().lane_weights.is_some());
    }

    #[test]
    fn learned_weights_keep_every_lane_alive() {
        let s = Scheduler::new(SchedulerConfig::default());
        // a nearly dead device lane must still get a floored weight, so
        // it keeps producing recovery evidence
        for _ in 0..4 {
            rec_shd(&s, "M.m", 10_000, &[10_000, 1], 1.0);
        }
        let w = s.sharded_weights("M.m", 2);
        assert!(w[2] > 0.0, "weights {w:?}");
        assert!(w[2] >= 0.04, "floored weight {w:?}");
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sharded_failures_penalize_the_fleet_lane() {
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "M.m";
        for _ in 0..2 {
            s.record_smp(m, Duration::from_millis(10));
            rec_dev(&s, m, 0.008, 0);
        }
        s.record_sharded_failure(m);
        s.record_sharded_failure(m);
        let h = s.history(m).unwrap();
        assert_eq!(h.sharded_failures, 2);
        assert!(!matches!(s.decide_sharded(m, 2), Choice::Sharded { .. }));
    }

    #[test]
    fn degraded_sharded_runs_complete_exploration() {
        let s = Scheduler::new(SchedulerConfig::default());
        let m = "Tiny.m";
        for _ in 0..2 {
            s.record_smp(m, Duration::from_millis(10));
            rec_dev(&s, m, 0.001, 64);
        }
        assert!(matches!(s.decide_sharded(m, 3), Choice::Sharded { lanes: 3 }));
        s.record_sharded_degraded(m, Duration::from_millis(10));
        s.record_sharded_degraded(m, Duration::from_millis(10));
        assert_eq!(s.decide_sharded(m, 3), Choice::Device);
        let h = s.history(m).unwrap();
        assert_eq!(h.sharded_runs, 2);
        assert_eq!(h.sharded_failures, 0);
    }

    #[test]
    fn set_sharded_weights_pins_and_normalizes() {
        let s = Scheduler::new(SchedulerConfig::default());
        s.set_sharded_weights("M.m", &[1.0, 2.0, 1.0]);
        let w = s.sharded_weights("M.m", 2);
        assert!((w[0] - 0.25).abs() < 1e-12 && (w[1] - 0.5).abs() < 1e-12);
        // bad components are sanitized; an all-dead pin is ignored
        s.set_sharded_weights("M.m", &[f64::NAN, -1.0, 0.0]);
        assert_eq!(s.sharded_weights("M.m", 2), w);
    }

    #[test]
    fn sharded_state_survives_json_text_roundtrip() {
        let cfg = SchedulerConfig::default();
        let s = Scheduler::new(cfg);
        for _ in 0..3 {
            s.record_smp("M.m", Duration::from_millis(20));
            rec_dev(&s, "M.m", 0.020, 4096);
            rec_shd(&s, "M.m", 300, &[400, 300], 0.008);
        }
        let first = s.decide_sharded("M.m", 2);
        assert!(matches!(first, Choice::Sharded { lanes: 2 }));
        let text = s.to_json().dump();
        let parsed = Json::parse(&text).expect("scheduler state parses");
        let restored = Scheduler::from_json(cfg, &parsed).expect("state restores");
        assert_eq!(restored.history("M.m"), s.history("M.m"));
        assert_eq!(restored.sharded_weights("M.m", 2), s.sharded_weights("M.m", 2));
        assert!(restored.decide_sharded("M.m", 2).same_lane(&first));
    }

    #[test]
    fn legacy_snapshot_loads_as_a_one_device_fleet() {
        // a hybrid-era snapshot: two-way fields only — its learned
        // device_fraction must steer a 1-device fleet's weights
        let text = r#"{"Old.m":{"smp_secs":[0.01,0.01],"device_secs":[0.002,0.002],
            "hybrid_secs":[0.004],"smp_items_per_sec":[100.0],
            "device_items_per_sec":[300.0],"smp_runs":2,"device_runs":2,
            "device_failures":0,"hybrid_runs":1,"hybrid_failures":0,
            "transfer_runs":3,"device_fraction":0.75,
            "bytes_h2d":128,"bytes_d2h":64,"launches":2,"last_choice":"hybrid"}}"#;
        let parsed = Json::parse(text).unwrap();
        let s = Scheduler::from_json(SchedulerConfig::default(), &parsed).unwrap();
        let h = s.history("Old.m").unwrap();
        assert!(h.sharded_secs.is_empty());
        assert_eq!(h.sharded_runs, 0);
        assert_eq!(h.lane_weights, None);
        assert!(h.device_lane_items_per_sec.is_empty());
        let w = s.sharded_weights("Old.m", 1);
        assert!((w[0] - 0.25).abs() < 1e-12 && (w[1] - 0.75).abs() < 1e-12);
        // a larger fleet gets the even default (the two-way ratio says
        // nothing about how lanes 2.. compare)
        let w3 = s.sharded_weights("Old.m", 3);
        assert!(w3.iter().all(|&x| (x - 0.25).abs() < 1e-12));
        // and the round-trip preserves the fleet fields once present
        s.set_sharded_weights("Old.m", &[0.2, 0.8]);
        let text = s.to_json().dump();
        let restored =
            Scheduler::from_json(SchedulerConfig::default(), &Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(restored.sharded_weights("Old.m", 1), vec![0.2, 0.8]);
    }

    #[test]
    fn legacy_snapshots_without_hybrid_fields_load() {
        // a PR-1-era snapshot: only the two-lane fields
        let text = r#"{"Old.m":{"smp_secs":[0.01,0.01],"device_secs":[0.002,0.002],
            "smp_runs":2,"device_runs":2,"device_failures":0,
            "bytes_h2d":128,"bytes_d2h":64,"launches":2,"last_choice":"device"}}"#;
        let parsed = Json::parse(text).unwrap();
        let s = Scheduler::from_json(SchedulerConfig::default(), &parsed).unwrap();
        let h = s.history("Old.m").unwrap();
        assert!(h.hybrid_secs.is_empty());
        assert_eq!(h.device_fraction, None);
        assert_eq!(h.batched_invocations, 0, "pre-serve snapshots carry no batch records");
        assert_eq!(h.mean_batch_requests(), None);
        assert_eq!(s.decide("Old.m"), Choice::Device);
    }

    fn sized_cfg() -> SchedulerConfig {
        SchedulerConfig { size_buckets: true, ..Default::default() }
    }

    #[test]
    fn bucket_of_is_floor_log2() {
        assert_eq!(bucket_of(0), 0); // clamped: 0 items can't index a bucket
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(1 << 20), 20);
        assert_eq!(bucket_of((1 << 21) - 1), 20);
    }

    #[test]
    fn sized_records_stay_aggregate_only_when_bucketing_is_off() {
        let s = Scheduler::new(SchedulerConfig::default());
        s.record_smp_sized("M.m", Duration::from_millis(10), 1000);
        rec_dev(&s, "M.m", 0.005, 64);
        let h = s.history("M.m").unwrap();
        assert!(h.size_buckets.is_empty(), "flag off: no buckets materialize");
        // the item extremes are still tracked (cheap, and they make a
        // later flag flip-on auditable)
        assert_eq!(h.items_min, Some(1000));
        assert_eq!(h.items_max, Some(1000));
        assert_eq!(s.decide_sized("M.m", 1000), s.decide("M.m"));
    }

    #[test]
    fn decision_flips_by_size_bucket() {
        // small inputs: SMP wins (launch overhead dominates); large
        // inputs: the device wins — one method, two settled lanes
        let s = Scheduler::new(sized_cfg());
        let (small, large) = (1_000u64, 1 << 20);
        for _ in 0..3 {
            s.record_smp_sized("M.m", Duration::from_millis(1), small);
            s.record_device_sized("M.m", Duration::from_millis(20), &dev_stats(0.02, 64), small);
            s.record_smp_sized("M.m", Duration::from_millis(20), large);
            s.record_device_sized("M.m", Duration::from_millis(1), &dev_stats(0.001, 64), large);
        }
        assert_eq!(s.decide_sized("M.m", small), Choice::Smp);
        assert_eq!(s.decide_sized("M.m", large), Choice::Device);
        // nearby sizes hash to the same buckets and inherit the verdicts
        assert_eq!(s.decide_sized("M.m", small + 20), Choice::Smp);
        assert_eq!(s.decide_sized("M.m", large + 999), Choice::Device);
        s.check_buckets().expect("windows must not leak across buckets");
        // the decision table carries one all-sizes row plus the buckets
        let rows = s.decision_table();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].bucket_log2_items, None);
        let by_bucket: Vec<(Option<u32>, Choice)> =
            rows[1..].iter().map(|r| (r.bucket_log2_items, r.choice)).collect();
        assert!(by_bucket.contains(&(Some(bucket_of(small)), Choice::Smp)));
        assert!(by_bucket.contains(&(Some(bucket_of(large)), Choice::Device)));
    }

    #[test]
    fn fresh_buckets_explore_from_scratch() {
        // a method settled on SMP in aggregate must still explore the
        // device when a never-seen size shows up: the bucket ladder
        // starts empty instead of inheriting the aggregate verdict
        let s = Scheduler::new(sized_cfg());
        for _ in 0..3 {
            s.record_smp_sized("M.m", Duration::from_millis(1), 100);
            s.record_device_sized("M.m", Duration::from_millis(50), &dev_stats(0.05, 64), 100);
        }
        assert_eq!(s.decide_sized("M.m", 100), Choice::Smp);
        assert_eq!(s.decide_sized("M.m", 1 << 22), Choice::Smp, "new bucket explores SMP first");
        s.record_smp_sized("M.m", Duration::from_millis(40), 1 << 22);
        s.record_smp_sized("M.m", Duration::from_millis(40), 1 << 22);
        assert_eq!(s.decide_sized("M.m", 1 << 22), Choice::Device, "then the device's turn");
    }

    #[test]
    fn hybrid_fraction_conditions_on_size() {
        let s = Scheduler::new(sized_cfg());
        // small inputs: device barely helps (25% share); large inputs:
        // device side is 3x the SMP side (75% share)
        for _ in 0..3 {
            s.record_hybrid(
                "M.m",
                HybridSample { items: 750, secs: 0.010 },
                HybridSample { items: 250, secs: 0.010 },
                &DeviceStats::default(),
            );
            s.record_hybrid(
                "M.m",
                HybridSample { items: 250_000, secs: 0.010 },
                HybridSample { items: 750_000, secs: 0.010 },
                &DeviceStats::default(),
            );
        }
        let small = s.hybrid_fraction_sized("M.m", 1_000);
        let large = s.hybrid_fraction_sized("M.m", 1_000_000);
        assert!((small - 0.25).abs() < 1e-9, "small-bucket equilibrium, got {small}");
        assert!((large - 0.75).abs() < 1e-9, "large-bucket equilibrium, got {large}");
        // an unseen size falls back to the all-sizes fraction
        let unseen = s.hybrid_fraction_sized("M.m", 32);
        assert_eq!(unseen, s.hybrid_fraction("M.m"));
        s.check_buckets().unwrap();
    }

    #[test]
    fn sharded_weights_condition_on_size() {
        let s = Scheduler::new(sized_cfg());
        // large inputs: lane 1 twice as fast as lane 0 and SMP
        rec_shd(&s, "M.m", 250_000, &[250_000, 500_000], 0.010);
        let w = s.sharded_weights_sized("M.m", 2, 1_000_000);
        assert!((w[0] - 0.25).abs() < 1e-9 && (w[2] - 0.5).abs() < 1e-9, "got {w:?}");
        // a size never sharded falls back to the all-sizes vector
        assert_eq!(s.sharded_weights_sized("M.m", 2, 64), s.sharded_weights("M.m", 2));
        // a method never sharded at all gets the even split
        let even = s.sharded_weights_sized("Other.m", 2, 64);
        assert!(even.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn degraded_and_failed_sized_records_complete_bucket_ladders() {
        // inputs too small to split: every sized hybrid submission
        // degrades — the bucket ladder must still converge off hybrid
        let s = Scheduler::new(sized_cfg());
        let m = "M.m";
        for _ in 0..2 {
            s.record_smp_sized(m, Duration::from_millis(10), 100);
            s.record_device_sized(m, Duration::from_millis(1), &dev_stats(0.001, 64), 100);
        }
        assert!(matches!(s.decide_hybrid_sized(m, 100), Choice::Hybrid { .. }));
        s.record_hybrid_degraded_sized(m, Duration::from_millis(10), 100);
        s.record_hybrid_degraded_sized(m, Duration::from_millis(10), 100);
        assert_eq!(s.decide_hybrid_sized(m, 100), Choice::Device);
        // same discipline for the fleet ladder at another size
        for _ in 0..2 {
            s.record_smp_sized(m, Duration::from_millis(2), 5_000);
            s.record_device_sized(m, Duration::from_millis(1), &dev_stats(0.001, 64), 5_000);
        }
        assert!(matches!(s.decide_sharded_sized(m, 2, 5_000), Choice::Sharded { lanes: 2 }));
        s.record_sharded_failure_sized(m, 5_000);
        s.record_sharded_failure_sized(m, 5_000);
        assert_eq!(s.decide_sharded_sized(m, 2, 5_000), Choice::Device);
        let b = s.bucket_history(m, bucket_of(5_000)).unwrap();
        assert_eq!(b.sharded_failures, 2);
        s.check_buckets().unwrap();
    }

    #[test]
    fn bucketed_state_survives_json_text_roundtrip() {
        let cfg = sized_cfg();
        let s = Scheduler::new(cfg);
        let (small, large) = (600u64, 1 << 18);
        for _ in 0..3 {
            s.record_smp_sized("M.m", Duration::from_millis(1), small);
            s.record_device_sized("M.m", Duration::from_millis(30), &dev_stats(0.03, 256), small);
            s.record_smp_sized("M.m", Duration::from_millis(30), large);
            s.record_device_sized("M.m", Duration::from_millis(1), &dev_stats(0.001, 256), large);
        }
        assert_eq!(s.decide_sized("M.m", small), Choice::Smp);
        assert_eq!(s.decide_sized("M.m", large), Choice::Device);
        let text = s.to_json().dump();
        let restored =
            Scheduler::from_json(cfg, &Json::parse(&text).expect("state parses")).unwrap();
        assert_eq!(restored.history("M.m"), s.history("M.m"), "buckets round-trip bit-for-bit");
        assert_eq!(restored.decide_sized("M.m", small), Choice::Smp);
        assert_eq!(restored.decide_sized("M.m", large), Choice::Device);
        restored.check_buckets().unwrap();
    }

    #[test]
    fn legacy_snapshot_loads_as_single_all_sizes_bucket() {
        // pre-bucket snapshots carry no size_buckets key: they load with
        // an empty bucket map (= everything in one all-sizes history)
        // and sized reads fall back to the aggregate learning
        let text = r#"{"Old.m":{"smp_secs":[0.01,0.01],"device_secs":[0.002,0.002],
            "smp_runs":2,"device_runs":2,"device_failures":0,
            "bytes_h2d":128,"bytes_d2h":64,"launches":2,
            "device_fraction":0.6,"last_choice":"device"}}"#;
        let s = Scheduler::from_json(sized_cfg(), &Json::parse(text).unwrap()).unwrap();
        let h = s.history("Old.m").unwrap();
        assert!(h.size_buckets.is_empty());
        assert_eq!(h.items_min, None);
        assert_eq!(h.items_max, None);
        s.check_buckets().expect("an unbucketed legacy snapshot is trivially leak-free");
        assert_eq!(s.hybrid_fraction_sized("Old.m", 1 << 16), 0.6);
        // the first sized decision starts that bucket's own exploration
        assert_eq!(s.decide_sized("Old.m", 1 << 16), Choice::Smp);
    }

    #[test]
    fn check_buckets_rejects_leaked_samples_and_nesting() {
        let s = Scheduler::new(sized_cfg());
        s.record_smp_sized("M.m", Duration::from_millis(1), 1000);
        s.check_buckets().unwrap();
        {
            // forge a leak: claim bucket 9 saw a 4096-item invocation
            let mut h = s.histories.lock().unwrap();
            let e = h.get_mut("M.m").unwrap();
            e.size_buckets.get_mut(&9).unwrap().items_max = Some(4096);
        }
        let err = s.check_buckets().expect_err("cross-bucket sample must be caught");
        assert!(err.contains("bucket 9"), "got: {err}");
        {
            let mut h = s.histories.lock().unwrap();
            let e = h.get_mut("M.m").unwrap();
            let b = e.size_buckets.get_mut(&9).unwrap();
            b.items_max = Some(1000);
            b.size_buckets.insert(3, MethodHistory::default());
        }
        let err = s.check_buckets().expect_err("nested buckets must be caught");
        assert!(err.contains("nested"), "got: {err}");
    }
}
