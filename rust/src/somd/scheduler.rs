//! Adaptive target selection (the loop paper §6 leaves to the runtime).
//!
//! The paper's Elina runtime obeys static `method:target` rules and
//! reverts to shared memory when a preference is inapplicable; automatic
//! version selection is explicitly delegated to the compiler/runtime
//! ("empowering the compiler to generate code for multiple architectures
//! from the same source").  This module closes that loop: a per-method
//! execution-history store feeds a cost model that resolves the
//! [`Target::Auto`](crate::somd::Target::Auto) rules variant at
//! invocation time.
//!
//! Recorded signals per method:
//!
//! * **SMP** — observed wall time of shared-memory invocations;
//! * **device** — the *measured* per-invocation execute time on the
//!   device lane (wall time from job start to completion on the device
//!   master, excluding queue wait), plus transfer-byte and launch totals
//!   from [`DeviceStats`](crate::device::DeviceStats).  Earlier revisions
//!   recorded the *modeled* device time here, which poisoned `auto`
//!   decisions with cost-model assumptions instead of observed cost; the
//!   modeled clock still lives in `DeviceStats` for the paper-figure
//!   reports.
//!
//! The decision rule is deliberately simple and deterministic:
//! explore each applicable side until it has `min_samples` observations
//! (SMP first — it is always applicable), then pick the side with the
//! lower trailing-window mean, with a hysteresis factor so the choice
//! only flips when the other side is *clearly* faster.  Histories
//! serialize to JSON so deployments can persist what they learned.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::device::DeviceStats;
use crate::util::json::Json;

/// Which side the cost model picked for one invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Choice {
    Smp,
    Device,
}

/// Tunables for the cost model.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Trailing samples kept per side.
    pub window: usize,
    /// Observations required per side before the means are compared.
    pub min_samples: usize,
    /// The challenger must be at least this factor faster to flip the
    /// previous choice (1.0 = no hysteresis).
    pub hysteresis: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { window: 8, min_samples: 2, hysteresis: 1.15 }
    }
}

/// Execution history of one method.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodHistory {
    /// Trailing SMP wall times (seconds).
    pub smp_secs: Vec<f64>,
    /// Trailing *measured* device execute times (seconds, queue wait
    /// excluded).
    pub device_secs: Vec<f64>,
    /// Lifetime totals (not windowed).
    pub smp_runs: u64,
    pub device_runs: u64,
    pub device_failures: u64,
    pub bytes_h2d: u64,
    pub bytes_d2h: u64,
    pub launches: u64,
    /// The last decision, for hysteresis.
    pub last_choice: Option<Choice>,
}

impl MethodHistory {
    fn push(buf: &mut Vec<f64>, v: f64, window: usize) {
        buf.push(v);
        if buf.len() > window {
            buf.remove(0);
        }
    }

    fn mean(buf: &[f64]) -> Option<f64> {
        if buf.is_empty() {
            None
        } else {
            Some(buf.iter().sum::<f64>() / buf.len() as f64)
        }
    }

    /// Trailing-window mean SMP seconds.
    pub fn smp_estimate(&self) -> Option<f64> {
        Self::mean(&self.smp_secs)
    }

    /// Trailing-window mean measured device seconds.
    pub fn device_estimate(&self) -> Option<f64> {
        Self::mean(&self.device_secs)
    }

    /// Mean transfer bytes per device run (the §7.3 "Crypt loses on the
    /// bus" signal, surfaced for reports).
    pub fn transfer_bytes_per_run(&self) -> f64 {
        if self.device_runs == 0 {
            0.0
        } else {
            (self.bytes_h2d + self.bytes_d2h) as f64 / self.device_runs as f64
        }
    }
}

/// One row of the decision table (bench/report surface).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRow {
    pub method: String,
    pub smp_secs: Option<f64>,
    pub device_secs: Option<f64>,
    pub transfer_bytes_per_run: f64,
    pub choice: Choice,
}

/// The history store + cost model.  Thread-safe; one per [`Engine`]
/// (shared with its device master thread).
///
/// [`Engine`]: crate::somd::Engine
#[derive(Debug, Default)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    histories: Mutex<BTreeMap<String, MethodHistory>>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, histories: Mutex::new(BTreeMap::new()) }
    }

    pub fn config(&self) -> SchedulerConfig {
        self.cfg
    }

    /// Record an SMP invocation's wall time.
    pub fn record_smp(&self, method: &str, wall: Duration) {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        MethodHistory::push(&mut e.smp_secs, wall.as_secs_f64(), self.cfg.window);
        e.smp_runs += 1;
    }

    /// Record a device invocation: `measured` is the observed execute
    /// wall time of the job itself (clock started after dequeue, so queue
    /// wait is excluded); `stats` contributes the transfer/launch totals.
    /// The trailing window holds *measured* seconds — the modeled
    /// `stats.device_time` is deliberately NOT recorded here, so `auto`
    /// compares like with like (observed SMP wall vs observed device
    /// wall).
    pub fn record_device(&self, method: &str, measured: Duration, stats: &DeviceStats) {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        MethodHistory::push(&mut e.device_secs, measured.as_secs_f64(), self.cfg.window);
        e.device_runs += 1;
        e.bytes_h2d += stats.bytes_h2d as u64;
        e.bytes_d2h += stats.bytes_d2h as u64;
        e.launches += stats.launches as u64;
    }

    /// Record a *failed* device invocation as a large penalty sample.
    /// Without this, a method whose device version always errors would
    /// never accumulate device samples, so the exploration phase would
    /// keep resolving `auto` to the broken lane forever; the penalty
    /// completes exploration and steers the method back to SMP.  Later
    /// successes slide the penalty out of the trailing window.
    pub fn record_device_failure(&self, method: &str) {
        const PENALTY_SECS: f64 = 1e6;
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        MethodHistory::push(&mut e.device_secs, PENALTY_SECS, self.cfg.window);
        e.device_runs += 1;
        e.device_failures += 1;
    }

    /// Resolve `Target::Auto` for a method whose device version IS
    /// applicable (the caller has already checked applicability; an
    /// inapplicable device reverts to SMP before ever reaching here).
    pub fn decide(&self, method: &str) -> Choice {
        let mut h = self.histories.lock().unwrap();
        let e = h.entry(method.to_string()).or_default();
        let choice = Self::decide_history(&self.cfg, e);
        e.last_choice = Some(choice);
        choice
    }

    fn decide_history(cfg: &SchedulerConfig, e: &MethodHistory) -> Choice {
        // explore first: SMP is always applicable, measure it first, then
        // give the device its minimum samples
        if e.smp_secs.len() < cfg.min_samples {
            return Choice::Smp;
        }
        if e.device_secs.len() < cfg.min_samples {
            return Choice::Device;
        }
        let smp = e.smp_estimate().expect("smp samples present");
        let dev = e.device_estimate().expect("device samples present");
        match e.last_choice {
            // hysteresis: the incumbent keeps the method unless the
            // challenger beats it by the configured factor
            Some(Choice::Smp) => {
                if smp > dev * cfg.hysteresis {
                    Choice::Device
                } else {
                    Choice::Smp
                }
            }
            Some(Choice::Device) => {
                if dev > smp * cfg.hysteresis {
                    Choice::Smp
                } else {
                    Choice::Device
                }
            }
            None => {
                if dev < smp {
                    Choice::Device
                } else {
                    Choice::Smp
                }
            }
        }
    }

    /// Peek at the decision without recording it (reports).
    pub fn predict(&self, method: &str) -> Choice {
        let h = self.histories.lock().unwrap();
        match h.get(method) {
            Some(e) => Self::decide_history(&self.cfg, e),
            None => Choice::Smp,
        }
    }

    /// Snapshot one method's history.
    pub fn history(&self, method: &str) -> Option<MethodHistory> {
        self.histories.lock().unwrap().get(method).cloned()
    }

    /// The full decision table, one row per known method.
    pub fn decision_table(&self) -> Vec<DecisionRow> {
        let h = self.histories.lock().unwrap();
        h.iter()
            .map(|(name, e)| DecisionRow {
                method: name.clone(),
                smp_secs: e.smp_estimate(),
                device_secs: e.device_estimate(),
                transfer_bytes_per_run: e.transfer_bytes_per_run(),
                choice: Self::decide_history(&self.cfg, e),
            })
            .collect()
    }

    // -- serialization ------------------------------------------------------

    /// Serialize every history to JSON (decision state round-trips).
    pub fn to_json(&self) -> Json {
        let h = self.histories.lock().unwrap();
        let mut top = BTreeMap::new();
        for (name, e) in h.iter() {
            let mut m = BTreeMap::new();
            m.insert(
                "smp_secs".to_string(),
                Json::Arr(e.smp_secs.iter().map(|&v| Json::Num(v)).collect()),
            );
            m.insert(
                "device_secs".to_string(),
                Json::Arr(e.device_secs.iter().map(|&v| Json::Num(v)).collect()),
            );
            m.insert("smp_runs".to_string(), Json::Num(e.smp_runs as f64));
            m.insert("device_runs".to_string(), Json::Num(e.device_runs as f64));
            m.insert("device_failures".to_string(), Json::Num(e.device_failures as f64));
            m.insert("bytes_h2d".to_string(), Json::Num(e.bytes_h2d as f64));
            m.insert("bytes_d2h".to_string(), Json::Num(e.bytes_d2h as f64));
            m.insert("launches".to_string(), Json::Num(e.launches as f64));
            m.insert(
                "last_choice".to_string(),
                match e.last_choice {
                    Some(Choice::Smp) => Json::Str("smp".to_string()),
                    Some(Choice::Device) => Json::Str("device".to_string()),
                    None => Json::Null,
                },
            );
            top.insert(name.clone(), Json::Obj(m));
        }
        Json::Obj(top)
    }

    /// Rebuild a scheduler from [`Scheduler::to_json`] output.
    pub fn from_json(cfg: SchedulerConfig, json: &Json) -> Result<Scheduler, String> {
        let obj = match json {
            Json::Obj(m) => m,
            _ => return Err("scheduler state must be a JSON object".to_string()),
        };
        let mut histories = BTreeMap::new();
        for (name, v) in obj {
            let secs = |key: &str| -> Result<Vec<f64>, String> {
                v.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("method '{name}': missing '{key}'"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| format!("bad number in '{key}'")))
                    .collect()
            };
            let num = |key: &str| -> u64 {
                v.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
            };
            let last_choice = match v.get("last_choice").and_then(Json::as_str) {
                Some("smp") => Some(Choice::Smp),
                Some("device") => Some(Choice::Device),
                _ => None,
            };
            histories.insert(
                name.clone(),
                MethodHistory {
                    smp_secs: secs("smp_secs")?,
                    device_secs: secs("device_secs")?,
                    smp_runs: num("smp_runs"),
                    device_runs: num("device_runs"),
                    device_failures: num("device_failures"),
                    bytes_h2d: num("bytes_h2d"),
                    bytes_d2h: num("bytes_d2h"),
                    launches: num("launches"),
                    last_choice,
                },
            );
        }
        Ok(Scheduler { cfg, histories: Mutex::new(histories) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev_stats(secs: f64, bytes: usize) -> DeviceStats {
        DeviceStats {
            launches: 1,
            bytes_h2d: bytes,
            device_time: Duration::from_secs_f64(secs),
            ..DeviceStats::default()
        }
    }

    /// Record a device run whose measured wall equals `secs`.
    fn rec_dev(s: &Scheduler, m: &str, secs: f64, bytes: usize) {
        s.record_device(m, Duration::from_secs_f64(secs), &dev_stats(secs, bytes));
    }

    #[test]
    fn explores_smp_then_device() {
        let s = Scheduler::new(SchedulerConfig::default());
        assert_eq!(s.decide("M.m"), Choice::Smp);
        s.record_smp("M.m", Duration::from_millis(10));
        s.record_smp("M.m", Duration::from_millis(10));
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn picks_faster_side_after_exploration() {
        let s = Scheduler::new(SchedulerConfig { hysteresis: 1.0, ..Default::default() });
        for _ in 0..3 {
            s.record_smp("M.m", Duration::from_millis(50));
            rec_dev(&s, "M.m", 0.005, 1000);
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn hysteresis_prevents_flapping_on_noise() {
        let s = Scheduler::new(SchedulerConfig {
            window: 4,
            min_samples: 2,
            hysteresis: 1.5,
        });
        for _ in 0..4 {
            s.record_smp("M.m", Duration::from_millis(10));
            rec_dev(&s, "M.m", 0.011, 0);
        }
        // smp incumbent; device is 10% faster? no: device is slower here.
        assert_eq!(s.decide("M.m"), Choice::Smp);
        // device becomes slightly faster, but within the hysteresis band
        for _ in 0..4 {
            rec_dev(&s, "M.m", 0.009, 0);
        }
        assert_eq!(s.decide("M.m"), Choice::Smp);
        // device becomes clearly faster — now it flips
        for _ in 0..4 {
            rec_dev(&s, "M.m", 0.004, 0);
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
        // and stays flipped on repeated decisions (stable boundary)
        for _ in 0..10 {
            assert_eq!(s.decide("M.m"), Choice::Device);
        }
    }

    #[test]
    fn failing_device_lane_steers_back_to_smp() {
        let s = Scheduler::new(SchedulerConfig::default());
        s.record_smp("M.m", Duration::from_millis(10));
        s.record_smp("M.m", Duration::from_millis(10));
        // exploration would now pick the device; it fails every time
        assert_eq!(s.decide("M.m"), Choice::Device);
        s.record_device_failure("M.m");
        assert_eq!(s.decide("M.m"), Choice::Device); // still exploring (1 < 2)
        s.record_device_failure("M.m");
        // penalties complete exploration and the broken lane loses
        assert_eq!(s.decide("M.m"), Choice::Smp);
        let h = s.history("M.m").unwrap();
        assert_eq!(h.device_failures, 2);
        // a recovered device (fast successes) can win the method back
        for _ in 0..8 {
            s.record_device("M.m", Duration::from_micros(100), &DeviceStats::default());
        }
        assert_eq!(s.decide("M.m"), Choice::Device);
    }

    #[test]
    fn json_roundtrip_preserves_decisions() {
        let cfg = SchedulerConfig::default();
        let s = Scheduler::new(cfg);
        for i in 0..5 {
            s.record_smp("A.a", Duration::from_millis(3 + i));
            rec_dev(&s, "A.a", 0.050, 1 << 20);
            s.record_smp("B.b", Duration::from_millis(80));
            rec_dev(&s, "B.b", 0.002, 64);
        }
        let a = s.decide("A.a");
        let b = s.decide("B.b");
        let restored = Scheduler::from_json(cfg, &s.to_json()).unwrap();
        assert_eq!(restored.decide("A.a"), a);
        assert_eq!(restored.decide("B.b"), b);
        assert_eq!(restored.history("A.a"), s.history("A.a"));
    }

    #[test]
    fn transfer_heavy_method_steers_to_smp() {
        // Crypt-shaped: device time dominated by transfers exceeds SMP
        let s = Scheduler::new(SchedulerConfig::default());
        for _ in 0..3 {
            s.record_smp("Crypt.pass", Duration::from_millis(8));
            rec_dev(&s, "Crypt.pass", 0.120, 50_000_000);
        }
        assert_eq!(s.decide("Crypt.pass"), Choice::Smp);
        // Series-shaped: compute dense, tiny transfers
        for _ in 0..3 {
            s.record_smp("Series.coefficients", Duration::from_millis(200));
            rec_dev(&s, "Series.coefficients", 0.004, 8_000);
        }
        assert_eq!(s.decide("Series.coefficients"), Choice::Device);
        let table = s.decision_table();
        assert_eq!(table.len(), 2);
        assert!(table[0].transfer_bytes_per_run > table[1].transfer_bytes_per_run);
    }
}
