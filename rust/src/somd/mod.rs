//! The SOMD model (the paper's contribution): Single Operation Multiple
//! Data — data parallelism at method level via Distribute-Map-Reduce.
//!
//! | paper construct | here |
//! |---|---|
//! | `dist` strategies | [`distribution`], [`partition`] |
//! | `reduce` strategies | [`reduction`] |
//! | method instances + `sync` | [`mi`], [`phaser`] |
//! | intermediate reductions | [`exchange`] |
//! | `shared` scalars | [`shared`] |
//! | shared array positions / views | [`grid`], [`distribution::View`] |
//! | the DMR engine (Algorithm 1) | [`master`] |
//! | Elina runtime + version rules (§6) | [`engine`], [`config`] |
//! | automatic version selection (§6's open loop) | [`scheduler`] |
//!
//! # Rules grammar (§6 + the `auto`/`hybrid` extensions)
//!
//! A rules file holds one `Class.method:target` line per method
//! (`#` comments allowed).  Targets:
//!
//! * `smp` (also `cpu`, `shared`) — the shared-memory pool (default);
//! * a device profile name (`fermi`, `geforce320m`, `passthrough`) —
//!   offload, reverting to SMP when inapplicable;
//! * `hybrid` — co-execute: split one invocation's index space between
//!   the SMP pool and the device at the scheduler's learned
//!   throughput-proportional ratio (reverting to SMP when the method has
//!   no hybrid spec, no device lane is attached, or the device share
//!   would underflow the minimum chunk);
//! * `sharded` (alias `fleet`) — shard across the whole device fleet:
//!   split one invocation's index space N-way over the SMP pool *and
//!   every attached device lane* at the scheduler's learned per-lane
//!   weights (stepping down to `hybrid`, then SMP, when inapplicable);
//! * `auto` — let the runtime decide per invocation from recorded
//!   execution history ([`scheduler::Scheduler`]): SMP wall times vs
//!   *measured* device execute times (queue wait excluded) vs hybrid
//!   wall times for co-execution-capable methods.  Transfer-heavy
//!   methods (Crypt-shaped) converge to SMP, compute-dense ones
//!   (Series-shaped) to the device or — when neither lane alone wins —
//!   to a hybrid split; the §7.3 findings, automated.

pub mod cluster;
pub mod config;
pub mod distribution;
pub mod engine;
pub mod exchange;
pub mod grid;
pub mod master;
pub mod mi;
pub mod partition;
pub mod phaser;
pub mod pipeline;
pub mod pool;
pub mod reduction;
pub mod scheduler;
pub mod shared;
pub mod tree;

pub use config::{Rules, Target};
pub use distribution::{Distribution, Range1, Range2, View};
pub use engine::{DeviceCountersSnapshot, Engine};
pub use scheduler::{
    bucket_of, choice_name, Choice, DecisionExplain, HybridSample, Scheduler, SchedulerConfig,
};
pub use master::{run_mis, SomdMethod};
pub use mi::MiCtx;
pub use partition::{
    split_fraction, split_weighted, split_weighted_floor, stitched_spans, Block1D, Block2D,
    BlockPart, Block2Part, RowDisjoint, Rows1D, SparsePart, TreeDist,
};
pub use phaser::Phaser;
pub use pipeline::{ExecutionPlan, PipelineReport, StageLane, StageReport};
pub use reduction::{Assemble, FnReduce, Reduction};
pub use shared::Shared;
