//! Cluster realization of SOMD (paper §4.2): a modeled cost structure
//! *and* a real TCP shared-nothing lane.
//!
//! The paper defers distributed-memory evaluation to future work but
//! specifies the execution model precisely: distributed arrays are
//! scattered hierarchically (node split, then the §4.1 copy-free split
//! inside each node), reductions fold hierarchically to cut the data
//! returned to the master, and — the PGAS-by-design property (Figure 6) —
//! every MI works on node-local data unless sharing is explicit, so
//! undistributed parameters are *replicated* to every node.
//!
//! The first half of this module implements that cost structure over a
//! simulated interconnect, composing with the calibrated intra-node
//! makespan model ([`crate::bench_suite::modeled`]).  The second half
//! makes the lane real: a length-prefixed binary protocol ([`wire`]), a
//! [`ClusterClient`] the engine registers as a remote fleet lane, and a
//! [`PeerServer`] that hosts method handlers (the `somd cluster serve`
//! peer binary backs them with a full local [`Engine`](super::Engine),
//! so a remote peer can itself be SMP, device, or hybrid inside).  Wire
//! frames carry *span + input bytes* out and *partial-result bytes*
//! back — the same `distribute → compute partials → rank-order reduce`
//! contract as every other lane, stretched across a socket.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::distribution::Range1;
use crate::obs::TraceRecorder;

/// Point-to-point interconnect model: `t(bytes) = latency + bytes/bw`.
#[derive(Debug, Clone, Copy)]
pub struct NetworkProfile {
    /// Interconnect name (report label).
    pub name: &'static str,
    /// Per-message latency.
    pub latency: Duration,
    /// Point-to-point bandwidth (bytes/s).
    pub bandwidth_bytes_per_sec: f64,
}

impl NetworkProfile {
    /// ~2009-era gigabit ethernet (the clusters of the paper's §4.2 era).
    pub fn gigabit_ethernet() -> Self {
        NetworkProfile {
            name: "1GbE",
            latency: Duration::from_micros(80),
            bandwidth_bytes_per_sec: 0.11e9,
        }
    }

    /// DDR InfiniBand.
    pub fn infiniband_ddr() -> Self {
        NetworkProfile {
            name: "IB-DDR",
            latency: Duration::from_micros(4),
            bandwidth_bytes_per_sec: 1.8e9,
        }
    }

    /// Modeled time to move `bytes` across one link.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        if bytes == 0 {
            return Duration::ZERO;
        }
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth_bytes_per_sec)
    }
}

/// Byte-level description of one SOMD invocation's communication.
#[derive(Debug, Clone, Copy)]
pub struct CommShape {
    /// Bytes of `dist`-qualified inputs (scattered: each node gets 1/N).
    pub distributed_in_bytes: usize,
    /// Bytes of undistributed inputs (replicated to every node — the
    /// §7.5 limitation: "undistributed parameters increase the amount of
    /// data to be transferred to each node").
    pub replicated_in_bytes: usize,
    /// Bytes of each node's partial result (hierarchically reduced).
    pub partial_result_bytes: usize,
}

/// Modeled timings for a cluster-wide invocation.
#[derive(Debug, Clone, Copy)]
pub struct ClusterModeled {
    /// Node count.
    pub nodes: usize,
    /// Scatter (distribution) time.
    pub scatter: Duration,
    /// Intra-node compute makespan (measured, supplied by the caller).
    pub compute: Duration,
    /// Hierarchical-reduction communication time.
    pub reduce_comm: Duration,
    /// Total modeled invocation time.
    pub t_par: Duration,
}

impl ClusterModeled {
    /// Modeled speedup over a sequential baseline.
    pub fn speedup_over(&self, t_seq: Duration) -> f64 {
        t_seq.as_secs_f64() / self.t_par.as_secs_f64()
    }
}

/// The §4.2 composition: sequential scatter of node shares + replicated
/// args, intra-node makespan (supplied by the caller — measured), and a
/// binary-tree hierarchical reduction.
pub fn model_cluster_invocation(
    net: &NetworkProfile,
    nodes: usize,
    comm: CommShape,
    intra_node_makespan: Duration,
) -> ClusterModeled {
    assert!(nodes > 0);
    // The master sends each remote node its share of the distributed data
    // plus a full copy of every undistributed argument (Figure 6: remote
    // MIs otherwise touch only local data).  Node 0 is the master itself.
    let share = comm.distributed_in_bytes / nodes;
    let mut scatter = Duration::ZERO;
    for _ in 1..nodes {
        scatter += net.transfer_time(share + comm.replicated_in_bytes);
    }
    // Hierarchical reduction: ceil(log2(nodes)) rounds of partial-result
    // exchange (valid because the programmer guarantees associativity,
    // §4.2 — statically checkable at deployment time).
    let rounds = usize::BITS - (nodes - 1).leading_zeros().min(usize::BITS - 1);
    let rounds = if nodes == 1 { 0 } else { rounds as usize };
    let reduce_comm =
        net.transfer_time(comm.partial_result_bytes).mul_f64(rounds.max(0) as f64);
    ClusterModeled {
        nodes,
        scatter,
        compute: intra_node_makespan,
        reduce_comm,
        t_par: scatter + intra_node_makespan + reduce_comm,
    }
}

/// Hierarchical distribution property (paper §4.2: "distribution
/// strategies are intrinsically associative"): splitting into `nodes`
/// then `per_node` partitions must refine the flat split.
pub fn hierarchical_ranges(
    len: usize,
    nodes: usize,
    per_node: usize,
) -> Vec<Vec<super::distribution::Range1>> {
    super::distribution::index_ranges(len, nodes)
        .into_iter()
        .map(|node_range| {
            super::distribution::index_ranges(node_range.len(), per_node)
                .into_iter()
                .map(|r| super::distribution::Range1::new(r.lo + node_range.lo, r.hi + node_range.lo))
                .collect()
        })
        .collect()
}

// ======================================================================
// The real lane: wire protocol, client, peer server.
// ======================================================================

/// Length-prefixed binary wire protocol of the cluster lane.
///
/// Every message is one frame: `[u8 kind][u32 payload_len LE][payload]`.
/// Integers are little-endian; strings and byte blobs are `u32` length
/// followed by raw bytes (strings are UTF-8).  The frame kinds:
///
/// | kind | message    | payload |
/// |------|------------|---------|
/// | 1    | `Hello`    | `u32 version`, `str name` |
/// | 2    | `HelloAck` | `u32 version`, `str name`, `u32 workers` |
/// | 3    | `Submit`   | `u64 id`, `str method`, `u64 span_lo`, `u64 span_hi`, `u32 deadline_ms`, `bytes input`, `u64 trace_id` |
/// | 4    | `Partial`  | `u64 id`, `f64 compute_secs`, `bytes payload` |
/// | 5    | `Error`    | `u64 id`, `str message` |
/// | 6    | `Ping`     | `u64 nonce` |
/// | 7    | `Pong`     | `u64 nonce` |
///
/// The codec is hand-rolled (the vendor set has no serde); frames above
/// [`MAX_FRAME_BYTES`] are rejected on both ends so a corrupt length
/// prefix cannot OOM a peer.  Full layout and lifecycle docs:
/// `docs/CLUSTER.md`.
pub mod wire {
    use std::io::Read;

    use anyhow::{bail, ensure, Result};

    /// Protocol version carried in `Hello`/`HelloAck` (mismatch = refuse).
    ///
    /// v2 appended `u64 trace_id` to `Submit` so a client's invocation
    /// trace stitches across the wire; the decoder rejects trailing
    /// bytes, so the extra field is a breaking change.
    pub const PROTO_VERSION: u32 = 2;
    /// Frame header size: 1 kind byte + 4 length bytes.
    pub const HEADER_BYTES: usize = 5;
    /// Upper bound on one frame's payload (guards the length prefix).
    pub const MAX_FRAME_BYTES: usize = 1 << 30;

    /// One decoded protocol message.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Frame {
        /// Client → peer greeting.
        Hello {
            /// Protocol version the client speaks.
            version: u32,
            /// Client's self-chosen name (diagnostics only).
            name: String,
        },
        /// Peer → client capability advertisement.
        HelloAck {
            /// Protocol version the peer speaks.
            version: u32,
            /// Peer's name (shows up as the lane label).
            name: String,
            /// Worker threads behind the peer's local engine.
            workers: u32,
        },
        /// Client → peer: compute one span of one method.
        Submit {
            /// Request id (echoed back in `Partial`/`Error`).
            id: u64,
            /// Method name, e.g. `"VecAdd.add"`.
            method: String,
            /// Span start (inclusive), in index-space items.
            lo: u64,
            /// Span end (exclusive).
            hi: u64,
            /// Client-side deadline, advisory for the peer.
            deadline_ms: u32,
            /// Method-specific encoding of the span's input.
            input: Vec<u8>,
            /// Client-side trace id the peer's execute span joins
            /// (0 = the client is not tracing this invocation).
            trace_id: u64,
        },
        /// Peer → client: a span's partial result.
        Partial {
            /// Request id this answers.
            id: u64,
            /// Peer-side compute seconds (excludes network time).
            secs: f64,
            /// Method-specific encoding of the partial result.
            payload: Vec<u8>,
        },
        /// Peer → client: a span failed remotely.
        Error {
            /// Request id this answers.
            id: u64,
            /// Human-readable failure description.
            message: String,
        },
        /// Heartbeat / RTT probe.
        Ping {
            /// Correlator echoed back in `Pong` (0 = keepalive, no waiter).
            nonce: u64,
        },
        /// Heartbeat / RTT probe reply.
        Pong {
            /// The `Ping`'s correlator.
            nonce: u64,
        },
    }

    impl Frame {
        fn kind(&self) -> u8 {
            match self {
                Frame::Hello { .. } => 1,
                Frame::HelloAck { .. } => 2,
                Frame::Submit { .. } => 3,
                Frame::Partial { .. } => 4,
                Frame::Error { .. } => 5,
                Frame::Ping { .. } => 6,
                Frame::Pong { .. } => 7,
            }
        }

        /// Serialize to one on-wire frame (header + payload).
        pub fn encode(&self) -> Vec<u8> {
            let mut p = Vec::new();
            match self {
                Frame::Hello { version, name } => {
                    put_u32(&mut p, *version);
                    put_str(&mut p, name);
                }
                Frame::HelloAck { version, name, workers } => {
                    put_u32(&mut p, *version);
                    put_str(&mut p, name);
                    put_u32(&mut p, *workers);
                }
                Frame::Submit { id, method, lo, hi, deadline_ms, input, trace_id } => {
                    put_u64(&mut p, *id);
                    put_str(&mut p, method);
                    put_u64(&mut p, *lo);
                    put_u64(&mut p, *hi);
                    put_u32(&mut p, *deadline_ms);
                    put_bytes(&mut p, input);
                    put_u64(&mut p, *trace_id);
                }
                Frame::Partial { id, secs, payload } => {
                    put_u64(&mut p, *id);
                    put_f64(&mut p, *secs);
                    put_bytes(&mut p, payload);
                }
                Frame::Error { id, message } => {
                    put_u64(&mut p, *id);
                    put_str(&mut p, message);
                }
                Frame::Ping { nonce } | Frame::Pong { nonce } => put_u64(&mut p, *nonce),
            }
            let mut out = Vec::with_capacity(HEADER_BYTES + p.len());
            out.push(self.kind());
            out.extend_from_slice(&(p.len() as u32).to_le_bytes());
            out.extend_from_slice(&p);
            out
        }

        /// Decode one frame from its kind byte and payload.
        pub fn decode(kind: u8, payload: &[u8]) -> Result<Frame> {
            let mut c = Cursor { buf: payload, pos: 0 };
            let f = match kind {
                1 => Frame::Hello { version: c.u32()?, name: c.str_()? },
                2 => Frame::HelloAck { version: c.u32()?, name: c.str_()?, workers: c.u32()? },
                3 => Frame::Submit {
                    id: c.u64()?,
                    method: c.str_()?,
                    lo: c.u64()?,
                    hi: c.u64()?,
                    deadline_ms: c.u32()?,
                    input: c.bytes()?,
                    trace_id: c.u64()?,
                },
                4 => Frame::Partial { id: c.u64()?, secs: c.f64()?, payload: c.bytes()? },
                5 => Frame::Error { id: c.u64()?, message: c.str_()? },
                6 => Frame::Ping { nonce: c.u64()? },
                7 => Frame::Pong { nonce: c.u64()? },
                k => bail!("unknown frame kind {k}"),
            };
            ensure!(c.pos == payload.len(), "trailing bytes in frame kind {kind}");
            Ok(f)
        }
    }

    fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
        put_u32(out, b.len() as u32);
        out.extend_from_slice(b);
    }

    fn put_str(out: &mut Vec<u8>, s: &str) {
        put_bytes(out, s.as_bytes());
    }

    struct Cursor<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl Cursor<'_> {
        fn take(&mut self, n: usize) -> Result<&[u8]> {
            ensure!(self.pos + n <= self.buf.len(), "truncated frame");
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        fn u32(&mut self) -> Result<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        fn u64(&mut self) -> Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        fn f64(&mut self) -> Result<f64> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        fn bytes(&mut self) -> Result<Vec<u8>> {
            let n = self.u32()? as usize;
            Ok(self.take(n)?.to_vec())
        }

        fn str_(&mut self) -> Result<String> {
            Ok(String::from_utf8(self.bytes()?)?)
        }
    }

    /// Incremental frame reader over any byte stream.
    ///
    /// Accumulates partial reads in an internal buffer, so it is safe to
    /// drive from a socket with a read timeout: a frame split across
    /// timeout ticks is reassembled, never dropped.  [`FrameReader::next`]
    /// returns `Ok(None)` on a timeout tick (the caller's chance to sweep
    /// deadlines or send a heartbeat) and `Err` on EOF or a socket error.
    pub struct FrameReader<R: Read> {
        stream: R,
        buf: Vec<u8>,
    }

    impl<R: Read> FrameReader<R> {
        /// Wrap a byte stream.
        pub fn new(stream: R) -> Self {
            FrameReader { stream, buf: Vec::new() }
        }

        /// Next decoded frame; `Ok(None)` on a read-timeout tick.
        pub fn next(&mut self) -> Result<Option<Frame>> {
            loop {
                if let Some((kind, payload)) = self.take_frame()? {
                    return Ok(Some(Frame::decode(kind, &payload)?));
                }
                let mut chunk = [0u8; 64 * 1024];
                match self.stream.read(&mut chunk) {
                    Ok(0) => bail!("peer closed the connection"),
                    Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        return Ok(None)
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e.into()),
                }
            }
        }

        fn take_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>> {
            if self.buf.len() < HEADER_BYTES {
                return Ok(None);
            }
            let kind = self.buf[0];
            let len = u32::from_le_bytes(self.buf[1..5].try_into().unwrap()) as usize;
            ensure!(len <= MAX_FRAME_BYTES, "oversized frame: {len} bytes");
            if self.buf.len() < HEADER_BYTES + len {
                return Ok(None);
            }
            let payload = self.buf[HEADER_BYTES..HEADER_BYTES + len].to_vec();
            self.buf.drain(..HEADER_BYTES + len);
            Ok(Some((kind, payload)))
        }
    }
}

/// Timing knobs of the cluster lane (all settable via `SOMD_CLUSTER_*`
/// environment variables, see [`ClusterConfig::from_env`] and
/// `docs/CLUSTER.md`).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// TCP connect + handshake timeout.
    pub connect_timeout: Duration,
    /// Per-submit deadline: a span unanswered past this is treated as a
    /// failed lane and covered by SMP partials.
    pub deadline: Duration,
    /// Keepalive ping interval (zero disables heartbeats).
    pub heartbeat: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            connect_timeout: Duration::from_millis(2_000),
            deadline: Duration::from_millis(10_000),
            heartbeat: Duration::from_millis(1_000),
        }
    }
}

impl ClusterConfig {
    /// Defaults overridden by `SOMD_CLUSTER_CONNECT_TIMEOUT_MS`,
    /// `SOMD_CLUSTER_DEADLINE_MS` and `SOMD_CLUSTER_HEARTBEAT_MS`.
    pub fn from_env() -> Self {
        let mut cfg = ClusterConfig::default();
        if let Some(ms) = env_ms("SOMD_CLUSTER_CONNECT_TIMEOUT_MS") {
            cfg.connect_timeout = ms;
        }
        if let Some(ms) = env_ms("SOMD_CLUSTER_DEADLINE_MS") {
            cfg.deadline = ms;
        }
        if let Some(ms) = env_ms("SOMD_CLUSTER_HEARTBEAT_MS") {
            cfg.heartbeat = ms;
        }
        cfg
    }
}

fn env_ms(var: &str) -> Option<Duration> {
    std::env::var(var).ok()?.trim().parse::<u64>().ok().map(Duration::from_millis)
}

/// A completed remote share: the method-specific partial-result bytes
/// plus the peer's self-reported compute seconds.
#[derive(Debug, Clone)]
pub struct RemotePartial {
    /// Encoded partial result (decoded by the method's `ClusterSpec`).
    pub payload: Vec<u8>,
    /// Peer-side compute seconds (excludes network time).
    pub secs: f64,
}

/// Completion callback of one [`ClusterClient::submit`].
pub type RemoteCallback = Box<dyn FnOnce(Result<RemotePartial>) + Send>;

struct PendingSubmit {
    done: RemoteCallback,
    deadline: Instant,
}

struct ClientShared {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, PendingSubmit>>,
    pings: Mutex<HashMap<u64, mpsc::Sender<()>>>,
    alive: AtomicBool,
}

impl ClientShared {
    fn send(&self, frame: &wire::Frame) -> Result<()> {
        let bytes = frame.encode();
        let mut w = self.writer.lock().unwrap();
        w.write_all(&bytes).context("cluster peer write")
    }

    /// Mark the connection dead and fail every in-flight submit.
    fn poison(&self, why: &str) {
        self.alive.store(false, Ordering::SeqCst);
        let drained: Vec<PendingSubmit> =
            { self.pending.lock().unwrap().drain().map(|(_, p)| p).collect() };
        for p in drained {
            (p.done)(Err(anyhow!("cluster peer lost: {why}")));
        }
        self.pings.lock().unwrap().clear();
    }
}

/// Client half of the cluster lane: one TCP connection to one peer,
/// registered with the engine as a remote fleet lane.
///
/// Submits are asynchronous — the callback runs on the client's reader
/// thread when the `Partial`/`Error` frame arrives, when the per-submit
/// deadline expires, or (with an error) immediately if the connection is
/// already dead, so the engine's completion latch always counts down.
pub struct ClusterClient {
    shared: Arc<ClientShared>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
    cfg: ClusterConfig,
    addr: String,
    peer_name: String,
    peer_workers: u32,
}

impl ClusterClient {
    /// Connect to a peer and complete the `Hello`/`HelloAck` handshake.
    pub fn connect(addr: &str, cfg: ClusterConfig) -> Result<ClusterClient> {
        let sock_addr: SocketAddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve cluster peer {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("cluster peer {addr} resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&sock_addr, cfg.connect_timeout)
            .with_context(|| format!("connect cluster peer {addr}"))?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("clone cluster stream")?;
        writer.set_write_timeout(Some(cfg.connect_timeout.max(cfg.deadline))).ok();

        // handshake under the connect timeout, then switch to the short
        // tick the reader loop sweeps deadlines on
        stream.set_read_timeout(Some(cfg.connect_timeout)).ok();
        let mut frames = wire::FrameReader::new(stream);
        let shared = Arc::new(ClientShared {
            writer: Mutex::new(writer),
            pending: Mutex::new(HashMap::new()),
            pings: Mutex::new(HashMap::new()),
            alive: AtomicBool::new(true),
        });
        shared.send(&wire::Frame::Hello {
            version: wire::PROTO_VERSION,
            name: format!("somd-client-{}", std::process::id()),
        })?;
        let (peer_name, peer_workers) = match frames.next()? {
            Some(wire::Frame::HelloAck { version, name, workers }) => {
                ensure!(
                    version == wire::PROTO_VERSION,
                    "cluster peer {addr} speaks protocol v{version}, want v{}",
                    wire::PROTO_VERSION
                );
                (name, workers)
            }
            Some(f) => bail!("cluster peer {addr} answered hello with {f:?}"),
            None => bail!("cluster peer {addr} handshake timed out"),
        };
        // the reader and writer clones share one socket, so the short
        // tick set here governs the reader loop's deadline sweeps
        shared.writer.lock().unwrap().set_read_timeout(Some(READ_TICK)).ok();

        let reader_shared = shared.clone();
        let heartbeat = cfg.heartbeat;
        let reader = std::thread::Builder::new()
            .name(format!("somd-cluster-{addr}"))
            .spawn(move || client_reader_loop(frames, &reader_shared, heartbeat))
            .context("spawn cluster reader")?;

        Ok(ClusterClient {
            shared,
            reader: Mutex::new(Some(reader)),
            next_id: AtomicU64::new(1),
            cfg,
            addr: addr.to_string(),
            peer_name,
            peer_workers,
        })
    }

    /// The address this client connected to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The peer's self-reported name.
    pub fn peer_name(&self) -> &str {
        &self.peer_name
    }

    /// Worker threads behind the peer's local engine (capability advert).
    pub fn peer_workers(&self) -> u32 {
        self.peer_workers
    }

    /// Whether the connection is still usable (a dead client fails
    /// submits fast so the engine covers the span synchronously).
    pub fn is_alive(&self) -> bool {
        self.shared.alive.load(Ordering::SeqCst)
    }

    /// Submit one span; `on_done` fires exactly once with the partial or
    /// an error (remote failure, dropped connection, or expired
    /// deadline).  Returns `Err` *without consuming the callback's turn*
    /// only when the submit could not be sent at all — the caller covers
    /// the span itself in that case.
    pub fn submit(
        &self,
        method: &str,
        span: Range1,
        input: Vec<u8>,
        on_done: RemoteCallback,
    ) -> Result<()> {
        self.submit_traced(method, span, input, on_done, 0)
    }

    /// [`Self::submit`] carrying the client invocation's trace id so the
    /// peer's execute span stitches into the same trace (0 = untraced).
    pub fn submit_traced(
        &self,
        method: &str,
        span: Range1,
        input: Vec<u8>,
        on_done: RemoteCallback,
        trace_id: u64,
    ) -> Result<()> {
        if !self.is_alive() {
            bail!("cluster peer {} is down", self.addr);
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        // register before sending: a fast peer must find the callback
        self.shared.pending.lock().unwrap().insert(
            id,
            PendingSubmit { done: on_done, deadline: Instant::now() + self.cfg.deadline },
        );
        let frame = wire::Frame::Submit {
            id,
            method: method.to_string(),
            lo: span.lo as u64,
            hi: span.hi as u64,
            deadline_ms: self.cfg.deadline.as_millis().min(u32::MAX as u128) as u32,
            input,
            trace_id,
        };
        if let Err(e) = self.shared.send(&frame) {
            // If a concurrent `poison` (reader died first) already drained
            // this entry, the callback has fired — returning `Err` too
            // would make the caller fail the same shard twice.
            let had = self.shared.pending.lock().unwrap().remove(&id).is_some();
            self.shared.poison("send failed");
            if had {
                return Err(e);
            }
            return Ok(());
        }
        Ok(())
    }

    /// Round-trip time of one `Ping`/`Pong` exchange.
    pub fn ping(&self) -> Result<Duration> {
        ensure!(self.is_alive(), "cluster peer {} is down", self.addr);
        let nonce = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        self.shared.pings.lock().unwrap().insert(nonce, tx);
        let t0 = Instant::now();
        let sent = self.shared.send(&wire::Frame::Ping { nonce });
        if let Err(e) = sent {
            self.shared.pings.lock().unwrap().remove(&nonce);
            return Err(e);
        }
        match rx.recv_timeout(self.cfg.deadline) {
            Ok(()) => Ok(t0.elapsed()),
            Err(_) => {
                self.shared.pings.lock().unwrap().remove(&nonce);
                bail!("ping to {} timed out", self.addr)
            }
        }
    }
}

impl Drop for ClusterClient {
    fn drop(&mut self) {
        self.shared.poison("client dropped");
        // unblock the reader's socket wait, then join it
        if let Ok(w) = self.shared.writer.lock() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.reader.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn client_reader_loop(
    mut frames: wire::FrameReader<TcpStream>,
    shared: &ClientShared,
    heartbeat: Duration,
) {
    let mut last_beat = Instant::now();
    loop {
        if !shared.alive.load(Ordering::SeqCst) {
            return;
        }
        match frames.next() {
            Ok(Some(wire::Frame::Partial { id, secs, payload })) => {
                // an answer past its deadline finds no pending entry and
                // is dropped — the span was already covered
                if let Some(p) = shared.pending.lock().unwrap().remove(&id) {
                    (p.done)(Ok(RemotePartial { payload, secs }));
                }
            }
            Ok(Some(wire::Frame::Error { id, message })) => {
                if let Some(p) = shared.pending.lock().unwrap().remove(&id) {
                    (p.done)(Err(anyhow!("remote error: {message}")));
                }
            }
            Ok(Some(wire::Frame::Pong { nonce })) => {
                if let Some(tx) = shared.pings.lock().unwrap().remove(&nonce) {
                    let _ = tx.send(());
                }
            }
            Ok(Some(_)) => {} // unexpected but harmless (e.g. stray Ping)
            Ok(None) => {
                // timeout tick: sweep expired deadlines…
                let now = Instant::now();
                let expired: Vec<PendingSubmit> = {
                    let mut p = shared.pending.lock().unwrap();
                    let ids: Vec<u64> =
                        p.iter().filter(|(_, v)| v.deadline <= now).map(|(k, _)| *k).collect();
                    ids.into_iter().filter_map(|id| p.remove(&id)).collect()
                };
                for p in expired {
                    (p.done)(Err(anyhow!("cluster deadline expired")));
                }
                // …and keep the connection warm
                if !heartbeat.is_zero() && last_beat.elapsed() >= heartbeat {
                    last_beat = now;
                    if shared.send(&wire::Frame::Ping { nonce: 0 }).is_err() {
                        shared.poison("heartbeat write failed");
                        return;
                    }
                }
            }
            Err(e) => {
                shared.poison(&e.to_string());
                return;
            }
        }
    }
}

/// The short read-timeout the client reader ticks on between frames.
const READ_TICK: Duration = Duration::from_millis(25);

/// A method handler a peer hosts: raw input bytes + the span to compute
/// → raw partial-result bytes.  The encoding is method-specific and must
/// match the client side's `ClusterSpec` codecs.
pub type HostFn = Box<dyn Fn(&[u8], Range1) -> Result<Vec<u8>> + Send + Sync>;

/// The set of methods one peer serves, plus its capability advert.
///
/// The `somd cluster serve` binary builds one of these over a full local
/// [`Engine`](super::Engine) (each handler decodes the span input, runs
/// the method through the engine — which may itself resolve to SMP,
/// device, or hybrid — and encodes the partial back); tests build
/// smaller ones over plain closures.
pub struct MethodHost {
    name: String,
    workers: u32,
    methods: std::collections::BTreeMap<String, HostFn>,
    tracer: Option<Arc<TraceRecorder>>,
}

impl MethodHost {
    /// An empty host advertising `name`.
    pub fn new(name: impl Into<String>) -> Self {
        MethodHost { name: name.into(), workers: 1, methods: Default::default(), tracer: None }
    }

    /// Set the advertised worker count.
    pub fn with_workers(mut self, workers: u32) -> Self {
        self.workers = workers;
        self
    }

    /// Attach a trace recorder: `Submit`s carrying a non-zero trace id
    /// get a `peer.execute` span recorded here, under the client's id,
    /// so the two halves can be stitched into one trace offline.
    pub fn with_tracer(mut self, tracer: Arc<TraceRecorder>) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Register a handler for `method`.
    pub fn register(
        mut self,
        method: impl Into<String>,
        f: impl Fn(&[u8], Range1) -> Result<Vec<u8>> + Send + Sync + 'static,
    ) -> Self {
        self.methods.insert(method.into(), Box::new(f));
        self
    }

    /// The registered method names.
    pub fn method_names(&self) -> Vec<&str> {
        self.methods.keys().map(String::as_str).collect()
    }

    fn call(&self, method: &str, input: &[u8], span: Range1) -> Result<Vec<u8>> {
        let f = self
            .methods
            .get(method)
            .ok_or_else(|| anyhow!("peer does not host method {method:?}"))?;
        f(input, span)
    }
}

/// Serving knobs of a peer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeOptions {
    /// Artificial delay before every reply (WAN simulation; also how the
    /// kill-mid-run test holds a span in flight).  `SOMD_CLUSTER_INJECT_DELAY_MS`.
    pub injected_delay: Duration,
}

impl ServeOptions {
    /// Defaults overridden by `SOMD_CLUSTER_INJECT_DELAY_MS`.
    pub fn from_env() -> Self {
        ServeOptions {
            injected_delay: env_ms("SOMD_CLUSTER_INJECT_DELAY_MS").unwrap_or(Duration::ZERO),
        }
    }
}

/// Server half of the cluster lane: accepts connections and answers
/// `Submit`s with the hosted methods.  Each connection gets its own
/// handler thread; each submit computes on its own thread so a slow span
/// never blocks the connection's frame loop.
pub struct PeerServer {
    addr: SocketAddr,
}

impl PeerServer {
    /// Bind `addr` (may be `host:0` for an ephemeral port) and serve in
    /// background threads for the rest of the process lifetime.
    pub fn bind(addr: &str, host: Arc<MethodHost>, opts: ServeOptions) -> Result<PeerServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        std::thread::Builder::new()
            .name("somd-cluster-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    match conn {
                        Ok(stream) => {
                            let host = host.clone();
                            let _ = std::thread::Builder::new()
                                .name("somd-cluster-conn".into())
                                .spawn(move || handle_conn(stream, &host, opts));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn accept loop")?;
        Ok(PeerServer { addr: local })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

fn handle_conn(stream: TcpStream, host: &Arc<MethodHost>, opts: ServeOptions) {
    stream.set_nodelay(true).ok();
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let send = |w: &Arc<Mutex<TcpStream>>, frame: &wire::Frame| -> bool {
        let bytes = frame.encode();
        w.lock().unwrap().write_all(&bytes).is_ok()
    };
    let mut frames = wire::FrameReader::new(stream);
    loop {
        let frame = match frames.next() {
            Ok(Some(f)) => f,
            Ok(None) => continue, // no read timeout set on the server side
            Err(_) => return,     // client went away
        };
        match frame {
            wire::Frame::Hello { version, .. } => {
                let ack = if version == wire::PROTO_VERSION {
                    wire::Frame::HelloAck {
                        version: wire::PROTO_VERSION,
                        name: host.name.clone(),
                        workers: host.workers,
                    }
                } else {
                    wire::Frame::Error {
                        id: 0,
                        message: format!(
                            "protocol v{version} not supported (peer speaks v{})",
                            wire::PROTO_VERSION
                        ),
                    }
                };
                if !send(&writer, &ack) {
                    return;
                }
            }
            wire::Frame::Ping { nonce } => {
                let w = writer.clone();
                let delay = opts.injected_delay;
                let reply = move || {
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    let _ = w.lock().unwrap().write_all(&wire::Frame::Pong { nonce }.encode());
                };
                if delay.is_zero() {
                    reply();
                } else {
                    let _ = std::thread::Builder::new().spawn(reply);
                }
            }
            wire::Frame::Submit { id, method, lo, hi, input, trace_id, .. } => {
                let host = host.clone();
                let w = writer.clone();
                let delay = opts.injected_delay;
                let _ = std::thread::Builder::new().name("somd-cluster-span".into()).spawn(
                    move || {
                        let t0 = Instant::now();
                        let span = Range1::new(lo as usize, hi as usize);
                        // join the client's trace id so the peer-side
                        // span lands in a trace stitchable with the
                        // client's export (trace_id 0 = untraced)
                        let tctx = match (&host.tracer, trace_id) {
                            (Some(t), id) if id != 0 => t.join(id),
                            _ => crate::obs::TraceCtx::disabled(),
                        };
                        let mut pspan = tctx.span("peer.execute", None);
                        pspan.field_str("method", method.clone());
                        pspan.field_u64("span_lo", lo);
                        pspan.field_u64("span_hi", hi);
                        let reply = match std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| host.call(&method, &input, span)),
                        ) {
                            Ok(Ok(payload)) => {
                                wire::Frame::Partial { id, secs: t0.elapsed().as_secs_f64(), payload }
                            }
                            Ok(Err(e)) => wire::Frame::Error { id, message: format!("{e:#}") },
                            Err(_) => wire::Frame::Error {
                                id,
                                message: format!("panic computing {method:?}"),
                            },
                        };
                        pspan.field_f64("execute_secs", t0.elapsed().as_secs_f64());
                        let ok = matches!(reply, wire::Frame::Partial { .. });
                        pspan.field_str("outcome", if ok { "ok" } else { "failed" });
                        pspan.finish();
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        let _ = w.lock().unwrap().write_all(&reply.encode());
                    },
                );
            }
            // clients never receive these; a confused peer is ignored
            wire::Frame::HelloAck { .. }
            | wire::Frame::Partial { .. }
            | wire::Frame::Error { .. }
            | wire::Frame::Pong { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::distribution::Range1;

    #[test]
    fn hierarchical_split_refines_flat_split() {
        let nested = hierarchical_ranges(1003, 4, 3);
        assert_eq!(nested.len(), 4);
        let flat: Vec<Range1> = nested.into_iter().flatten().collect();
        assert_eq!(flat.len(), 12);
        assert_eq!(flat[0].lo, 0);
        assert_eq!(flat.last().unwrap().hi, 1003);
        for w in flat.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
    }

    #[test]
    fn undistributed_args_scale_scatter_with_nodes() {
        // the §7.5 limitation, quantified: replicated bytes are paid per
        // remote node, distributed bytes are not
        let net = NetworkProfile::gigabit_ethernet();
        let comm_dist =
            CommShape { distributed_in_bytes: 8 << 20, replicated_in_bytes: 0, partial_result_bytes: 8 };
        let comm_repl =
            CommShape { distributed_in_bytes: 0, replicated_in_bytes: 8 << 20, partial_result_bytes: 8 };
        let w = Duration::from_millis(10);
        let d2 = model_cluster_invocation(&net, 2, comm_dist, w).scatter;
        let d8 = model_cluster_invocation(&net, 8, comm_dist, w).scatter;
        let r2 = model_cluster_invocation(&net, 2, comm_repl, w).scatter;
        let r8 = model_cluster_invocation(&net, 8, comm_repl, w).scatter;
        // distributed: total scatter bytes constant-ish (7/8 of data at 8 nodes)
        assert!(d8 < d2.mul_f64(2.0));
        // replicated: scatter grows ~linearly with node count
        assert!(r8 > r2.mul_f64(3.0));
    }

    #[test]
    fn single_node_has_no_network_cost() {
        let net = NetworkProfile::infiniband_ddr();
        let comm =
            CommShape { distributed_in_bytes: 1 << 20, replicated_in_bytes: 1 << 20, partial_result_bytes: 64 };
        let m = model_cluster_invocation(&net, 1, comm, Duration::from_millis(5));
        assert_eq!(m.scatter, Duration::ZERO);
        assert_eq!(m.reduce_comm, Duration::ZERO);
        assert_eq!(m.t_par, Duration::from_millis(5));
    }

    #[test]
    fn hierarchical_reduce_is_logarithmic() {
        let net = NetworkProfile::gigabit_ethernet();
        let comm = CommShape {
            distributed_in_bytes: 0,
            replicated_in_bytes: 0,
            partial_result_bytes: 1 << 20,
        };
        let w = Duration::ZERO;
        let m2 = model_cluster_invocation(&net, 2, comm, w).reduce_comm;
        let m16 = model_cluster_invocation(&net, 16, comm, w).reduce_comm;
        assert!((m16.as_secs_f64() / m2.as_secs_f64() - 4.0).abs() < 0.01); // log2(16)/log2(2)
    }

    #[test]
    fn compute_bound_work_scales_transfer_bound_crosses_over() {
        // Series-like (tiny data, heavy compute) keeps winning with more
        // nodes; Crypt-like (data ~ work) hits a communication wall.
        let net = NetworkProfile::gigabit_ethernet();
        let t_seq = Duration::from_secs(10);
        let series = CommShape {
            distributed_in_bytes: 80_000,
            replicated_in_bytes: 0,
            partial_result_bytes: 80_000,
        };
        let crypt = CommShape {
            distributed_in_bytes: 50_000_000,
            replicated_in_bytes: 0,
            partial_result_bytes: 50_000_000 / 8,
        };
        let mut prev_series = 0.0;
        let mut crypt_speedups = Vec::new();
        for nodes in [1usize, 2, 4, 8, 16] {
            let w = Duration::from_secs_f64(10.0 / nodes as f64);
            let s = model_cluster_invocation(&net, nodes, series, w).speedup_over(t_seq);
            assert!(s > prev_series, "series should keep scaling");
            prev_series = s;
            // crypt-like workload: 0.45 s of compute total
            let wc = Duration::from_secs_f64(0.45 / nodes as f64);
            crypt_speedups.push(
                model_cluster_invocation(&net, nodes, crypt, wc)
                    .speedup_over(Duration::from_secs_f64(0.45)),
            );
        }
        // crypt crosses over: more nodes eventually stop helping
        let max = crypt_speedups.iter().cloned().fold(0.0, f64::max);
        assert!(*crypt_speedups.last().unwrap() < max, "{crypt_speedups:?}");
    }

    // --- wire protocol + live-socket suite -------------------------------

    #[test]
    fn wire_frames_round_trip_through_a_byte_stream() {
        let frames = vec![
            wire::Frame::Hello { version: 1, name: "c".into() },
            wire::Frame::HelloAck { version: 1, name: "peer-a".into(), workers: 8 },
            wire::Frame::Submit {
                id: 7,
                method: "VecAdd.add".into(),
                lo: 10,
                hi: 250,
                deadline_ms: 5_000,
                input: vec![1, 2, 3, 255],
                trace_id: 42,
            },
            wire::Frame::Partial { id: 7, secs: 0.125, payload: vec![9; 300] },
            wire::Frame::Error { id: 8, message: "no such method".into() },
            wire::Frame::Ping { nonce: 42 },
            wire::Frame::Pong { nonce: 42 },
        ];
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.encode());
        }
        let mut reader = wire::FrameReader::new(std::io::Cursor::new(bytes));
        for want in &frames {
            let got = reader.next().expect("frame reads").expect("frame present");
            assert_eq!(&got, want);
        }
    }

    #[test]
    fn wire_reader_rejects_oversized_and_truncated_frames() {
        // corrupt length prefix: must error out, not try to allocate 2 GiB
        let mut bytes = vec![4u8];
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut reader = wire::FrameReader::new(std::io::Cursor::new(bytes));
        assert!(reader.next().is_err());

        // truncated payload: decoding must fail cleanly
        let good = wire::Frame::Error { id: 1, message: "x".into() }.encode();
        assert!(wire::Frame::decode(5, &good[wire::HEADER_BYTES..good.len() - 1]).is_err());
    }

    fn doubling_host() -> Arc<MethodHost> {
        Arc::new(MethodHost::new("test-peer").with_workers(4).register(
            "Test.double",
            |input: &[u8], span: Range1| {
                anyhow::ensure!(span.len() == input.len(), "span/input mismatch");
                Ok(input.iter().map(|b| b.wrapping_mul(2)).collect())
            },
        ))
    }

    #[test]
    fn loopback_submit_round_trips_and_pings() {
        let server =
            PeerServer::bind("127.0.0.1:0", doubling_host(), ServeOptions::default()).unwrap();
        let client =
            ClusterClient::connect(&server.addr().to_string(), ClusterConfig::default()).unwrap();
        assert_eq!(client.peer_name(), "test-peer");
        assert_eq!(client.peer_workers(), 4);
        assert!(client.is_alive());

        let (tx, rx) = mpsc::channel();
        client
            .submit(
                "Test.double",
                Range1::new(0, 4),
                vec![1, 2, 3, 100],
                Box::new(move |r| tx.send(r).unwrap()),
            )
            .unwrap();
        let partial = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(partial.payload, vec![2, 4, 6, 200]);
        assert!(partial.secs >= 0.0);

        let rtt = client.ping().expect("pong comes back");
        assert!(rtt < Duration::from_secs(5));
    }

    #[test]
    fn unknown_method_comes_back_as_a_remote_error() {
        let server =
            PeerServer::bind("127.0.0.1:0", doubling_host(), ServeOptions::default()).unwrap();
        let client =
            ClusterClient::connect(&server.addr().to_string(), ClusterConfig::default()).unwrap();
        let (tx, rx) = mpsc::channel();
        client
            .submit("No.such", Range1::new(0, 1), vec![0], Box::new(move |r| tx.send(r).unwrap()))
            .unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.to_string().contains("No.such"), "{err:#}");
    }

    #[test]
    fn deadline_expiry_fails_the_span_without_killing_the_client() {
        // the peer holds every reply for 10 s; a 150 ms deadline must fire
        let opts = ServeOptions { injected_delay: Duration::from_secs(10) };
        let server = PeerServer::bind("127.0.0.1:0", doubling_host(), opts).unwrap();
        let cfg = ClusterConfig {
            deadline: Duration::from_millis(150),
            heartbeat: Duration::ZERO,
            ..ClusterConfig::default()
        };
        let client = ClusterClient::connect(&server.addr().to_string(), cfg).unwrap();
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        client
            .submit(
                "Test.double",
                Range1::new(0, 2),
                vec![1, 2],
                Box::new(move |r| tx.send(r).unwrap()),
            )
            .unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err:#}");
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline must beat the slow reply");
        // the connection itself stays usable for later submits
        assert!(client.is_alive());
    }

    #[test]
    fn dropped_connection_fails_pending_submits() {
        // a plain listener that accepts and immediately drops the socket
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            // answer the handshake, then hang up with a submit in flight
            let (stream, _) = listener.accept().unwrap();
            let mut frames = wire::FrameReader::new(stream.try_clone().unwrap());
            let mut stream = stream;
            loop {
                match frames.next() {
                    Ok(Some(wire::Frame::Hello { .. })) => {
                        let ack = wire::Frame::HelloAck {
                            version: wire::PROTO_VERSION,
                            name: "flaky".into(),
                            workers: 1,
                        };
                        stream.write_all(&ack.encode()).unwrap();
                    }
                    Ok(Some(wire::Frame::Submit { .. })) => return, // drop the connection
                    Ok(Some(_)) => {}
                    Ok(None) => {}
                    Err(_) => return,
                }
            }
        });
        let cfg = ClusterConfig { heartbeat: Duration::ZERO, ..ClusterConfig::default() };
        let client = ClusterClient::connect(&addr.to_string(), cfg).unwrap();
        let (tx, rx) = mpsc::channel();
        client
            .submit("Any.m", Range1::new(0, 1), vec![0], Box::new(move |r| tx.send(r).unwrap()))
            .unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.to_string().contains("peer lost"), "{err:#}");
        assert!(!client.is_alive());
        // further submits fail fast so the engine covers synchronously
        assert!(client
            .submit("Any.m", Range1::new(0, 1), vec![0], Box::new(|_| {}))
            .is_err());
    }
}
