//! Cluster realization of SOMD (paper §4.2), as a *model*.
//!
//! The paper defers distributed-memory evaluation to future work but
//! specifies the execution model precisely: distributed arrays are
//! scattered hierarchically (node split, then the §4.1 copy-free split
//! inside each node), reductions fold hierarchically to cut the data
//! returned to the master, and — the PGAS-by-design property (Figure 6) —
//! every MI works on node-local data unless sharing is explicit, so
//! undistributed parameters are *replicated* to every node.
//!
//! This module implements that cost structure over a simulated
//! interconnect, composing with the calibrated intra-node makespan model
//! ([`crate::bench_suite::modeled`]): no cluster exists here, so network
//! time is virtual, but the work times it combines are measured.

use std::time::Duration;

/// Point-to-point interconnect model: `t(bytes) = latency + bytes/bw`.
#[derive(Debug, Clone, Copy)]
pub struct NetworkProfile {
    /// Interconnect name (report label).
    pub name: &'static str,
    /// Per-message latency.
    pub latency: Duration,
    /// Point-to-point bandwidth (bytes/s).
    pub bandwidth_bytes_per_sec: f64,
}

impl NetworkProfile {
    /// ~2009-era gigabit ethernet (the clusters of the paper's §4.2 era).
    pub fn gigabit_ethernet() -> Self {
        NetworkProfile {
            name: "1GbE",
            latency: Duration::from_micros(80),
            bandwidth_bytes_per_sec: 0.11e9,
        }
    }

    /// DDR InfiniBand.
    pub fn infiniband_ddr() -> Self {
        NetworkProfile {
            name: "IB-DDR",
            latency: Duration::from_micros(4),
            bandwidth_bytes_per_sec: 1.8e9,
        }
    }

    /// Modeled time to move `bytes` across one link.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        if bytes == 0 {
            return Duration::ZERO;
        }
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth_bytes_per_sec)
    }
}

/// Byte-level description of one SOMD invocation's communication.
#[derive(Debug, Clone, Copy)]
pub struct CommShape {
    /// Bytes of `dist`-qualified inputs (scattered: each node gets 1/N).
    pub distributed_in_bytes: usize,
    /// Bytes of undistributed inputs (replicated to every node — the
    /// §7.5 limitation: "undistributed parameters increase the amount of
    /// data to be transferred to each node").
    pub replicated_in_bytes: usize,
    /// Bytes of each node's partial result (hierarchically reduced).
    pub partial_result_bytes: usize,
}

/// Modeled timings for a cluster-wide invocation.
#[derive(Debug, Clone, Copy)]
pub struct ClusterModeled {
    /// Node count.
    pub nodes: usize,
    /// Scatter (distribution) time.
    pub scatter: Duration,
    /// Intra-node compute makespan (measured, supplied by the caller).
    pub compute: Duration,
    /// Hierarchical-reduction communication time.
    pub reduce_comm: Duration,
    /// Total modeled invocation time.
    pub t_par: Duration,
}

impl ClusterModeled {
    /// Modeled speedup over a sequential baseline.
    pub fn speedup_over(&self, t_seq: Duration) -> f64 {
        t_seq.as_secs_f64() / self.t_par.as_secs_f64()
    }
}

/// The §4.2 composition: sequential scatter of node shares + replicated
/// args, intra-node makespan (supplied by the caller — measured), and a
/// binary-tree hierarchical reduction.
pub fn model_cluster_invocation(
    net: &NetworkProfile,
    nodes: usize,
    comm: CommShape,
    intra_node_makespan: Duration,
) -> ClusterModeled {
    assert!(nodes > 0);
    // The master sends each remote node its share of the distributed data
    // plus a full copy of every undistributed argument (Figure 6: remote
    // MIs otherwise touch only local data).  Node 0 is the master itself.
    let share = comm.distributed_in_bytes / nodes;
    let mut scatter = Duration::ZERO;
    for _ in 1..nodes {
        scatter += net.transfer_time(share + comm.replicated_in_bytes);
    }
    // Hierarchical reduction: ceil(log2(nodes)) rounds of partial-result
    // exchange (valid because the programmer guarantees associativity,
    // §4.2 — statically checkable at deployment time).
    let rounds = usize::BITS - (nodes - 1).leading_zeros().min(usize::BITS - 1);
    let rounds = if nodes == 1 { 0 } else { rounds as usize };
    let reduce_comm =
        net.transfer_time(comm.partial_result_bytes).mul_f64(rounds.max(0) as f64);
    ClusterModeled {
        nodes,
        scatter,
        compute: intra_node_makespan,
        reduce_comm,
        t_par: scatter + intra_node_makespan + reduce_comm,
    }
}

/// Hierarchical distribution property (paper §4.2: "distribution
/// strategies are intrinsically associative"): splitting into `nodes`
/// then `per_node` partitions must refine the flat split.
pub fn hierarchical_ranges(
    len: usize,
    nodes: usize,
    per_node: usize,
) -> Vec<Vec<super::distribution::Range1>> {
    super::distribution::index_ranges(len, nodes)
        .into_iter()
        .map(|node_range| {
            super::distribution::index_ranges(node_range.len(), per_node)
                .into_iter()
                .map(|r| super::distribution::Range1::new(r.lo + node_range.lo, r.hi + node_range.lo))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::distribution::Range1;

    #[test]
    fn hierarchical_split_refines_flat_split() {
        let nested = hierarchical_ranges(1003, 4, 3);
        assert_eq!(nested.len(), 4);
        let flat: Vec<Range1> = nested.into_iter().flatten().collect();
        assert_eq!(flat.len(), 12);
        assert_eq!(flat[0].lo, 0);
        assert_eq!(flat.last().unwrap().hi, 1003);
        for w in flat.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
    }

    #[test]
    fn undistributed_args_scale_scatter_with_nodes() {
        // the §7.5 limitation, quantified: replicated bytes are paid per
        // remote node, distributed bytes are not
        let net = NetworkProfile::gigabit_ethernet();
        let comm_dist =
            CommShape { distributed_in_bytes: 8 << 20, replicated_in_bytes: 0, partial_result_bytes: 8 };
        let comm_repl =
            CommShape { distributed_in_bytes: 0, replicated_in_bytes: 8 << 20, partial_result_bytes: 8 };
        let w = Duration::from_millis(10);
        let d2 = model_cluster_invocation(&net, 2, comm_dist, w).scatter;
        let d8 = model_cluster_invocation(&net, 8, comm_dist, w).scatter;
        let r2 = model_cluster_invocation(&net, 2, comm_repl, w).scatter;
        let r8 = model_cluster_invocation(&net, 8, comm_repl, w).scatter;
        // distributed: total scatter bytes constant-ish (7/8 of data at 8 nodes)
        assert!(d8 < d2.mul_f64(2.0));
        // replicated: scatter grows ~linearly with node count
        assert!(r8 > r2.mul_f64(3.0));
    }

    #[test]
    fn single_node_has_no_network_cost() {
        let net = NetworkProfile::infiniband_ddr();
        let comm =
            CommShape { distributed_in_bytes: 1 << 20, replicated_in_bytes: 1 << 20, partial_result_bytes: 64 };
        let m = model_cluster_invocation(&net, 1, comm, Duration::from_millis(5));
        assert_eq!(m.scatter, Duration::ZERO);
        assert_eq!(m.reduce_comm, Duration::ZERO);
        assert_eq!(m.t_par, Duration::from_millis(5));
    }

    #[test]
    fn hierarchical_reduce_is_logarithmic() {
        let net = NetworkProfile::gigabit_ethernet();
        let comm = CommShape {
            distributed_in_bytes: 0,
            replicated_in_bytes: 0,
            partial_result_bytes: 1 << 20,
        };
        let w = Duration::ZERO;
        let m2 = model_cluster_invocation(&net, 2, comm, w).reduce_comm;
        let m16 = model_cluster_invocation(&net, 16, comm, w).reduce_comm;
        assert!((m16.as_secs_f64() / m2.as_secs_f64() - 4.0).abs() < 0.01); // log2(16)/log2(2)
    }

    #[test]
    fn compute_bound_work_scales_transfer_bound_crosses_over() {
        // Series-like (tiny data, heavy compute) keeps winning with more
        // nodes; Crypt-like (data ~ work) hits a communication wall.
        let net = NetworkProfile::gigabit_ethernet();
        let t_seq = Duration::from_secs(10);
        let series = CommShape {
            distributed_in_bytes: 80_000,
            replicated_in_bytes: 0,
            partial_result_bytes: 80_000,
        };
        let crypt = CommShape {
            distributed_in_bytes: 50_000_000,
            replicated_in_bytes: 0,
            partial_result_bytes: 50_000_000 / 8,
        };
        let mut prev_series = 0.0;
        let mut crypt_speedups = Vec::new();
        for nodes in [1usize, 2, 4, 8, 16] {
            let w = Duration::from_secs_f64(10.0 / nodes as f64);
            let s = model_cluster_invocation(&net, nodes, series, w).speedup_over(t_seq);
            assert!(s > prev_series, "series should keep scaling");
            prev_series = s;
            // crypt-like workload: 0.45 s of compute total
            let wc = Duration::from_secs_f64(0.45 / nodes as f64);
            crypt_speedups.push(
                model_cluster_invocation(&net, nodes, crypt, wc)
                    .speedup_over(Duration::from_secs_f64(0.45)),
            );
        }
        // crypt crosses over: more nodes eventually stop helping
        let max = crypt_speedups.iter().cloned().fold(0.0, f64::max);
        assert!(*crypt_speedups.last().unwrap() < max, "{crypt_speedups:?}");
    }
}
