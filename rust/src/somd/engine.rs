//! The Elina-like runtime engine (paper §6): owns the worker pool, the
//! version-selection rules and the invocation entry points.

use std::sync::Arc;

use super::config::{Rules, Target};
use super::master::SomdMethod;
use super::pool::{JobHandle, WorkerPool};

pub struct Engine {
    workers: usize,
    rules: Rules,
    pool: WorkerPool,
}

impl Engine {
    /// `workers` is the default MI count per invocation (paper: one per
    /// available processor unless overridden at deployment time).
    pub fn new(workers: usize) -> Self {
        Self::with_rules(workers, Rules::empty())
    }

    pub fn with_rules(workers: usize, rules: Rules) -> Self {
        let workers = workers.max(1);
        Self { workers, rules, pool: WorkerPool::new(workers) }
    }

    /// Default engine: one MI per available core.
    pub fn default_for_host() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(cores)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn rules(&self) -> &Rules {
        &self.rules
    }

    /// The architecture the rules select for `method` (§6); device targets
    /// are resolved by the caller against the available device profiles
    /// and revert to SMP when inapplicable.
    pub fn target_for(&self, method: &str) -> Target {
        self.rules.target_for(method)
    }

    /// Synchronous SOMD invocation with the engine's default MI count.
    pub fn invoke<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        method.invoke(input, self.workers)
    }

    /// Synchronous invocation with an explicit MI count.
    pub fn invoke_with(&self, nparts: usize) -> InvokeWith<'_> {
        InvokeWith { _engine: self, nparts }
    }

    /// Asynchronous submission: the invocation competes for the pool with
    /// other concurrently submitted SOMD requests (§6).
    pub fn submit<I, P, E, R>(
        &self,
        method: Arc<SomdMethod<I, P, E, R>>,
        input: Arc<I>,
    ) -> JobHandle<R>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        let n = self.workers;
        self.pool.submit(move || method.invoke(&input, n))
    }
}

pub struct InvokeWith<'a> {
    _engine: &'a Engine,
    nparts: usize,
}

impl InvokeWith<'_> {
    pub fn call<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        method.invoke(input, self.nparts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::partition::Block1D;
    use crate::somd::reduction;

    fn sum_method() -> SomdMethod<Vec<i64>, crate::somd::partition::BlockPart, (), i64> {
        SomdMethod::new(
            "sum",
            |v: &Vec<i64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, p, _, _| p.own.iter().map(|i| v[i]).sum(),
            reduction::sum::<i64>(),
        )
    }

    #[test]
    fn engine_invokes_with_default_workers() {
        let e = Engine::new(4);
        let data: Vec<i64> = (0..100).collect();
        assert_eq!(e.invoke(&sum_method(), &data), 4950);
    }

    #[test]
    fn explicit_partition_count() {
        let e = Engine::new(2);
        let data: Vec<i64> = (1..=10).collect();
        assert_eq!(e.invoke_with(7).call(&sum_method(), &data), 55);
    }

    #[test]
    fn concurrent_submissions() {
        let e = Engine::new(3);
        let m = Arc::new(sum_method());
        let data = Arc::new((0..1000).collect::<Vec<i64>>());
        let handles: Vec<_> =
            (0..6).map(|_| e.submit(m.clone(), data.clone())).collect();
        for h in handles {
            assert_eq!(h.join(), 499_500);
        }
    }

    #[test]
    fn rules_select_target() {
        let mut rules = Rules::empty();
        rules.set("Series.coefficients", Target::Device("fermi".into()));
        let e = Engine::with_rules(2, rules);
        assert_eq!(e.target_for("Series.coefficients"), Target::Device("fermi".into()));
        assert_eq!(e.target_for("Crypt.encrypt"), Target::Smp);
    }
}
