//! The Elina-like runtime engine (paper §6): owns the worker pool, the
//! version-selection rules, the adaptive scheduler and the invocation
//! entry points.
//!
//! Four execution lanes serve asynchronous submissions:
//!
//! * **SMP lane** — invocations compete for the [`WorkerPool`] exactly as
//!   in the paper's runtime;
//! * **device lanes (the fleet)** — PJRT objects are `Rc`-confined, so
//!   device work funnels through *device master* threads, one per
//!   configured fleet lane ([`Engine::with_device_fleet`]); each master
//!   owns its own [`Registry`] and a warm [`DeviceSession`] per profile.
//!   Heterogeneous mixes (`fermi` + `geforce320m`, …) are first-class.
//!   Whole-invocation device jobs dispatch to the **least-loaded** lane
//!   matching the resolved profile (falling back to the least-loaded
//!   lane overall), so concurrent submitters — the serving layer's
//!   dispatchers above all — actually use every device.  Warm-session
//!   reuse per lane is observable through [`DeviceCounters`].
//! * **hybrid lane** — one invocation *forked* across SMP and one device
//!   lane: the index space splits at the scheduler's learned ratio, the
//!   SMP share runs as a pool job while the device share queues on a
//!   master thread, and a completion latch merges the partial results
//!   through the method's reduction when the second side finishes
//!   (neither side ever blocks a worker waiting for the other — that
//!   would deadlock against the device lane's pool-backed kernels).
//! * **sharded lane** — the fleet generalization of hybrid: one
//!   invocation split N-way across SMP *and every device lane at once*,
//!   at the scheduler's learned per-lane weights
//!   ([`split_weighted_floor`]), joined by the same
//!   completion-latch discipline counted down over `k + 1` shares.
//! * **cluster (remote) lanes** — TCP peers attached with
//!   [`Engine::with_cluster_peers`] join the sharded split as additional
//!   lanes *after* the device fleet: a remote span's input is encoded by
//!   the method's [`ClusterSpec`](crate::backend::ClusterSpec), shipped
//!   to the peer (itself a full engine behind `somd cluster serve`), and
//!   the partial-result bytes fill the lane's latch slot when the reply
//!   lands — or an error does, on a dropped connection or expired
//!   deadline, in which case the SMP side covers the span in place with
//!   a [`record_sharded_failure`](Scheduler::record_sharded_failure)
//!   penalty, exactly like a failed device lane.  This is the first
//!   point where the learned per-lane weights span hosts, not threads.
//!
//! Rules resolve per method as `smp | device(<profile>) | hybrid |
//! sharded | auto`; `auto` defers to the [`Scheduler`]'s
//! execution-history cost model (per-device-lane throughput windows on
//! fleets of two or more).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::cluster::{ClusterClient, ClusterConfig, RemoteCallback, RemotePartial};
use super::config::{Rules, Target};
use super::distribution::Range1;
use super::master::SomdMethod;
use super::partition::{split_fraction, split_weighted_floor};
use super::pool::{JobHandle, WorkerPool};
use super::scheduler::{choice_name, Choice, DecisionExplain, Scheduler, SchedulerConfig};
use crate::backend::{DeviceShare, Executed, HeteroMethod, HybridMerge, ShardedMerge};
use crate::device::{DeviceProfile, DeviceSession, DeviceStats, UploadCounters};
use crate::obs::{
    chrome_trace, jsonl, HubSnapshot, MetricsHub, OpenSpan, SpanRef, TraceCtx, TraceFormat,
    TraceRecorder,
};
use crate::runtime::Registry;

/// The lane label an invocation's resolved [`Target`] lands on (span
/// fields + hub series).
fn target_label(t: &Target) -> &'static str {
    match t {
        Target::Smp => "smp",
        Target::Device(_) => "device",
        Target::Hybrid => "hybrid",
        Target::Sharded => "sharded",
        Target::Auto => "auto",
    }
}

// ---------------------------------------------------------------------------
// Device master thread
// ---------------------------------------------------------------------------

/// Warm-session accounting: evidence that concurrent device submissions
/// batch their setup instead of paying it per call.
#[derive(Debug, Default)]
pub struct DeviceCounters {
    sessions_created: AtomicUsize,
    warm_hits: AtomicUsize,
    jobs_run: AtomicUsize,
    /// Upload-memo accounting shared with every session on this lane
    /// (pipeline `put_cached` hits/uploads/invalidations).
    uploads: Arc<UploadCounters>,
}

/// Point-in-time copy of [`DeviceCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceCountersSnapshot {
    /// Sessions constructed on the master thread (cold setups).
    pub sessions_created: usize,
    /// Jobs that found their profile's session already warm.
    pub warm_hits: usize,
    /// Total device jobs executed.
    pub jobs_run: usize,
    /// Memoized uploads that paid a real H2D transfer (cache misses).
    pub uploads: usize,
    /// Memoized uploads served from a resident buffer (cache hits).
    pub upload_hits: usize,
    /// Memo entries dropped (capacity eviction / unresolvable handle).
    pub upload_invalidations: usize,
}

impl DeviceCounters {
    fn snapshot(&self) -> DeviceCountersSnapshot {
        DeviceCountersSnapshot {
            sessions_created: self.sessions_created.load(Ordering::SeqCst),
            warm_hits: self.warm_hits.load(Ordering::SeqCst),
            jobs_run: self.jobs_run.load(Ordering::SeqCst),
            uploads: self.uploads.uploads(),
            upload_hits: self.uploads.hits(),
            upload_invalidations: self.uploads.invalidations(),
        }
    }
}

/// The master thread's execution context: the registry plus one warm
/// session per device profile (both thread-confined).
pub struct DeviceCtx<'r> {
    registry: &'r Registry,
    sessions: BTreeMap<String, DeviceSession<'r>>,
    counters: Arc<DeviceCounters>,
}

impl<'r> DeviceCtx<'r> {
    /// The artifact registry owned by this master thread.
    pub fn registry(&self) -> &'r Registry {
        self.registry
    }

    /// The warm session for `profile`, created on first use.
    pub fn session(&mut self, profile: &str) -> anyhow::Result<&mut DeviceSession<'r>> {
        if self.sessions.contains_key(profile) {
            self.counters.warm_hits.fetch_add(1, Ordering::SeqCst);
        } else {
            let p = DeviceProfile::by_name(profile)
                .ok_or_else(|| anyhow::anyhow!("unknown device profile '{profile}'"))?;
            let mut session = DeviceSession::new(self.registry, p);
            // one shared counter set per lane so `Engine::device_counters`
            // can total memo behaviour across profiles
            session.set_upload_counters(self.counters.uploads.clone());
            self.sessions.insert(profile.to_string(), session);
            self.counters.sessions_created.fetch_add(1, Ordering::SeqCst);
        }
        Ok(self.sessions.get_mut(profile).expect("session just ensured"))
    }
}

type DeviceJob = Box<dyn for<'r> FnOnce(&mut DeviceCtx<'r>) + Send>;

struct DeviceMaster {
    tx: Option<mpsc::Sender<DeviceJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
    counters: Arc<DeviceCounters>,
    /// Jobs submitted but not yet finished on this master — the
    /// least-loaded dispatch signal.  Incremented at submit, decremented
    /// by the master loop after each job runs.
    pending: Arc<AtomicUsize>,
}

impl DeviceMaster {
    fn spawn(dir: PathBuf, device_id: usize) -> anyhow::Result<DeviceMaster> {
        let counters = Arc::new(DeviceCounters::default());
        let pending = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<DeviceJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let thread_counters = counters.clone();
        let thread_pending = pending.clone();
        let handle = std::thread::Builder::new()
            .name(format!("somd-device-master-{device_id}"))
            .spawn(move || master_loop(dir, rx, ready_tx, thread_counters, thread_pending))
            .expect("spawn device master thread");
        match ready_rx.recv() {
            Ok(Ok(())) => {
                Ok(DeviceMaster { tx: Some(tx), handle: Some(handle), counters, pending })
            }
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(anyhow::anyhow!("device master failed to start: {e}"))
            }
            Err(_) => {
                let _ = handle.join();
                Err(anyhow::anyhow!("device master died during startup"))
            }
        }
    }

    fn submit(&self, job: DeviceJob) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("device master channel open")
            .send(job)
            .expect("device master thread alive");
    }

    fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }
}

impl Drop for DeviceMaster {
    fn drop(&mut self) {
        drop(self.tx.take()); // closing the channel ends the loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One lane of the device fleet: a master thread pinned to a configured
/// profile (its warm-session home; the ctx can still serve other
/// profiles on demand, preserving the single-master behavior for rules
/// that name a profile no lane was configured with).
struct DeviceLane {
    master: DeviceMaster,
    profile: String,
    /// The profile's canonical `'static` name, for execution reports.
    static_name: &'static str,
}

/// One remote (cluster) lane: a TCP connection to a peer engine,
/// participating in sharded splits after the local device fleet.
struct RemoteLane {
    client: Arc<ClusterClient>,
    /// `tcp://<addr>` as the lane's report label (leaked once per
    /// connect so it can stand where device profile names do).
    static_name: &'static str,
}

fn master_loop(
    dir: PathBuf,
    rx: mpsc::Receiver<DeviceJob>,
    ready: mpsc::Sender<Result<(), String>>,
    counters: Arc<DeviceCounters>,
    pending: Arc<AtomicUsize>,
) {
    // the registry must be created on this thread (PJRT is Rc-confined)
    let registry = match Registry::load(&dir) {
        Ok(r) => {
            let _ = ready.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Pre-compile every artifact before serving jobs: lowering is a
    // one-time load cost, and charging it to the first job's *measured*
    // execute time would hand the scheduler an inflated first device
    // sample (which, with hysteresis, could lock a method out of the
    // device lane for good).  Missing/broken artifacts stay lazy errors.
    for name in registry.names().map(String::from).collect::<Vec<_>>() {
        let _ = registry.artifact(&name);
    }
    let mut ctx = DeviceCtx { registry: &registry, sessions: BTreeMap::new(), counters };
    while let Ok(job) = rx.recv() {
        ctx.counters.jobs_run.fetch_add(1, Ordering::SeqCst);
        // a panicking job must not take down the lane for queued peers
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&mut ctx)));
        pending.fetch_sub(1, Ordering::SeqCst);
    }
}

// ---------------------------------------------------------------------------
// Hybrid fork/join (completion latch)
// ---------------------------------------------------------------------------

/// The SMP half's outcome: partials + execute seconds (or a panic).
type SmpHalf<R> = std::thread::Result<(Vec<R>, f64)>;
/// The device half's outcome: success, error, or panic.
type DevHalf<R> = std::thread::Result<anyhow::Result<DeviceShare<R>>>;
/// What the latch finally sends to the caller's handle.
type HybridOutcome<R> = std::thread::Result<anyhow::Result<(R, Executed)>>;

/// The two result slots of one forked invocation.  Whichever side fills
/// its slot *second* performs the merge — a count-down latch, not a
/// blocking join, so no pool worker or master-thread slot ever parks
/// waiting for the other lane.
struct HybridSlots<R> {
    smp: Option<SmpHalf<R>>,
    dev: Option<DevHalf<R>>,
}

/// Shared state of one in-flight hybrid invocation (held by both halves'
/// jobs until the latch completes).
struct HybridInFlight<I: ?Sized, P, E, R> {
    method: Arc<HeteroMethod<I, P, E, R>>,
    input: Arc<I>,
    sched: Arc<Scheduler>,
    profile: String,
    smp_span: Range1,
    dev_span: Range1,
    fraction: f64,
    smp_parts: usize,
    tx: mpsc::Sender<HybridOutcome<R>>,
    slots: Mutex<HybridSlots<R>>,
    /// Trace handle both halves open their lane spans through.
    tctx: TraceCtx,
    /// The invocation root's span id (lane spans parent here).
    root_span: u64,
    /// The root span itself — closed by the latch after the merge, so
    /// the trace is complete before the caller's handle resolves.
    root: Mutex<Option<OpenSpan>>,
    hub: Arc<MetricsHub>,
    /// Fork instant: the device half's master-queue wait is measured
    /// from here to its dequeue.
    enqueued: Instant,
}

impl<I, P, E, R> HybridInFlight<I, P, E, R>
where
    I: ?Sized + Sync,
    P: Send + Sync,
    E: Sync,
    R: Send,
{
    /// The SMP half: compute the leading share's partials on this pool
    /// worker (fanning out scoped MIs as a plain invocation would).
    fn run_smp_half(&self) {
        let mut span = self.tctx.span("lane.smp", Some(self.root_span));
        span.field_u64("span_items", self.smp_span.len() as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let t0 = Instant::now();
            let partials =
                self.method.hybrid_smp_partials(&self.input, self.smp_span, self.smp_parts);
            (partials, t0.elapsed().as_secs_f64())
        }));
        if let Ok((_, secs)) = &result {
            span.field_f64("execute_secs", *secs);
            self.hub.observe(
                &format!(
                    "somd_lane_execute_seconds{{method=\"{}\",lane=\"smp\"}}",
                    self.method.name()
                ),
                *secs,
            );
        }
        span.finish();
        let both = {
            let mut slots = self.slots.lock().unwrap();
            slots.smp = Some(result);
            slots.dev.is_some()
        };
        if both {
            self.finish();
        }
    }

    /// The device half: run the trailing share on the master thread's
    /// warm session, clocked after dequeue (queue wait excluded).
    fn run_device_half(&self, ctx: &mut DeviceCtx<'_>) {
        // dequeue instant: everything since the fork was master-queue wait
        let wait = self.enqueued.elapsed();
        let mut span = self.tctx.span("lane.device", Some(self.root_span));
        span.field_u64("span_items", self.dev_span.len() as u64);
        span.field_f64("queue_wait_secs", wait.as_secs_f64());
        let result: DevHalf<R> = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let session = ctx.session(&self.profile)?;
            let before = session.stats();
            let t0 = Instant::now();
            let partial = self.method.hybrid_device_partial(session, &self.input, self.dev_span)?;
            let secs = t0.elapsed().as_secs_f64();
            let mut stats = session.stats().delta_since(&before);
            stats.queue_wait = wait;
            let profile = session.profile().name;
            Ok(DeviceShare { partial, secs, stats, profile })
        }));
        if let Ok(Ok(share)) = &result {
            annotate_device_span(&mut span, share.profile, share.secs, &share.stats);
            observe_device_execute(&self.hub, self.method.name(), share.secs, wait);
        }
        span.finish();
        let both = {
            let mut slots = self.slots.lock().unwrap();
            slots.dev = Some(result);
            slots.smp.is_some()
        };
        if both {
            self.finish();
        }
    }

    /// Latch release: merge (or fall back), record history, send.
    fn finish(&self) {
        let (smp, dev) = {
            let mut slots = self.slots.lock().unwrap();
            (
                slots.smp.take().expect("smp half completed"),
                slots.dev.take().expect("device half completed"),
            )
        };
        let mut mspan = self.tctx.span("merge", Some(self.root_span));
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.merge(smp, dev)));
        mspan.field_str(
            "outcome",
            if matches!(&outcome, Ok(Ok(Ok(_)))) { "merged" } else { "failed" },
        );
        mspan.finish();
        // close the invocation root before releasing the caller, so the
        // trace is complete when join() returns
        *self.root.lock().unwrap() = None;
        let _ = match outcome {
            Ok(msg) => self.tx.send(msg),
            Err(panic) => self.tx.send(Err(panic)),
        };
    }

    fn merge(&self, smp: SmpHalf<R>, dev: DevHalf<R>) -> HybridOutcome<R> {
        let smp = match smp {
            Ok(v) => v,
            // the SMP half panicked: propagate the payload to join()
            Err(p) => return Err(p),
        };
        // a panicked device half folds into the failure path of the
        // shared merge (the SMP side covers its span; the penalty steers
        // `auto` away).  When the device half finished last, that cover
        // runs on the master thread — it stalls the device lane for one
        // share's worth of CPU work, an accepted cost of the failure path.
        let dev = match dev {
            Ok(r) => r,
            Err(_panic) => Err(anyhow::anyhow!("hybrid device half panicked")),
        };
        let m = HybridMerge {
            sched: &self.sched,
            input: &self.input,
            smp_span: self.smp_span,
            dev_span: self.dev_span,
            fraction: self.fraction,
            nparts: self.smp_parts,
        };
        Ok(Ok(self.method.finish_hybrid(m, smp, dev)))
    }
}

// ---------------------------------------------------------------------------
// Sharded fork/join (N-way completion latch)
// ---------------------------------------------------------------------------

/// The `k + 1` result slots of one sharded invocation plus the count of
/// shares still outstanding.  Whichever share finishes *last* performs
/// the merge — the [`HybridSlots`] latch counted down over the whole
/// fleet, with the same no-blocking-join guarantee.
struct ShardSlots<R> {
    smp: Option<SmpHalf<R>>,
    devs: Vec<Option<DevHalf<R>>>,
    remaining: usize,
}

/// Shared state of one in-flight sharded invocation (held by the SMP
/// share's pool job and every participating device lane's master job
/// until the latch counts down).
struct ShardedInFlight<I: ?Sized, P, E, R> {
    method: Arc<HeteroMethod<I, P, E, R>>,
    input: Arc<I>,
    sched: Arc<Scheduler>,
    smp_span: Range1,
    dev_spans: Vec<Range1>,
    profiles: Vec<&'static str>,
    weights: Vec<f64>,
    smp_parts: usize,
    tx: mpsc::Sender<HybridOutcome<R>>,
    slots: Mutex<ShardSlots<R>>,
    /// Trace handle every share opens its lane span through.
    tctx: TraceCtx,
    /// The invocation root's span id (lane spans parent here).
    root_span: u64,
    /// The root span itself — closed by the latch after the merge.
    root: Mutex<Option<OpenSpan>>,
    hub: Arc<MetricsHub>,
    /// Fork instant: each device share's master-queue wait is measured
    /// from here to its dequeue.
    enqueued: Instant,
}

impl<I, P, E, R> ShardedInFlight<I, P, E, R>
where
    I: ?Sized + Sync,
    P: Send + Sync,
    E: Sync,
    R: Send,
{
    /// The SMP share: compute the leading span's partials on this pool
    /// worker.
    fn run_smp_shard(&self) {
        let mut span = self.tctx.span("lane.smp", Some(self.root_span));
        span.field_u64("span_items", self.smp_span.len() as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let t0 = Instant::now();
            let partials =
                self.method.hybrid_smp_partials(&self.input, self.smp_span, self.smp_parts);
            (partials, t0.elapsed().as_secs_f64())
        }));
        if let Ok((_, secs)) = &result {
            span.field_f64("execute_secs", *secs);
            self.hub.observe(
                &format!(
                    "somd_lane_execute_seconds{{method=\"{}\",lane=\"smp\"}}",
                    self.method.name()
                ),
                *secs,
            );
        }
        span.finish();
        let last = {
            let mut slots = self.slots.lock().unwrap();
            slots.smp = Some(result);
            slots.remaining -= 1;
            slots.remaining == 0
        };
        if last {
            self.finish();
        }
    }

    /// Device lane `i`'s share: run its span on that lane's master
    /// thread and warm session, clocked after dequeue.
    fn run_device_shard(&self, i: usize, ctx: &mut DeviceCtx<'_>) {
        // dequeue instant: everything since the fork was master-queue wait
        let wait = self.enqueued.elapsed();
        let mut span = self.tctx.span("lane.device", Some(self.root_span));
        span.field_u64("lane", i as u64);
        span.field_u64("span_items", self.dev_spans[i].len() as u64);
        span.field_f64("queue_wait_secs", wait.as_secs_f64());
        let result: DevHalf<R> = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let session = ctx.session(self.profiles[i])?;
            let before = session.stats();
            let t0 = Instant::now();
            let partial =
                self.method.hybrid_device_partial(session, &self.input, self.dev_spans[i])?;
            let secs = t0.elapsed().as_secs_f64();
            let mut stats = session.stats().delta_since(&before);
            stats.queue_wait = wait;
            let profile = session.profile().name;
            Ok(DeviceShare { partial, secs, stats, profile })
        }));
        if let Ok(Ok(share)) = &result {
            annotate_device_span(&mut span, share.profile, share.secs, &share.stats);
            observe_device_execute(&self.hub, self.method.name(), share.secs, wait);
        }
        span.finish();
        self.fill_lane_slot(i, result);
    }

    /// Remote lane `i`'s completion: decode the peer's partial-result
    /// bytes (or fold the network/deadline failure into the lane's slot
    /// so the SMP side covers the span).  Runs on the cluster client's
    /// reader thread; `t0` is the submit instant, so `secs` is the full
    /// client-observed round trip — the honest throughput a slow link
    /// earns its weight with.
    fn finish_remote_shard(
        &self,
        i: usize,
        profile: &'static str,
        t0: Instant,
        res: anyhow::Result<RemotePartial>,
    ) {
        let mut span = self.tctx.span("lane.remote", Some(self.root_span));
        span.field_u64("lane", i as u64);
        span.field_str("peer", profile);
        span.field_u64("span_items", self.dev_spans[i].len() as u64);
        let result: DevHalf<R> = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let remote = res?;
            let partial = self.method.cluster_decode_partial(&remote.payload)?;
            Ok(DeviceShare {
                partial,
                secs: t0.elapsed().as_secs_f64(),
                stats: DeviceStats::default(),
                profile,
            })
        }));
        match &result {
            Ok(Ok(share)) => span.field_f64("round_trip_secs", share.secs),
            _ => span.field_str("outcome", "failed"),
        }
        span.finish();
        self.fill_lane_slot(i, result);
    }

    /// The shared latch tail: store lane `i`'s outcome, count down, and
    /// let the last share merge.
    fn fill_lane_slot(&self, i: usize, result: DevHalf<R>) {
        let last = {
            let mut slots = self.slots.lock().unwrap();
            slots.devs[i] = Some(result);
            slots.remaining -= 1;
            slots.remaining == 0
        };
        if last {
            self.finish();
        }
    }

    /// Latch release: merge every share (covering failures), record
    /// history, send.
    fn finish(&self) {
        let (smp, devs) = {
            let mut slots = self.slots.lock().unwrap();
            (slots.smp.take().expect("smp share completed"), std::mem::take(&mut slots.devs))
        };
        let mut mspan = self.tctx.span("merge", Some(self.root_span));
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.merge(smp, devs)));
        mspan.field_str(
            "outcome",
            if matches!(&outcome, Ok(Ok(Ok(_)))) { "merged" } else { "failed" },
        );
        mspan.finish();
        // close the invocation root before releasing the caller, so the
        // trace is complete when join() returns
        *self.root.lock().unwrap() = None;
        let _ = match outcome {
            Ok(msg) => self.tx.send(msg),
            Err(panic) => self.tx.send(Err(panic)),
        };
    }

    fn merge(&self, smp: SmpHalf<R>, devs: Vec<Option<DevHalf<R>>>) -> HybridOutcome<R> {
        let smp = match smp {
            Ok(v) => v,
            // the SMP share panicked: propagate the payload to join()
            Err(p) => return Err(p),
        };
        // panicked device shares fold into the failure path of the shared
        // merge exactly like the hybrid latch's device half
        let devs: Vec<Option<anyhow::Result<DeviceShare<R>>>> = devs
            .into_iter()
            .map(|slot| {
                slot.map(|outcome| match outcome {
                    Ok(r) => r,
                    Err(_panic) => Err(anyhow::anyhow!("sharded device share panicked")),
                })
            })
            .collect();
        let m = ShardedMerge {
            sched: &self.sched,
            input: &self.input,
            smp_span: self.smp_span,
            dev_spans: &self.dev_spans,
            profiles: &self.profiles,
            weights: &self.weights,
            nparts: self.smp_parts,
        };
        Ok(Ok(self.method.finish_sharded(m, smp, devs)))
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// The runtime engine: worker pool + rules + scheduler + optional device
/// fleet (see the module docs for the four lanes).
pub struct Engine {
    workers: usize,
    rules: Rules,
    // Arc so the xla parallel-kernel runner can hold the pool alive past
    // the engine's lifetime (the runner is a process-wide install)
    pool: Arc<WorkerPool>,
    scheduler: Arc<Scheduler>,
    /// The device fleet: one master thread + warm sessions per lane
    /// (empty = no device lanes attached).
    device: Vec<DeviceLane>,
    /// Remote cluster peers, as sharded lanes after the device fleet
    /// (empty = single-host engine).
    remote: Vec<RemoteLane>,
    auto_profile: String,
    /// The invocation span recorder (disabled by default; `SOMD_TRACE`).
    tracer: Arc<TraceRecorder>,
    /// The unified metrics registry every lane feeds.
    hub: Arc<MetricsHub>,
}

impl Engine {
    /// `workers` is the default MI count per invocation (paper: one per
    /// available processor unless overridden at deployment time).
    pub fn new(workers: usize) -> Self {
        Self::with_rules(workers, Rules::empty())
    }

    /// An engine with explicit version-selection rules (§6).
    pub fn with_rules(workers: usize, rules: Rules) -> Self {
        let workers = workers.max(1);
        Self {
            workers,
            rules,
            pool: Arc::new(WorkerPool::new(workers)),
            scheduler: Arc::new(Scheduler::new(SchedulerConfig::default())),
            device: Vec::new(),
            remote: Vec::new(),
            auto_profile: "fermi".to_string(),
            tracer: Arc::new(TraceRecorder::from_env()),
            hub: Arc::new(MetricsHub::new()),
        }
    }

    /// Default engine: one MI per available core.
    pub fn default_for_host() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(cores)
    }

    /// Attach a single-lane device fleet: spawns one master thread, which
    /// loads the artifact registry from `artifacts_dir` and keeps warm
    /// sessions.  `auto_profile` is the device profile `Target::Auto`
    /// (and the hybrid lane) resolves to.  Kept as the two-lane entry
    /// point — it is exactly [`Engine::with_device_fleet`] over one
    /// profile, and every pre-fleet caller keeps its behavior.
    pub fn with_device_master(
        self,
        artifacts_dir: impl Into<PathBuf>,
        auto_profile: &str,
    ) -> anyhow::Result<Self> {
        self.with_device_fleet(artifacts_dir, &[auto_profile])
    }

    /// Attach a **device fleet**: one master thread + warm
    /// [`DeviceSession`] per configured profile, heterogeneous mixes
    /// (`fermi` + `geforce320m`, …) allowed — the same profile may even
    /// appear twice to model two identical cards.  The first profile is
    /// the fleet's *auto profile* (what `Target::Auto` and the two-way
    /// hybrid lane resolve to).  Whole-invocation device jobs dispatch to
    /// the least-loaded matching lane; `Target::Sharded` splits one
    /// invocation across SMP and *every* lane at the scheduler's learned
    /// per-lane weights.
    pub fn with_device_fleet(
        mut self,
        artifacts_dir: impl Into<PathBuf>,
        profiles: &[&str],
    ) -> anyhow::Result<Self> {
        if profiles.is_empty() {
            anyhow::bail!("a device fleet needs at least one profile");
        }
        let mut static_names = Vec::with_capacity(profiles.len());
        for p in profiles {
            match DeviceProfile::by_name(p) {
                Some(prof) => static_names.push(prof.name),
                None => anyhow::bail!("unknown device profile '{p}'"),
            }
        }
        // Route the compiled interpreter's chunked kernels through this
        // engine's worker pool: device-lane kernels then compete for the
        // same SMP workers as shared-memory invocations (§6).  Process-
        // wide and first-engine-wins; the Arc keeps the pool's threads
        // alive for later engines that lose the install race.  Safe from
        // nested-submission deadlock because kernels only ever run on
        // device-master threads, never on pool workers, and chunk jobs
        // themselves never re-submit.
        let pool = self.pool.clone();
        xla::install_parallel_runner(Box::new(move |jobs: Vec<xla::ParallelJob>| {
            let handles: Vec<_> = jobs.into_iter().map(|j| pool.submit(j)).collect();
            for h in handles {
                h.join();
            }
        }));
        let dir: PathBuf = artifacts_dir.into();
        let mut lanes = Vec::with_capacity(profiles.len());
        for (i, p) in profiles.iter().enumerate() {
            lanes.push(DeviceLane {
                master: DeviceMaster::spawn(dir.clone(), i)?,
                profile: p.to_string(),
                static_name: static_names[i],
            });
        }
        self.device = lanes;
        self.auto_profile = profiles[0].to_string();
        Ok(self)
    }

    /// The fleet profiles named by `SOMD_FLEET_PROFILES` (comma-separated
    /// profile tokens; default `fermi,geforce320m` — the paper's two §7.3
    /// systems side by side).  Companion knob:
    /// [`Engine::fleet_min_device_items_from_env`].  Both are documented
    /// in `docs/BENCHMARKS.md`'s knob table.
    pub fn fleet_profiles_from_env() -> Vec<String> {
        match std::env::var("SOMD_FLEET_PROFILES") {
            Ok(v) if !v.trim().is_empty() => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
            _ => vec!["fermi".to_string(), "geforce320m".to_string()],
        }
    }

    /// The `SOMD_FLEET_MIN_DEVICE_ITEMS` override for the scheduler's
    /// `min_device_items` floor (the smallest index-space share a fleet
    /// lane may receive before it is starved back into the SMP share);
    /// `None` when unset or unparsable.
    pub fn fleet_min_device_items_from_env() -> Option<usize> {
        std::env::var("SOMD_FLEET_MIN_DEVICE_ITEMS").ok().and_then(|v| v.parse().ok())
    }

    /// Attach **remote cluster peers** with the `SOMD_CLUSTER_*` timing
    /// knobs from the environment: connects (and handshakes) to each
    /// `host:port` address, registering every peer as a sharded lane
    /// after the device fleet.  A method shards across the remote lanes
    /// when it carries a [`ClusterSpec`](crate::backend::ClusterSpec)
    /// (the wire codecs) in addition to its hybrid spec; spans sent to a
    /// peer that dies or misses its deadline are covered by SMP partials
    /// in place, with the sharded-failure penalty — exactly like a
    /// failed device lane.  See `docs/CLUSTER.md`.
    pub fn with_cluster_peers(self, addrs: &[String]) -> anyhow::Result<Self> {
        self.with_cluster_peers_cfg(addrs, ClusterConfig::from_env())
    }

    /// [`Engine::with_cluster_peers`] with explicit timing knobs.
    pub fn with_cluster_peers_cfg(
        mut self,
        addrs: &[String],
        cfg: ClusterConfig,
    ) -> anyhow::Result<Self> {
        if addrs.is_empty() {
            anyhow::bail!("a cluster fleet needs at least one peer address");
        }
        for addr in addrs {
            let client = ClusterClient::connect(addr, cfg)?;
            let static_name: &'static str =
                Box::leak(format!("tcp://{addr}").into_boxed_str());
            self.remote.push(RemoteLane { client: Arc::new(client), static_name });
        }
        Ok(self)
    }

    /// The peer addresses named by `SOMD_CLUSTER_PEERS` (comma-separated
    /// `host:port` tokens; empty when unset) — the deployment-time way to
    /// grow an engine past one box.
    pub fn cluster_peers_from_env() -> Vec<String> {
        match std::env::var("SOMD_CLUSTER_PEERS") {
            Ok(v) => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Remote-lane count of the attached cluster fleet (0 = single host).
    pub fn remote_lane_count(&self) -> usize {
        self.remote.len()
    }

    /// The report label of each remote lane (`tcp://<addr>`), in lane
    /// order after the device fleet.
    pub fn remote_lane_names(&self) -> Vec<&'static str> {
        self.remote.iter().map(|l| l.static_name).collect()
    }

    /// The cluster clients behind the remote lanes, in lane order (the
    /// network bench pings RTT percentiles through these).
    pub fn remote_clients(&self) -> Vec<Arc<ClusterClient>> {
        self.remote.iter().map(|l| l.client.clone()).collect()
    }

    /// Replace the scheduler (e.g. restored from persisted JSON history,
    /// or configured with non-default hybrid tunables).
    pub fn with_scheduler(mut self, scheduler: Scheduler) -> Self {
        self.scheduler = Arc::new(scheduler);
        self
    }

    /// Replace the span recorder (the env-configured default records
    /// nothing unless `SOMD_TRACE` is truthy) — how tests and the `somd
    /// trace` subcommand turn tracing on for one engine.
    pub fn with_tracer(mut self, tracer: TraceRecorder) -> Self {
        self.tracer = Arc::new(tracer);
        self
    }

    /// The invocation span recorder.
    pub fn tracer(&self) -> &Arc<TraceRecorder> {
        &self.tracer
    }

    /// The unified metrics hub every lane feeds.
    pub fn hub(&self) -> &Arc<MetricsHub> {
        &self.hub
    }

    /// Render every retained trace in `format` (Chrome-trace JSON or
    /// JSONL) — see `docs/OBSERVABILITY.md` for the formats.
    pub fn export_trace(&self, format: TraceFormat) -> String {
        let traces = self.tracer.traces();
        match format {
            TraceFormat::Chrome => chrome_trace(&traces),
            TraceFormat::Jsonl => jsonl(&traces),
        }
    }

    /// Point-in-time metrics: the hub's own series plus the per-lane
    /// warm-session/upload counters folded in as `somd_device_lane_*`
    /// gauges — one snapshot covering every layer below the caller.
    pub fn metrics_snapshot(&self) -> HubSnapshot {
        let mut s = self.hub.snapshot();
        for (i, c) in self.device_lane_counters().iter().enumerate() {
            let lane = |name: &str| format!("{name}{{lane=\"{i}\"}}");
            s.counters.insert(lane("somd_device_lane_jobs_total"), c.jobs_run as u64);
            s.counters.insert(lane("somd_device_lane_warm_hits_total"), c.warm_hits as u64);
            s.counters
                .insert(lane("somd_device_lane_sessions_created_total"), c.sessions_created as u64);
            s.counters.insert(lane("somd_device_lane_uploads_total"), c.uploads as u64);
            s.counters.insert(lane("somd_device_lane_upload_hits_total"), c.upload_hits as u64);
        }
        s
    }

    /// The default MI count per invocation.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The engine's version-selection rules.
    pub fn rules(&self) -> &Rules {
        &self.rules
    }

    /// The execution-history store driving `Target::Auto`.
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Whether any device lane is up (master thread + registry loaded).
    pub fn device_ready(&self) -> bool {
        !self.device.is_empty()
    }

    /// Device-lane count of the attached fleet (0 = no fleet).
    pub fn fleet_size(&self) -> usize {
        self.device.len()
    }

    /// The configured profile of each fleet lane, in `device_id` order.
    pub fn device_lane_profiles(&self) -> Vec<&str> {
        self.device.iter().map(|l| l.profile.as_str()).collect()
    }

    /// Jobs submitted-but-unfinished per fleet lane, in `device_id`
    /// order — the signal least-loaded dispatch reads.
    pub fn device_lane_pending(&self) -> Vec<usize> {
        self.device.iter().map(|l| l.master.pending()).collect()
    }

    /// Run `f` on the device master of fleet lane `lane`, blocking until
    /// it completes.  The pipeline layer pins a plan's device stages to
    /// *one* lane through this entry: the lane's warm sessions — and with
    /// them resident [`crate::device::BufId`]s and the upload memo —
    /// survive across jobs (FIFO per lane), which is what lets stage
    /// `i+1` consume stage `i`'s output without a host round-trip.
    pub fn run_on_lane<T, F>(&self, lane: usize, f: F) -> anyhow::Result<T>
    where
        T: Send + 'static,
        F: for<'r> FnOnce(&mut DeviceCtx<'r>) -> T + Send + 'static,
    {
        let l = self.device.get(lane).ok_or_else(|| {
            anyhow::anyhow!("no device lane {lane} (fleet size {})", self.device.len())
        })?;
        let (tx, rx) = mpsc::channel();
        l.master.submit(Box::new(move |ctx| {
            let _ = tx.send(f(ctx));
        }));
        // a panicking job drops `tx` without sending (the master's
        // catch_unwind keeps the lane alive); surface that as an error
        rx.recv().map_err(|_| anyhow::anyhow!("device lane {lane} job panicked"))
    }

    /// The profile `Target::Auto` and the hybrid lane resolve to when the
    /// device side participates.
    pub fn auto_profile(&self) -> &str {
        &self.auto_profile
    }

    /// Warm-session counters summed over the whole fleet, if any lane is
    /// attached (the pre-fleet aggregate view; per-lane counters via
    /// [`Engine::device_lane_counters`]).
    pub fn device_counters(&self) -> Option<DeviceCountersSnapshot> {
        if self.device.is_empty() {
            return None;
        }
        let mut total = DeviceCountersSnapshot {
            sessions_created: 0,
            warm_hits: 0,
            jobs_run: 0,
            uploads: 0,
            upload_hits: 0,
            upload_invalidations: 0,
        };
        for l in &self.device {
            let s = l.master.counters.snapshot();
            total.sessions_created += s.sessions_created;
            total.warm_hits += s.warm_hits;
            total.jobs_run += s.jobs_run;
            total.uploads += s.uploads;
            total.upload_hits += s.upload_hits;
            total.upload_invalidations += s.upload_invalidations;
        }
        Some(total)
    }

    /// Warm-session counters per fleet lane, in `device_id` order.
    pub fn device_lane_counters(&self) -> Vec<DeviceCountersSnapshot> {
        self.device.iter().map(|l| l.master.counters.snapshot()).collect()
    }

    /// The least-loaded lane able to serve `profile`: among lanes
    /// *configured* with that profile when any exist, otherwise among the
    /// whole fleet (any master can warm a session for any known profile —
    /// the pre-fleet single-master behavior, preserved for rules that
    /// name an unconfigured profile).  Ties break toward the lower
    /// `device_id`, deterministically (a strict-improvement scan —
    /// `Iterator::min_by_key` keeps the *last* of equal minima, which
    /// would make tie-breaking depend on fleet order reversal).
    fn pick_lane(&self, profile: &str) -> Option<&DeviceLane> {
        fn least_loaded<'a>(
            mut lanes: impl Iterator<Item = &'a DeviceLane>,
        ) -> Option<&'a DeviceLane> {
            let mut best = lanes.next()?;
            let mut best_pending = best.master.pending();
            for l in lanes {
                let p = l.master.pending();
                if p < best_pending {
                    best = l;
                    best_pending = p;
                }
            }
            Some(best)
        }
        least_loaded(self.device.iter().filter(|l| l.profile == profile))
            .or_else(|| least_loaded(self.device.iter()))
    }

    /// Block until every device job submitted so far has *executed*: a
    /// barrier job round-trips the master thread's FIFO queue, so when
    /// this returns, no previously queued device work is still pending.
    ///
    /// [`Engine::drop`](Drop) runs the same barrier first, which is the
    /// shutdown-hardening contract: queued device jobs (including hybrid
    /// device halves whose completion latch still needs the worker pool)
    /// complete while every engine resource is provably alive, instead
    /// of racing the master thread's channel-drain against field
    /// teardown.  The serving layer also calls this on drain, after its
    /// dispatchers have joined, to make shutdown deterministic end to
    /// end.  No-op without a device lane.
    pub fn drain(&self) {
        // barrier every lane first, then wait — the fleet flushes in
        // parallel instead of serializing lane by lane
        let mut waits = Vec::with_capacity(self.device.len());
        for lane in &self.device {
            let (tx, rx) = mpsc::channel::<()>();
            let barrier: DeviceJob = Box::new(move |_ctx: &mut DeviceCtx<'_>| {
                let _ = tx.send(());
            });
            // the pending count must rise before the barrier can run and
            // fall, or the counter would underflow on a fast master
            lane.master.pending.fetch_add(1, Ordering::SeqCst);
            // tolerate a master thread that already died (it never does
            // under normal operation — jobs are panic-caught — but a
            // drain must not turn an exotic failure into a double panic)
            let sent =
                lane.master.tx.as_ref().map(|t| t.send(barrier).is_ok()).unwrap_or(false);
            if sent {
                waits.push(rx);
            } else {
                lane.master.pending.fetch_sub(1, Ordering::SeqCst);
            }
        }
        for rx in waits {
            let _ = rx.recv();
        }
    }

    /// The architecture the rules select for `method` (§6); device targets
    /// are resolved by the caller against the available device profiles
    /// and revert to SMP when inapplicable.
    pub fn target_for(&self, method: &str) -> Target {
        self.rules.target_for(method)
    }

    /// The shared §6 + Auto resolution: rules first, then applicability,
    /// then — for `auto` — the history cost model.  `applicable(profile)`
    /// reports whether a device version could actually run on the named
    /// profile in the *caller's* context (submission lane vs caller-held
    /// registry), `hybrid_applicable` whether the method could co-execute
    /// there (hybrid spec present + registry/lane reachable), and
    /// `sharded_lanes` how many fleet lanes an N-way shard could span (0
    /// = sharding unreachable, e.g. the synchronous caller-driven path) —
    /// the only parts that differ between entry points.  `auto` considers
    /// the hybrid lane only when both flags hold, and replaces the hybrid
    /// rung with the sharded one on fleets of two or more lanes; a forced
    /// `Target::Hybrid` reverts to SMP when inapplicable, and a forced
    /// `Target::Sharded` steps down to hybrid, then SMP — the §6
    /// nearest-applicable discipline.
    pub fn resolve_target(
        &self,
        method: &str,
        applicable: &dyn Fn(&str) -> bool,
        hybrid_applicable: bool,
        sharded_lanes: usize,
    ) -> Target {
        self.resolve_target_items(method, applicable, hybrid_applicable, sharded_lanes, None)
    }

    /// [`Engine::resolve_target`] with the invocation's index-space item
    /// count when the caller knows it: `auto` then consults the
    /// scheduler's *per-size* ladder (see
    /// [`Scheduler::decide_sized`](crate::somd::Scheduler::decide_sized)),
    /// so one method can settle on different lanes for different input
    /// sizes.  Unsized callers keep the all-sizes behavior.
    fn resolve_target_items(
        &self,
        method: &str,
        applicable: &dyn Fn(&str) -> bool,
        hybrid_applicable: bool,
        sharded_lanes: usize,
        items: Option<u64>,
    ) -> Target {
        self.resolve_target_items_explained(
            method,
            applicable,
            hybrid_applicable,
            sharded_lanes,
            items,
        )
        .0
    }

    /// [`Engine::resolve_target_items`] plus the scheduler's
    /// [`DecisionExplain`] — the payload the `resolve` span is annotated
    /// with.  When the rules said `auto` and the cost model actually ran
    /// the payload is the ladder's; rule-forced targets carry a
    /// read-only [`Scheduler::explain_forced`] payload instead (reason
    /// `rule-forced`, estimates and incumbent from the same history the
    /// ladder would have consulted, no hysteresis state touched).
    fn resolve_target_items_explained(
        &self,
        method: &str,
        applicable: &dyn Fn(&str) -> bool,
        hybrid_applicable: bool,
        sharded_lanes: usize,
        items: Option<u64>,
    ) -> (Target, Option<DecisionExplain>) {
        let forced = |choice: Choice| Some(self.scheduler.explain_forced(method, choice, items));
        match self.rules.target_for(method) {
            Target::Device(name) => {
                if applicable(&name) {
                    (Target::Device(name), forced(Choice::Device))
                } else {
                    (Target::Smp, forced(Choice::Smp))
                }
            }
            Target::Hybrid => {
                if hybrid_applicable {
                    let device_fraction = self.scheduler.hybrid_fraction(method);
                    (Target::Hybrid, forced(Choice::Hybrid { device_fraction }))
                } else {
                    (Target::Smp, forced(Choice::Smp))
                }
            }
            Target::Sharded => {
                if sharded_lanes >= 1 {
                    (Target::Sharded, forced(Choice::Sharded { lanes: sharded_lanes }))
                } else if hybrid_applicable {
                    let device_fraction = self.scheduler.hybrid_fraction(method);
                    (Target::Hybrid, forced(Choice::Hybrid { device_fraction }))
                } else {
                    (Target::Smp, forced(Choice::Smp))
                }
            }
            Target::Auto => {
                if applicable(&self.auto_profile) {
                    if sharded_lanes >= 2 {
                        let ex =
                            self.scheduler.decide_sharded_explained(method, sharded_lanes, items);
                        let t = match ex.choice {
                            Choice::Device => Target::Device(self.auto_profile.clone()),
                            Choice::Smp => Target::Smp,
                            Choice::Hybrid { .. } => Target::Hybrid,
                            Choice::Sharded { .. } => Target::Sharded,
                        };
                        (t, Some(ex))
                    } else if hybrid_applicable {
                        let ex = self.scheduler.decide_hybrid_explained(method, items);
                        let t = match ex.choice {
                            Choice::Device => Target::Device(self.auto_profile.clone()),
                            Choice::Smp => Target::Smp,
                            Choice::Hybrid { .. } => Target::Hybrid,
                            // decide_hybrid never proposes a shard; a
                            // sharded incumbent restored from a fleet
                            // snapshot runs as the two-way split here
                            Choice::Sharded { .. } => Target::Hybrid,
                        };
                        (t, Some(ex))
                    } else {
                        let ex = self.scheduler.decide_explained(method, items);
                        let t = match ex.choice {
                            Choice::Device => Target::Device(self.auto_profile.clone()),
                            _ => Target::Smp,
                        };
                        (t, Some(ex))
                    }
                } else {
                    // `auto` with no applicable device: no ladder ran and
                    // no rule forced the lane, so there is nothing to
                    // explain.
                    (Target::Smp, None)
                }
            }
            // only `Target::Smp` remains: an explicit rules-table SMP pin
            Target::Smp => (Target::Smp, forced(Choice::Smp)),
        }
    }

    /// Submission-time resolution against the engine's own device fleet,
    /// for methods without a hybrid spec (kept for the plain two-lane
    /// callers and tests; [`Engine::submit_hetero`] resolves with the
    /// method's full capability set).
    pub fn resolve_submit(&self, method: &str, has_device_version: bool) -> Target {
        self.resolve_target(
            method,
            &|profile: &str| {
                has_device_version
                    && !self.device.is_empty()
                    && DeviceProfile::by_name(profile).is_some()
            },
            false,
            0,
        )
    }

    /// Full submission-time resolution for a [`HeteroMethod`];
    /// `items` is the invocation's index-space size when the method can
    /// report one, keying `auto`'s per-size ladder.
    fn resolve_for_submit<I, P, E, R>(
        &self,
        method: &HeteroMethod<I, P, E, R>,
        items: Option<u64>,
    ) -> (Target, Option<DecisionExplain>)
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        let hybrid_ok = method.has_hybrid_version()
            && !self.device.is_empty()
            && DeviceProfile::by_name(&self.auto_profile).is_some();
        // sharding spans the whole device fleet through the same hybrid
        // spec, plus every live remote peer when the method carries the
        // wire codecs (a dead peer stops counting toward resolution; a
        // span sent to one that dies later is covered by SMP partials)
        let cluster_ok = method.has_hybrid_version()
            && method.has_cluster_version()
            && self.remote.iter().any(|l| l.client.is_alive());
        let mut sharded_lanes = if hybrid_ok { self.device.len() } else { 0 };
        if cluster_ok {
            sharded_lanes += self.remote.len();
        }
        self.resolve_target_items_explained(
            method.name(),
            &|profile: &str| {
                method.has_device_version()
                    && !self.device.is_empty()
                    && DeviceProfile::by_name(profile).is_some()
            },
            hybrid_ok,
            sharded_lanes,
            items,
        )
    }

    /// Synchronous SOMD invocation with the engine's default MI count.
    pub fn invoke<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        let t0 = Instant::now();
        let r = method.invoke(input, self.workers);
        self.scheduler.record_smp(method.name(), t0.elapsed());
        r
    }

    /// Synchronous invocation with an explicit MI count.
    pub fn invoke_with(&self, nparts: usize) -> InvokeWith<'_> {
        InvokeWith { _engine: self, nparts }
    }

    /// Asynchronous submission: the invocation competes for the pool with
    /// other concurrently submitted SOMD requests (§6).
    pub fn submit<I, P, E, R>(
        &self,
        method: Arc<SomdMethod<I, P, E, R>>,
        input: Arc<I>,
    ) -> JobHandle<R>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        let n = self.workers;
        let sched = self.scheduler.clone();
        self.pool.submit(move || {
            let t0 = Instant::now();
            let r = method.invoke(&input, n);
            sched.record_smp(method.name(), t0.elapsed());
            r
        })
    }

    /// Asynchronous *multi-version* submission: resolves the target at
    /// submission time (rules → applicability → history for `auto`),
    /// queues device work on the master thread, SMP work on the pool, and
    /// hybrid work on *both* (forked at the learned split ratio, joined
    /// by a completion latch), and feeds observed timings back into the
    /// scheduler history.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use std::sync::Arc;
    /// use somd::backend::{Executed, HeteroMethod};
    /// use somd::somd::partition::Block1D;
    /// use somd::somd::reduction::Assemble;
    /// use somd::somd::{Engine, Rules, SomdMethod, Target};
    ///
    /// let mut rules = Rules::empty();
    /// rules.set("VecAdd.add", Target::Auto);
    /// let engine = Engine::with_rules(4, rules)
    ///     .with_device_master("artifacts", "fermi")?;
    ///
    /// let method = Arc::new(HeteroMethod::smp_only(SomdMethod::new(
    ///     "VecAdd.add",
    ///     |inp: &(Vec<f32>, Vec<f32>), n| Block1D::new().ranges(inp.0.len(), n),
    ///     |_, _| (),
    ///     |inp, p, _, _| p.own.iter().map(|i| inp.0[i] + inp.1[i]).collect::<Vec<f32>>(),
    ///     Assemble,
    /// )));
    /// let input = Arc::new((vec![1.0f32; 1024], vec![2.0f32; 1024]));
    /// let (out, how) = engine.submit_hetero(method, input).join()?;
    /// assert_eq!(out[0], 3.0);
    /// assert!(matches!(how, Executed::Smp { .. } | Executed::Device { .. }));
    /// # anyhow::Ok(())
    /// ```
    pub fn submit_hetero<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        self.submit_hetero_in(method, input, None)
    }

    /// [`Engine::submit_hetero`] nested under an existing span: `parent`
    /// (e.g. the serving layer's `serve.batch` span) becomes the
    /// invocation root's parent, so a fused dispatch's lane spans land in
    /// the batch's trace instead of opening their own.  `None` starts a
    /// fresh trace — exactly `submit_hetero`.
    pub fn submit_hetero_in<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        parent: Option<SpanRef>,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        // size the invocation when the method can report it — `auto` then
        // resolves per size bucket, and the lane records below land in
        // the matching bucket
        let items = method.has_hybrid_version().then(|| method.hybrid_items(&input) as u64);
        let tctx = match parent {
            Some(p) => self.tracer.join(p.trace),
            None => self.tracer.begin(),
        };
        let mut root = tctx.span("invoke", parent.map(|p| p.span));
        root.field_str("method", method.name());
        if let Some(it) = items {
            root.field_u64("items", it);
        }
        // the resolve span times the actual rules + cost-model pass and
        // carries its decision-explain payload
        let mut rspan = tctx.span("resolve", Some(root.id()));
        let (target, explain) = self.resolve_for_submit(method.as_ref(), items);
        rspan.field_str("target", target_label(&target));
        if let Some(ex) = &explain {
            rspan.field_str("choice", ex.choice_name());
            rspan.field_str("reason", ex.reason);
            rspan.field_f64("hysteresis", ex.hysteresis);
            if let Some(v) = ex.smp_est {
                rspan.field_f64("smp_est_secs", v);
            }
            if let Some(v) = ex.device_est {
                rspan.field_f64("device_est_secs", v);
            }
            if let Some(v) = ex.hybrid_est {
                rspan.field_f64("hybrid_est_secs", v);
            }
            if let Some(v) = ex.sharded_est {
                rspan.field_f64("sharded_est_secs", v);
            }
            if let Some(inc) = &ex.incumbent {
                rspan.field_str("incumbent", choice_name(inc));
            }
            if let Some(b) = ex.bucket {
                rspan.field_u64("size_bucket", b as u64);
            }
        }
        rspan.finish();
        root.field_str("target", target_label(&target));
        self.hub.counter_add(
            &format!(
                "somd_invocations_total{{method=\"{}\",lane=\"{}\"}}",
                method.name(),
                target_label(&target)
            ),
            1,
        );
        match target {
            Target::Device(profile) => {
                // least-loaded dispatch: concurrent whole-invocation jobs
                // (the serving layer's independent batches above all)
                // spread across the fleet instead of queuing on one lane
                let lane = self.pick_lane(&profile).expect("resolved device lane");
                let sched = self.scheduler.clone();
                let hub = self.hub.clone();
                let (tx, handle) = JobHandle::pair();
                let enqueued = Instant::now();
                let job: DeviceJob = Box::new(move |ctx: &mut DeviceCtx<'_>| {
                    let wait = enqueued.elapsed();
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_device_job(
                            method.as_ref(),
                            &profile,
                            ctx,
                            input.as_ref(),
                            &sched,
                            &tctx,
                            root.id(),
                            wait,
                            &hub,
                        )
                    }));
                    // the invocation's root span ends with its only lane
                    // job — closed before the caller's handle resolves
                    drop(root);
                    let _ = tx.send(result);
                });
                lane.master.submit(job);
                handle
            }
            Target::Hybrid => self.submit_hybrid(method, input, tctx, root),
            Target::Sharded => self.submit_sharded(method, input, tctx, root),
            // Auto resolves to Smp before reaching here when inapplicable
            _ => self.submit_smp_full(method, input, Degraded::No, tctx, root),
        }
    }

    /// [`Engine::submit_hetero`] for a *fused* invocation the serving
    /// layer coalesced out of `batch_requests` client requests: records
    /// the batch occupancy (requests + fused item count) into the
    /// scheduler history before submitting, so reports can tell
    /// coalesced traffic from singleton calls, then runs through the
    /// ordinary lane resolution — the launch's wall/stats samples feed
    /// lane and ratio learning exactly like any other invocation, now
    /// denominated in fused index spaces.
    pub fn submit_hetero_batched<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        batch_requests: usize,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        if method.has_batch_version() {
            let items = method.batch_items(&input);
            self.scheduler.record_batch(method.name(), batch_requests, items);
        }
        self.submit_hetero(method, input)
    }

    /// [`Engine::submit_hetero_batched`] nested under an existing span —
    /// the serving layer parents each fused dispatch's invocation trace
    /// under its `serve.batch` span through this entry.
    pub fn submit_hetero_batched_in<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        batch_requests: usize,
        parent: Option<SpanRef>,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        if method.has_batch_version() {
            let items = method.batch_items(&input);
            self.scheduler.record_batch(method.name(), batch_requests, items);
        }
        self.submit_hetero_in(method, input, parent)
    }

    /// The pure-SMP submission path.  A `Degraded` marker notes a
    /// co-execution resolution whose device share(s) underflowed the
    /// minimum chunk: the wall is then also recorded as a (degraded)
    /// hybrid or sharded sample so the scheduler's exploration rung
    /// completes instead of re-resolving co-execution forever on inputs
    /// too small to split.
    fn submit_smp_full<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        degraded: Degraded,
        tctx: TraceCtx,
        root: OpenSpan,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        let n = self.workers;
        let sched = self.scheduler.clone();
        let hub = self.hub.clone();
        self.pool.submit(move || {
            let mut span = tctx.span("lane.smp", Some(root.id()));
            match degraded {
                Degraded::No => {}
                Degraded::Hybrid => span.field_str("degraded", "hybrid"),
                Degraded::Sharded => span.field_str("degraded", "sharded"),
            }
            let items = method.has_hybrid_version().then(|| method.hybrid_items(&input) as u64);
            let t0 = Instant::now();
            let r = method.smp.invoke(&input, n);
            let wall = t0.elapsed();
            span.field_f64("execute_secs", wall.as_secs_f64());
            span.field_u64("partitions", n as u64);
            span.finish();
            hub.observe(
                &format!(
                    "somd_lane_execute_seconds{{method=\"{}\",lane=\"smp\"}}",
                    method.name()
                ),
                wall.as_secs_f64(),
            );
            match items {
                Some(it) => sched.record_smp_sized(method.name(), wall, it),
                None => sched.record_smp(method.name(), wall),
            }
            match (degraded, items) {
                (Degraded::No, _) => {}
                (Degraded::Hybrid, Some(it)) => {
                    sched.record_hybrid_degraded_sized(method.name(), wall, it)
                }
                (Degraded::Hybrid, None) => sched.record_hybrid_degraded(method.name(), wall),
                (Degraded::Sharded, Some(it)) => {
                    sched.record_sharded_degraded_sized(method.name(), wall, it)
                }
                (Degraded::Sharded, None) => sched.record_sharded_degraded(method.name(), wall),
            }
            // the root span closes before the caller's handle resolves
            drop(root);
            Ok((r, Executed::Smp { partitions: n }))
        })
    }

    /// Fork one invocation across both lanes (see the module docs): the
    /// SMP share becomes a pool job, the device share a master-thread
    /// job, and whichever finishes second releases the completion latch
    /// that merges the partials and resolves the caller's handle.
    fn submit_hybrid<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        tctx: TraceCtx,
        root: OpenSpan,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        let total = method.hybrid_items(&input);
        let fraction = self.scheduler.hybrid_fraction_sized(method.name(), total as u64);
        let (smp_span, dev_span) = split_fraction(total, fraction);
        if dev_span.is_empty() || dev_span.len() < self.scheduler.config().min_device_items {
            // the device share underflows the minimum chunk: co-execution
            // would be pure overhead, run the whole invocation on SMP
            return self.submit_smp_full(method, input, Degraded::Hybrid, tctx, root);
        }
        {
            let mut pspan = tctx.span("partition", Some(root.id()));
            pspan.field_f64("device_fraction", fraction);
            pspan.field_u64("smp_items", smp_span.len() as u64);
            pspan.field_u64("device_items", dev_span.len() as u64);
        }
        let (tx, handle) = JobHandle::pair();
        let root_span = root.id();
        let shared = Arc::new(HybridInFlight {
            method,
            input,
            sched: self.scheduler.clone(),
            profile: self.auto_profile.clone(),
            smp_span,
            dev_span,
            fraction,
            smp_parts: self.workers,
            tx,
            slots: Mutex::new(HybridSlots { smp: None, dev: None }),
            tctx,
            root_span,
            root: Mutex::new(Some(root)),
            hub: self.hub.clone(),
            enqueued: Instant::now(),
        });
        let dev_shared = shared.clone();
        let job: DeviceJob = Box::new(move |ctx: &mut DeviceCtx<'_>| {
            dev_shared.run_device_half(ctx);
        });
        // the hybrid device half belongs on the auto profile's
        // least-loaded lane
        self.pick_lane(&self.auto_profile).expect("resolved hybrid lane").master.submit(job);
        self.pool.submit(move || shared.run_smp_half());
        handle
    }

    /// Shard one invocation across the SMP pool and *every* fleet lane
    /// (see the module docs): the index space splits at the scheduler's
    /// learned per-lane weights under the `min_device_items` floor —
    /// starved lanes fold back into the SMP share — the SMP share becomes
    /// a pool job, each live device span a job on its own master thread,
    /// and the last share to finish releases the N-way completion latch
    /// that merges the partials and resolves the caller's handle.
    fn submit_sharded<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
        tctx: TraceCtx,
        root: OpenSpan,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        // lane order: device fleet first, then remote peers — remote
        // lanes only count when the method carries the wire codecs
        let dlanes = self.device.len();
        let rlanes = if method.has_cluster_version() { self.remote.len() } else { 0 };
        let lanes = dlanes + rlanes;
        debug_assert!(lanes >= 1, "sharded resolution without any lane");
        let total = method.hybrid_items(&input);
        let weights = self.scheduler.sharded_weights_sized(method.name(), lanes, total as u64);
        let spans =
            split_weighted_floor(total, &weights, self.scheduler.config().min_device_items);
        let smp_span = spans[0];
        let lane_spans: Vec<Range1> = spans[1..].to_vec();
        if lane_spans.iter().all(|s| s.is_empty()) {
            // every lane's share starved under the floor: co-execution
            // would be pure overhead, run the whole invocation on SMP
            return self.submit_smp_full(method, input, Degraded::Sharded, tctx, root);
        }
        let live = lane_spans.iter().filter(|s| !s.is_empty()).count();
        {
            let mut pspan = tctx.span("partition", Some(root.id()));
            pspan.field_u64("smp_items", smp_span.len() as u64);
            pspan.field_u64("lanes", lanes as u64);
            pspan.field_u64("live_lanes", live as u64);
        }
        let mut profiles: Vec<&'static str> =
            self.device.iter().map(|l| l.static_name).collect();
        profiles.extend(self.remote.iter().take(rlanes).map(|l| l.static_name));
        let (tx, handle) = JobHandle::pair();
        let root_span = root.id();
        let shared = Arc::new(ShardedInFlight {
            method,
            input,
            sched: self.scheduler.clone(),
            smp_span,
            dev_spans: lane_spans.clone(),
            profiles,
            weights,
            smp_parts: self.workers,
            tx,
            slots: Mutex::new(ShardSlots {
                smp: None,
                devs: (0..lanes).map(|_| None).collect(),
                remaining: live + 1,
            }),
            tctx,
            root_span,
            root: Mutex::new(Some(root)),
            hub: self.hub.clone(),
            enqueued: Instant::now(),
        });
        for (i, lane) in self.device.iter().enumerate() {
            if lane_spans[i].is_empty() {
                continue; // starved: its items live in the SMP span now
            }
            let dev_shared = shared.clone();
            let job: DeviceJob = Box::new(move |ctx: &mut DeviceCtx<'_>| {
                dev_shared.run_device_shard(i, ctx);
            });
            lane.master.submit(job);
        }
        for (k, lane) in self.remote.iter().take(rlanes).enumerate() {
            let i = dlanes + k;
            let span = lane_spans[i];
            if span.is_empty() {
                continue; // starved: its items live in the SMP span now
            }
            // encode on the submitting thread (the scatter of §4.2);
            // the callback lands on the client's reader thread with the
            // peer's partial — or the failure the SMP side then covers
            let payload = shared.method.cluster_encode_span(&shared.input, span);
            let remote_shared = shared.clone();
            let profile = lane.static_name;
            let t0 = Instant::now();
            let cb: RemoteCallback = Box::new(move |res| {
                remote_shared.finish_remote_shard(i, profile, t0, res);
            });
            // the trace id rides the wire so the peer's execute span
            // stitches into this invocation's trace
            if let Err(e) = lane.client.submit_traced(
                shared.method.name(),
                span,
                payload,
                cb,
                shared.tctx.trace_id(),
            ) {
                // nothing was sent and the callback never fires: fail the
                // lane's slot here so the merge covers its span
                shared.fill_lane_slot(i, Ok(Err(e)));
            }
        }
        self.pool.submit(move || shared.run_smp_shard());
        handle
    }
}

/// Which co-execution lane a pure-SMP run stands in for (see
/// [`Engine::submit_smp_full`]).
#[derive(Clone, Copy)]
enum Degraded {
    /// A plain SMP resolution — nothing degraded.
    No,
    /// A hybrid resolution whose device share underflowed the floor.
    Hybrid,
    /// A sharded resolution all of whose device shares underflowed.
    Sharded,
}

impl Drop for Engine {
    /// Deterministic shutdown: flush the device-master queue (see
    /// [`Engine::drain`]) while the pool, scheduler and master are all
    /// still alive, so no in-flight device job — and no hybrid latch
    /// depending on one — is left racing the field-by-field teardown
    /// that follows.
    fn drop(&mut self) {
        self.drain();
    }
}

/// Attach the per-lane device execution payload (profile, clocks, the
/// transfer-byte accounting [`DeviceStats`] carries) to a `lane.device`
/// span.
fn annotate_device_span(
    span: &mut OpenSpan,
    profile: &'static str,
    secs: f64,
    stats: &DeviceStats,
) {
    span.field_str("profile", profile);
    span.field_f64("execute_secs", secs);
    span.field_u64("launches", stats.launches as u64);
    span.field_u64("bytes_h2d", stats.bytes_h2d as u64);
    span.field_u64("bytes_d2h", stats.bytes_d2h as u64);
    span.field_u64("transfers_skipped", stats.skipped_transfers() as u64);
    span.field_u64("bytes_skipped", stats.skipped_transfer_bytes() as u64);
}

/// Feed one device execution into the hub: the per-method execute
/// histogram, the queue-wait gauge, and the transfer-byte counters.
fn observe_device_execute(hub: &MetricsHub, method: &str, secs: f64, wait: Duration) {
    hub.observe(
        &format!("somd_lane_execute_seconds{{method=\"{method}\",lane=\"device\"}}"),
        secs,
    );
    hub.gauge_set("somd_device_queue_wait_seconds", wait.as_secs_f64());
    hub.observe("somd_device_queue_wait_seconds_window", wait.as_secs_f64());
}

/// One device job on the master thread: warm session in, stats delta out.
/// `wait` is the master-queue wait the submitting side clocked up to this
/// job's dequeue — recorded as a span field, a hub gauge and a scheduler
/// history window, but kept out of the measured execute time.
#[allow(clippy::too_many_arguments)]
fn run_device_job<I, P, E, R>(
    method: &HeteroMethod<I, P, E, R>,
    profile: &str,
    ctx: &mut DeviceCtx<'_>,
    input: &I,
    sched: &Scheduler,
    tctx: &TraceCtx,
    parent: u64,
    wait: Duration,
    hub: &MetricsHub,
) -> anyhow::Result<(R, Executed)>
where
    I: ?Sized + Sync,
    P: Send + Sync,
    E: Sync,
    R: Send,
{
    let mut span = tctx.span("lane.device", Some(parent));
    span.field_f64("queue_wait_secs", wait.as_secs_f64());
    // size the records when the method can report its item count, so
    // they land in the invocation's size bucket
    let items = method.has_hybrid_version().then(|| method.hybrid_items(input) as u64);
    if let Some(it) = items {
        span.field_u64("span_items", it);
    }
    let session = ctx.session(profile)?;
    let before = session.stats();
    // measured execute time: the clock starts after the job was dequeued
    // on the master thread, so queue wait never pollutes the history
    let t0 = Instant::now();
    let r = match method.invoke_on_session(session, input) {
        Ok(r) => r,
        Err(e) => {
            span.field_str("outcome", "failed");
            // a failing lane must still feed the cost model, or `auto`
            // would keep exploring the broken device forever
            match items {
                Some(it) => sched.record_device_failure_sized(method.name(), it),
                None => sched.record_device_failure(method.name()),
            }
            return Err(e);
        }
    };
    let measured = t0.elapsed();
    let mut stats = session.stats().delta_since(&before);
    stats.queue_wait = wait;
    match items {
        Some(it) => sched.record_device_sized(method.name(), measured, &stats, it),
        None => sched.record_device(method.name(), measured, &stats),
    }
    let profile_name = session.profile().name;
    annotate_device_span(&mut span, profile_name, measured.as_secs_f64(), &stats);
    observe_device_execute(hub, method.name(), measured.as_secs_f64(), wait);
    span.finish();
    Ok((r, Executed::Device { profile: profile_name, stats }))
}

/// Builder for a synchronous invocation with an explicit MI count.
pub struct InvokeWith<'a> {
    _engine: &'a Engine,
    nparts: usize,
}

impl InvokeWith<'_> {
    /// Invoke `method` with the configured MI count.
    pub fn call<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        method.invoke(input, self.nparts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::partition::Block1D;
    use crate::somd::reduction;

    fn sum_method() -> SomdMethod<Vec<i64>, crate::somd::partition::BlockPart, (), i64> {
        SomdMethod::new(
            "sum",
            |v: &Vec<i64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, p, _, _| p.own.iter().map(|i| v[i]).sum(),
            reduction::sum::<i64>(),
        )
    }

    #[test]
    fn engine_invokes_with_default_workers() {
        let e = Engine::new(4);
        let data: Vec<i64> = (0..100).collect();
        assert_eq!(e.invoke(&sum_method(), &data), 4950);
    }

    #[test]
    fn explicit_partition_count() {
        let e = Engine::new(2);
        let data: Vec<i64> = (1..=10).collect();
        assert_eq!(e.invoke_with(7).call(&sum_method(), &data), 55);
    }

    #[test]
    fn concurrent_submissions() {
        let e = Engine::new(3);
        let m = Arc::new(sum_method());
        let data = Arc::new((0..1000).collect::<Vec<i64>>());
        let handles: Vec<_> =
            (0..6).map(|_| e.submit(m.clone(), data.clone())).collect();
        for h in handles {
            assert_eq!(h.join(), 499_500);
        }
    }

    #[test]
    fn rules_select_target() {
        let mut rules = Rules::empty();
        rules.set("Series.coefficients", Target::Device("fermi".into()));
        let e = Engine::with_rules(2, rules);
        assert_eq!(e.target_for("Series.coefficients"), Target::Device("fermi".into()));
        assert_eq!(e.target_for("Crypt.encrypt"), Target::Smp);
    }

    #[test]
    fn invocations_feed_the_history_store() {
        let e = Engine::new(2);
        let data: Vec<i64> = (0..100).collect();
        e.invoke(&sum_method(), &data);
        let h = e.scheduler().history("sum").expect("history recorded");
        assert_eq!(h.smp_runs, 1);
        assert_eq!(h.smp_secs.len(), 1);
    }

    #[test]
    fn auto_without_device_lane_resolves_to_smp() {
        let mut rules = Rules::empty();
        rules.set("sum", Target::Auto);
        let e = Engine::with_rules(2, rules);
        assert_eq!(e.resolve_submit("sum", true), Target::Smp);
        assert_eq!(e.resolve_submit("sum", false), Target::Smp);
    }

    #[test]
    fn hybrid_rule_without_device_lane_resolves_to_smp() {
        let mut rules = Rules::empty();
        rules.set("sum", Target::Hybrid);
        let e = Engine::with_rules(2, rules);
        // no device master: even a hybrid-capable method reverts to SMP
        assert_eq!(e.resolve_target("sum", &|_| false, false, 0), Target::Smp);
    }

    #[test]
    fn sharded_rule_steps_down_the_applicability_ladder() {
        let mut rules = Rules::empty();
        rules.set("sum", Target::Sharded);
        let e = Engine::with_rules(2, rules);
        // no fleet, no hybrid: all the way down to SMP
        assert_eq!(e.resolve_target("sum", &|_| false, false, 0), Target::Smp);
        // hybrid reachable but no fleet lanes (the sync path): two-way
        assert_eq!(e.resolve_target("sum", &|_| true, true, 0), Target::Hybrid);
        // a fleet of any size runs the shard
        assert_eq!(e.resolve_target("sum", &|_| true, true, 1), Target::Sharded);
        assert_eq!(e.resolve_target("sum", &|_| true, true, 3), Target::Sharded);
    }

    #[test]
    fn auto_on_a_fleet_walks_the_sharded_ladder() {
        let mut rules = Rules::empty();
        rules.set("sum", Target::Auto);
        let e = Engine::with_rules(2, rules);
        // fresh history, 2-lane fleet: exploration starts at SMP
        assert_eq!(e.resolve_target("sum", &|_| true, true, 2), Target::Smp);
        e.scheduler().record_smp("sum", std::time::Duration::from_millis(5));
        e.scheduler().record_smp("sum", std::time::Duration::from_millis(5));
        assert_eq!(
            e.resolve_target("sum", &|_| true, true, 2),
            Target::Device("fermi".to_string())
        );
        e.scheduler().record_device(
            "sum",
            std::time::Duration::from_millis(5),
            &crate::device::DeviceStats::default(),
        );
        e.scheduler().record_device(
            "sum",
            std::time::Duration::from_millis(5),
            &crate::device::DeviceStats::default(),
        );
        // third rung on a multi-lane fleet is the N-way shard, not hybrid
        assert_eq!(e.resolve_target("sum", &|_| true, true, 2), Target::Sharded);
    }

    #[test]
    fn device_master_requires_known_profile() {
        let e = Engine::new(1);
        assert!(e.with_device_master("artifacts", "h100").is_err());
    }

    #[test]
    fn fleet_requires_known_profiles_and_at_least_one_lane() {
        assert!(Engine::new(1).with_device_fleet("artifacts", &[]).is_err());
        assert!(Engine::new(1).with_device_fleet("artifacts", &["fermi", "h100"]).is_err());
    }

    #[test]
    fn fleet_accessors_without_a_fleet() {
        let e = Engine::new(1);
        assert!(!e.device_ready());
        assert_eq!(e.fleet_size(), 0);
        assert!(e.device_lane_profiles().is_empty());
        assert!(e.device_lane_pending().is_empty());
        assert!(e.device_counters().is_none());
        assert!(e.device_lane_counters().is_empty());
    }
}
