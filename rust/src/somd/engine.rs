//! The Elina-like runtime engine (paper §6): owns the worker pool, the
//! version-selection rules, the adaptive scheduler and the invocation
//! entry points.
//!
//! Two execution lanes serve asynchronous submissions:
//!
//! * **SMP lane** — invocations compete for the [`WorkerPool`] exactly as
//!   in the paper's runtime;
//! * **device lane** — PJRT objects are `Rc`-confined, so all device work
//!   funnels through one *device master* thread that owns the
//!   [`Registry`] and a warm [`DeviceSession`] per profile.  Concurrent
//!   submissions to the same profile reuse the warm session instead of
//!   re-creating registry/session state per call (observable through
//!   [`DeviceCounters`]).
//!
//! Rules resolve per method as `smp | device(<profile>) | auto`; `auto`
//! defers to the [`Scheduler`]'s execution-history cost model.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::config::{Rules, Target};
use super::master::SomdMethod;
use super::pool::{JobHandle, WorkerPool};
use super::scheduler::{Choice, Scheduler, SchedulerConfig};
use crate::backend::{Executed, HeteroMethod};
use crate::device::{DeviceProfile, DeviceSession};
use crate::runtime::Registry;

// ---------------------------------------------------------------------------
// Device master thread
// ---------------------------------------------------------------------------

/// Warm-session accounting: evidence that concurrent device submissions
/// batch their setup instead of paying it per call.
#[derive(Debug, Default)]
pub struct DeviceCounters {
    sessions_created: AtomicUsize,
    warm_hits: AtomicUsize,
    jobs_run: AtomicUsize,
}

/// Point-in-time copy of [`DeviceCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceCountersSnapshot {
    /// Sessions constructed on the master thread (cold setups).
    pub sessions_created: usize,
    /// Jobs that found their profile's session already warm.
    pub warm_hits: usize,
    /// Total device jobs executed.
    pub jobs_run: usize,
}

impl DeviceCounters {
    fn snapshot(&self) -> DeviceCountersSnapshot {
        DeviceCountersSnapshot {
            sessions_created: self.sessions_created.load(Ordering::SeqCst),
            warm_hits: self.warm_hits.load(Ordering::SeqCst),
            jobs_run: self.jobs_run.load(Ordering::SeqCst),
        }
    }
}

/// The master thread's execution context: the registry plus one warm
/// session per device profile (both thread-confined).
pub struct DeviceCtx<'r> {
    registry: &'r Registry,
    sessions: BTreeMap<String, DeviceSession<'r>>,
    counters: Arc<DeviceCounters>,
}

impl<'r> DeviceCtx<'r> {
    pub fn registry(&self) -> &'r Registry {
        self.registry
    }

    /// The warm session for `profile`, created on first use.
    pub fn session(&mut self, profile: &str) -> anyhow::Result<&mut DeviceSession<'r>> {
        if self.sessions.contains_key(profile) {
            self.counters.warm_hits.fetch_add(1, Ordering::SeqCst);
        } else {
            let p = DeviceProfile::by_name(profile)
                .ok_or_else(|| anyhow::anyhow!("unknown device profile '{profile}'"))?;
            self.sessions.insert(profile.to_string(), DeviceSession::new(self.registry, p));
            self.counters.sessions_created.fetch_add(1, Ordering::SeqCst);
        }
        Ok(self.sessions.get_mut(profile).expect("session just ensured"))
    }
}

type DeviceJob = Box<dyn for<'r> FnOnce(&mut DeviceCtx<'r>) + Send>;

struct DeviceMaster {
    tx: Option<mpsc::Sender<DeviceJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
    counters: Arc<DeviceCounters>,
}

impl DeviceMaster {
    fn spawn(dir: PathBuf) -> anyhow::Result<DeviceMaster> {
        let counters = Arc::new(DeviceCounters::default());
        let (tx, rx) = mpsc::channel::<DeviceJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let thread_counters = counters.clone();
        let handle = std::thread::Builder::new()
            .name("somd-device-master".into())
            .spawn(move || master_loop(dir, rx, ready_tx, thread_counters))
            .expect("spawn device master thread");
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(DeviceMaster { tx: Some(tx), handle: Some(handle), counters }),
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(anyhow::anyhow!("device master failed to start: {e}"))
            }
            Err(_) => {
                let _ = handle.join();
                Err(anyhow::anyhow!("device master died during startup"))
            }
        }
    }

    fn submit(&self, job: DeviceJob) {
        self.tx
            .as_ref()
            .expect("device master channel open")
            .send(job)
            .expect("device master thread alive");
    }
}

impl Drop for DeviceMaster {
    fn drop(&mut self) {
        drop(self.tx.take()); // closing the channel ends the loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn master_loop(
    dir: PathBuf,
    rx: mpsc::Receiver<DeviceJob>,
    ready: mpsc::Sender<Result<(), String>>,
    counters: Arc<DeviceCounters>,
) {
    // the registry must be created on this thread (PJRT is Rc-confined)
    let registry = match Registry::load(&dir) {
        Ok(r) => {
            let _ = ready.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Pre-compile every artifact before serving jobs: lowering is a
    // one-time load cost, and charging it to the first job's *measured*
    // execute time would hand the scheduler an inflated first device
    // sample (which, with hysteresis, could lock a method out of the
    // device lane for good).  Missing/broken artifacts stay lazy errors.
    for name in registry.names().map(String::from).collect::<Vec<_>>() {
        let _ = registry.artifact(&name);
    }
    let mut ctx = DeviceCtx { registry: &registry, sessions: BTreeMap::new(), counters };
    while let Ok(job) = rx.recv() {
        ctx.counters.jobs_run.fetch_add(1, Ordering::SeqCst);
        // a panicking job must not take down the lane for queued peers
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&mut ctx)));
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

pub struct Engine {
    workers: usize,
    rules: Rules,
    // Arc so the xla parallel-kernel runner can hold the pool alive past
    // the engine's lifetime (the runner is a process-wide install)
    pool: Arc<WorkerPool>,
    scheduler: Arc<Scheduler>,
    device: Option<DeviceMaster>,
    auto_profile: String,
}

impl Engine {
    /// `workers` is the default MI count per invocation (paper: one per
    /// available processor unless overridden at deployment time).
    pub fn new(workers: usize) -> Self {
        Self::with_rules(workers, Rules::empty())
    }

    pub fn with_rules(workers: usize, rules: Rules) -> Self {
        let workers = workers.max(1);
        Self {
            workers,
            rules,
            pool: Arc::new(WorkerPool::new(workers)),
            scheduler: Arc::new(Scheduler::new(SchedulerConfig::default())),
            device: None,
            auto_profile: "fermi".to_string(),
        }
    }

    /// Default engine: one MI per available core.
    pub fn default_for_host() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(cores)
    }

    /// Attach the device lane: spawns the master thread, which loads the
    /// artifact registry from `artifacts_dir` and keeps warm sessions.
    /// `auto_profile` is the device profile `Target::Auto` resolves to.
    pub fn with_device_master(
        mut self,
        artifacts_dir: impl Into<PathBuf>,
        auto_profile: &str,
    ) -> anyhow::Result<Self> {
        if DeviceProfile::by_name(auto_profile).is_none() {
            anyhow::bail!("unknown device profile '{auto_profile}'");
        }
        // Route the compiled interpreter's chunked kernels through this
        // engine's worker pool: device-lane kernels then compete for the
        // same SMP workers as shared-memory invocations (§6).  Process-
        // wide and first-engine-wins; the Arc keeps the pool's threads
        // alive for later engines that lose the install race.  Safe from
        // nested-submission deadlock because kernels only ever run on the
        // device-master thread, never on pool workers, and chunk jobs
        // themselves never re-submit.
        let pool = self.pool.clone();
        xla::install_parallel_runner(Box::new(move |jobs: Vec<xla::ParallelJob>| {
            let handles: Vec<_> = jobs.into_iter().map(|j| pool.submit(j)).collect();
            for h in handles {
                h.join();
            }
        }));
        self.device = Some(DeviceMaster::spawn(artifacts_dir.into())?);
        self.auto_profile = auto_profile.to_string();
        Ok(self)
    }

    /// Replace the scheduler (e.g. restored from persisted JSON history).
    pub fn with_scheduler(mut self, scheduler: Scheduler) -> Self {
        self.scheduler = Arc::new(scheduler);
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn rules(&self) -> &Rules {
        &self.rules
    }

    /// The execution-history store driving `Target::Auto`.
    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    /// Whether the device lane is up (master thread + registry loaded).
    pub fn device_ready(&self) -> bool {
        self.device.is_some()
    }

    /// The profile `Target::Auto` resolves to when the device side wins.
    pub fn auto_profile(&self) -> &str {
        &self.auto_profile
    }

    /// Warm-session counters of the device lane, if attached.
    pub fn device_counters(&self) -> Option<DeviceCountersSnapshot> {
        self.device.as_ref().map(|d| d.counters.snapshot())
    }

    /// The architecture the rules select for `method` (§6); device targets
    /// are resolved by the caller against the available device profiles
    /// and revert to SMP when inapplicable.
    pub fn target_for(&self, method: &str) -> Target {
        self.rules.target_for(method)
    }

    /// The shared §6 + Auto resolution: rules first, then applicability,
    /// then — for `auto` — the history cost model.  `applicable(profile)`
    /// reports whether a device version could actually run on the named
    /// profile in the *caller's* context (submission lane vs caller-held
    /// registry) — the only part that differs between entry points.
    pub fn resolve_target(&self, method: &str, applicable: &dyn Fn(&str) -> bool) -> Target {
        match self.rules.target_for(method) {
            Target::Device(name) => {
                if applicable(&name) {
                    Target::Device(name)
                } else {
                    Target::Smp
                }
            }
            Target::Auto => {
                if applicable(&self.auto_profile) {
                    match self.scheduler.decide(method) {
                        Choice::Device => Target::Device(self.auto_profile.clone()),
                        Choice::Smp => Target::Smp,
                    }
                } else {
                    Target::Smp
                }
            }
            t => t,
        }
    }

    /// Submission-time resolution against the engine's own device lane.
    pub fn resolve_submit(&self, method: &str, has_device_version: bool) -> Target {
        self.resolve_target(method, &|profile: &str| {
            has_device_version
                && self.device.is_some()
                && DeviceProfile::by_name(profile).is_some()
        })
    }

    /// Synchronous SOMD invocation with the engine's default MI count.
    pub fn invoke<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        let t0 = Instant::now();
        let r = method.invoke(input, self.workers);
        self.scheduler.record_smp(method.name(), t0.elapsed());
        r
    }

    /// Synchronous invocation with an explicit MI count.
    pub fn invoke_with(&self, nparts: usize) -> InvokeWith<'_> {
        InvokeWith { _engine: self, nparts }
    }

    /// Asynchronous submission: the invocation competes for the pool with
    /// other concurrently submitted SOMD requests (§6).
    pub fn submit<I, P, E, R>(
        &self,
        method: Arc<SomdMethod<I, P, E, R>>,
        input: Arc<I>,
    ) -> JobHandle<R>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        let n = self.workers;
        let sched = self.scheduler.clone();
        self.pool.submit(move || {
            let t0 = Instant::now();
            let r = method.invoke(&input, n);
            sched.record_smp(method.name(), t0.elapsed());
            r
        })
    }

    /// Asynchronous *multi-version* submission: resolves the target at
    /// submission time (rules → applicability → history for `auto`),
    /// queues device work on the master thread and SMP work on the pool,
    /// and feeds observed timings back into the scheduler history.
    pub fn submit_hetero<I, P, E, R>(
        &self,
        method: Arc<HeteroMethod<I, P, E, R>>,
        input: Arc<I>,
    ) -> JobHandle<anyhow::Result<(R, Executed)>>
    where
        I: Send + Sync + 'static,
        P: Send + Sync + 'static,
        E: Sync + 'static,
        R: Send + 'static,
    {
        match self.resolve_submit(method.name(), method.has_device_version()) {
            Target::Device(profile) => {
                let sched = self.scheduler.clone();
                let (tx, handle) = JobHandle::pair();
                let job: DeviceJob = Box::new(move |ctx: &mut DeviceCtx<'_>| {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_device_job(method.as_ref(), &profile, ctx, input.as_ref(), &sched)
                    }));
                    let _ = tx.send(result);
                });
                self.device.as_ref().expect("resolved device lane").submit(job);
                handle
            }
            // Auto resolves to Smp before reaching here when inapplicable
            _ => {
                let n = self.workers;
                let sched = self.scheduler.clone();
                self.pool.submit(move || {
                    let t0 = Instant::now();
                    let r = method.smp.invoke(&input, n);
                    sched.record_smp(method.name(), t0.elapsed());
                    Ok((r, Executed::Smp { partitions: n }))
                })
            }
        }
    }
}

/// One device job on the master thread: warm session in, stats delta out.
fn run_device_job<I, P, E, R>(
    method: &HeteroMethod<I, P, E, R>,
    profile: &str,
    ctx: &mut DeviceCtx<'_>,
    input: &I,
    sched: &Scheduler,
) -> anyhow::Result<(R, Executed)>
where
    I: ?Sized + Sync,
    P: Send + Sync,
    E: Sync,
    R: Send,
{
    let session = ctx.session(profile)?;
    let before = session.stats();
    // measured execute time: the clock starts after the job was dequeued
    // on the master thread, so queue wait never pollutes the history
    let t0 = Instant::now();
    let r = match method.invoke_on_session(session, input) {
        Ok(r) => r,
        Err(e) => {
            // a failing lane must still feed the cost model, or `auto`
            // would keep exploring the broken device forever
            sched.record_device_failure(method.name());
            return Err(e);
        }
    };
    let measured = t0.elapsed();
    let stats = session.stats().delta_since(&before);
    sched.record_device(method.name(), measured, &stats);
    let profile_name = session.profile().name;
    Ok((r, Executed::Device { profile: profile_name, stats }))
}

pub struct InvokeWith<'a> {
    _engine: &'a Engine,
    nparts: usize,
}

impl InvokeWith<'_> {
    pub fn call<I, P, E, R>(&self, method: &SomdMethod<I, P, E, R>, input: &I) -> R
    where
        I: ?Sized + Sync,
        P: Send + Sync,
        E: Sync,
        R: Send,
    {
        method.invoke(input, self.nparts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::partition::Block1D;
    use crate::somd::reduction;

    fn sum_method() -> SomdMethod<Vec<i64>, crate::somd::partition::BlockPart, (), i64> {
        SomdMethod::new(
            "sum",
            |v: &Vec<i64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, p, _, _| p.own.iter().map(|i| v[i]).sum(),
            reduction::sum::<i64>(),
        )
    }

    #[test]
    fn engine_invokes_with_default_workers() {
        let e = Engine::new(4);
        let data: Vec<i64> = (0..100).collect();
        assert_eq!(e.invoke(&sum_method(), &data), 4950);
    }

    #[test]
    fn explicit_partition_count() {
        let e = Engine::new(2);
        let data: Vec<i64> = (1..=10).collect();
        assert_eq!(e.invoke_with(7).call(&sum_method(), &data), 55);
    }

    #[test]
    fn concurrent_submissions() {
        let e = Engine::new(3);
        let m = Arc::new(sum_method());
        let data = Arc::new((0..1000).collect::<Vec<i64>>());
        let handles: Vec<_> =
            (0..6).map(|_| e.submit(m.clone(), data.clone())).collect();
        for h in handles {
            assert_eq!(h.join(), 499_500);
        }
    }

    #[test]
    fn rules_select_target() {
        let mut rules = Rules::empty();
        rules.set("Series.coefficients", Target::Device("fermi".into()));
        let e = Engine::with_rules(2, rules);
        assert_eq!(e.target_for("Series.coefficients"), Target::Device("fermi".into()));
        assert_eq!(e.target_for("Crypt.encrypt"), Target::Smp);
    }

    #[test]
    fn invocations_feed_the_history_store() {
        let e = Engine::new(2);
        let data: Vec<i64> = (0..100).collect();
        e.invoke(&sum_method(), &data);
        let h = e.scheduler().history("sum").expect("history recorded");
        assert_eq!(h.smp_runs, 1);
        assert_eq!(h.smp_secs.len(), 1);
    }

    #[test]
    fn auto_without_device_lane_resolves_to_smp() {
        let mut rules = Rules::empty();
        rules.set("sum", Target::Auto);
        let e = Engine::with_rules(2, rules);
        assert_eq!(e.resolve_submit("sum", true), Target::Smp);
        assert_eq!(e.resolve_submit("sum", false), Target::Smp);
    }

    #[test]
    fn device_master_requires_known_profile() {
        let e = Engine::new(1);
        assert!(e.with_device_master("artifacts", "h100").is_err());
    }
}
