//! Distribution strategies (the paper's `dist` qualifier, §3.1).
//!
//! A [`Distribution`] maps a value of type `T` to a list of partitions of
//! the *same* logical type (`T -> List<T>` in the paper).  On shared
//! memory the built-in array strategies are **copy-free**: they produce
//! index ranges over the original data (§4.1), optionally widened by a
//! halo [`View`] (`dist(view = <1,1>,<1,1>)`, §3.1 "Shared Array
//! Positions").

/// Half-open index range `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range1 {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Exclusive upper bound.
    pub hi: usize,
}

impl Range1 {
    /// `[lo, hi)`; panics when `lo > hi`.
    pub fn new(lo: usize, hi: usize) -> Self {
        assert!(lo <= hi, "invalid range [{lo}, {hi})");
        Self { lo, hi }
    }

    /// Number of indexes covered.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Whether the range covers no indexes.
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// Iterate the covered indexes.
    pub fn iter(&self) -> std::ops::Range<usize> {
        self.lo..self.hi
    }

    /// Widen by a halo view, clamped to `[0, bound)` — the MI's *readable*
    /// window (Figure 4a).
    pub fn with_view(&self, view: View, bound: usize) -> Range1 {
        Range1 { lo: self.lo.saturating_sub(view.before), hi: (self.hi + view.after).min(bound) }
    }

    /// Intersect with explicit loop bounds `[e1, e2)` — the max/min loop
    /// boundary translation of §5.1.
    pub fn clamp(&self, e1: usize, e2: usize) -> Range1 {
        let lo = self.lo.max(e1);
        let hi = self.hi.min(e2);
        Range1 { lo, hi: hi.max(lo) }
    }
}

/// Per-dimension halo: how many indexes beyond the partition boundary are
/// visible to the MI (paper `view = <before, after>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct View {
    /// Visible indexes before the partition's lower bound.
    pub before: usize,
    /// Visible indexes after the partition's upper bound.
    pub after: usize,
}

impl View {
    /// A symmetric halo of `k` indexes on both sides.
    pub fn sym(k: usize) -> View {
        View { before: k, after: k }
    }
}

/// 2-D partition: a row range and a column range (the default
/// (block, block) matrix distribution of §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range2 {
    /// The covered rows.
    pub rows: Range1,
    /// The covered columns.
    pub cols: Range1,
}

/// A partitioning strategy over values of type `T`.
///
/// `Part` is the partition *descriptor* handed to each MI; for the built-in
/// array strategies it is an index range (copy-free), for user strategies
/// (e.g. `TreeDist`) it may own data.
pub trait Distribution<T: ?Sized>: Send + Sync {
    /// The partition descriptor handed to each MI.
    type Part: Send;

    /// Split `value` into exactly `n` partitions (some possibly empty).
    fn distribute(&self, value: &T, n: usize) -> Vec<Self::Part>;
}

/// The paper's default `IndexPartitioner`: split `len` indexes into `n`
/// contiguous ranges, spreading the remainder over the leading ranges.
pub fn index_ranges(len: usize, n: usize) -> Vec<Range1> {
    assert!(n > 0);
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push(Range1::new(lo, lo + sz));
        lo += sz;
    }
    debug_assert_eq!(lo, len);
    out
}

/// Near-square process grid for the (block, block) 2-D distribution: the
/// factorization `n = pr * pc` minimizing `|pr - pc|`.
pub fn near_square_grid(n: usize) -> (usize, usize) {
    assert!(n > 0);
    let mut pr = (n as f64).sqrt() as usize;
    while pr > 1 && n % pr != 0 {
        pr -= 1;
    }
    (pr.max(1), n / pr.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_ranges_cover_exactly() {
        for len in [0, 1, 7, 100, 101] {
            for n in [1, 2, 3, 8] {
                let rs = index_ranges(len, n);
                assert_eq!(rs.len(), n);
                assert_eq!(rs[0].lo, 0);
                assert_eq!(rs.last().unwrap().hi, len);
                for w in rs.windows(2) {
                    assert_eq!(w[0].hi, w[1].lo);
                }
                let sizes: Vec<usize> = rs.iter().map(Range1::len).collect();
                let max = sizes.iter().max().unwrap();
                let min = sizes.iter().min().unwrap();
                assert!(max - min <= 1, "balanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn view_widens_and_clamps() {
        let r = Range1::new(10, 20);
        assert_eq!(r.with_view(View::sym(2), 100), Range1::new(8, 22));
        let edge = Range1::new(0, 5);
        assert_eq!(edge.with_view(View::sym(3), 6), Range1::new(0, 6));
    }

    #[test]
    fn clamp_is_max_min_translation() {
        let r = Range1::new(10, 20);
        assert_eq!(r.clamp(12, 30), Range1::new(12, 20));
        assert_eq!(r.clamp(0, 15), Range1::new(10, 15));
        // disjoint clamp yields an empty range, not a panic
        assert!(r.clamp(25, 30).is_empty());
    }

    #[test]
    fn near_square_grids() {
        assert_eq!(near_square_grid(1), (1, 1));
        assert_eq!(near_square_grid(4), (2, 2));
        assert_eq!(near_square_grid(6), (2, 3));
        assert_eq!(near_square_grid(8), (2, 4));
        assert_eq!(near_square_grid(7), (1, 7));
    }
}
