//! The DMR engine (paper Algorithm 1): distribute → map (MIs) → reduce.
//!
//! [`run_mis`] realizes the map stage: one scoped thread per MI, a shared
//! `fence` phaser for `sync` blocks, an [`Exchange`] for intermediate
//! reductions, and a rank-indexed results vector fed to the reduction —
//! exactly the compiled master/slave split of §5.1.  MIs of one invocation
//! are co-scheduled (scoped threads), so barrier-coupled groups cannot
//! deadlock on pool capacity.

use std::sync::Mutex;

use super::exchange::Exchange;
use super::mi::MiCtx;
use super::phaser::Phaser;
use super::reduction::Reduction;

/// Execute one MI per partition and return their results in rank order.
pub fn run_mis<I, P, E, R, F>(input: &I, parts: &[P], env: &E, body: &F) -> Vec<R>
where
    I: ?Sized + Sync,
    P: Send + Sync,
    E: Sync,
    R: Send,
    F: Fn(&I, &P, &E, &MiCtx) -> R + Sync,
{
    let n = parts.len();
    assert!(n > 0, "SOMD invocation with zero partitions");
    let fence = Phaser::new(n);
    let exchange = Exchange::new(n);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    if n == 1 {
        // Degenerate single-MI invocation: run inline (the master executing
        // its own MI, §4 "these roles may be mixed up").
        let ctx = MiCtx::new(0, 1, &fence, &exchange);
        let r = body(input, &parts[0], env, &ctx);
        return vec![r];
    }

    std::thread::scope(|s| {
        for (rank, part) in parts.iter().enumerate() {
            let fence = &fence;
            let exchange = &exchange;
            let results = &results;
            s.spawn(move || {
                let ctx = MiCtx::new(rank, n, fence, exchange);
                let r = body(input, part, env, &ctx);
                *results[rank].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("MI produced no result"))
        .collect()
}

/// A SOMD method: the paper's annotated subroutine, carried as data so the
/// engine can select among compiled versions (§6).
///
/// * `I` — the input dataset type (the method's parameters)
/// * `P` — the partition descriptor produced by the `dist` strategy
/// * `E` — the invocation environment (shared variables, shared arrays)
/// * `R` — the method's return type
pub struct SomdMethod<I: ?Sized, P, E, R> {
    name: String,
    partition: Box<dyn Fn(&I, usize) -> Vec<P> + Send + Sync>,
    env: Box<dyn Fn(&I, usize) -> E + Send + Sync>,
    body: Box<dyn Fn(&I, &P, &E, &MiCtx) -> R + Send + Sync>,
    reduce: Box<dyn Reduction<R>>,
}

impl<I: ?Sized + Sync, P: Send + Sync, E: Sync, R: Send> SomdMethod<I, P, E, R> {
    /// Assemble a method from its name, `dist` strategy, environment
    /// constructor, MI body and `reduce` strategy.
    pub fn new(
        name: impl Into<String>,
        partition: impl Fn(&I, usize) -> Vec<P> + Send + Sync + 'static,
        env: impl Fn(&I, usize) -> E + Send + Sync + 'static,
        body: impl Fn(&I, &P, &E, &MiCtx) -> R + Send + Sync + 'static,
        reduce: impl Reduction<R> + 'static,
    ) -> Self {
        Self {
            name: name.into(),
            partition: Box::new(partition),
            env: Box::new(env),
            body: Box::new(body),
            reduce: Box::new(reduce),
        }
    }

    /// The method's rules-file name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Synchronous SOMD invocation (Figure 1): distribute, map, reduce.
    pub fn invoke(&self, input: &I, nparts: usize) -> R {
        let parts = (self.partition)(input, nparts);
        let env = (self.env)(input, parts.len());
        let partials = run_mis(input, &parts, &env, &self.body);
        self.reduce.reduce(partials)
    }

    /// Distribute only (exposed for tests and the modeled executor).
    pub fn partitions(&self, input: &I, nparts: usize) -> Vec<P> {
        (self.partition)(input, nparts)
    }

    /// Run the map stage sequentially, one partition at a time, returning
    /// the partials and per-partition wall times.  This is the measurement
    /// core of the calibrated parallel model (DESIGN.md §3: 1-core host).
    pub fn map_sequential_timed(&self, input: &I, nparts: usize) -> (Vec<R>, Vec<std::time::Duration>) {
        let (partials, times, _) = self.map_sequential_timed_env(input, nparts);
        (partials, times)
    }

    /// [`Self::map_sequential_timed`] plus the environment-creation time
    /// (shared grids are allocated+copied by the master — a real part of
    /// the invocation cost the model must include).
    pub fn map_sequential_timed_env(
        &self,
        input: &I,
        nparts: usize,
    ) -> (Vec<R>, Vec<std::time::Duration>, std::time::Duration) {
        let parts = (self.partition)(input, nparts);
        let t0 = std::time::Instant::now();
        let env = (self.env)(input, parts.len());
        let t_env = t0.elapsed();
        let mut partials = Vec::with_capacity(parts.len());
        let mut times = Vec::with_capacity(parts.len());
        for (rank, part) in parts.iter().enumerate() {
            let fence = Phaser::new(1);
            let exchange = Exchange::new(1);
            let ctx = MiCtx::new(rank, 1, &fence, &exchange);
            let t0 = std::time::Instant::now();
            partials.push((self.body)(input, part, &env, &ctx));
            times.push(t0.elapsed());
        }
        (partials, times, t_env)
    }

    /// Apply the reduction to collected partials (rank order).
    pub fn reduce(&self, partials: Vec<R>) -> R {
        self.reduce.reduce(partials)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::distribution::Range1;
    use crate::somd::partition::Block1D;
    use crate::somd::reduction;

    fn sum_method() -> SomdMethod<Vec<f64>, crate::somd::partition::BlockPart, (), f64> {
        SomdMethod::new(
            "sum",
            |v: &Vec<f64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, part, _, _| part.own.iter().map(|i| v[i]).sum::<f64>(),
            reduction::sum::<f64>(),
        )
    }

    #[test]
    fn sum_matches_sequential_for_all_partition_counts() {
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let want: f64 = data.iter().sum();
        let m = sum_method();
        for n in [1, 2, 3, 7, 8] {
            assert_eq!(m.invoke(&data, n), want);
        }
    }

    #[test]
    fn results_are_rank_ordered() {
        let m = SomdMethod::new(
            "ranks",
            |len: &usize, n| Block1D::new().ranges(*len, n),
            |_, _| (),
            |_, _, _, ctx| ctx.rank(),
            reduction::FnReduce::new(|parts: Vec<usize>| {
                assert_eq!(parts, (0..parts.len()).collect::<Vec<_>>());
                parts.len()
            }),
        );
        assert_eq!(m.invoke(&100, 6), 6);
    }

    #[test]
    fn sync_blocks_align_mis() {
        // every MI increments a shared counter inside a sync block; after
        // the fence all MIs must observe all increments.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let m = SomdMethod::new(
            "syncy",
            |_: &(), n| (0..n).map(|i| Range1::new(i, i + 1)).collect::<Vec<_>>(),
            |_, n| Arc::new(AtomicUsize::new(n)),
            |_, _, env: &Arc<AtomicUsize>, ctx| {
                let n = ctx.parts();
                ctx.sync(|| {
                    env.fetch_add(1, Ordering::SeqCst);
                });
                let seen = env.load(Ordering::SeqCst);
                assert_eq!(seen, 2 * n);
                1usize
            },
            reduction::sum::<usize>(),
        );
        assert_eq!(m.invoke(&(), 8), 8);
    }

    #[test]
    fn map_sequential_matches_parallel() {
        let data: Vec<f64> = (0..500).map(|i| (i % 13) as f64).collect();
        let m = sum_method();
        let (partials, times) = m.map_sequential_timed(&data, 5);
        assert_eq!(times.len(), 5);
        assert_eq!(m.reduce(partials), m.invoke(&data, 5));
    }
}
