//! Binary-tree substrate for the `TreeDist` user-defined distribution
//! (paper Listings 11/12: counting the nodes of a tree in parallel).

use std::sync::Arc;

/// Immutable shareable binary tree (Arc-linked so partitions are cheap).
#[derive(Debug, Clone)]
pub enum Tree<A> {
    /// The empty tree.
    Nil,
    /// An interior node.
    Node {
        /// The node's payload.
        value: A,
        /// Left subtree.
        left: Arc<Tree<A>>,
        /// Right subtree.
        right: Arc<Tree<A>>,
    },
}

impl<A: Clone> Tree<A> {
    /// A single node with Nil children.
    pub fn leaf(value: A) -> Self {
        Tree::Node { value, left: Arc::new(Tree::Nil), right: Arc::new(Tree::Nil) }
    }

    /// A node over two subtrees.
    pub fn node(value: A, left: Tree<A>, right: Tree<A>) -> Self {
        Tree::Node { value, left: Arc::new(left), right: Arc::new(right) }
    }

    /// A full binary tree of the given depth (depth 0 = single node).
    pub fn full(depth: usize, value: A) -> Self {
        if depth == 0 {
            Tree::leaf(value)
        } else {
            let sub = Tree::full(depth - 1, value.clone());
            Tree::node(value, sub.clone(), sub)
        }
    }

    /// Whether this is the empty tree.
    pub fn is_nil(&self) -> bool {
        matches!(self, Tree::Nil)
    }

    /// The left subtree (Nil for Nil).
    pub fn left_or_nil(&self) -> Tree<A> {
        match self {
            Tree::Nil => Tree::Nil,
            Tree::Node { left, .. } => (**left).clone(),
        }
    }

    /// The right subtree (Nil for Nil).
    pub fn right_or_nil(&self) -> Tree<A> {
        match self {
            Tree::Nil => Tree::Nil,
            Tree::Node { right, .. } => (**right).clone(),
        }
    }

    /// Copy only the top `levels` levels (Listing 12's `tree.Copy(n)`):
    /// nodes below the cut become Nil, so the top partition's node count is
    /// disjoint from the subtree partitions.
    pub fn copy_top(&self, levels: usize) -> Tree<A> {
        match self {
            Tree::Nil => Tree::Nil,
            Tree::Node { value, left, right } => {
                if levels == 0 {
                    Tree::Nil
                } else {
                    Tree::Node {
                        value: value.clone(),
                        left: Arc::new(left.copy_top(levels - 1)),
                        right: Arc::new(right.copy_top(levels - 1)),
                    }
                }
            }
        }
    }

    /// Sequential node count (Listing 11's `countSize`).
    pub fn count(&self) -> usize {
        // iterative to survive deep, unbalanced trees
        let mut stack: Vec<&Tree<A>> = vec![self];
        let mut n = 0;
        while let Some(t) = stack.pop() {
            if let Tree::Node { left, right, .. } = t {
                n += 1;
                stack.push(left);
                stack.push(right);
            }
        }
        n
    }

    /// Build a random-ish unbalanced tree with exactly `n` nodes.
    pub fn with_nodes(n: usize, value: A, rng: &mut crate::util::prng::Xorshift64) -> Tree<A> {
        if n == 0 {
            return Tree::Nil;
        }
        let left_n = if n == 1 { 0 } else { rng.below(n - 1) };
        let right_n = n - 1 - left_n;
        Tree::node(
            value.clone(),
            Tree::with_nodes(left_n, value.clone(), rng),
            Tree::with_nodes(right_n, value, rng),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xorshift64;

    #[test]
    fn full_tree_count() {
        assert_eq!(Tree::full(0, 0).count(), 1);
        assert_eq!(Tree::full(3, 0).count(), 15);
    }

    #[test]
    fn copy_top_plus_subtrees_partition_count() {
        let t = Tree::full(4, 0); // 31 nodes
        let top = t.copy_top(2); // 3 nodes
        assert_eq!(top.count(), 3);
        let subs = [
            t.left_or_nil().left_or_nil(),
            t.left_or_nil().right_or_nil(),
            t.right_or_nil().left_or_nil(),
            t.right_or_nil().right_or_nil(),
        ];
        let total: usize = subs.iter().map(Tree::count).sum();
        assert_eq!(top.count() + total, 31);
    }

    #[test]
    fn with_nodes_exact() {
        let mut rng = Xorshift64::new(5);
        for n in [0, 1, 2, 17, 100] {
            assert_eq!(Tree::with_nodes(n, 0u8, &mut rng).count(), n);
        }
    }

    #[test]
    fn deep_tree_count_does_not_overflow_stack() {
        // degenerate left spine
        let mut t = Tree::leaf(0u8);
        for _ in 0..100_000 {
            t = Tree::Node {
                value: 0,
                left: Arc::new(t),
                right: Arc::new(Tree::Nil),
            };
        }
        assert_eq!(t.count(), 100_001);
        // drop iteratively to avoid recursive Drop blowing the stack
        std::mem::forget(t);
    }
}
