//! Runtime version-selection rules (paper §6): the user may force a target
//! per method with `Class.method:target_architecture` rules; inapplicable
//! preferences revert to the default (shared memory).

use std::collections::BTreeMap;

/// Where a SOMD method executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Target {
    /// Shared-memory thread pool (the default for stand-alone machines).
    Smp,
    /// Offload to a device profile (e.g. "fermi", "geforce320m").
    Device(String),
    /// Let the runtime decide from recorded execution history (the
    /// version-selection loop the paper leaves to the runtime — resolved
    /// per invocation by [`crate::somd::scheduler::Scheduler`]).  For
    /// methods with a hybrid spec this may resolve to [`Target::Hybrid`].
    Auto,
    /// Co-execute on both lanes at once: the invocation's index space is
    /// split between the SMP pool and the device at the scheduler's
    /// learned ratio.  Reverts to SMP when the method has no hybrid spec
    /// or no device lane is attached (§6 fallback discipline).
    Hybrid,
    /// Shard across the whole device fleet: the invocation's index space
    /// is split N-way — the SMP pool plus *every* attached device lane —
    /// at the scheduler's learned per-lane weights
    /// ([`crate::somd::scheduler::Scheduler::sharded_weights`]).  Reverts
    /// to hybrid on the synchronous (caller-driven) path, and to SMP when
    /// the method has no hybrid spec or no fleet is attached.
    Sharded,
}

/// Per-method `method:target` rules (paper §6), parsed from a rules file.
#[derive(Debug, Clone, Default)]
pub struct Rules {
    map: BTreeMap<String, Target>,
}

impl Rules {
    /// A rule set with no entries: every method defaults to SMP.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse `method:target` lines; `#` starts a comment; blank lines ok.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (method, target) = line
                .split_once(':')
                .ok_or_else(|| format!("line {}: expected 'method:target'", lineno + 1))?;
            let target = match target.trim() {
                "smp" | "cpu" | "shared" => Target::Smp,
                "auto" => Target::Auto,
                "hybrid" => Target::Hybrid,
                "sharded" | "fleet" => Target::Sharded,
                dev if !dev.is_empty() => Target::Device(dev.to_string()),
                _ => return Err(format!("line {}: empty target", lineno + 1)),
            };
            map.insert(method.trim().to_string(), target);
        }
        Ok(Self { map })
    }

    /// Read and parse a rules file from disk.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::parse(&text)
    }

    /// Set (or replace) the target for one method programmatically.
    pub fn set(&mut self, method: impl Into<String>, target: Target) {
        self.map.insert(method.into(), target);
    }

    /// The target for `method`; defaults to shared memory (§6).
    pub fn target_for(&self, method: &str) -> Target {
        self.map.get(method).cloned().unwrap_or(Target::Smp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_rules_with_comments() {
        let r = Rules::parse(
            "# force GPU for series\nSeries.coefficients:fermi\nCrypt.encrypt : smp\n",
        )
        .unwrap();
        assert_eq!(r.target_for("Series.coefficients"), Target::Device("fermi".into()));
        assert_eq!(r.target_for("Crypt.encrypt"), Target::Smp);
    }

    #[test]
    fn default_is_smp() {
        assert_eq!(Rules::empty().target_for("anything"), Target::Smp);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Rules::parse("no-colon-here").is_err());
    }

    #[test]
    fn parses_auto_target() {
        let r = Rules::parse("Series.coefficients:auto\n").unwrap();
        assert_eq!(r.target_for("Series.coefficients"), Target::Auto);
    }

    #[test]
    fn parses_hybrid_target() {
        let r = Rules::parse("Series.coefficients:hybrid  # co-execute\n").unwrap();
        assert_eq!(r.target_for("Series.coefficients"), Target::Hybrid);
    }

    #[test]
    fn parses_sharded_target() {
        let r = Rules::parse("Series.coefficients:sharded\nCrypt.cipher:fleet\n").unwrap();
        assert_eq!(r.target_for("Series.coefficients"), Target::Sharded);
        assert_eq!(r.target_for("Crypt.cipher"), Target::Sharded);
    }
}
