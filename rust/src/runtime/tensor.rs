//! Host-side tensors and dtype plumbing between the coordinator and PJRT
//! literals.

use anyhow::{anyhow, bail, Result};

/// Element types used by the artifact set (f32 device arithmetic mirrors
/// the paper's forced single precision on GPU; u32 carries IDEA words).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    S32,
    S64,
    U32,
}

impl DType {
    pub fn parse(tag: &str) -> Result<DType> {
        Ok(match tag {
            "f32" => DType::F32,
            "f64" => DType::F64,
            "s32" => DType::S32,
            "s64" => DType::S64,
            "u32" => DType::U32,
            other => bail!("unknown dtype tag '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::S32 | DType::U32 => 4,
            DType::F64 | DType::S64 => 8,
        }
    }
}

/// An owned host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    F64(Vec<f64>, Vec<usize>),
    S32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn vec_f32(v: Vec<f32>) -> Self {
        let n = v.len();
        HostTensor::F32(v, vec![n])
    }

    pub fn vec_u32(v: Vec<u32>) -> Self {
        let n = v.len();
        HostTensor::U32(v, vec![n])
    }

    pub fn vec_s32(v: Vec<i32>) -> Self {
        let n = v.len();
        HostTensor::S32(v, vec![n])
    }

    pub fn mat_f32(v: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(v.len(), rows * cols);
        HostTensor::F32(v, vec![rows, cols])
    }

    pub fn mat_u32(v: Vec<u32>, rows: usize, cols: usize) -> Self {
        assert_eq!(v.len(), rows * cols);
        HostTensor::U32(v, vec![rows, cols])
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::F64(..) => DType::F64,
            HostTensor::S32(..) => DType::S32,
            HostTensor::U32(..) => DType::U32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::F64(_, s) | HostTensor::S32(_, s)
            | HostTensor::U32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::F64(v, _) => v.len(),
            HostTensor::S32(v, _) => v.len(),
            HostTensor::U32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload size — the unit of the device transfer accounting.
    pub fn bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            HostTensor::U32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not u32")),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::S32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not s32")),
        }
    }

    /// Sum of all elements as f64 (checksum helper for the e2e driver).
    pub fn checksum(&self) -> f64 {
        match self {
            HostTensor::F32(v, _) => v.iter().map(|&x| x as f64).sum(),
            HostTensor::F64(v, _) => v.iter().sum(),
            HostTensor::S32(v, _) => v.iter().map(|&x| x as f64).sum(),
            HostTensor::U32(v, _) => v.iter().map(|&x| x as f64).sum(),
        }
    }

    /// Convert into a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::F64(v, _) => xla::Literal::vec1(v),
            HostTensor::S32(v, _) => xla::Literal::vec1(v),
            HostTensor::U32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a PJRT literal back to the host.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(match lit.ty()? {
            xla::ElementType::F32 => HostTensor::F32(lit.to_vec::<f32>()?, dims),
            xla::ElementType::F64 => HostTensor::F64(lit.to_vec::<f64>()?, dims),
            xla::ElementType::S32 => HostTensor::S32(lit.to_vec::<i32>()?, dims),
            xla::ElementType::U32 => HostTensor::U32(lit.to_vec::<u32>()?, dims),
            other => bail!("unsupported literal element type {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for tag in ["f32", "f64", "s32", "s64", "u32"] {
            assert!(DType::parse(tag).is_ok());
        }
        assert!(DType::parse("bf16").is_err());
    }

    #[test]
    fn bytes_accounting() {
        let t = HostTensor::mat_f32(vec![0.0; 12], 3, 4);
        assert_eq!(t.bytes(), 48);
        assert_eq!(t.shape(), &[3, 4]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::mat_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_u32() {
        let t = HostTensor::vec_u32(vec![7, 8, 9]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn checksum_sums() {
        assert_eq!(HostTensor::vec_s32(vec![1, 2, 3]).checksum(), 6.0);
    }
}
