//! Host-side tensors and dtype plumbing between the coordinator and PJRT
//! literals.

use anyhow::{anyhow, bail, Result};

/// Element types used by the artifact set (f32 device arithmetic mirrors
/// the paper's forced single precision on GPU; u32 carries IDEA words).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (the GPU arithmetic type).
    F32,
    /// 64-bit IEEE float (host-side substrate arithmetic).
    F64,
    /// 32-bit signed integer (index arrays).
    S32,
    /// 64-bit signed integer (manifest-only; no host tensor).
    S64,
    /// 32-bit unsigned integer (IDEA words).
    U32,
}

impl DType {
    /// Parse a manifest dtype tag (`"f32"`, `"u32"`, …).
    pub fn parse(tag: &str) -> Result<DType> {
        Ok(match tag {
            "f32" => DType::F32,
            "f64" => DType::F64,
            "s32" => DType::S32,
            "s64" => DType::S64,
            "u32" => DType::U32,
            other => bail!("unknown dtype tag '{other}'"),
        })
    }

    /// Bytes per element of this dtype.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::S32 | DType::U32 => 4,
            DType::F64 | DType::S64 => 8,
        }
    }
}

/// An owned host tensor (row-major): element payload + shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// f32 payload + shape.
    F32(Vec<f32>, Vec<usize>),
    /// f64 payload + shape.
    F64(Vec<f64>, Vec<usize>),
    /// i32 payload + shape.
    S32(Vec<i32>, Vec<usize>),
    /// u32 payload + shape.
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    /// A rank-0 f32 scalar.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    /// A rank-1 f32 vector.
    pub fn vec_f32(v: Vec<f32>) -> Self {
        let n = v.len();
        HostTensor::F32(v, vec![n])
    }

    /// A rank-1 u32 vector.
    pub fn vec_u32(v: Vec<u32>) -> Self {
        let n = v.len();
        HostTensor::U32(v, vec![n])
    }

    /// A rank-1 i32 vector.
    pub fn vec_s32(v: Vec<i32>) -> Self {
        let n = v.len();
        HostTensor::S32(v, vec![n])
    }

    /// A rank-2 row-major f32 matrix.
    pub fn mat_f32(v: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(v.len(), rows * cols);
        HostTensor::F32(v, vec![rows, cols])
    }

    /// A rank-2 row-major u32 matrix.
    pub fn mat_u32(v: Vec<u32>, rows: usize, cols: usize) -> Self {
        assert_eq!(v.len(), rows * cols);
        HostTensor::U32(v, vec![rows, cols])
    }

    /// This tensor's element type.
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::F64(..) => DType::F64,
            HostTensor::S32(..) => DType::S32,
            HostTensor::U32(..) => DType::U32,
        }
    }

    /// This tensor's shape (row-major dims; empty for a scalar).
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::F64(_, s) | HostTensor::S32(_, s)
            | HostTensor::U32(_, s) => s,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::F64(v, _) => v.len(),
            HostTensor::S32(v, _) => v.len(),
            HostTensor::U32(v, _) => v.len(),
        }
    }

    /// Whether the tensor holds zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slice rows `[lo, hi)` along the leading dimension into an owned
    /// tensor (the shape keeps its trailing dims; a rank-1 tensor slices
    /// elements).  This is the host-side half of the device backend's
    /// partial D2H download
    /// ([`DeviceSession::get_rows`](crate::device::DeviceSession::get_rows)),
    /// used by hybrid co-execution to fetch only the device's sub-range
    /// of an output.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<HostTensor> {
        let shape = self.shape();
        if shape.is_empty() {
            bail!("cannot row-slice a scalar tensor");
        }
        let rows = shape[0];
        if lo > hi || hi > rows {
            bail!("row slice [{lo}, {hi}) out of bounds for {rows} rows");
        }
        let per: usize = shape[1..].iter().product::<usize>().max(1);
        let mut new_shape = shape.to_vec();
        new_shape[0] = hi - lo;
        let (a, b) = (lo * per, hi * per);
        Ok(match self {
            HostTensor::F32(v, _) => HostTensor::F32(v[a..b].to_vec(), new_shape),
            HostTensor::F64(v, _) => HostTensor::F64(v[a..b].to_vec(), new_shape),
            HostTensor::S32(v, _) => HostTensor::S32(v[a..b].to_vec(), new_shape),
            HostTensor::U32(v, _) => HostTensor::U32(v[a..b].to_vec(), new_shape),
        })
    }

    /// Payload size — the unit of the device transfer accounting.
    pub fn bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    /// Borrow the payload as f32, erroring on other dtypes.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Borrow the payload as u32, erroring on other dtypes.
    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            HostTensor::U32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not u32")),
        }
    }

    /// Borrow the payload as i32, erroring on other dtypes.
    pub fn as_s32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::S32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not s32")),
        }
    }

    /// Sum of all elements as f64 (checksum helper for the e2e driver).
    pub fn checksum(&self) -> f64 {
        match self {
            HostTensor::F32(v, _) => v.iter().map(|&x| x as f64).sum(),
            HostTensor::F64(v, _) => v.iter().sum(),
            HostTensor::S32(v, _) => v.iter().map(|&x| x as f64).sum(),
            HostTensor::U32(v, _) => v.iter().map(|&x| x as f64).sum(),
        }
    }

    /// Convert into a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::F64(v, _) => xla::Literal::vec1(v),
            HostTensor::S32(v, _) => xla::Literal::vec1(v),
            HostTensor::U32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a PJRT literal back to the host.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(match lit.ty()? {
            xla::ElementType::F32 => HostTensor::F32(lit.to_vec::<f32>()?, dims),
            xla::ElementType::F64 => HostTensor::F64(lit.to_vec::<f64>()?, dims),
            xla::ElementType::S32 => HostTensor::S32(lit.to_vec::<i32>()?, dims),
            xla::ElementType::U32 => HostTensor::U32(lit.to_vec::<u32>()?, dims),
            other => bail!("unsupported literal element type {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for tag in ["f32", "f64", "s32", "s64", "u32"] {
            assert!(DType::parse(tag).is_ok());
        }
        assert!(DType::parse("bf16").is_err());
    }

    #[test]
    fn bytes_accounting() {
        let t = HostTensor::mat_f32(vec![0.0; 12], 3, 4);
        assert_eq!(t.bytes(), 48);
        assert_eq!(t.shape(), &[3, 4]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::mat_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_u32() {
        let t = HostTensor::vec_u32(vec![7, 8, 9]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn checksum_sums() {
        assert_eq!(HostTensor::vec_s32(vec![1, 2, 3]).checksum(), 6.0);
    }

    #[test]
    fn slice_rows_matrix_and_vector() {
        let m = HostTensor::mat_u32((0..12).collect(), 3, 4);
        let s = m.slice_rows(1, 3).unwrap();
        assert_eq!(s.shape(), &[2, 4]);
        assert_eq!(s.as_u32().unwrap(), &[4, 5, 6, 7, 8, 9, 10, 11]);
        let v = HostTensor::vec_f32(vec![0.0, 1.0, 2.0, 3.0]);
        let s = v.slice_rows(2, 4).unwrap();
        assert_eq!(s.shape(), &[2]);
        assert_eq!(s.as_f32().unwrap(), &[2.0, 3.0]);
        // degenerate and invalid slices
        assert_eq!(v.slice_rows(1, 1).unwrap().len(), 0);
        assert!(v.slice_rows(3, 5).is_err());
        assert!(HostTensor::scalar_f32(1.0).slice_rows(0, 1).is_err());
    }
}
