//! Thread-local PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and must not cross
//! threads; each thread that touches PJRT gets its own client lazily.
//! Compiled executables are likewise thread-confined (see
//! [`super::registry::Registry`]).

use std::cell::RefCell;
use std::mem::ManuallyDrop;
use std::sync::Mutex;

use anyhow::{Context, Result};

thread_local! {
    // ManuallyDrop: TfrtCpuClient teardown at thread exit races other
    // threads' PJRT state (observed SIGSEGV under `cargo test`); clients
    // live for the process lifetime instead.
    static CLIENT: RefCell<Option<ManuallyDrop<xla::PjRtClient>>> = const { RefCell::new(None) };
}

// Client *creation* is also serialized: concurrent TfrtCpuClient
// construction is not thread-safe in xla_extension 0.5.1.
static CREATE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with this thread's PJRT CPU client (created on first use).
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> Result<R>) -> Result<R> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let _guard = CREATE_LOCK.lock().unwrap();
            *slot = Some(ManuallyDrop::new(
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            ));
        }
        f(slot.as_ref().unwrap())
    })
}

/// Platform info string (used by `somd info`).
pub fn platform() -> Result<String> {
    with_client(|c| Ok(format!("{} ({} devices)", c.platform_name(), c.device_count())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_cpu() {
        let p = platform().unwrap();
        assert!(p.to_lowercase().contains("cpu"), "{p}");
    }

    #[test]
    fn client_reused_within_thread() {
        // second call must not fail (and should reuse the cached client)
        with_client(|_| Ok(())).unwrap();
        with_client(|_| Ok(())).unwrap();
    }
}
