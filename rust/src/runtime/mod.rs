//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the rust hot path.
//!
//! Python never runs here — `make artifacts` is the only compile-path step;
//! afterwards the binary is self-contained.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so every PJRT object is confined to the thread that created it; the
//! [`client`] module hands out a thread-local client, and the device
//! backend runs entirely on the master thread — which is exactly the
//! paper's host-side orchestration model (Algorithm 2).

pub mod client;
pub mod executable;
pub mod registry;
pub mod tensor;

pub use executable::{tensor_fingerprint, Artifact};
pub use registry::{ArtifactInfo, Registry, TensorSpec};
pub use tensor::{DType, HostTensor};
