//! A compiled AOT artifact: HLO text → PJRT executable → execution with
//! host tensors (literals) or device-resident buffers.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::client::with_client;
use super::registry::ArtifactInfo;
use super::tensor::HostTensor;

/// One compiled executable plus its manifest metadata.  Thread-confined
/// (PJRT objects are not `Send`).
pub struct Artifact {
    info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Parse HLO text and compile it on this thread's PJRT CPU client.
    ///
    /// HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
    /// 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see aot.py / DESIGN.md).
    pub fn compile(path: &Path, info: ArtifactInfo) -> Result<Artifact> {
        Self::compile_inner(path, info, None)
    }

    /// Like [`Artifact::compile`] but with elementwise fusion forced on
    /// or off regardless of `XLA_FUSE` — the bench and equivalence suite
    /// compare fused vs unfused schedules in one process through this.
    pub fn compile_with_fusion(path: &Path, info: ArtifactInfo, fuse: bool) -> Result<Artifact> {
        Self::compile_inner(path, info, Some(fuse))
    }

    fn compile_inner(path: &Path, info: ArtifactInfo, fuse: Option<bool>) -> Result<Artifact> {
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 artifact path {}", path.display()))?;
        // cached per thread: warm engine lanes re-open registries without
        // re-parsing artifact text
        let proto = xla::HloModuleProto::from_text_file_cached(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|c| {
            Ok(match fuse {
                None => c.compile(&comp)?,
                Some(fuse) => c.compile_with_fusion(&comp, fuse)?,
            })
        })
        .with_context(|| format!("compiling artifact '{}'", info.name))?;
        Ok(Artifact { info, exe })
    }

    /// Manifest metadata of this artifact.
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.info.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                got
            );
        }
        Ok(())
    }

    /// Execute with host tensors; returns host tensors (tuple outputs are
    /// flattened).  This path pays H2D+D2H conversion every call — the
    /// device backend uses [`Artifact::execute_buffers`] to keep data
    /// resident instead.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute_rows(inputs, None)
    }

    /// Execute on an explicit interpreter lane (naive tree-walker vs
    /// compiled bytecode) — the equivalence suite and the interp bench
    /// drive both lanes over the same inputs through this entry.
    pub fn execute_lane(&self, inputs: &[HostTensor], lane: xla::EvalLane) -> Result<Vec<HostTensor>> {
        self.execute_rows(inputs, Some(lane))
    }

    fn execute_rows(
        &self,
        inputs: &[HostTensor],
        lane: Option<xla::EvalLane>,
    ) -> Result<Vec<HostTensor>> {
        self.check_arity(inputs.len())?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
        let rows = match lane {
            None => self.exe.execute::<xla::Literal>(&literals)?,
            Some(lane) => self.exe.execute_lane::<xla::Literal>(&literals, lane)?,
        };
        let mut out = Vec::new();
        for buf in &rows[0] {
            let mut lit = buf.to_literal_sync()?;
            if lit.shape()?.is_tuple() {
                for el in lit.decompose_tuple()? {
                    out.push(HostTensor::from_literal(&el)?);
                }
            } else {
                out.push(HostTensor::from_literal(&lit)?);
            }
        }
        Ok(out)
    }

    /// Whether the artifact lowered to the compiled lane at load time.
    pub fn has_compiled_form(&self) -> bool {
        self.exe.has_compiled_form()
    }

    /// Lowered instruction count (None when only the naive lane exists).
    /// Under fusion this counts *dispatches* — a fused chain is one.
    pub fn compiled_instruction_count(&self) -> Option<usize> {
        self.exe.compiled_instruction_count()
    }

    /// Constituent instruction count (fused chains counted by their
    /// members); equals the unfused schedule's instruction count.
    pub fn compiled_constituent_count(&self) -> Option<usize> {
        self.exe.compiled_constituent_count()
    }

    /// Number of fused dispatch sites in the compiled schedule.
    pub fn fused_kernel_count(&self) -> Option<usize> {
        self.exe.fused_kernel_count()
    }

    /// Largest fused chain's constituent count (0 when nothing fused).
    pub fn max_fused_constituents(&self) -> Option<u64> {
        self.exe.max_fused_constituents()
    }

    /// Execute with device-resident buffers, producing device-resident
    /// outputs (no host roundtrip) — the Aparapi `setExplicit(true)` path
    /// the paper's SOR master uses to avoid per-iteration transfers.
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        self.check_arity(inputs.len())?;
        let mut rows = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let row = rows.remove(0);
        Ok(row)
    }

    /// Upload a host tensor to the device (explicit `put`).
    ///
    /// Uses the typed-slice path: `buffer_from_host_literal` aborts inside
    /// xla_extension 0.5.1 on literals produced by `reshape` (their shape
    /// carries no layout).
    pub fn put(t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let dims = t.shape().to_vec();
        with_client(|c| {
            Ok(match t {
                HostTensor::F32(v, _) => c.buffer_from_host_buffer(v, &dims, None)?,
                HostTensor::F64(v, _) => c.buffer_from_host_buffer(v, &dims, None)?,
                HostTensor::S32(v, _) => c.buffer_from_host_buffer(v, &dims, None)?,
                HostTensor::U32(v, _) => c.buffer_from_host_buffer(v, &dims, None)?,
            })
        })
    }

    /// Download a device buffer to the host (explicit `get`).
    pub fn get(buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }

    /// Download a device buffer that may hold a tuple (multi-output
    /// programs lower their root as a tuple even with return_tuple=False);
    /// returns the flattened leaves.
    pub fn get_all(buf: &xla::PjRtBuffer) -> Result<Vec<HostTensor>> {
        let mut lit = buf.to_literal_sync()?;
        if lit.shape()?.is_tuple() {
            lit.decompose_tuple()?.iter().map(HostTensor::from_literal).collect()
        } else {
            Ok(vec![HostTensor::from_literal(&lit)?])
        }
    }

    /// Download only rows `[lo, hi)` (leading dimension) of a non-tuple
    /// device buffer — the hybrid lane's partial `get`: the SMP side owns
    /// the rest of the index space, so fetching it would be wasted bus
    /// traffic.  The PJRT CPU client has no strided-copy entry, so this
    /// materializes the literal and slices host-side; the *accounted*
    /// transfer (what the device cost model charges) is the slice only —
    /// see [`DeviceSession::get_rows`](crate::device::DeviceSession::get_rows).
    pub fn get_rows(buf: &xla::PjRtBuffer, lo: usize, hi: usize) -> Result<HostTensor> {
        Self::get(buf)?.slice_rows(lo, hi)
    }
}

/// Content hash of a host tensor (FNV-1a over dtype tag, shape, and exact
/// payload bits) — the key of the pipeline layer's upload memo cache.  Two
/// tensors collide in the cache only when they are bitwise identical, so a
/// memoized upload can never serve stale device data: mutating the host
/// payload changes the fingerprint and forces a fresh `put`.
pub fn tensor_fingerprint(t: &HostTensor) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |word: u64| {
        for b in word.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
    };
    let (tag, shape) = match t {
        HostTensor::F32(_, s) => (0u64, s),
        HostTensor::F64(_, s) => (1, s),
        HostTensor::S32(_, s) => (2, s),
        HostTensor::U32(_, s) => (3, s),
    };
    eat(tag);
    eat(shape.len() as u64);
    for &d in shape {
        eat(d as u64);
    }
    match t {
        HostTensor::F32(v, _) => v.iter().for_each(|x| eat(u64::from(x.to_bits()))),
        HostTensor::F64(v, _) => v.iter().for_each(|x| eat(x.to_bits())),
        HostTensor::S32(v, _) => v.iter().for_each(|x| eat(*x as u32 as u64)),
        HostTensor::U32(v, _) => v.iter().for_each(|x| eat(u64::from(*x))),
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::Registry;

    fn reg() -> Registry {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Registry::load(dir).unwrap()
    }

    #[test]
    fn vecadd_executes_with_literals() {
        let r = reg();
        let a = r.artifact("vecadd").unwrap();
        let n = a.info().inputs[0].elems();
        let x = HostTensor::vec_f32((0..n).map(|i| i as f32).collect());
        let y = HostTensor::vec_f32(vec![1.0; n]);
        let out = a.execute(&[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].as_f32().unwrap();
        assert_eq!(v[0], 1.0);
        assert_eq!(v[n - 1], n as f32);
    }

    #[test]
    fn vecadd_executes_with_buffers() {
        let r = reg();
        let a = r.artifact("vecadd").unwrap();
        let n = a.info().inputs[0].elems();
        let x = Artifact::put(&HostTensor::vec_f32(vec![2.0; n])).unwrap();
        let y = Artifact::put(&HostTensor::vec_f32(vec![3.0; n])).unwrap();
        let out = a.execute_buffers(&[&x, &y]).unwrap();
        assert_eq!(out.len(), 1);
        let host = Artifact::get(&out[0]).unwrap();
        assert!(host.as_f32().unwrap().iter().all(|&v| v == 5.0));
    }

    #[test]
    fn arity_mismatch_rejected() {
        let r = reg();
        let a = r.artifact("vecadd").unwrap();
        assert!(a.execute(&[HostTensor::vec_f32(vec![1.0])]).is_err());
    }

    #[test]
    fn fingerprint_tracks_content_shape_and_dtype() {
        let a = HostTensor::vec_f32(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(tensor_fingerprint(&a), tensor_fingerprint(&a.clone()));
        // payload mutation changes the hash
        let mut b = vec![1.0f32, 2.0, 3.0, 4.0];
        b[2] = 3.5;
        assert_ne!(tensor_fingerprint(&a), tensor_fingerprint(&HostTensor::vec_f32(b)));
        // same bytes, different shape
        let flat = HostTensor::F32(vec![0.0; 4], vec![4]);
        let mat = HostTensor::F32(vec![0.0; 4], vec![2, 2]);
        assert_ne!(tensor_fingerprint(&flat), tensor_fingerprint(&mat));
        // same bit pattern, different dtype
        let s = HostTensor::vec_s32(vec![7, 8]);
        let u = HostTensor::vec_u32(vec![7, 8]);
        assert_ne!(tensor_fingerprint(&s), tensor_fingerprint(&u));
    }
}
