//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python -m compile.aot`) and lazily compiles executables on first use.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::executable::Artifact;
use super::tensor::DType;
use crate::util::json::Json;

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Row-major dims (empty for a scalar).
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Payload size in bytes.
    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = DType::parse(
            j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, shape })
    }
}

/// Manifest entry for one AOT-compiled executable.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Registry key (unique per manifest).
    pub name: String,
    /// HLO-text file name, relative to the registry dir.
    pub file: String,
    /// Input specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output specs, in result order.
    pub outputs: Vec<TensorSpec>,
    /// Free-form manifest metadata (bench tag, problem sizes, …).
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactInfo {
    /// A numeric metadata value (e.g. `blocks`, `n`, `chunk`).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }
}

/// The loaded manifest plus a per-thread compile cache.
pub struct Registry {
    dir: PathBuf,
    infos: BTreeMap<String, ArtifactInfo>,
    /// The workload scale the artifacts were lowered at (`aot.py --scale`).
    pub scale: f64,
    cache: RefCell<BTreeMap<String, Rc<Artifact>>>,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let scale = json.get("scale").and_then(Json::as_f64).unwrap_or(1.0);
        let mut infos = BTreeMap::new();
        for a in json.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact without name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact without file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let meta = match a.get("meta") {
                Some(Json::Obj(m)) => m.clone(),
                _ => BTreeMap::new(),
            };
            infos.insert(name.clone(), ArtifactInfo { name, file, inputs, outputs, meta });
        }
        Ok(Registry { dir, infos, scale, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Default location: `$SOMD_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Registry> {
        let dir = std::env::var("SOMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    /// The directory this registry was loaded from (what an
    /// [`Engine::with_device_fleet`](crate::somd::Engine::with_device_fleet)
    /// caller passes so every fleet lane loads the same artifacts).
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// Iterate the manifest's artifact names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.infos.keys().map(String::as_str)
    }

    /// Manifest metadata for `name`.
    pub fn info(&self, name: &str) -> Result<&ArtifactInfo> {
        self.infos.get(name).ok_or_else(|| {
            anyhow!("artifact '{name}' not in manifest (have: {:?})", self.infos.keys())
        })
    }

    /// Find an artifact by benchmark tag and a meta key/value (e.g. the
    /// crypt executable for a given block count).
    pub fn find_by_meta(&self, bench: &str, key: &str, val: usize) -> Option<&ArtifactInfo> {
        self.infos.values().find(|i| {
            i.meta.get("bench").and_then(Json::as_str) == Some(bench)
                && i.meta_usize(key) == Some(val)
        })
    }

    /// All artifacts tagged with a benchmark.
    pub fn by_bench(&self, bench: &str) -> Vec<&ArtifactInfo> {
        self.infos
            .values()
            .filter(|i| i.meta.get("bench").and_then(Json::as_str) == Some(bench))
            .collect()
    }

    /// Number of executables compiled and cached so far (warm-session
    /// observability: a reused registry keeps this monotone instead of
    /// recompiling per call).
    pub fn cached_artifacts(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let info = self.info(name)?.clone();
        let path = self.dir.join(&info.file);
        if !path.exists() {
            bail!("artifact file {} missing — run `make artifacts`", path.display());
        }
        let art = Rc::new(Artifact::compile(&path, info)?);
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Compile `name` with elementwise fusion forced on or off, ignoring
    /// `XLA_FUSE`.  Deliberately *uncached*: the fused-vs-unfused bench
    /// and equivalence suite need both schedules of one artifact alive
    /// at once, and must not poison the default cache with either.
    pub fn artifact_with_fusion(&self, name: &str, fuse: bool) -> Result<Rc<Artifact>> {
        let info = self.info(name)?.clone();
        let path = self.dir.join(&info.file);
        if !path.exists() {
            bail!("artifact file {} missing — run `make artifacts`", path.display());
        }
        Ok(Rc::new(Artifact::compile_with_fusion(&path, info, fuse)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let reg = Registry::load(artifacts_dir()).unwrap();
        let info = reg.info("vecadd").unwrap();
        assert_eq!(info.inputs.len(), 2);
        assert_eq!(info.inputs[0].dtype, DType::F32);
        assert_eq!(info.inputs[0].shape, vec![1 << 20]);
        assert_eq!(info.outputs.len(), 1);
    }

    #[test]
    fn unknown_artifact_errors() {
        let reg = Registry::load(artifacts_dir()).unwrap();
        assert!(reg.info("nope").is_err());
    }

    #[test]
    fn spec_bytes() {
        let s = TensorSpec { dtype: DType::F32, shape: vec![2, 3] };
        assert_eq!(s.elems(), 6);
        assert_eq!(s.bytes(), 24);
    }
}
