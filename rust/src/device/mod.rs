//! GPU cost-structure simulator (DESIGN.md §3 substitution).
//!
//! The paper evaluates on a Tesla C2050 ("Fermi") and a GeForce 320M
//! through Aparapi/OpenCL.  Neither device (nor any GPU) exists here, so
//! the device backend executes the real AOT-compiled XLA artifacts on the
//! PJRT CPU client — the "device is fast at data-parallel math" part is
//! *measured* — while the cost structure that drives every GPU-side
//! finding in §7.3 is *modeled* from a [`profile::DeviceProfile`]:
//!
//! * host↔device transfer time per byte (PCIe for Fermi; near-free for the
//!   320M, which shares memory with the host — the reason it wins Crypt),
//! * a fixed launch overhead per kernel (the reason SOR's 100 `sync`
//!   relaunches hurt),
//! * a compute scale factor (relative device throughput),
//! * the thread-grid configuration rules of §5.2 (group-size rounding).
//!
//! [`session::DeviceSession`] tracks both the *measured wall* time and the
//! *modeled device* time; benches report the modeled time for the figure
//! shapes and record both in EXPERIMENTS.md.

pub mod grid;
pub mod memory;
pub mod profile;
pub mod session;

pub use grid::GridConfig;
pub use memory::{BufId, DeviceMemory};
pub use profile::DeviceProfile;
pub use session::{Arg, DeviceSession, DeviceStats, UploadCounters};
