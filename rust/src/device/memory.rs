//! Device memory manager: explicit residency for host↔device data
//! (the Aparapi `kernel.setExplicit(true)` / `put` / `get` model the
//! paper's SOR master relies on, Listing 17).
//!
//! Buffers are real PJRT buffers (so launches chain without host copies);
//! the manager adds the byte/time accounting the simulator needs.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::{Artifact, HostTensor};

/// Opaque handle to a device-resident buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BufId(pub(crate) u64);

pub(crate) struct Entry {
    pub buf: xla::PjRtBuffer,
    pub bytes: usize,
    /// Pin count: `free` only releases the buffer when this drops to 0,
    /// so a pipeline stage and the upload memo cache can share residency.
    pub refs: usize,
}

/// Tracks device-resident buffers and total residency.
#[derive(Default)]
pub struct DeviceMemory {
    entries: BTreeMap<u64, Entry>,
    next: u64,
    resident_bytes: usize,
    peak_bytes: usize,
}

impl DeviceMemory {
    /// An empty memory pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Upload a host tensor; returns its handle (counts bytes).
    pub fn put(&mut self, t: &HostTensor) -> Result<BufId> {
        let buf = Artifact::put(t)?;
        Ok(self.adopt(buf, t.bytes()))
    }

    /// Adopt an existing PJRT buffer (e.g. a launch output) into the pool.
    pub fn adopt(&mut self, buf: xla::PjRtBuffer, bytes: usize) -> BufId {
        let id = self.next;
        self.next += 1;
        self.entries.insert(id, Entry { buf, bytes, refs: 1 });
        self.resident_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes);
        BufId(id)
    }

    /// Pin a resident buffer: one extra `free` is now required before the
    /// backing storage is released.  Residency accounting is unchanged —
    /// the bytes are already on the device.
    pub fn retain(&mut self, id: BufId) -> Result<()> {
        let e = self
            .entries
            .get_mut(&id.0)
            .ok_or_else(|| anyhow!("retain of dangling device buffer {id:?}"))?;
        e.refs += 1;
        Ok(())
    }

    /// Current pin count of a resident buffer.
    pub fn refs_of(&self, id: BufId) -> Result<usize> {
        Ok(self.entry(id)?.refs)
    }

    /// Download to host (does not free).
    pub fn get(&self, id: BufId) -> Result<HostTensor> {
        let e = self.entry(id)?;
        Artifact::get(&e.buf)
    }

    pub(crate) fn entry(&self, id: BufId) -> Result<&Entry> {
        self.entries.get(&id.0).ok_or_else(|| anyhow!("dangling device buffer {id:?}"))
    }

    /// Accounted size of a resident buffer.
    pub fn bytes_of(&self, id: BufId) -> Result<usize> {
        Ok(self.entry(id)?.bytes)
    }

    /// Release one reference to a resident buffer; the storage is freed
    /// when the last reference drops (double frees error).
    pub fn free(&mut self, id: BufId) -> Result<()> {
        let e = self.entries.get_mut(&id.0).ok_or_else(|| anyhow!("double free of {id:?}"))?;
        e.refs -= 1;
        if e.refs == 0 {
            let e = self.entries.remove(&id.0).expect("entry vanished");
            self.resident_bytes -= e.bytes;
        }
        Ok(())
    }

    /// Currently resident bytes.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// High-water mark of resident bytes.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Count of live (unfreed) buffers.
    pub fn live_buffers(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip_and_accounting() {
        let mut m = DeviceMemory::new();
        let t = HostTensor::vec_f32(vec![1.5; 1000]);
        let id = m.put(&t).unwrap();
        assert_eq!(m.resident_bytes(), 4000);
        let back = m.get(id).unwrap();
        assert_eq!(back, t);
        m.free(id).unwrap();
        assert_eq!(m.resident_bytes(), 0);
        assert_eq!(m.peak_bytes(), 4000);
    }

    #[test]
    fn double_free_rejected() {
        let mut m = DeviceMemory::new();
        let id = m.put(&HostTensor::vec_f32(vec![0.0; 4])).unwrap();
        m.free(id).unwrap();
        assert!(m.free(id).is_err());
        assert!(m.get(id).is_err());
    }

    #[test]
    fn retain_pins_across_one_free() {
        let mut m = DeviceMemory::new();
        let t = HostTensor::vec_f32(vec![2.0; 8]);
        let id = m.put(&t).unwrap();
        m.retain(id).unwrap();
        assert_eq!(m.refs_of(id).unwrap(), 2);
        m.free(id).unwrap();
        // still resident: the second reference keeps the storage alive
        assert_eq!(m.get(id).unwrap(), t);
        assert_eq!(m.resident_bytes(), 32);
        m.free(id).unwrap();
        assert_eq!(m.resident_bytes(), 0);
        assert!(m.get(id).is_err());
        assert!(m.retain(id).is_err());
    }
}
