//! A device session: the master-side view of one offloaded SOMD method
//! (paper Algorithm 2).  Owns the memory manager, runs kernel launches
//! against the artifact registry, and keeps two clocks:
//!
//! * **wall** — real time spent in PJRT execution on this host (the
//!   compiled bytecode lane of the vendored `xla` shim since PR 2; see
//!   `rust/vendor/xla/README.md` for the parse → lower → schedule →
//!   execute pipeline);
//! * **device** — the modeled time on the profiled GPU: measured compute
//!   x `compute_scale`, plus modeled transfer and launch costs.
//!
//! The scheduler history that resolves `method:auto` is fed *measured*
//! execute wall time (the engine clocks each job on the device master
//! after dequeue); the modeled clock only drives the paper-figure
//! reports.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::grid::GridConfig;
use super::memory::{BufId, DeviceMemory};
use super::profile::DeviceProfile;
use crate::runtime::{tensor_fingerprint, Artifact, HostTensor, Registry};

/// Default capacity of the per-session upload memo cache (entries);
/// overridden by `SOMD_PIPELINE_MEMO_CAP`.
const DEFAULT_MEMO_CAP: usize = 32;

fn memo_cap_from_env() -> usize {
    std::env::var("SOMD_PIPELINE_MEMO_CAP")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(DEFAULT_MEMO_CAP)
}

/// Shared counters for the upload memo cache — one set per device lane,
/// surfaced through `Engine::device_counters` so tests can pin cache
/// behaviour (the staleness property rides on `uploads` vs `hits`).
#[derive(Debug, Default)]
pub struct UploadCounters {
    uploads: AtomicUsize,
    hits: AtomicUsize,
    invalidations: AtomicUsize,
}

impl UploadCounters {
    /// Cache misses that paid a real H2D upload.
    pub fn uploads(&self) -> usize {
        self.uploads.load(Ordering::Relaxed)
    }

    /// Cache hits that skipped the upload (content hash matched).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Entries dropped from the cache (capacity eviction or an
    /// unresolvable handle) — each one forces a re-upload on next use.
    pub fn invalidations(&self) -> usize {
        self.invalidations.load(Ordering::Relaxed)
    }

    fn note_upload(&self) {
        self.uploads.fetch_add(1, Ordering::Relaxed);
    }

    fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn note_invalidation(&self) {
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }
}

/// A kernel argument: already-resident buffer or host data to upload
/// on demand (§4.3 on-demand copying).
pub enum Arg<'a> {
    /// An already-resident device buffer.
    Buf(BufId),
    /// Host data uploaded on demand for this launch (freed afterwards).
    Host(&'a HostTensor),
}

/// Accumulated accounting for one session.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceStats {
    /// Kernel launches issued.
    pub launches: usize,
    /// Host→device transfer operations.
    pub h2d_transfers: usize,
    /// Device→host transfer operations.
    pub d2h_transfers: usize,
    /// Bytes moved host→device.
    pub bytes_h2d: usize,
    /// Bytes moved device→host.
    pub bytes_d2h: usize,
    /// Measured wall time spent executing kernels on this host.
    pub wall_compute: Duration,
    /// Modeled time on the profiled GPU (compute scale + transfers +
    /// launch overheads).
    pub device_time: Duration,
    /// High-water mark of resident device bytes.
    pub peak_resident_bytes: usize,
    /// Total §5.2 grid threads launched (including idle boundary threads).
    pub total_threads_launched: usize,
    /// Sum over launches of the idle-thread fraction (see
    /// [`DeviceStats::mean_idle_fraction`]).
    pub idle_thread_fraction_sum: f64,
    /// H2D transfers *skipped* because the data was already resident
    /// (memoized upload hit, or a pipeline stage consuming an upstream
    /// device output in place).  Counted explicitly — never folded into
    /// `h2d_transfers` as a silent zero — so the §7.3 bus-pressure model
    /// can tell a cheap run from a resident one.
    pub h2d_skipped: usize,
    /// D2H transfers skipped at a resident stage boundary.
    pub d2h_skipped: usize,
    /// Bytes that would have crossed the bus H2D but stayed resident.
    pub bytes_h2d_skipped: usize,
    /// Bytes that would have crossed the bus D2H but stayed resident.
    pub bytes_d2h_skipped: usize,
    /// Modeled transfer time hidden under stage compute by the pipeline's
    /// double-buffered overlap (already excluded from `device_time`).
    pub overlapped_transfer_time: Duration,
    /// Time the job sat on the device master's queue between enqueue and
    /// dequeue.  Sessions never accumulate this themselves — the engine
    /// stamps it onto the per-job delta after `delta_since`, so it stays
    /// out of the measured-execute clock (and the scheduler's
    /// `device_secs` history) by construction.
    pub queue_wait: Duration,
}

impl DeviceStats {
    /// Mean boundary-divergence across launches (§5.2).
    pub fn mean_idle_fraction(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.idle_thread_fraction_sum / self.launches as f64
        }
    }

    /// Total bytes moved across the (modeled) bus, both directions.
    pub fn total_transfer_bytes(&self) -> usize {
        self.bytes_h2d + self.bytes_d2h
    }

    /// Transfer operations avoided by residency, both directions.
    pub fn skipped_transfers(&self) -> usize {
        self.h2d_skipped + self.d2h_skipped
    }

    /// Bytes that stayed device-resident instead of crossing the bus.
    pub fn skipped_transfer_bytes(&self) -> usize {
        self.bytes_h2d_skipped + self.bytes_d2h_skipped
    }

    /// Fold another session's accounting into this one — how the device
    /// fleet totals the per-lane shares of one sharded invocation into a
    /// single transfer/launch record for the scheduler history.
    /// Additive counters sum; the residency peak keeps the maximum (the
    /// lanes' sessions are disjoint address spaces, but a single
    /// conservative high-water mark is the honest summary).
    pub fn absorb(&mut self, other: &DeviceStats) {
        self.launches += other.launches;
        self.h2d_transfers += other.h2d_transfers;
        self.d2h_transfers += other.d2h_transfers;
        self.bytes_h2d += other.bytes_h2d;
        self.bytes_d2h += other.bytes_d2h;
        self.wall_compute += other.wall_compute;
        self.device_time += other.device_time;
        self.peak_resident_bytes = self.peak_resident_bytes.max(other.peak_resident_bytes);
        self.total_threads_launched += other.total_threads_launched;
        self.idle_thread_fraction_sum += other.idle_thread_fraction_sum;
        self.h2d_skipped += other.h2d_skipped;
        self.d2h_skipped += other.d2h_skipped;
        self.bytes_h2d_skipped += other.bytes_h2d_skipped;
        self.bytes_d2h_skipped += other.bytes_d2h_skipped;
        self.overlapped_transfer_time += other.overlapped_transfer_time;
        self.queue_wait += other.queue_wait;
    }

    /// The accounting accumulated since `earlier` — the per-job slice a
    /// warm (reused) session hands to the scheduler history.
    pub fn delta_since(&self, earlier: &DeviceStats) -> DeviceStats {
        DeviceStats {
            launches: self.launches.saturating_sub(earlier.launches),
            h2d_transfers: self.h2d_transfers.saturating_sub(earlier.h2d_transfers),
            d2h_transfers: self.d2h_transfers.saturating_sub(earlier.d2h_transfers),
            bytes_h2d: self.bytes_h2d.saturating_sub(earlier.bytes_h2d),
            bytes_d2h: self.bytes_d2h.saturating_sub(earlier.bytes_d2h),
            wall_compute: self.wall_compute.saturating_sub(earlier.wall_compute),
            device_time: self.device_time.saturating_sub(earlier.device_time),
            // residency peaks are session-lifetime quantities; the delta
            // keeps the later snapshot's view
            peak_resident_bytes: self.peak_resident_bytes,
            total_threads_launched: self
                .total_threads_launched
                .saturating_sub(earlier.total_threads_launched),
            idle_thread_fraction_sum: (self.idle_thread_fraction_sum
                - earlier.idle_thread_fraction_sum)
                .max(0.0),
            h2d_skipped: self.h2d_skipped.saturating_sub(earlier.h2d_skipped),
            d2h_skipped: self.d2h_skipped.saturating_sub(earlier.d2h_skipped),
            bytes_h2d_skipped: self.bytes_h2d_skipped.saturating_sub(earlier.bytes_h2d_skipped),
            bytes_d2h_skipped: self.bytes_d2h_skipped.saturating_sub(earlier.bytes_d2h_skipped),
            overlapped_transfer_time: self
                .overlapped_transfer_time
                .saturating_sub(earlier.overlapped_transfer_time),
            queue_wait: self.queue_wait.saturating_sub(earlier.queue_wait),
        }
    }
}

/// The master-side view of one offloaded method: memory manager +
/// accounting over a borrowed artifact [`Registry`].
pub struct DeviceSession<'r> {
    registry: &'r Registry,
    profile: DeviceProfile,
    mem: DeviceMemory,
    stats: DeviceStats,
    /// Content-hash → resident handle memo for [`DeviceSession::put_cached`]
    /// (the cache holds its own reference on each entry).
    memo: BTreeMap<u64, BufId>,
    /// FIFO insertion order backing capacity eviction.
    memo_order: VecDeque<u64>,
    memo_cap: usize,
    counters: Arc<UploadCounters>,
    overlap: bool,
    /// Modeled compute time banked by launches and spent hiding
    /// subsequent H2D cost when overlap is on.
    overlap_budget: Duration,
}

impl<'r> DeviceSession<'r> {
    /// A fresh session over `registry` under the given cost profile.
    pub fn new(registry: &'r Registry, profile: DeviceProfile) -> Self {
        Self {
            registry,
            profile,
            mem: DeviceMemory::new(),
            stats: DeviceStats::default(),
            memo: BTreeMap::new(),
            memo_order: VecDeque::new(),
            memo_cap: memo_cap_from_env(),
            counters: Arc::new(UploadCounters::default()),
            overlap: false,
            overlap_budget: Duration::ZERO,
        }
    }

    /// Share this lane's upload-memo counters (the engine passes one set
    /// per device lane so `Engine::device_counters` can total them).
    pub fn set_upload_counters(&mut self, counters: Arc<UploadCounters>) {
        self.counters = counters;
    }

    /// The session's upload-memo counters.
    pub fn upload_counters(&self) -> &Arc<UploadCounters> {
        &self.counters
    }

    /// Override the upload memo capacity (0 disables memoization).
    pub fn set_memo_cap(&mut self, cap: usize) {
        self.memo_cap = cap;
        self.evict_over_cap();
    }

    /// Enable/disable H2D-under-compute overlap.  Turning it off drops
    /// any banked compute budget.
    pub fn set_overlap(&mut self, on: bool) {
        self.overlap = on;
        if !on {
            self.overlap_budget = Duration::ZERO;
        }
    }

    /// The cost profile this session models.
    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// The artifact registry this session launches from.
    pub fn registry(&self) -> &'r Registry {
        self.registry
    }

    /// Snapshot of the accumulated accounting.
    pub fn stats(&self) -> DeviceStats {
        let mut s = self.stats.clone();
        s.peak_resident_bytes = self.mem.peak_bytes();
        s
    }

    /// The session's device-memory manager (residency observability).
    pub fn memory(&self) -> &DeviceMemory {
        &self.mem
    }

    /// Explicit `put`: upload and account the transfer.  With overlap
    /// enabled, the modeled bus cost is hidden under compute time banked
    /// by preceding launches (double-buffering: stage `i+1`'s H2D rides
    /// under stage `i`'s kernel) — the hidden share is still reported in
    /// `overlapped_transfer_time`, never silently dropped.
    pub fn put(&mut self, t: &HostTensor) -> Result<BufId> {
        let id = self.mem.put(t)?;
        self.stats.h2d_transfers += 1;
        self.stats.bytes_h2d += t.bytes();
        let cost = self.profile.h2d_time(t.bytes());
        let hidden =
            if self.overlap { cost.min(self.overlap_budget) } else { Duration::ZERO };
        self.overlap_budget = self.overlap_budget.saturating_sub(hidden);
        self.stats.overlapped_transfer_time += hidden;
        self.stats.device_time += cost.saturating_sub(hidden);
        Ok(id)
    }

    /// Memoized `put`: if a bitwise-identical tensor (same dtype, shape
    /// and payload bits — see [`tensor_fingerprint`]) was uploaded through
    /// this cache and is still resident, pin and return the existing
    /// handle instead of crossing the bus again.  The skipped transfer is
    /// counted in `h2d_skipped`/`bytes_h2d_skipped`.  The returned handle
    /// carries its own reference: callers `free` it exactly as they would
    /// a plain `put` handle; the cache's pin keeps the buffer alive for
    /// future hits.  Staleness is impossible by construction — a mutated
    /// host tensor fingerprints differently and misses.
    pub fn put_cached(&mut self, t: &HostTensor) -> Result<BufId> {
        if self.memo_cap == 0 {
            self.counters.note_upload();
            return self.put(t);
        }
        let fp = tensor_fingerprint(t);
        if let Some(&id) = self.memo.get(&fp) {
            if self.mem.retain(id).is_ok() {
                self.stats.h2d_skipped += 1;
                self.stats.bytes_h2d_skipped += t.bytes();
                self.counters.note_hit();
                return Ok(id);
            }
            // the handle went dangling (defensive; the cache pin should
            // prevent this) — drop the entry and re-upload
            self.memo.remove(&fp);
            self.memo_order.retain(|&k| k != fp);
            self.counters.note_invalidation();
        }
        let id = self.put(t)?;
        self.mem.retain(id)?; // the cache's own pin
        self.memo.insert(fp, id);
        self.memo_order.push_back(fp);
        self.counters.note_upload();
        self.evict_over_cap();
        Ok(id)
    }

    fn evict_over_cap(&mut self) {
        while self.memo.len() > self.memo_cap {
            let Some(fp) = self.memo_order.pop_front() else { break };
            if let Some(id) = self.memo.remove(&fp) {
                let _ = self.mem.free(id); // release the cache's pin
                self.counters.note_invalidation();
            }
        }
    }

    /// Record a resident stage boundary: a pipeline handed `bytes` of an
    /// upstream device output straight to the downstream stage, skipping
    /// the D2H+H2D round-trip an isolated invocation would have paid.
    pub fn note_resident_handoff(&mut self, bytes: usize) {
        self.stats.d2h_skipped += 1;
        self.stats.h2d_skipped += 1;
        self.stats.bytes_d2h_skipped += bytes;
        self.stats.bytes_h2d_skipped += bytes;
    }

    /// Explicit `get`: download and account the transfer.
    pub fn get(&mut self, id: BufId) -> Result<HostTensor> {
        let t = self.mem.get(id)?;
        self.stats.d2h_transfers += 1;
        self.stats.bytes_d2h += t.bytes();
        self.stats.device_time += self.profile.d2h_time(t.bytes());
        Ok(t)
    }

    /// Partial `get` for hybrid co-execution: download only rows
    /// `[lo, hi)` (leading dimension) of a resident buffer.  The transfer
    /// accounting — byte counts and the modeled D2H clock — charges the
    /// *slice* only: the SMP lane owns the rest of the index space, so
    /// a real device would never move it across the bus.  (The PJRT CPU
    /// stand-in materializes the full literal host-side first; that copy
    /// is measured wall time, not modeled bus traffic — see
    /// [`Artifact::get_rows`].)
    pub fn get_rows(&mut self, id: BufId, lo: usize, hi: usize) -> Result<HostTensor> {
        let slice = {
            let e = self.mem.entry(id)?;
            Artifact::get_rows(&e.buf, lo, hi)?
        };
        self.stats.d2h_transfers += 1;
        self.stats.bytes_d2h += slice.bytes();
        self.stats.device_time += self.profile.d2h_time(slice.bytes());
        Ok(slice)
    }

    /// Release a resident buffer.
    pub fn free(&mut self, id: BufId) -> Result<()> {
        self.mem.free(id)
    }

    /// Pin a resident buffer: one extra [`DeviceSession::free`] is then
    /// required before the storage is released.  The pipeline layer pins
    /// a device stage's inputs so a failing stage evaluator cannot leave
    /// the SMP fallback without the data it needs to re-run the stage.
    pub fn retain(&mut self, id: BufId) -> Result<()> {
        self.mem.retain(id)
    }

    /// Launch `artifact` over `args`; host args are uploaded on demand.
    /// Outputs stay device-resident.  `problem_size` drives the §5.2
    /// thread-grid model for divergence accounting.
    pub fn launch(&mut self, artifact: &str, args: &[Arg<'_>], problem_size: usize) -> Result<Vec<BufId>> {
        let art: Rc<Artifact> = self.registry.artifact(artifact)?;

        // on-demand uploads
        let mut temp_ids: Vec<BufId> = Vec::new();
        let mut ids: Vec<BufId> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::Buf(id) => ids.push(*id),
                Arg::Host(t) => {
                    let id = self.put(t)?;
                    temp_ids.push(id);
                    ids.push(id);
                }
            }
        }
        let bufs: Vec<&xla::PjRtBuffer> =
            ids.iter().map(|id| self.mem.entry(*id).map(|e| &e.buf)).collect::<Result<_>>()?;

        let t0 = Instant::now();
        let outs = art.execute_buffers(&bufs)?;
        let wall = t0.elapsed();

        // clocks
        self.stats.launches += 1;
        self.stats.wall_compute += wall;
        let modeled =
            Duration::from_secs_f64(wall.as_secs_f64() * self.profile.compute_scale)
                + self.profile.launch_overhead;
        self.stats.device_time += modeled;
        if self.overlap {
            // this kernel's modeled occupancy can hide later uploads
            self.overlap_budget += modeled;
        }
        let grid = GridConfig::for_problem(problem_size, self.profile.max_group_size);
        self.stats.total_threads_launched += grid.total_threads();
        self.stats.idle_thread_fraction_sum += grid.idle_fraction(problem_size);

        // adopt outputs with byte sizes from the manifest
        let out_specs = &art.info().outputs;
        let mut out_ids = Vec::with_capacity(outs.len());
        for (i, buf) in outs.into_iter().enumerate() {
            let bytes = out_specs.get(i).map(|s| s.bytes()).unwrap_or(0);
            out_ids.push(self.mem.adopt(buf, bytes));
        }
        for id in temp_ids {
            self.mem.free(id)?;
        }
        Ok(out_ids)
    }

    /// Launch and immediately download every output (counts D2H).
    /// Multi-output programs whose root is a tuple buffer are flattened.
    pub fn launch_to_host(
        &mut self,
        artifact: &str,
        args: &[Arg<'_>],
        problem_size: usize,
    ) -> Result<Vec<HostTensor>> {
        let ids = self.launch(artifact, args, problem_size)?;
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let leaves = {
                let e = self.mem.entry(id)?;
                Artifact::get_all(&e.buf)?
            };
            for t in leaves {
                self.stats.d2h_transfers += 1;
                self.stats.bytes_d2h += t.bytes();
                self.stats.device_time += self.profile.d2h_time(t.bytes());
                out.push(t);
            }
            self.free(id)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> Registry {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Registry::load(dir).unwrap()
    }

    #[test]
    fn launch_with_host_args_counts_transfers() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = HostTensor::vec_f32(vec![1.0; n]);
        let b = HostTensor::vec_f32(vec![2.0; n]);
        let out = s.launch_to_host("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap();
        assert!(out[0].as_f32().unwrap().iter().all(|&v| v == 3.0));
        let st = s.stats();
        assert_eq!(st.launches, 1);
        assert_eq!(st.h2d_transfers, 2);
        assert_eq!(st.d2h_transfers, 1);
        assert_eq!(st.bytes_h2d, 2 * 4 * n);
        assert!(st.device_time > Duration::ZERO);
        // temps freed after launch; no residual residency
        assert_eq!(s.memory().live_buffers(), 0);
    }

    #[test]
    fn resident_chaining_avoids_transfers() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = s.put(&HostTensor::vec_f32(vec![1.0; n])).unwrap();
        let b = s.put(&HostTensor::vec_f32(vec![1.0; n])).unwrap();
        let h2d_after_puts = s.stats().bytes_h2d;
        // chain: c = a+b; d = c+c — no host roundtrip between launches
        let c = s.launch("vecadd", &[Arg::Buf(a), Arg::Buf(b)], n).unwrap()[0];
        let d = s.launch("vecadd", &[Arg::Buf(c), Arg::Buf(c)], n).unwrap()[0];
        assert_eq!(s.stats().bytes_h2d, h2d_after_puts);
        let out = s.get(d).unwrap();
        assert!(out.as_f32().unwrap().iter().all(|&v| v == 4.0));
        assert_eq!(s.stats().d2h_transfers, 1);
    }

    #[test]
    fn stats_delta_isolates_one_job() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = HostTensor::vec_f32(vec![1.0; n]);
        let b = HostTensor::vec_f32(vec![2.0; n]);
        s.launch_to_host("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap();
        let before = s.stats();
        s.launch_to_host("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap();
        let delta = s.stats().delta_since(&before);
        assert_eq!(delta.launches, 1);
        assert_eq!(delta.h2d_transfers, 2);
        assert_eq!(delta.bytes_h2d, 2 * 4 * n);
        assert!(delta.device_time > Duration::ZERO);
        assert_eq!(delta.total_transfer_bytes(), delta.bytes_h2d + delta.bytes_d2h);
    }

    #[test]
    fn get_rows_accounts_only_the_slice() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = HostTensor::vec_f32(vec![1.0; n]);
        let b = HostTensor::vec_f32(vec![2.0; n]);
        let out = s.launch("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap()[0];
        let d2h_before = s.stats().bytes_d2h;
        let (lo, hi) = (n / 2, n / 2 + 1000);
        let slice = s.get_rows(out, lo, hi).unwrap();
        assert_eq!(slice.len(), 1000);
        assert!(slice.as_f32().unwrap().iter().all(|&v| v == 3.0));
        // the accounted transfer is the slice, not the full vector
        assert_eq!(s.stats().bytes_d2h - d2h_before, 1000 * 4);
        assert_eq!(s.stats().d2h_transfers, 1);
        s.free(out).unwrap();
    }

    #[test]
    fn absorb_sums_counters_and_keeps_peak() {
        let mut a = DeviceStats {
            launches: 2,
            bytes_h2d: 100,
            bytes_d2h: 10,
            peak_resident_bytes: 500,
            idle_thread_fraction_sum: 0.25,
            ..DeviceStats::default()
        };
        let b = DeviceStats {
            launches: 3,
            bytes_h2d: 50,
            bytes_d2h: 40,
            peak_resident_bytes: 900,
            idle_thread_fraction_sum: 0.5,
            ..DeviceStats::default()
        };
        a.absorb(&b);
        assert_eq!(a.launches, 5);
        assert_eq!(a.bytes_h2d, 150);
        assert_eq!(a.bytes_d2h, 50);
        assert_eq!(a.peak_resident_bytes, 900);
        assert!((a.idle_thread_fraction_sum - 0.75).abs() < 1e-12);
    }

    #[test]
    fn put_cached_skips_repeat_uploads_and_never_serves_stale_data() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        s.set_memo_cap(8);
        let t = HostTensor::vec_f32(vec![1.0, 2.0, 3.0]);
        let a = s.put_cached(&t).unwrap();
        let b = s.put_cached(&t.clone()).unwrap();
        assert_eq!(a, b);
        let st = s.stats();
        assert_eq!(st.h2d_transfers, 1);
        assert_eq!(st.h2d_skipped, 1);
        assert_eq!(st.bytes_h2d_skipped, t.bytes());
        assert_eq!(s.upload_counters().uploads(), 1);
        assert_eq!(s.upload_counters().hits(), 1);
        // mutation invalidates the content-hash match: fresh upload, and
        // the returned buffer holds the new payload, not the old one
        let t2 = HostTensor::vec_f32(vec![1.0, 2.0, 4.0]);
        let c = s.put_cached(&t2).unwrap();
        assert_ne!(a, c);
        assert_eq!(s.upload_counters().uploads(), 2);
        assert_eq!(s.mem.get(c).unwrap(), t2);
        assert_eq!(s.mem.get(a).unwrap(), t);
    }

    #[test]
    fn memo_capacity_eviction_counts_invalidations() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        s.set_memo_cap(1);
        let t1 = HostTensor::vec_f32(vec![1.0]);
        let t2 = HostTensor::vec_f32(vec![2.0]);
        s.put_cached(&t1).unwrap();
        s.put_cached(&t2).unwrap(); // evicts t1's entry
        assert_eq!(s.upload_counters().invalidations(), 1);
        s.put_cached(&t1).unwrap(); // must re-upload, not hit
        assert_eq!(s.upload_counters().uploads(), 3);
        assert_eq!(s.upload_counters().hits(), 0);
    }

    #[test]
    fn overlap_hides_h2d_under_banked_compute() {
        let r = reg();
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = HostTensor::vec_f32(vec![1.0; n]);
        let b = HostTensor::vec_f32(vec![2.0; n]);
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        s.set_overlap(true);
        // first launch banks modeled compute; the next stage's uploads
        // then ride under it
        s.launch_to_host("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap();
        let id = s.put(&a).unwrap();
        s.free(id).unwrap();
        let st = s.stats();
        assert!(st.overlapped_transfer_time > Duration::ZERO, "{st:?}");
        // the hidden share left device_time, but is still reported
        let mut plain = DeviceSession::new(&r, DeviceProfile::fermi());
        let pid = plain.put(&a).unwrap();
        plain.free(pid).unwrap();
        assert!(plain.stats().overlapped_transfer_time == Duration::ZERO);
    }

    #[test]
    fn resident_handoff_counts_skipped_round_trip() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::fermi());
        s.note_resident_handoff(4096);
        let st = s.stats();
        assert_eq!(st.d2h_skipped, 1);
        assert_eq!(st.h2d_skipped, 1);
        assert_eq!(st.skipped_transfers(), 2);
        assert_eq!(st.skipped_transfer_bytes(), 2 * 4096);
        // a delta slice carries the skip counters too
        let delta = s.stats().delta_since(&DeviceStats::default());
        assert_eq!(delta.bytes_d2h_skipped, 4096);
    }

    #[test]
    fn passthrough_device_time_tracks_wall() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::passthrough());
        let n = r.info("vecadd").unwrap().inputs[0].elems();
        let a = HostTensor::vec_f32(vec![0.0; n]);
        let b = HostTensor::vec_f32(vec![0.0; n]);
        s.launch_to_host("vecadd", &[Arg::Host(&a), Arg::Host(&b)], n).unwrap();
        let st = s.stats();
        // modeled time == measured compute (no overheads) for passthrough
        let diff =
            (st.device_time.as_secs_f64() - st.wall_compute.as_secs_f64()).abs();
        assert!(diff < 1e-6, "{st:?}");
    }
}
