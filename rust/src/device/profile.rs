//! Device profiles: the two GPU-accelerated systems of the paper's §7.3
//! evaluation plus a zero-overhead passthrough used for calibration.
//!
//! Parameters are order-of-magnitude figures for the 2010-era parts
//! (PCIe 2.0 x16 effective ~4 GB/s; JNI/Aparapi launch path tens of µs;
//! the 320M is an integrated laptop part sharing host memory, far slower
//! at compute but paying near-zero transfer cost).  Figure shapes depend
//! on the *ratios*, not the absolute values — see DESIGN.md §3.

use std::time::Duration;

/// Cost-structure parameters of one modeled device (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    /// Profile name (the rules-file device target token).
    pub name: &'static str,
    /// Host→device bandwidth (bytes/s).
    pub h2d_bytes_per_sec: f64,
    /// Device→host bandwidth (bytes/s).
    pub d2h_bytes_per_sec: f64,
    /// Fixed cost per transfer operation (DMA setup / JNI crossing).
    pub transfer_setup: Duration,
    /// Fixed cost per kernel launch.
    pub launch_overhead: Duration,
    /// Multiplier applied to the measured XLA wall time to model the
    /// device's relative compute throughput (1.0 = as measured).
    pub compute_scale: f64,
    /// Integrated device: transfers are host-memory copies.
    pub shares_host_memory: bool,
    /// Maximum work-group size (§5.2 grid configuration).
    pub max_group_size: usize,
}

impl DeviceProfile {
    /// NVIDIA Tesla C2050, 3 GB, PCIe-attached ("Fermi" system, §7.3).
    ///
    /// `compute_scale` maps measured host-XLA wall time to device time:
    /// one host core ≈ 25 GFLOPs SP vs the C2050's ≈ 1030 GFLOPs peak
    /// ⇒ ≈ 0.024.  Transfer bandwidth is the *effective* Aparapi path
    /// (JNI-copied, unpinned staging both ways — far below raw PCIe 2.0;
    /// this is what makes GPU-Crypt lose, §7.3).
    pub fn fermi() -> Self {
        DeviceProfile {
            name: "fermi",
            h2d_bytes_per_sec: 0.60e9,
            d2h_bytes_per_sec: 0.55e9,
            transfer_setup: Duration::from_micros(150),
            launch_overhead: Duration::from_micros(60),
            compute_scale: 0.024,
            shares_host_memory: false,
            max_group_size: 512,
        }
    }

    /// NVIDIA GeForce 320M, 256 MB carved from host memory (MacBook Pro
    /// system, §7.3): ~10x less compute than the C2050 (48 cores ≈ 91
    /// GFLOPs SP ⇒ scale ≈ 0.2 of a host core), but transfers are plain
    /// host-memory copies — the reason it beats the Fermi on Crypt.
    pub fn geforce_320m() -> Self {
        DeviceProfile {
            name: "geforce320m",
            h2d_bytes_per_sec: 2.0e9,
            d2h_bytes_per_sec: 2.0e9,
            transfer_setup: Duration::from_micros(20),
            launch_overhead: Duration::from_micros(40),
            compute_scale: 0.15,
            shares_host_memory: true,
            max_group_size: 512,
        }
    }

    /// Zero-overhead passthrough: raw PJRT execution (calibration /
    /// correctness tests).
    pub fn passthrough() -> Self {
        DeviceProfile {
            name: "passthrough",
            h2d_bytes_per_sec: f64::INFINITY,
            d2h_bytes_per_sec: f64::INFINITY,
            transfer_setup: Duration::ZERO,
            launch_overhead: Duration::ZERO,
            compute_scale: 1.0,
            shares_host_memory: true,
            max_group_size: 512,
        }
    }

    /// Look a profile up by its rules-file token.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "fermi" => Some(Self::fermi()),
            "geforce320m" | "320m" => Some(Self::geforce_320m()),
            "passthrough" => Some(Self::passthrough()),
            _ => None,
        }
    }

    /// Modeled duration of moving `bytes` host→device.
    pub fn h2d_time(&self, bytes: usize) -> Duration {
        self.xfer_time(bytes, self.h2d_bytes_per_sec)
    }

    /// Modeled duration of moving `bytes` device→host.
    pub fn d2h_time(&self, bytes: usize) -> Duration {
        self.xfer_time(bytes, self.d2h_bytes_per_sec)
    }

    fn xfer_time(&self, bytes: usize, bw: f64) -> Duration {
        if bytes == 0 {
            return Duration::ZERO;
        }
        let secs = bytes as f64 / bw;
        self.transfer_setup + Duration::from_secs_f64(secs.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceProfile::by_name("fermi").unwrap().name, "fermi");
        assert_eq!(DeviceProfile::by_name("320m").unwrap().name, "geforce320m");
        assert!(DeviceProfile::by_name("h100").is_none());
    }

    #[test]
    fn transfer_times_scale_with_bytes() {
        let f = DeviceProfile::fermi();
        let t1 = f.h2d_time(4_000_000);
        let t2 = f.h2d_time(8_000_000);
        assert!(t2 > t1);
        // 4 MB over 0.6 GB/s ≈ 6.7 ms + setup
        assert!((t1.as_secs_f64() - 0.00682).abs() < 1e-3, "{t1:?}");
    }

    #[test]
    fn integrated_part_transfers_cheaper() {
        let fermi = DeviceProfile::fermi();
        let m320 = DeviceProfile::geforce_320m();
        let bytes = 50_000_000;
        assert!(m320.h2d_time(bytes) < fermi.h2d_time(bytes) / 2);
    }

    #[test]
    fn passthrough_is_free() {
        let p = DeviceProfile::passthrough();
        assert_eq!(p.h2d_time(1 << 30), Duration::ZERO);
        assert_eq!(p.launch_overhead, Duration::ZERO);
    }
}
