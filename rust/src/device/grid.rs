//! Thread-grid configuration (paper §5.2 "Configuration of the Thread
//! Grid"): round the problem size up to a whole number of maximal
//! work-groups; threads beyond the loop bounds diverge idle.

/// The computed grid for one kernel launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridConfig {
    /// Work-group count.
    pub groups: usize,
    /// Threads per work-group.
    pub group_size: usize,
}

impl GridConfig {
    /// Paper example: `numberOfThreads(1000000) = 1000448 = 1954 x 512`.
    pub fn for_problem(problem_size: usize, max_group_size: usize) -> GridConfig {
        assert!(max_group_size > 0);
        let groups = problem_size.div_ceil(max_group_size).max(1);
        GridConfig { groups, group_size: max_group_size }
    }

    /// Total launched threads (groups × group size).
    pub fn total_threads(&self) -> usize {
        self.groups * self.group_size
    }

    /// Fraction of launched threads that fall outside the loop bounds
    /// (§5.2 boundary-group divergence).
    pub fn idle_fraction(&self, problem_size: usize) -> f64 {
        let total = self.total_threads();
        if total == 0 {
            return 0.0;
        }
        (total.saturating_sub(problem_size)) as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_example() {
        let g = GridConfig::for_problem(1_000_000, 512);
        assert_eq!(g.groups, 1954);
        assert_eq!(g.total_threads(), 1_000_448);
    }

    #[test]
    fn exact_fit_has_no_idle_threads() {
        let g = GridConfig::for_problem(1024, 512);
        assert_eq!(g.groups, 2);
        assert_eq!(g.idle_fraction(1024), 0.0);
    }

    #[test]
    fn tiny_problem_one_group() {
        let g = GridConfig::for_problem(3, 512);
        assert_eq!(g.groups, 1);
        assert!(g.idle_fraction(3) > 0.99);
    }
}
