//! The unified metrics hub: one snapshotable registry for counters,
//! gauges and bounded histogram windows, with Prometheus text
//! exposition.
//!
//! Metric names follow Prometheus conventions and may carry inline
//! labels — `somd_lane_execute_seconds{method="Series.coefficients",lane="device"}`
//! is one series; the part before `{` is the family the `# TYPE` line
//! is emitted for.  Histograms keep a bounded window of recent samples
//! and export as Prometheus *summaries* (p50/p95/p99 quantiles via
//! [`crate::util::stats::percentiles`] plus a `_count`).  No serde:
//! exposition is plain string assembly, same discipline as
//! `somd/cluster.rs`.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::percentiles;

/// Samples retained per histogram series (oldest dropped beyond this).
pub const HISTO_WINDOW: usize = 512;

#[derive(Default)]
struct HubInner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histos: BTreeMap<String, Vec<f64>>,
}

/// The process-wide metrics registry one engine (and its service)
/// feeds.  All operations take one short mutex; snapshots are cheap
/// copies.
#[derive(Default)]
pub struct MetricsHub {
    inner: Mutex<HubInner>,
}

impl std::fmt::Debug for MetricsHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let i = self.inner.lock().unwrap();
        f.debug_struct("MetricsHub")
            .field("counters", &i.counters.len())
            .field("gauges", &i.gauges.len())
            .field("histos", &i.histos.len())
            .finish()
    }
}

impl MetricsHub {
    /// An empty hub.
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    /// Add `v` to the monotonic counter `name`.
    pub fn counter_add(&self, name: &str, v: u64) {
        let mut i = self.inner.lock().unwrap();
        *i.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Set the gauge `name` to `v` (last-write-wins).
    pub fn gauge_set(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    /// Record one sample into the histogram window `name`.
    pub fn observe(&self, name: &str, v: f64) {
        let mut i = self.inner.lock().unwrap();
        let w = i.histos.entry(name.to_string()).or_default();
        if w.len() >= HISTO_WINDOW {
            w.remove(0);
        }
        w.push(v);
    }

    /// Point-in-time copy of every series.
    pub fn snapshot(&self) -> HubSnapshot {
        let i = self.inner.lock().unwrap();
        HubSnapshot {
            counters: i.counters.clone(),
            gauges: i.gauges.clone(),
            histos: i.histos.clone(),
        }
    }
}

/// Point-in-time copy of a [`MetricsHub`] (plus whatever extra series
/// the caller folds in before rendering).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HubSnapshot {
    /// Monotonic counters by full series name (labels inline).
    pub counters: BTreeMap<String, u64>,
    /// Gauges by full series name.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram windows by full series name.
    pub histos: BTreeMap<String, Vec<f64>>,
}

/// `name{a="b"}` → the family part before `{` (the whole name when
/// unlabelled).
fn family(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Insert an extra `key="value"` label into a (possibly labelled)
/// series name.
fn with_label(name: &str, key: &str, value: &str) -> String {
    match name.strip_suffix('}') {
        Some(prefix) => format!("{prefix},{key}=\"{value}\"}}"),
        None => format!("{name}{{{key}=\"{value}\"}}"),
    }
}

/// Append `suffix` to the family part, keeping labels:
/// `f{l} + _count → f_count{l}`.
fn family_suffixed(name: &str, suffix: &str) -> String {
    match name.find('{') {
        Some(i) => format!("{}{}{}", &name[..i], suffix, &name[i..]),
        None => format!("{name}{suffix}"),
    }
}

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl HubSnapshot {
    /// Render as the Prometheus text exposition format (version 0.0.4):
    /// counters and gauges verbatim, histogram windows as summaries
    /// with `quantile` labels plus a `_count` series.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let mut typed: std::collections::BTreeSet<String> = Default::default();
        let mut type_line = |out: &mut String, name: &str, kind: &str| {
            let fam = family(name).to_string();
            if typed.insert(fam.clone()) {
                out.push_str(&format!("# TYPE {fam} {kind}\n"));
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, name, "counter");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, name, "gauge");
            out.push_str(&format!("{name} {}\n", fmt_value(*v)));
        }
        for (name, w) in &self.histos {
            if w.is_empty() {
                continue;
            }
            type_line(&mut out, name, "summary");
            let p = percentiles(w);
            for (q, val) in [("0.5", p.p50), ("0.95", p.p95), ("0.99", p.p99)] {
                out.push_str(&format!("{} {}\n", with_label(name, "quantile", q), fmt_value(val)));
            }
            out.push_str(&format!("{} {}\n", family_suffixed(name, "_count"), p.n));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let hub = MetricsHub::new();
        hub.counter_add("a_total", 2);
        hub.counter_add("a_total", 3);
        hub.gauge_set("g", 1.0);
        hub.gauge_set("g", 7.5);
        let s = hub.snapshot();
        assert_eq!(s.counters["a_total"], 5);
        assert_eq!(s.gauges["g"], 7.5);
    }

    #[test]
    fn histogram_window_is_bounded() {
        let hub = MetricsHub::new();
        for i in 0..(HISTO_WINDOW + 10) {
            hub.observe("h", i as f64);
        }
        let s = hub.snapshot();
        assert_eq!(s.histos["h"].len(), HISTO_WINDOW);
        assert_eq!(s.histos["h"][0], 10.0); // oldest 10 evicted
    }

    #[test]
    fn prometheus_text_shapes() {
        let hub = MetricsHub::new();
        hub.counter_add("somd_jobs_total{lane=\"device\"}", 4);
        hub.gauge_set("somd_queue_wait_seconds", 0.25);
        hub.observe("somd_exec_seconds{method=\"M\"}", 1.0);
        hub.observe("somd_exec_seconds{method=\"M\"}", 3.0);
        let text = hub.snapshot().prometheus_text();
        assert!(text.contains("# TYPE somd_jobs_total counter"));
        assert!(text.contains("somd_jobs_total{lane=\"device\"} 4\n"));
        assert!(text.contains("# TYPE somd_queue_wait_seconds gauge"));
        assert!(text.contains("somd_queue_wait_seconds 0.25\n"));
        assert!(text.contains("# TYPE somd_exec_seconds summary"));
        assert!(text.contains("somd_exec_seconds{method=\"M\",quantile=\"0.5\"} 2\n"));
        assert!(text.contains("somd_exec_seconds_count{method=\"M\"} 2\n"));
    }

    #[test]
    fn label_helpers() {
        assert_eq!(with_label("f", "q", "0.5"), "f{q=\"0.5\"}");
        assert_eq!(with_label("f{a=\"b\"}", "q", "0.5"), "f{a=\"b\",q=\"0.5\"}");
        assert_eq!(family_suffixed("f{a=\"b\"}", "_count"), "f_count{a=\"b\"}");
        assert_eq!(family("f{a=\"b\"}"), "f");
    }
}
