//! Observability: invocation tracing + the unified metrics hub.
//!
//! The engine makes placement decisions across five mechanisms (auto,
//! hybrid, sharded, cluster, pipeline) that the caller never sees; this
//! module makes them visible without touching the compute path's cost
//! profile:
//!
//! * [`trace`] — a per-engine bounded ring-buffer [`TraceRecorder`]
//!   records nested spans for the full invocation lifecycle (submit →
//!   resolve-with-decision-explain → partition → per-lane execute →
//!   merge/fallback), with parent/child ids so hybrid forks, sharded
//!   latches, cluster peers, batched serve dispatches and pipeline
//!   stages all nest under one trace.  Disabled tracing costs one
//!   relaxed atomic load per invocation.
//! * [`export`] — Chrome-trace/Perfetto JSON and JSONL renderers
//!   ([`Engine::export_trace`](crate::somd::Engine::export_trace), the
//!   `somd trace` subcommand).
//! * [`hub`] — the [`MetricsHub`] registry (counters, gauges, bounded
//!   histogram windows) with Prometheus text exposition
//!   ([`Service::metrics_text`](crate::serve::Service::metrics_text)).
//! * [`scrape`] — an optional `std::net` scrape endpoint serving that
//!   text.
//!
//! Knobs: `SOMD_TRACE`, `SOMD_TRACE_CAP`.  Span taxonomy, exporter
//! formats and the metric name scheme are documented in
//! `docs/OBSERVABILITY.md`.

pub mod export;
pub mod hub;
pub mod scrape;
pub mod trace;

pub use export::{chrome_trace, jsonl, TraceFormat};
pub use hub::{HubSnapshot, MetricsHub};
pub use scrape::{spawn_metrics_endpoint, MetricsEndpoint};
pub use trace::{
    FieldValue, OpenSpan, SpanRecord, SpanRef, Trace, TraceCtx, TraceRecorder, DEFAULT_TRACE_CAP,
};
