//! Span tracing: a per-[`Engine`](crate::somd::Engine) bounded
//! ring-buffer recorder for nested invocation spans.
//!
//! The recorder is built for a hot path that is almost always *not*
//! tracing: [`TraceRecorder::begin`] is a single relaxed atomic load
//! when disabled, and every [`TraceCtx`]/[`OpenSpan`] operation on a
//! disabled context is a no-op on plain fields (no lock, no clock
//! read).  When enabled, spans carry parent ids so one invocation's
//! hybrid forks, N-way sharded latches, cluster peer spans (stitched by
//! trace id over the wire protocol), batched serve dispatches and
//! pipeline stages all nest under one trace; whole traces are evicted
//! oldest-first once the ring holds `cap` of them.
//!
//! Knobs: `SOMD_TRACE` (`1`/`on`/`true`/`yes` enables recording),
//! `SOMD_TRACE_CAP` (ring capacity in whole traces, default 64).  See
//! `docs/OBSERVABILITY.md` for the span taxonomy.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default ring capacity (whole traces) when `SOMD_TRACE_CAP` is unset.
pub const DEFAULT_TRACE_CAP: usize = 64;

/// One recorded span field value.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldValue {
    /// An unsigned integer (counts, bytes, ids).
    U64(u64),
    /// A float (seconds, fractions, estimates).
    F64(f64),
    /// A short string (lane names, reasons, profiles).
    Str(String),
}

/// One completed span: a named interval inside a trace, with an
/// optional parent span id and a flat key/value field list.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Span id, unique within the recorder.
    pub id: u64,
    /// Parent span id (`None` for a trace's root span).
    pub parent: Option<u64>,
    /// The trace this span belongs to.
    pub trace_id: u64,
    /// Span name (see the taxonomy in `docs/OBSERVABILITY.md`).
    pub name: &'static str,
    /// Start, in nanoseconds since the recorder's epoch.
    pub start_ns: u64,
    /// End, in nanoseconds since the recorder's epoch.
    pub end_ns: u64,
    /// Attached key/value payload (decision explains, byte counts, …).
    pub fields: Vec<(&'static str, FieldValue)>,
}

impl SpanRecord {
    /// Field lookup by key (first match).
    pub fn field(&self, key: &str) -> Option<&FieldValue> {
        self.fields.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

/// One invocation's spans, in completion order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// The trace id every member span carries.
    pub trace_id: u64,
    /// Completed spans (a span appears when it *finishes*, so parents —
    /// which outlive their children — appear after them).
    pub spans: Vec<SpanRecord>,
}

impl Trace {
    /// The root spans of this trace (no parent).  A well-formed
    /// invocation trace has exactly one.
    pub fn roots(&self) -> Vec<&SpanRecord> {
        self.spans.iter().filter(|s| s.parent.is_none()).collect()
    }

    /// Find the first span with `name`.
    pub fn find(&self, name: &str) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.name == name)
    }

    /// All spans with `name`.
    pub fn find_all(&self, name: &str) -> Vec<&SpanRecord> {
        self.spans.iter().filter(|s| s.name == name).collect()
    }
}

struct Ring {
    traces: VecDeque<Trace>,
}

/// The per-engine span recorder: a bounded ring of whole traces.
///
/// Cheap to share (`Arc`); disabled recorders cost one relaxed atomic
/// load per would-be trace.  See the [module docs](self) for knobs.
pub struct TraceRecorder {
    enabled: AtomicBool,
    cap: usize,
    next_span: AtomicU64,
    next_trace: AtomicU64,
    epoch: Instant,
    ring: Mutex<Ring>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("enabled", &self.enabled())
            .field("cap", &self.cap)
            .finish()
    }
}

fn env_truthy(var: &str) -> bool {
    matches!(
        std::env::var(var).unwrap_or_default().trim().to_ascii_lowercase().as_str(),
        "1" | "on" | "true" | "yes"
    )
}

impl TraceRecorder {
    /// A recorder with explicit settings (`cap` is clamped to ≥ 1).
    pub fn new(enabled: bool, cap: usize) -> TraceRecorder {
        TraceRecorder {
            enabled: AtomicBool::new(enabled),
            cap: cap.max(1),
            next_span: AtomicU64::new(1),
            next_trace: AtomicU64::new(1),
            epoch: Instant::now(),
            ring: Mutex::new(Ring { traces: VecDeque::new() }),
        }
    }

    /// A recorder configured from `SOMD_TRACE` / `SOMD_TRACE_CAP`.
    pub fn from_env() -> TraceRecorder {
        let cap = std::env::var("SOMD_TRACE_CAP")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_TRACE_CAP);
        TraceRecorder::new(env_truthy("SOMD_TRACE"), cap)
    }

    /// Whether spans are currently being recorded.
    pub fn enabled(&self) -> bool {
        // Relaxed: the flag gates best-effort diagnostics, not data the
        // compute path depends on — no ordering with other memory needed
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording on/off at runtime (already-open spans keep their
    /// recording decision).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Ring capacity, in whole traces.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Start a fresh trace.  When disabled this is one atomic load and
    /// the returned context records nothing.
    pub fn begin(self: &Arc<Self>) -> TraceCtx {
        if !self.enabled() {
            return TraceCtx::disabled();
        }
        let id = self.next_trace.fetch_add(1, Ordering::Relaxed);
        TraceCtx { rec: Some(self.clone()), trace_id: id }
    }

    /// Join an existing trace by id (cluster peers stitch the client's
    /// trace id received over the wire; `0` means "no trace").
    pub fn join(self: &Arc<Self>, trace_id: u64) -> TraceCtx {
        if trace_id == 0 || !self.enabled() {
            return TraceCtx::disabled();
        }
        TraceCtx { rec: Some(self.clone()), trace_id }
    }

    /// Nanoseconds since this recorder's epoch.
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn record(&self, span: SpanRecord) {
        let mut ring = self.ring.lock().unwrap();
        // newest traces live at the back; spans of an in-flight trace
        // almost always target it, so scan from the back
        if let Some(t) = ring.traces.iter_mut().rev().find(|t| t.trace_id == span.trace_id) {
            t.spans.push(span);
            return;
        }
        if ring.traces.len() >= self.cap {
            ring.traces.pop_front(); // evict the oldest *whole* trace
        }
        ring.traces.push_back(Trace { trace_id: span.trace_id, spans: vec![span] });
    }

    /// Point-in-time copy of every retained trace, oldest first.
    pub fn traces(&self) -> Vec<Trace> {
        self.ring.lock().unwrap().traces.iter().cloned().collect()
    }

    /// Drop every retained trace (the span/trace id counters keep
    /// counting so ids never repeat within a recorder).
    pub fn clear(&self) {
        self.ring.lock().unwrap().traces.clear();
    }

    /// Total retained spans across all traces.
    pub fn span_count(&self) -> usize {
        self.ring.lock().unwrap().traces.iter().map(|t| t.spans.len()).sum()
    }

    /// Retained trace count.
    pub fn trace_count(&self) -> usize {
        self.ring.lock().unwrap().traces.len()
    }
}

/// A (trace id, span id) pair naming one open span across layer
/// boundaries — how the serving layer parents engine invocations under
/// its batch span without holding the span itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRef {
    /// The trace the span belongs to.
    pub trace: u64,
    /// The span id.
    pub span: u64,
}

/// A handle on one trace: the factory spans of a single invocation are
/// opened through.  Cloneable and `Send` so forks (hybrid halves,
/// sharded lanes, remote callbacks) can open sibling spans; a context
/// from a disabled recorder records nothing at zero cost.
#[derive(Clone)]
pub struct TraceCtx {
    rec: Option<Arc<TraceRecorder>>,
    trace_id: u64,
}

impl std::fmt::Debug for TraceCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCtx")
            .field("trace_id", &self.trace_id)
            .field("recording", &self.is_recording())
            .finish()
    }
}

impl TraceCtx {
    /// A context that records nothing.
    pub fn disabled() -> TraceCtx {
        TraceCtx { rec: None, trace_id: 0 }
    }

    /// Whether spans opened here will be recorded.
    pub fn is_recording(&self) -> bool {
        self.rec.is_some()
    }

    /// This context's trace id (`0` when disabled).
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Open a span.  `parent` is a span id from this same trace
    /// (usually [`OpenSpan::id`] of the enclosing span), `None` for the
    /// root.  The span records itself when dropped or
    /// [`finish`](OpenSpan::finish)ed — exactly once, even across
    /// panics.
    pub fn span(&self, name: &'static str, parent: Option<u64>) -> OpenSpan {
        match &self.rec {
            None => OpenSpan {
                rec: None,
                trace_id: 0,
                id: 0,
                parent: None,
                name,
                start_ns: 0,
                fields: Vec::new(),
            },
            Some(rec) => OpenSpan {
                id: rec.next_span.fetch_add(1, Ordering::Relaxed),
                start_ns: rec.now_ns(),
                rec: Some(rec.clone()),
                trace_id: self.trace_id,
                parent: parent.filter(|&p| p != 0),
                name,
                fields: Vec::new(),
            },
        }
    }
}

/// An in-flight span.  Dropping it records the interval (so unwinding
/// through a panic still closes the span); attach payload with the
/// `field_*` setters while it is open.
pub struct OpenSpan {
    rec: Option<Arc<TraceRecorder>>,
    trace_id: u64,
    id: u64,
    parent: Option<u64>,
    name: &'static str,
    start_ns: u64,
    fields: Vec<(&'static str, FieldValue)>,
}

impl OpenSpan {
    /// This span's id, for parenting children (`0` when not recording).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Whether this span will actually be recorded.
    pub fn is_recording(&self) -> bool {
        self.rec.is_some()
    }

    /// A [`SpanRef`] naming this span (`None` when not recording).
    pub fn span_ref(&self) -> Option<SpanRef> {
        self.rec.as_ref().map(|_| SpanRef { trace: self.trace_id, span: self.id })
    }

    /// Attach an integer field.
    pub fn field_u64(&mut self, key: &'static str, v: u64) {
        if self.rec.is_some() {
            self.fields.push((key, FieldValue::U64(v)));
        }
    }

    /// Attach a float field.
    pub fn field_f64(&mut self, key: &'static str, v: f64) {
        if self.rec.is_some() {
            self.fields.push((key, FieldValue::F64(v)));
        }
    }

    /// Attach a string field.
    pub fn field_str(&mut self, key: &'static str, v: impl Into<String>) {
        if self.rec.is_some() {
            self.fields.push((key, FieldValue::Str(v.into())));
        }
    }

    /// Close the span now (equivalent to dropping it; provided so call
    /// sites can mark the intended end explicitly).
    pub fn finish(self) {}
}

impl Drop for OpenSpan {
    fn drop(&mut self) {
        let Some(rec) = self.rec.take() else { return };
        let end_ns = rec.now_ns();
        rec.record(SpanRecord {
            id: self.id,
            parent: self.parent,
            trace_id: self.trace_id,
            name: self.name,
            start_ns: self.start_ns,
            end_ns,
            fields: std::mem::take(&mut self.fields),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Arc::new(TraceRecorder::new(false, 8));
        let ctx = rec.begin();
        assert!(!ctx.is_recording());
        let mut s = ctx.span("invoke", None);
        s.field_u64("items", 10);
        assert_eq!(s.id(), 0);
        s.finish();
        assert_eq!(rec.span_count(), 0);
        assert_eq!(rec.trace_count(), 0);
    }

    #[test]
    fn spans_nest_and_record_once() {
        let rec = Arc::new(TraceRecorder::new(true, 8));
        let ctx = rec.begin();
        let mut root = ctx.span("invoke", None);
        root.field_str("method", "M.run");
        let child = ctx.span("lane.smp", Some(root.id()));
        let root_id = root.id();
        let child_id = child.id();
        child.finish();
        root.finish();
        let traces = rec.traces();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.spans.len(), 2);
        let root = t.find("invoke").unwrap();
        let child = t.find("lane.smp").unwrap();
        assert_eq!(root.id, root_id);
        assert_eq!(root.parent, None);
        assert_eq!(child.parent, Some(root_id));
        assert_eq!(child.id, child_id);
        assert!(root.start_ns <= child.start_ns);
        assert!(child.end_ns <= root.end_ns);
        assert!(matches!(root.field("method"), Some(FieldValue::Str(s)) if s == "M.run"));
    }

    #[test]
    fn ring_evicts_oldest_whole_trace() {
        let rec = Arc::new(TraceRecorder::new(true, 2));
        let mut first_id = 0;
        for i in 0..3 {
            let ctx = rec.begin();
            if i == 0 {
                first_id = ctx.trace_id();
            }
            ctx.span("invoke", None).finish();
        }
        let traces = rec.traces();
        assert_eq!(traces.len(), 2);
        assert!(traces.iter().all(|t| t.trace_id != first_id));
    }

    #[test]
    fn join_stitches_and_zero_is_disabled() {
        let rec = Arc::new(TraceRecorder::new(true, 4));
        let ctx = rec.begin();
        let id = ctx.trace_id();
        ctx.span("invoke", None).finish();
        let peer = rec.join(id);
        peer.span("peer.execute", None).finish();
        assert_eq!(rec.trace_count(), 1);
        assert_eq!(rec.traces()[0].spans.len(), 2);
        assert!(!rec.join(0).is_recording());
    }

    #[test]
    fn runtime_toggle() {
        let rec = Arc::new(TraceRecorder::new(false, 4));
        assert!(!rec.begin().is_recording());
        rec.set_enabled(true);
        assert!(rec.begin().is_recording());
    }
}
