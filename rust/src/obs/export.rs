//! Trace exporters: Chrome-trace/Perfetto JSON and JSONL.
//!
//! Both render through [`crate::util::json::Json`] so string escaping
//! and number formatting are exactly the crate's canonical JSON (no
//! serde, like the rest of the tree).  `chrome.json` files open
//! directly in `chrome://tracing` / [Perfetto](https://ui.perfetto.dev)
//! as complete-event (`ph: "X"`) timelines — one "process" per trace,
//! one "thread" per span, so nesting renders as the familiar flame
//! rows; JSONL emits one span object per line for `jq`-style pipelines.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::trace::{FieldValue, SpanRecord, Trace};

/// Export format selector for [`Engine::export_trace`].
///
/// [`Engine::export_trace`]: crate::somd::Engine::export_trace
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome-trace / Perfetto JSON (`{"traceEvents": [...]}`).
    Chrome,
    /// One JSON object per span per line.
    Jsonl,
}

impl TraceFormat {
    /// Parse a CLI spelling (`chrome` | `jsonl`).
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s.trim().to_ascii_lowercase().as_str() {
            "chrome" | "perfetto" | "json" => Some(TraceFormat::Chrome),
            "jsonl" => Some(TraceFormat::Jsonl),
            _ => None,
        }
    }
}

fn field_json(v: &FieldValue) -> Json {
    match v {
        FieldValue::U64(n) => Json::Num(*n as f64),
        FieldValue::F64(f) => Json::Num(*f),
        FieldValue::Str(s) => Json::Str(s.clone()),
    }
}

fn span_args(span: &SpanRecord) -> Json {
    let mut args = BTreeMap::new();
    if let Some(p) = span.parent {
        args.insert("parent".to_string(), Json::Num(p as f64));
    }
    for (k, v) in &span.fields {
        args.insert((*k).to_string(), field_json(v));
    }
    Json::Obj(args)
}

/// Render traces as one Chrome-trace JSON document.
pub fn chrome_trace(traces: &[Trace]) -> String {
    let mut events = Vec::new();
    for t in traces {
        for s in &t.spans {
            let mut e = BTreeMap::new();
            e.insert("ph".to_string(), Json::Str("X".to_string()));
            e.insert("name".to_string(), Json::Str(s.name.to_string()));
            // chrome timestamps are microseconds; keep sub-µs precision
            e.insert("ts".to_string(), Json::Num(s.start_ns as f64 / 1000.0));
            e.insert(
                "dur".to_string(),
                Json::Num(s.end_ns.saturating_sub(s.start_ns) as f64 / 1000.0),
            );
            e.insert("pid".to_string(), Json::Num(t.trace_id as f64));
            e.insert("tid".to_string(), Json::Num(s.id as f64));
            e.insert("args".to_string(), span_args(s));
            events.push(Json::Obj(e));
        }
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(top).dump()
}

/// Render traces as JSONL: one span object per line, in trace order.
pub fn jsonl(traces: &[Trace]) -> String {
    let mut out = String::new();
    for t in traces {
        for s in &t.spans {
            let mut o = BTreeMap::new();
            o.insert("trace".to_string(), Json::Num(t.trace_id as f64));
            o.insert("span".to_string(), Json::Num(s.id as f64));
            if let Some(p) = s.parent {
                o.insert("parent".to_string(), Json::Num(p as f64));
            }
            o.insert("name".to_string(), Json::Str(s.name.to_string()));
            o.insert("start_ns".to_string(), Json::Num(s.start_ns as f64));
            o.insert("end_ns".to_string(), Json::Num(s.end_ns as f64));
            let mut fields = BTreeMap::new();
            for (k, v) in &s.fields {
                fields.insert((*k).to_string(), field_json(v));
            }
            o.insert("fields".to_string(), Json::Obj(fields));
            out.push_str(&Json::Obj(o).dump());
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceRecorder;
    use std::sync::Arc;

    fn sample() -> Vec<Trace> {
        let rec = Arc::new(TraceRecorder::new(true, 4));
        let ctx = rec.begin();
        let mut root = ctx.span("invoke", None);
        root.field_str("method", "M\"quoted\".run");
        let mut child = ctx.span("lane.device", Some(root.id()));
        child.field_u64("bytes_h2d", 4096);
        child.finish();
        root.finish();
        rec.traces()
    }

    #[test]
    fn chrome_trace_parses_and_carries_events() {
        let doc = chrome_trace(&sample());
        let v = Json::parse(&doc).expect("chrome trace must be valid JSON");
        let events = v.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
        }
        let dev = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("lane.device"))
            .unwrap();
        let h2d = dev.get("args").and_then(|a| a.get("bytes_h2d")).and_then(Json::as_f64);
        assert_eq!(h2d, Some(4096.0));
        assert!(dev.get("args").and_then(|a| a.get("parent")).is_some());
    }

    #[test]
    fn jsonl_one_parseable_object_per_span() {
        let text = jsonl(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).expect("each JSONL line must parse");
            assert!(v.get("name").and_then(Json::as_str).is_some());
        }
    }

    #[test]
    fn format_parses() {
        assert_eq!(TraceFormat::parse("chrome"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("JSONL"), Some(TraceFormat::Jsonl));
        assert_eq!(TraceFormat::parse("xml"), None);
    }
}
