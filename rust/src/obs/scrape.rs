//! An optional Prometheus scrape endpoint over `std::net` (no HTTP
//! stack, same no-dependency discipline as the cluster wire).
//!
//! [`spawn_metrics_endpoint`] binds a listener and answers every HTTP
//! request with the current metrics text; the returned handle stops the
//! listener on drop.  One request per connection, HTTP/1.0-style —
//! exactly what a Prometheus scraper (or `curl`) needs and nothing
//! more.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

/// A running scrape endpoint; dropping it stops the listener thread.
pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsEndpoint").field("addr", &self.addr).finish()
    }
}

impl MetricsEndpoint {
    /// The bound address (resolves an ephemeral `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Bind `addr` (may be `127.0.0.1:0`) and serve `render()` as
/// `text/plain; version=0.0.4` to every request until the returned
/// handle is dropped.
pub fn spawn_metrics_endpoint(
    addr: &str,
    render: impl Fn() -> String + Send + Sync + 'static,
) -> Result<MetricsEndpoint> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind metrics {addr}"))?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let thread_stop = stop.clone();
    let handle = std::thread::Builder::new()
        .name("somd-metrics".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if thread_stop.load(Ordering::SeqCst) {
                    return;
                }
                let Ok(mut stream) = conn else { return };
                stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
                // drain the request line + headers (best effort; scrapers
                // send tiny GETs, and the reply is the same regardless)
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = render();
                let reply = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(reply.as_bytes());
            }
        })
        .context("spawn metrics endpoint")?;
    Ok(MetricsEndpoint { addr: local, stop, handle: Some(handle) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn endpoint_serves_and_stops() {
        let ep = spawn_metrics_endpoint("127.0.0.1:0", || "somd_up 1\n".to_string()).unwrap();
        let addr = ep.addr();
        let reply = http_get(addr);
        assert!(reply.starts_with("HTTP/1.0 200 OK"), "got: {reply}");
        assert!(reply.contains("text/plain; version=0.0.4"));
        assert!(reply.ends_with("somd_up 1\n"));
        drop(ep);
        // the listener is gone: a fresh connect either fails outright or
        // is the throwaway accept draining — a follow-up must fail
        std::thread::sleep(Duration::from_millis(50));
        assert!(TcpStream::connect(addr).is_err() || TcpStream::connect(addr).is_err());
    }
}
