//! # SOMD — Single Operation Multiple Data
//!
//! A reproduction of *"Heterogeneous Programming with Single Operation
//! Multiple Data"* (Paulino & Marques, JCSS 2013) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SOMD coordination runtime: `dist`/`reduce`
//!   strategies, method instances, `sync` fences, intermediate reductions,
//!   shared scalars/arrays, the Elina-like engine, and the version
//!   selector ([`somd`]).
//! * **Device backend** — the paper's GPU target, realized as AOT-compiled
//!   XLA executables run through PJRT ([`runtime`]) under a GPU
//!   cost-structure simulator ([`device`]): explicit put/get transfers,
//!   thread-grid configuration, one kernel launch per `sync` iteration.
//! * **Benchmarks** — the JavaGrande Section-2 substrate used by the
//!   paper's evaluation ([`bench_suite`]): sequential, SOMD, and
//!   hand-threaded versions of Crypt, LUFact, Series, SOR and
//!   SparseMatMult, plus the harness regenerating every table and figure.
//! * **Serving layer** — a multi-client invocation service in front of
//!   the engine ([`serve`]): per-method micro-batch queues coalesce
//!   compatible concurrent requests into few fused launches, with
//!   admission control and graceful drain.
//! * **Observability** — invocation tracing + the unified metrics hub
//!   ([`obs`]): nested spans for every placement decision and lane
//!   execution (Chrome-trace/JSONL export), and a Prometheus-exposable
//!   metrics registry (see `docs/OBSERVABILITY.md`).
//!
//! See DESIGN.md for the paper→repo map, `docs/ARCHITECTURE.md` for the
//! navigable three-layer guide (including the hybrid co-execution
//! walkthrough and the serving sequence diagram), `docs/SERVING.md` for
//! the serving layer, `docs/BENCHMARKS.md` for the bench surface, and
//! EXPERIMENTS.md for results.

#![warn(missing_docs)]

pub mod backend;
pub mod bench_suite;
pub mod device;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod somd;
pub mod util;

/// Crate version (also reported by `somd --version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
