//! `network_bench` — cluster-wire latency probe.
//!
//! Measures per-peer round-trip time through the cluster lane's real
//! `Ping`/`Pong` frames (the same codepath the engine's heartbeats use),
//! reporting p50/p95/p99 percentiles per peer.  Injected latency
//! (`--delay-ms` on a peer, or `SOMD_CLUSTER_INJECT_DELAY_MS`) shows up
//! directly in the percentiles, so the tool doubles as a WAN-simulation
//! sanity check for `docs/CLUSTER.md`'s deadline guidance.
//!
//! ```text
//! network_bench serve [--addr HOST:PORT] [--delay-ms MS]
//! network_bench ping  --peers host:port[,host:port...] [--probes N]
//! network_bench local [--peers N] [--probes N] [--delay-ms MS]
//! ```
//!
//! * `serve` — host a minimal echo peer until killed (prints
//!   `SOMD_CLUSTER_LISTENING <addr>` once bound);
//! * `ping` — probe already-running peers;
//! * `local` — self-spawn `--peers` echo peers on ephemeral localhost
//!   ports, probe them, print the report, and kill them.
//!
//! Output: one JSON object (`schema: network_rtt/v1`) on stdout.

use std::collections::BTreeMap;
use std::io::BufRead;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use somd::somd::cluster::{ClusterClient, ClusterConfig, MethodHost, PeerServer, ServeOptions};
use somd::util::cli::Args;
use somd::util::json::Json;
use somd::util::stats;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("serve") => serve(args),
        Some("ping") => {
            let peers: Vec<String> = args
                .opt("peers")
                .ok_or_else(|| anyhow!("ping needs --peers host:port[,host:port...]"))?
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let probes = args.opt_usize("probes", 100);
            let report = probe_peers(&peers, probes)?;
            println!("{}", report.dump());
            Ok(())
        }
        Some("local") => local(args),
        _ => {
            eprintln!(
                "usage: network_bench <serve|ping|local>\n\
                 \x20 serve [--addr HOST:PORT] [--delay-ms MS]\n\
                 \x20 ping  --peers host:port[,host:port...] [--probes N]\n\
                 \x20 local [--peers N] [--probes N] [--delay-ms MS]"
            );
            Ok(())
        }
    }
}

/// Host a minimal echo peer forever (the probe target of `ping`/`local`).
fn serve(args: &Args) -> Result<()> {
    let addr = args.opt("addr").unwrap_or("127.0.0.1:0");
    let mut opts = ServeOptions::from_env();
    if let Some(ms) = args.opt("delay-ms") {
        opts.injected_delay = Duration::from_millis(ms.parse()?);
    }
    let host = Arc::new(
        MethodHost::new("network-bench-echo")
            .register("Echo.bytes", |payload, _span| Ok(payload.to_vec())),
    );
    let server = PeerServer::bind(addr, host, opts)?;
    println!("SOMD_CLUSTER_LISTENING {}", server.addr());
    loop {
        std::thread::park();
    }
}

/// Connect to each peer and measure ping RTT percentiles.
fn probe_peers(peers: &[String], probes: usize) -> Result<Json> {
    if peers.is_empty() {
        bail!("no peers to probe");
    }
    let cfg = ClusterConfig::from_env();
    let mut rows = Vec::new();
    for addr in peers {
        let client = ClusterClient::connect(addr, cfg)?;
        client.ping()?; // warm the path, untimed
        let mut ms = Vec::with_capacity(probes);
        for _ in 0..probes.max(1) {
            ms.push(client.ping()?.as_secs_f64() * 1e3);
        }
        let p = stats::percentiles(&ms);
        let mut m = BTreeMap::new();
        m.insert("peer".to_string(), Json::Str(format!("tcp://{addr}")));
        m.insert("name".to_string(), Json::Str(client.peer_name().to_string()));
        m.insert("n".to_string(), Json::Num(p.n as f64));
        m.insert("p50_ms".to_string(), Json::Num(p.p50));
        m.insert("p95_ms".to_string(), Json::Num(p.p95));
        m.insert("p99_ms".to_string(), Json::Num(p.p99));
        m.insert("max_ms".to_string(), Json::Num(p.max));
        rows.push(Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str("network_rtt/v1".to_string()));
    top.insert("probes".to_string(), Json::Num(probes as f64));
    top.insert("peers".to_string(), Json::Arr(rows));
    Ok(Json::Obj(top))
}

/// Self-spawn echo peers, probe them, report, and tear them down.
fn local(args: &Args) -> Result<()> {
    let n = args.opt_usize("peers", 2).max(1);
    let probes = args.opt_usize("probes", 100);
    let delay = args.opt("delay-ms").unwrap_or("0").to_string();
    let exe = std::env::current_exe().context("locate network_bench")?;
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let mut cmd = Command::new(&exe);
        cmd.arg("serve").arg("--addr").arg("127.0.0.1:0");
        if delay != "0" {
            cmd.arg("--delay-ms").arg(&delay);
        }
        cmd.stdout(Stdio::piped()).stderr(Stdio::null());
        let mut child = cmd.spawn().context("spawn echo peer")?;
        let stdout = child.stdout.take().ok_or_else(|| anyhow!("peer stdout not piped"))?;
        let mut lines = std::io::BufReader::new(stdout).lines();
        let addr = loop {
            match lines.next() {
                Some(Ok(line)) => {
                    if let Some(rest) = line.strip_prefix("SOMD_CLUSTER_LISTENING ") {
                        break rest.trim().to_string();
                    }
                }
                Some(Err(e)) => {
                    let _ = child.kill();
                    return Err(anyhow!("reading peer stdout: {e}"));
                }
                None => {
                    let _ = child.kill();
                    bail!("echo peer exited before announcing its address");
                }
            }
        };
        std::thread::spawn(move || for _ in lines {});
        children.push(child);
        addrs.push(addr);
    }
    let report = probe_peers(&addrs, probes);
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
    println!("{}", report?.dump());
    Ok(())
}
