//! Multi-architecture method dispatch (paper Figure 9 + §6): one SOMD
//! source, several compiled versions; the runtime picks per the user's
//! `method:target` rules and falls back to shared memory when a
//! preference is inapplicable on the available hardware.
//!
//! Beyond the paper's static rules, `method:auto` defers the choice to
//! the engine's [`Scheduler`](crate::somd::scheduler::Scheduler): every
//! invocation through this module feeds its observed SMP wall time or
//! device stats back into the per-method execution history, so `auto`
//! converges on whichever architecture actually runs the method fastest.

use std::time::Instant;

use anyhow::Result;

use crate::device::{DeviceProfile, DeviceSession, DeviceStats};
use crate::runtime::Registry;
use crate::somd::engine::Engine;
use crate::somd::master::SomdMethod;
use crate::somd::Target;

/// A device-side implementation of a SOMD method (the master code of
/// Algorithm 2, driving kernels through a [`DeviceSession`]).
///
/// `Send + Sync` so a [`HeteroMethod`] can be shared with the engine's
/// device master thread; the *session* handed in at call time is still
/// thread-confined.
pub type DeviceFn<I, R> = Box<dyn Fn(&mut DeviceSession<'_>, &I) -> Result<R> + Send + Sync>;

/// The compiled versions of one SOMD method.
pub struct HeteroMethod<I: ?Sized, P, E, R> {
    pub smp: SomdMethod<I, P, E, R>,
    device: Option<DeviceFn<I, R>>,
}

/// Where an invocation actually ran (after fallback resolution).
#[derive(Debug, Clone, PartialEq)]
pub enum Executed {
    Smp { partitions: usize },
    Device { profile: &'static str, stats: DeviceStats },
}

impl<I: ?Sized + Sync, P: Send + Sync, E: Sync, R: Send> HeteroMethod<I, P, E, R> {
    pub fn smp_only(smp: SomdMethod<I, P, E, R>) -> Self {
        Self { smp, device: None }
    }

    pub fn with_device(smp: SomdMethod<I, P, E, R>, device: DeviceFn<I, R>) -> Self {
        Self { smp, device: Some(device) }
    }

    pub fn name(&self) -> &str {
        self.smp.name()
    }

    pub fn has_device_version(&self) -> bool {
        self.device.is_some()
    }

    /// Resolve the target for this method (§6): user rules first, then
    /// applicability (device version compiled? profile known? registry
    /// loaded?) — inapplicable preferences revert to the default.
    /// `auto` consults the engine's execution-history cost model.
    /// Delegates to [`Engine::resolve_target`] so the sync and async
    /// entry points can never drift apart.
    pub fn resolve(&self, engine: &Engine, registry: Option<&Registry>) -> Target {
        engine.resolve_target(self.smp.name(), &|profile: &str| {
            self.device.is_some()
                && registry.is_some()
                && DeviceProfile::by_name(profile).is_some()
        })
    }

    /// Invoke through the engine, honoring the rules; returns the result
    /// and where it ran.  Observed timings feed the scheduler history.
    pub fn invoke(
        &self,
        engine: &Engine,
        registry: Option<&Registry>,
        input: &I,
    ) -> Result<(R, Executed)> {
        match self.resolve(engine, registry) {
            Target::Smp | Target::Auto => {
                let t0 = Instant::now();
                let r = self.smp.invoke(input, engine.workers());
                engine.scheduler().record_smp(self.smp.name(), t0.elapsed());
                Ok((r, Executed::Smp { partitions: engine.workers() }))
            }
            Target::Device(name) => {
                let profile = DeviceProfile::by_name(&name).expect("resolved profile");
                let reg = registry.expect("resolved registry");
                let mut session = DeviceSession::new(reg, profile);
                let t0 = Instant::now();
                let r = match self.invoke_on_session(&mut session, input) {
                    Ok(r) => r,
                    Err(e) => {
                        // feed the failure to the cost model so `auto`
                        // steers back to SMP instead of retrying forever
                        engine.scheduler().record_device_failure(self.smp.name());
                        return Err(e);
                    }
                };
                let measured = t0.elapsed();
                let stats = session.stats();
                engine.scheduler().record_device(self.smp.name(), measured, &stats);
                Ok((
                    r,
                    Executed::Device { profile: session.profile().name, stats },
                ))
            }
        }
    }

    /// Run the compiled device version on an existing (possibly warm)
    /// session — the engine's device master lane enters here.
    pub fn invoke_on_session(
        &self,
        session: &mut DeviceSession<'_>,
        input: &I,
    ) -> Result<R> {
        let dev = self
            .device
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("method '{}' has no device version", self.name()))?;
        dev(session, input)
    }

    /// Force execution on a given device profile regardless of rules
    /// (bench harness entry).
    pub fn invoke_on_device(
        &self,
        registry: &Registry,
        profile: DeviceProfile,
        input: &I,
    ) -> Result<(R, DeviceStats)> {
        let mut session = DeviceSession::new(registry, profile);
        let r = self.invoke_on_session(&mut session, input)?;
        let stats = session.stats();
        Ok((r, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::partition::Block1D;
    use crate::somd::scheduler::Choice;
    use crate::somd::{reduction, Rules};
    use std::time::Duration;

    fn method() -> HeteroMethod<Vec<i64>, crate::somd::partition::BlockPart, (), i64> {
        HeteroMethod::smp_only(SomdMethod::new(
            "Sum.sum",
            |v: &Vec<i64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, p, _, _| p.own.iter().map(|i| v[i]).sum(),
            reduction::sum::<i64>(),
        ))
    }

    #[test]
    fn defaults_to_smp() {
        let e = Engine::new(2);
        let m = method();
        let (r, how) = m.invoke(&e, None, &vec![1, 2, 3]).unwrap();
        assert_eq!(r, 6);
        assert_eq!(how, Executed::Smp { partitions: 2 });
    }

    #[test]
    fn inapplicable_device_rule_falls_back() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Device("fermi".into()));
        let e = Engine::with_rules(2, rules);
        let m = method(); // no device version, no registry
        assert_eq!(m.resolve(&e, None), Target::Smp);
        let (r, _) = m.invoke(&e, None, &vec![5, 5]).unwrap();
        assert_eq!(r, 10);
    }

    #[test]
    fn unknown_profile_falls_back() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Device("h100".into()));
        let e = Engine::with_rules(2, rules);
        let m = method();
        assert_eq!(m.resolve(&e, None), Target::Smp);
    }

    #[test]
    fn auto_without_device_version_falls_back_to_smp() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Auto);
        let e = Engine::with_rules(2, rules);
        let m = method(); // no device version compiled
        assert_eq!(m.resolve(&e, None), Target::Smp);
        let (r, how) = m.invoke(&e, None, &vec![2, 3]).unwrap();
        assert_eq!(r, 5);
        assert!(matches!(how, Executed::Smp { .. }));
    }

    #[test]
    fn invocations_record_history() {
        let e = Engine::new(2);
        let m = method();
        m.invoke(&e, None, &vec![1, 2, 3]).unwrap();
        m.invoke(&e, None, &vec![4, 5, 6]).unwrap();
        let h = e.scheduler().history("Sum.sum").expect("history");
        assert_eq!(h.smp_runs, 2);
        assert!(h.smp_secs.iter().all(|&s| s >= 0.0));
        assert_eq!(h.device_runs, 0);
        // seeded device history (measured wall) steers a later auto decision
        e.scheduler().record_device("Sum.sum", Duration::from_secs(5), &DeviceStats::default());
        e.scheduler().record_device("Sum.sum", Duration::from_secs(5), &DeviceStats::default());
        assert_eq!(e.scheduler().decide("Sum.sum"), Choice::Smp);
    }
}
