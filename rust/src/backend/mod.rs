//! Multi-architecture method dispatch (paper Figure 9 + §6): one SOMD
//! source, several compiled versions; the runtime picks per the user's
//! `method:target` rules and falls back to shared memory when a
//! preference is inapplicable on the available hardware.
//!
//! Beyond the paper's static rules, `method:auto` defers the choice to
//! the engine's [`Scheduler`](crate::somd::scheduler::Scheduler): every
//! invocation through this module feeds its observed SMP wall time or
//! device stats back into the per-method execution history, so `auto`
//! converges on whichever architecture actually runs the method fastest.
//!
//! Since the hybrid co-execution PR a method may additionally carry a
//! [`HybridSpec`]: the invocation's index space is then *split* between
//! the SMP pool and the device at the scheduler's learned ratio
//! (`method:hybrid` forces it; `method:auto` considers it as a third
//! lane), with the partial results merged through the method's ordinary
//! reduction.  Since the device-fleet PR the same spec also powers
//! **N-way sharding** (`method:sharded`): the engine splits one
//! invocation across the SMP pool *and every device lane of the fleet*
//! at the scheduler's learned per-lane weights, each lane evaluating one
//! contiguous sub-span through the spec's device evaluator.  See
//! `docs/ARCHITECTURE.md` for the full walkthrough and
//! `docs/PAPER_MAP.md` for the paper construct each piece implements.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::device::{BufId, DeviceProfile, DeviceSession, DeviceStats};
use crate::runtime::{HostTensor, Registry};
use crate::somd::distribution::Range1;
use crate::somd::engine::Engine;
use crate::somd::master::SomdMethod;
use crate::somd::partition::split_fraction;
use crate::somd::scheduler::{HybridSample, Scheduler};
use crate::somd::Target;

/// A device-side implementation of a SOMD method (the master code of
/// Algorithm 2, driving kernels through a [`DeviceSession`]).
///
/// `Send + Sync` so a [`HeteroMethod`] can be shared with the engine's
/// device master thread; the *session* handed in at call time is still
/// thread-confined.
pub type DeviceFn<I, R> = Box<dyn Fn(&mut DeviceSession<'_>, &I) -> Result<R> + Send + Sync>;

/// The three pieces hybrid co-execution needs from a method: the size of
/// its index space, an SMP evaluator over a sub-span, and a device
/// evaluator over a sub-span.
///
/// * `items` — how many index-space items one invocation covers (blocks
///   for Crypt, coefficients for Series, elements for vecadd, …).
/// * `smp` — compute the *partial results* for a sub-span on the CPU,
///   fanned out over `nparts` MIs (implementations typically call
///   [`Block1D::ranges_in`](crate::somd::partition::Block1D::ranges_in)
///   and [`run_mis`](crate::somd::master::run_mis) so the share executes
///   exactly like a whole-space invocation would).
/// * `device` — compute one partial result for a sub-span on a
///   [`DeviceSession`] (an AOT artifact launched over the sub-range;
///   see [`DeviceSession::get_rows`] for the partial-download entry).
///
/// The SMP share always covers the *leading* span and the device share
/// the *trailing* span, so `smp partials ++ [device partial]` is in rank
/// order and the method's ordinary reduction merges them.
pub struct HybridSpec<I: ?Sized, R> {
    items: Box<dyn Fn(&I) -> usize + Send + Sync>,
    smp: Box<dyn Fn(&I, Range1, usize) -> Vec<R> + Send + Sync>,
    device: Box<dyn Fn(&mut DeviceSession<'_>, &I, Range1) -> Result<R> + Send + Sync>,
}

impl<I: ?Sized, R> HybridSpec<I, R> {
    /// Build a hybrid spec from the three evaluators (see the type-level
    /// docs for their contracts).
    pub fn new(
        items: impl Fn(&I) -> usize + Send + Sync + 'static,
        smp: impl Fn(&I, Range1, usize) -> Vec<R> + Send + Sync + 'static,
        device: impl Fn(&mut DeviceSession<'_>, &I, Range1) -> Result<R> + Send + Sync + 'static,
    ) -> Self {
        Self { items: Box::new(items), smp: Box::new(smp), device: Box::new(device) }
    }
}

/// The batch-compose/split contract of one method: what the serving
/// layer's micro-batcher needs to coalesce N compatible invocations into
/// one fused invocation and de-multiplex the result (see
/// [`crate::serve`] and `docs/SERVING.md`).
///
/// * `items` — how many index-space items one request covers (the same
///   notion of "items" a [`HybridSpec`] uses, so batch caps and hybrid
///   splits speak one unit).
/// * `compat` — a compatibility key; only requests with equal keys may
///   fuse (e.g. Crypt requests hash their subkey schedule: two passes
///   under different keys must never share a launch).  Defaults to a
///   constant, i.e. "all requests to this method are compatible".
/// * `compose` — build the fused input from a batch of request inputs,
///   concatenating index spaces *in request order*.
/// * `split` — cut the fused result back into per-request results;
///   `counts[i]` is request `i`'s item count, in the same order
///   `compose` saw.  Must return exactly one result per request.
///
/// The contract the round-trip tests enforce: for any batch,
/// `split(invoke(compose(inputs)))[i]` is **bitwise identical** to
/// `invoke(inputs[i])` — coalescing is an execution-schedule choice,
/// never a semantic one.
pub struct BatchSpec<I: ?Sized, R> {
    items: Box<dyn Fn(&I) -> usize + Send + Sync>,
    compat: Box<dyn Fn(&I) -> u64 + Send + Sync>,
    compose: Box<dyn Fn(&[Arc<I>]) -> Arc<I> + Send + Sync>,
    split: Box<dyn Fn(R, &[usize]) -> Vec<R> + Send + Sync>,
}

impl<I: ?Sized, R> BatchSpec<I, R> {
    /// Build a batch spec from the three core evaluators (see the
    /// type-level docs for their contracts); every request is considered
    /// compatible until [`BatchSpec::with_compat`] installs a key.
    pub fn new(
        items: impl Fn(&I) -> usize + Send + Sync + 'static,
        compose: impl Fn(&[Arc<I>]) -> Arc<I> + Send + Sync + 'static,
        split: impl Fn(R, &[usize]) -> Vec<R> + Send + Sync + 'static,
    ) -> Self {
        Self {
            items: Box::new(items),
            compat: Box::new(|_| 0),
            compose: Box::new(compose),
            split: Box::new(split),
        }
    }

    /// Install a compatibility key: the batcher only fuses requests whose
    /// keys are equal (builder style).
    pub fn with_compat(mut self, compat: impl Fn(&I) -> u64 + Send + Sync + 'static) -> Self {
        self.compat = Box::new(compat);
        self
    }
}

/// The wire codecs the cluster lane needs from a method: how to encode a
/// sub-span of the input for shipment to a remote peer, and how to
/// decode the peer's partial-result bytes back into a partial the
/// ordinary reduction can merge.
///
/// * `encode` — serialize everything a peer needs to compute `span`
///   (typically just the span's slice of the distributed inputs plus any
///   replicated scalars — the paper's §4.2 scatter, on a socket).  The
///   byte layout is method-private: only this method's handler on the
///   peer (`somd cluster serve` registers one per method) ever reads it.
/// * `decode` — parse the peer's partial-result bytes into an `R`.  The
///   partial occupies the same rank-order slot a local device share
///   would, so `smp partials ++ lane partials` still merges through the
///   method's ordinary reduction.
///
/// A method with a `ClusterSpec` (and the [`HybridSpec`] that defines
/// its item space and SMP span evaluator) can shard across remote peers;
/// without one, remote lanes are simply not counted for that method.
pub struct ClusterSpec<I: ?Sized, R> {
    encode: Box<dyn Fn(&I, Range1) -> Vec<u8> + Send + Sync>,
    decode: Box<dyn Fn(&[u8]) -> Result<R> + Send + Sync>,
}

impl<I: ?Sized, R> ClusterSpec<I, R> {
    /// Build a cluster spec from the two codecs (see the type-level docs
    /// for their contracts).
    pub fn new(
        encode: impl Fn(&I, Range1) -> Vec<u8> + Send + Sync + 'static,
        decode: impl Fn(&[u8]) -> Result<R> + Send + Sync + 'static,
    ) -> Self {
        Self { encode: Box::new(encode), decode: Box::new(decode) }
    }
}

/// How one method participates as a *stage* of an
/// [`ExecutionPlan`](crate::somd::pipeline::ExecutionPlan): type-erased
/// evaluators over the pipeline's wire format — host tensors between
/// host-side lanes, resident device buffers between fused device stages.
///
/// * `smp` — host tensors in, host tensors out, on the SMP pool (always
///   present — the universal fallback, §6, extended to pipelines).
/// * `device` — resident buffers in, resident buffers out on one
///   [`DeviceSession`].  The stage takes ownership of its input handles
///   (it frees or forwards them) and its outputs *stay resident* for the
///   downstream stage — the whole point of the pipeline layer.
/// * `hybrid` — host tensors in/out, co-executed across SMP + device at
///   a **fixed** fraction (`SOMD_PIPELINE_HYBRID_FRACTION`): a learned
///   ratio would make the fused and reference runs split differently and
///   break the suite's bitwise-equality contract for order-sensitive
///   float reductions.
///
/// The contract the pipeline suite enforces: for equal input tensors,
/// every evaluator produces bitwise-identical output tensors under the
/// same lane — residency is an execution-schedule choice, never a
/// semantic one (the same promise [`BatchSpec`] makes for coalescing).
pub struct PipelineSpec {
    pub(crate) smp: Box<dyn Fn(&[HostTensor]) -> Result<Vec<HostTensor>> + Send + Sync>,
    pub(crate) device: Option<
        Box<
            dyn for<'r> Fn(&mut DeviceSession<'r>, Vec<BufId>) -> Result<Vec<BufId>>
                + Send
                + Sync,
        >,
    >,
    pub(crate) hybrid:
        Option<Box<dyn Fn(&Engine, &Registry, &[HostTensor]) -> Result<Vec<HostTensor>> + Send + Sync>>,
}

impl PipelineSpec {
    /// A stage with only the (always-applicable) SMP evaluator.
    pub fn new(
        smp: impl Fn(&[HostTensor]) -> Result<Vec<HostTensor>> + Send + Sync + 'static,
    ) -> Self {
        Self { smp: Box::new(smp), device: None, hybrid: None }
    }

    /// Attach a resident-buffer device evaluator (builder style).
    pub fn with_device(
        mut self,
        device: impl for<'r> Fn(&mut DeviceSession<'r>, Vec<BufId>) -> Result<Vec<BufId>>
            + Send
            + Sync
            + 'static,
    ) -> Self {
        self.device = Some(Box::new(device));
        self
    }

    /// Attach a fixed-fraction hybrid evaluator (builder style).
    pub fn with_hybrid(
        mut self,
        hybrid: impl Fn(&Engine, &Registry, &[HostTensor]) -> Result<Vec<HostTensor>>
            + Send
            + Sync
            + 'static,
    ) -> Self {
        self.hybrid = Some(Box::new(hybrid));
        self
    }

    /// Whether a resident-buffer device evaluator is attached.
    pub fn has_device(&self) -> bool {
        self.device.is_some()
    }

    /// Whether a fixed-fraction hybrid evaluator is attached.
    pub fn has_hybrid(&self) -> bool {
        self.hybrid.is_some()
    }
}

/// The device half's successful outcome, as handed to the shared hybrid
/// merge ([`HeteroMethod::finish_hybrid`]) by both the sync and the
/// async lane.
pub(crate) struct DeviceShare<R> {
    /// The device share's partial result.
    pub(crate) partial: R,
    /// The device share's own execute seconds (queue wait excluded).
    pub(crate) secs: f64,
    /// Per-share device accounting (stats delta on warm sessions).
    pub(crate) stats: DeviceStats,
    /// Profile the share ran under.
    pub(crate) profile: &'static str,
}

/// One sharded invocation's bookkeeping, handed to
/// [`HeteroMethod::finish_sharded`] by the engine's N-way completion
/// latch (the fleet counterpart of [`HybridMerge`]).
pub(crate) struct ShardedMerge<'a, I: ?Sized> {
    /// The scheduler history to feed.
    pub(crate) sched: &'a Scheduler,
    /// The invocation's input (needed to cover failed device spans).
    pub(crate) input: &'a I,
    /// The SMP share's (leading) span.
    pub(crate) smp_span: Range1,
    /// One contiguous span per device lane, in lane order after the SMP
    /// span; starved lanes hold empty spans.
    pub(crate) dev_spans: &'a [Range1],
    /// The per-lane device profile names, for the execution report.
    pub(crate) profiles: &'a [&'static str],
    /// The weight vector this invocation split at (SMP first).
    pub(crate) weights: &'a [f64],
    /// MI count of the SMP share (and of any failure covers).
    pub(crate) nparts: usize,
}

/// One forked invocation's bookkeeping, shared by the sync and async
/// hybrid lanes so their merge/fallback invariants cannot drift.
pub(crate) struct HybridMerge<'a, I: ?Sized> {
    /// The scheduler history to feed.
    pub(crate) sched: &'a Scheduler,
    /// The invocation's input (needed to cover a failed device share).
    pub(crate) input: &'a I,
    /// The SMP share's span.
    pub(crate) smp_span: Range1,
    /// The device share's span.
    pub(crate) dev_span: Range1,
    /// The split ratio this invocation used.
    pub(crate) fraction: f64,
    /// MI count of the SMP share (and of the fallback cover).
    pub(crate) nparts: usize,
}

/// The compiled versions of one SOMD method.
pub struct HeteroMethod<I: ?Sized, P, E, R> {
    /// The shared-memory version (always present — SMP is the universal
    /// fallback, §6).
    pub smp: SomdMethod<I, P, E, R>,
    device: Option<DeviceFn<I, R>>,
    hybrid: Option<HybridSpec<I, R>>,
    batch: Option<BatchSpec<I, R>>,
    cluster: Option<ClusterSpec<I, R>>,
    pipeline: Option<PipelineSpec>,
}

/// Where an invocation actually ran (after fallback resolution).
#[derive(Debug, Clone, PartialEq)]
pub enum Executed {
    /// Whole invocation on the shared-memory pool.
    Smp {
        /// MI count of the invocation.
        partitions: usize,
    },
    /// Whole invocation offloaded to the device lane.
    Device {
        /// Device profile the session ran under.
        profile: &'static str,
        /// Per-invocation device accounting (transfers, launches, clocks).
        stats: DeviceStats,
    },
    /// Invocation split across both lanes (hybrid co-execution).
    Hybrid {
        /// Device profile the device share ran under.
        profile: &'static str,
        /// MI count of the SMP share.
        smp_partitions: usize,
        /// Index-space items the SMP share covered.
        smp_items: usize,
        /// Index-space items the device share covered.
        device_items: usize,
        /// The split ratio this invocation used.
        device_fraction: f64,
        /// Device accounting for the device share.
        stats: DeviceStats,
    },
    /// Invocation sharded N-way across the SMP pool and the whole device
    /// fleet.
    Sharded {
        /// MI count of the SMP share.
        smp_partitions: usize,
        /// Index-space items the SMP share covered.
        smp_items: usize,
        /// The per-lane weight vector this invocation split at (SMP
        /// first, `lanes.len() + 1` entries).
        weights: Vec<f64>,
        /// Per-device-lane execution reports, in fleet order.
        lanes: Vec<ShardLane>,
    },
}

/// One device lane's slice of a sharded invocation, as reported in
/// [`Executed::Sharded`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLane {
    /// The lane's position in the fleet (the scheduler's `device_id`).
    pub device_id: usize,
    /// Device profile the lane runs under.
    pub profile: &'static str,
    /// Index-space items the lane's span covered (0 = starved under the
    /// `min_device_items` floor; the SMP share absorbed them).
    pub items: usize,
    /// Whether the lane's share succeeded (a failed share was covered by
    /// the SMP side and penalized in the history).
    pub ok: bool,
    /// The lane's own execute seconds (queue wait excluded; 0 for
    /// starved lanes).
    pub secs: f64,
    /// Device accounting for the lane's share.
    pub stats: DeviceStats,
}

impl<I: ?Sized + Sync, P: Send + Sync, E: Sync, R: Send> HeteroMethod<I, P, E, R> {
    /// A method with only the (always-applicable) SMP version.
    pub fn smp_only(smp: SomdMethod<I, P, E, R>) -> Self {
        Self { smp, device: None, hybrid: None, batch: None, cluster: None, pipeline: None }
    }

    /// A method with an SMP version and a whole-invocation device version.
    pub fn with_device(smp: SomdMethod<I, P, E, R>, device: DeviceFn<I, R>) -> Self {
        Self {
            smp,
            device: Some(device),
            hybrid: None,
            batch: None,
            cluster: None,
            pipeline: None,
        }
    }

    /// Attach a hybrid co-execution spec (builder style).
    pub fn with_hybrid(mut self, hybrid: HybridSpec<I, R>) -> Self {
        self.hybrid = Some(hybrid);
        self
    }

    /// Attach a batch-compose/split spec so the serving layer can coalesce
    /// concurrent invocations of this method (builder style).
    pub fn with_batch(mut self, batch: BatchSpec<I, R>) -> Self {
        self.batch = Some(batch);
        self
    }

    /// Attach the wire codecs so remote peers can carry shards of this
    /// method (builder style); requires a [`HybridSpec`] to define the
    /// item space the spans are cut from.
    pub fn with_cluster(mut self, cluster: ClusterSpec<I, R>) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Attach a pipeline-stage spec so an
    /// [`ExecutionPlan`](crate::somd::pipeline::ExecutionPlan) can chain
    /// this method with device-resident intermediates (builder style).
    pub fn with_pipeline(mut self, pipeline: PipelineSpec) -> Self {
        self.pipeline = Some(pipeline);
        self
    }

    /// Whether a pipeline-stage spec is attached.
    pub fn has_pipeline_version(&self) -> bool {
        self.pipeline.is_some()
    }

    /// Detach the pipeline-stage spec (the execution plan takes ownership
    /// of the stage evaluators; the method keeps its other versions).
    pub fn take_pipeline(&mut self) -> Option<PipelineSpec> {
        self.pipeline.take()
    }

    /// The method's rules-file name.
    pub fn name(&self) -> &str {
        self.smp.name()
    }

    /// Whether a whole-invocation device version is compiled in.
    pub fn has_device_version(&self) -> bool {
        self.device.is_some()
    }

    /// Whether this method can co-execute (a [`HybridSpec`] is attached).
    pub fn has_hybrid_version(&self) -> bool {
        self.hybrid.is_some()
    }

    /// Whether the serving layer may coalesce invocations of this method
    /// (a [`BatchSpec`] is attached).
    pub fn has_batch_version(&self) -> bool {
        self.batch.is_some()
    }

    /// Whether remote peers can carry shards of this method (a
    /// [`ClusterSpec`] is attached).
    pub fn has_cluster_version(&self) -> bool {
        self.cluster.is_some()
    }

    /// Encode `span`'s input for shipment to a remote peer.
    ///
    /// # Panics
    /// Panics when the method has no [`ClusterSpec`]; the engine only
    /// routes here after [`HeteroMethod::has_cluster_version`] checks.
    pub fn cluster_encode_span(&self, input: &I, span: Range1) -> Vec<u8> {
        (self.cluster.as_ref().expect("cluster spec present").encode)(input, span)
    }

    /// Decode a peer's partial-result bytes (cluster-capable methods
    /// only; see [`HeteroMethod::cluster_encode_span`] for the panic
    /// contract).
    pub fn cluster_decode_partial(&self, payload: &[u8]) -> Result<R> {
        (self.cluster.as_ref().expect("cluster spec present").decode)(payload)
    }

    /// Index-space items of one request (batchable methods only).
    ///
    /// # Panics
    /// Panics when the method has no [`BatchSpec`]; the serving layer
    /// only routes here after [`HeteroMethod::has_batch_version`] checks.
    pub fn batch_items(&self, input: &I) -> usize {
        (self.batch.as_ref().expect("batch spec present").items)(input)
    }

    /// The request's compatibility key (batchable methods only; see
    /// [`HeteroMethod::batch_items`] for the panic contract).
    pub fn batch_compat(&self, input: &I) -> u64 {
        (self.batch.as_ref().expect("batch spec present").compat)(input)
    }

    /// Fuse a batch of request inputs into one invocation input, in
    /// request order (batchable methods only; see
    /// [`HeteroMethod::batch_items`] for the panic contract).
    pub fn batch_compose(&self, inputs: &[Arc<I>]) -> Arc<I> {
        (self.batch.as_ref().expect("batch spec present").compose)(inputs)
    }

    /// De-multiplex a fused result back into per-request results;
    /// `counts[i]` is request `i`'s item count in compose order
    /// (batchable methods only; see [`HeteroMethod::batch_items`] for
    /// the panic contract).
    pub fn batch_split(&self, fused: R, counts: &[usize]) -> Vec<R> {
        (self.batch.as_ref().expect("batch spec present").split)(fused, counts)
    }

    /// Resolve the target for this method (§6): user rules first, then
    /// applicability (device version compiled? profile known? registry
    /// loaded?) — inapplicable preferences revert to the default.
    /// `auto` consults the engine's execution-history cost model.
    /// Delegates to [`Engine::resolve_target`] so the sync and async
    /// entry points can never drift apart.
    pub fn resolve(&self, engine: &Engine, registry: Option<&Registry>) -> Target {
        let hybrid_ok = self.hybrid.is_some()
            && registry.is_some()
            && DeviceProfile::by_name(engine.auto_profile()).is_some();
        engine.resolve_target(
            self.smp.name(),
            &|profile: &str| {
                self.device.is_some()
                    && registry.is_some()
                    && DeviceProfile::by_name(profile).is_some()
            },
            hybrid_ok,
            // the synchronous path is caller-driven against the caller's
            // own registry — it cannot reach the engine's fleet lanes, so
            // `sharded` preferences revert to two-way hybrid here (the
            // §6 nearest-applicable discipline)
            0,
        )
    }

    /// Invoke through the engine, honoring the rules; returns the result
    /// and where it ran.  Observed timings feed the scheduler history.
    pub fn invoke(
        &self,
        engine: &Engine,
        registry: Option<&Registry>,
        input: &I,
    ) -> Result<(R, Executed)> {
        // the invocation's index-space size, when the method can report
        // one (hybrid spec attached) — it keys the scheduler's per-size
        // windows so lane learning conditions on input size
        let items = self.hybrid.as_ref().map(|h| (h.items)(input) as u64);
        match self.resolve(engine, registry) {
            Target::Smp | Target::Auto => {
                let t0 = Instant::now();
                let r = self.smp.invoke(input, engine.workers());
                let wall = t0.elapsed();
                match items {
                    Some(it) => engine.scheduler().record_smp_sized(self.smp.name(), wall, it),
                    None => engine.scheduler().record_smp(self.smp.name(), wall),
                }
                Ok((r, Executed::Smp { partitions: engine.workers() }))
            }
            // a sharded resolution can only surface on the engine's async
            // fleet path; the sync lane runs its nearest applicable form,
            // the two-way hybrid split (same spec, one device)
            Target::Hybrid | Target::Sharded => {
                let reg = registry.expect("resolved registry");
                self.invoke_hybrid(engine, reg, input, None)
            }
            Target::Device(name) => {
                let profile = DeviceProfile::by_name(&name).expect("resolved profile");
                let reg = registry.expect("resolved registry");
                let mut session = DeviceSession::new(reg, profile);
                let t0 = Instant::now();
                let r = match self.invoke_on_session(&mut session, input) {
                    Ok(r) => r,
                    Err(e) => {
                        // feed the failure to the cost model so `auto`
                        // steers back to SMP instead of retrying forever
                        match items {
                            Some(it) => engine
                                .scheduler()
                                .record_device_failure_sized(self.smp.name(), it),
                            None => engine.scheduler().record_device_failure(self.smp.name()),
                        }
                        return Err(e);
                    }
                };
                let measured = t0.elapsed();
                let stats = session.stats();
                match items {
                    Some(it) => engine
                        .scheduler()
                        .record_device_sized(self.smp.name(), measured, &stats, it),
                    None => engine.scheduler().record_device(self.smp.name(), measured, &stats),
                }
                Ok((
                    r,
                    Executed::Device { profile: session.profile().name, stats },
                ))
            }
        }
    }

    /// Split one invocation across the SMP pool and the device (hybrid
    /// co-execution), synchronously: the SMP share runs on a scoped
    /// thread (fanning out its MIs as usual) while the calling thread
    /// drives the device share through a fresh session; the partial
    /// results merge through the method's reduction.
    ///
    /// `fraction_override` pins the split ratio (experiments, the
    /// correctness suite's degenerate 0.0/1.0 splits); `None` uses the
    /// scheduler's learned [`hybrid_fraction`] and also enforces the
    /// `min_device_items` floor — a device share below it degrades to a
    /// plain SMP invocation.
    ///
    /// If the device half fails the SMP side covers its span too (the §6
    /// revert-to-shared-memory discipline, applied mid-invocation): the
    /// caller still gets a full result, tagged [`Executed::Smp`], and the
    /// failure is penalized in the scheduler history.
    ///
    /// [`hybrid_fraction`]: crate::somd::scheduler::Scheduler::hybrid_fraction
    pub fn invoke_hybrid(
        &self,
        engine: &Engine,
        registry: &Registry,
        input: &I,
        fraction_override: Option<f64>,
    ) -> Result<(R, Executed)> {
        let spec = self
            .hybrid
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("method '{}' has no hybrid spec", self.name()))?;
        let profile = DeviceProfile::by_name(engine.auto_profile())
            .ok_or_else(|| anyhow::anyhow!("unknown device profile '{}'", engine.auto_profile()))?;
        let total = (spec.items)(input);
        let fraction = fraction_override
            .unwrap_or_else(|| engine.scheduler().hybrid_fraction_sized(self.name(), total as u64));
        let (smp_span, dev_span) = split_fraction(total, fraction);
        let min_items = engine.scheduler().config().min_device_items;
        if dev_span.is_empty() || (fraction_override.is_none() && dev_span.len() < min_items) {
            // device share underflows the minimum chunk: a launch over it
            // would be pure overhead — run the whole invocation on SMP.
            // The wall is also recorded as a (degraded) hybrid sample so
            // the exploration rung completes and `auto` can settle.
            let t0 = Instant::now();
            let r = self.smp.invoke(input, engine.workers());
            let wall = t0.elapsed();
            engine.scheduler().record_smp_sized(self.name(), wall, total as u64);
            engine.scheduler().record_hybrid_degraded_sized(self.name(), wall, total as u64);
            return Ok((r, Executed::Smp { partitions: engine.workers() }));
        }

        let n = engine.workers();
        let mut session = DeviceSession::new(registry, profile);
        let (smp_half, dev_half) = std::thread::scope(|s| {
            let handle = s.spawn(|| {
                let t0 = Instant::now();
                let partials = (spec.smp)(input, smp_span, n);
                (partials, t0.elapsed().as_secs_f64())
            });
            let t0 = Instant::now();
            let dev = (spec.device)(&mut session, input, dev_span)
                .map(|r| (r, t0.elapsed().as_secs_f64()));
            let smp = handle.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
            (smp, dev)
        });
        let dev = dev_half.map(|(partial, secs)| DeviceShare {
            partial,
            secs,
            stats: session.stats(),
            profile: session.profile().name,
        });
        let merge = HybridMerge {
            sched: engine.scheduler(),
            input,
            smp_span,
            dev_span,
            fraction,
            nparts: n,
        };
        Ok(self.finish_hybrid(merge, smp_half, dev))
    }

    /// The shared tail of both hybrid lanes (sync above, the engine's
    /// completion latch for async): record history, push the device
    /// partial after the rank-ordered SMP partials and reduce — or, when
    /// the device share failed, penalize the history and cover its span
    /// on the SMP side so the caller still gets a complete result.
    /// Keeping one copy prevents the two lanes' ordering and failure
    /// invariants from drifting.
    pub(crate) fn finish_hybrid(
        &self,
        m: HybridMerge<'_, I>,
        smp: (Vec<R>, f64),
        dev: Result<DeviceShare<R>>,
    ) -> (R, Executed) {
        let (mut partials, smp_secs) = smp;
        match dev {
            Ok(share) => {
                m.sched.record_hybrid(
                    self.name(),
                    HybridSample { items: m.smp_span.len(), secs: smp_secs },
                    HybridSample { items: m.dev_span.len(), secs: share.secs },
                    &share.stats,
                );
                partials.push(share.partial);
                let r = self.smp.reduce(partials);
                (
                    r,
                    Executed::Hybrid {
                        profile: share.profile,
                        smp_partitions: m.nparts,
                        smp_items: m.smp_span.len(),
                        device_items: m.dev_span.len(),
                        device_fraction: m.fraction,
                        stats: share.stats,
                    },
                )
            }
            Err(_) => {
                // the device share failed: cover its span on the SMP side
                let total = (m.smp_span.len() + m.dev_span.len()) as u64;
                m.sched.record_hybrid_failure_sized(self.name(), total);
                partials.extend(self.hybrid_smp_partials(m.input, m.dev_span, m.nparts));
                let r = self.smp.reduce(partials);
                (r, Executed::Smp { partitions: m.nparts })
            }
        }
    }

    /// The merge tail of one sharded (N-way fleet) invocation, run by
    /// whichever lane releases the engine's completion latch last:
    /// record history, stitch the partials in span order (SMP leading,
    /// then each device lane's span in fleet order) and reduce.  A
    /// failed device share is covered by SMP partials over its span —
    /// the caller always receives a complete result — and the whole run
    /// is penalized in the history; starved lanes (`None`, empty span)
    /// contribute nothing.  The single-copy discipline of
    /// [`HeteroMethod::finish_hybrid`], generalized to N lanes.
    pub(crate) fn finish_sharded(
        &self,
        m: ShardedMerge<'_, I>,
        smp: (Vec<R>, f64),
        devs: Vec<Option<Result<DeviceShare<R>>>>,
    ) -> (R, Executed) {
        let (mut partials, smp_secs) = smp;
        let mut lanes = Vec::with_capacity(devs.len());
        let mut samples = Vec::with_capacity(devs.len());
        let mut total_stats = DeviceStats::default();
        let mut any_ok = false;
        let mut any_failed = false;
        for (i, dev) in devs.into_iter().enumerate() {
            let span = m.dev_spans[i];
            match dev {
                Some(Ok(share)) => {
                    any_ok = true;
                    total_stats.absorb(&share.stats);
                    samples.push(HybridSample { items: span.len(), secs: share.secs });
                    lanes.push(ShardLane {
                        device_id: i,
                        profile: share.profile,
                        items: span.len(),
                        ok: true,
                        secs: share.secs,
                        stats: share.stats,
                    });
                    partials.push(share.partial);
                }
                Some(Err(_)) => {
                    // the lane's share failed: cover its span on the SMP
                    // side, in place, so rank order is preserved
                    any_failed = true;
                    samples.push(HybridSample { items: 0, secs: 0.0 });
                    lanes.push(ShardLane {
                        device_id: i,
                        profile: m.profiles[i],
                        items: span.len(),
                        ok: false,
                        secs: 0.0,
                        stats: DeviceStats::default(),
                    });
                    partials.extend(self.hybrid_smp_partials(m.input, span, m.nparts));
                }
                None => {
                    // starved under the floor: the SMP span absorbed it
                    samples.push(HybridSample { items: 0, secs: 0.0 });
                    lanes.push(ShardLane {
                        device_id: i,
                        profile: m.profiles[i],
                        items: 0,
                        ok: true,
                        secs: 0.0,
                        stats: DeviceStats::default(),
                    });
                }
            }
        }
        if any_failed {
            // a broken shard must not feed the weight learner — the
            // penalty steers `auto` away until the fleet proves itself
            let total = m.smp_span.len() + m.dev_spans.iter().map(|s| s.len()).sum::<usize>();
            m.sched.record_sharded_failure_sized(self.name(), total as u64);
        } else {
            m.sched.record_sharded(
                self.name(),
                HybridSample { items: m.smp_span.len(), secs: smp_secs },
                &samples,
                &total_stats,
            );
        }
        let r = self.smp.reduce(partials);
        if any_ok {
            (
                r,
                Executed::Sharded {
                    smp_partitions: m.nparts,
                    smp_items: m.smp_span.len(),
                    weights: m.weights.to_vec(),
                    lanes,
                },
            )
        } else {
            // every device lane failed: this was effectively an SMP run
            (r, Executed::Smp { partitions: m.nparts })
        }
    }

    /// Total index-space items of one invocation (hybrid methods only).
    ///
    /// # Panics
    /// Panics when the method has no [`HybridSpec`]; the engine only
    /// routes here after [`HeteroMethod::has_hybrid_version`] checks.
    pub fn hybrid_items(&self, input: &I) -> usize {
        (self.hybrid.as_ref().expect("hybrid spec present").items)(input)
    }

    /// Compute the SMP partial results for `span` (hybrid methods only;
    /// see [`HeteroMethod::hybrid_items`] for the panic contract).
    pub fn hybrid_smp_partials(&self, input: &I, span: Range1, nparts: usize) -> Vec<R> {
        (self.hybrid.as_ref().expect("hybrid spec present").smp)(input, span, nparts)
    }

    /// Compute the device partial result for `span` on an existing
    /// session (hybrid methods only; see [`HeteroMethod::hybrid_items`]
    /// for the panic contract).
    pub fn hybrid_device_partial(
        &self,
        session: &mut DeviceSession<'_>,
        input: &I,
        span: Range1,
    ) -> Result<R> {
        (self.hybrid.as_ref().expect("hybrid spec present").device)(session, input, span)
    }

    /// Run the compiled device version on an existing (possibly warm)
    /// session — the engine's device master lane enters here.
    pub fn invoke_on_session(
        &self,
        session: &mut DeviceSession<'_>,
        input: &I,
    ) -> Result<R> {
        let dev = self
            .device
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("method '{}' has no device version", self.name()))?;
        dev(session, input)
    }

    /// Force execution on a given device profile regardless of rules
    /// (bench harness entry).
    pub fn invoke_on_device(
        &self,
        registry: &Registry,
        profile: DeviceProfile,
        input: &I,
    ) -> Result<(R, DeviceStats)> {
        let mut session = DeviceSession::new(registry, profile);
        let r = self.invoke_on_session(&mut session, input)?;
        let stats = session.stats();
        Ok((r, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::somd::partition::Block1D;
    use crate::somd::scheduler::Choice;
    use crate::somd::{reduction, Rules};
    use std::time::Duration;

    fn method() -> HeteroMethod<Vec<i64>, crate::somd::partition::BlockPart, (), i64> {
        HeteroMethod::smp_only(SomdMethod::new(
            "Sum.sum",
            |v: &Vec<i64>, n| Block1D::new().ranges(v.len(), n),
            |_, _| (),
            |v, p, _, _| p.own.iter().map(|i| v[i]).sum(),
            reduction::sum::<i64>(),
        ))
    }

    #[test]
    fn defaults_to_smp() {
        let e = Engine::new(2);
        let m = method();
        let (r, how) = m.invoke(&e, None, &vec![1, 2, 3]).unwrap();
        assert_eq!(r, 6);
        assert_eq!(how, Executed::Smp { partitions: 2 });
    }

    #[test]
    fn inapplicable_device_rule_falls_back() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Device("fermi".into()));
        let e = Engine::with_rules(2, rules);
        let m = method(); // no device version, no registry
        assert_eq!(m.resolve(&e, None), Target::Smp);
        let (r, _) = m.invoke(&e, None, &vec![5, 5]).unwrap();
        assert_eq!(r, 10);
    }

    #[test]
    fn unknown_profile_falls_back() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Device("h100".into()));
        let e = Engine::with_rules(2, rules);
        let m = method();
        assert_eq!(m.resolve(&e, None), Target::Smp);
    }

    #[test]
    fn auto_without_device_version_falls_back_to_smp() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Auto);
        let e = Engine::with_rules(2, rules);
        let m = method(); // no device version compiled
        assert_eq!(m.resolve(&e, None), Target::Smp);
        let (r, how) = m.invoke(&e, None, &vec![2, 3]).unwrap();
        assert_eq!(r, 5);
        assert!(matches!(how, Executed::Smp { .. }));
    }

    #[test]
    fn hybrid_rule_without_spec_falls_back_to_smp() {
        let mut rules = Rules::empty();
        rules.set("Sum.sum", Target::Hybrid);
        let e = Engine::with_rules(2, rules);
        let m = method(); // no hybrid spec, no registry
        assert_eq!(m.resolve(&e, None), Target::Smp);
        let (r, how) = m.invoke(&e, None, &vec![4, 5]).unwrap();
        assert_eq!(r, 9);
        assert!(matches!(how, Executed::Smp { .. }));
    }

    #[test]
    fn batch_spec_composes_and_splits_in_request_order() {
        use crate::somd::partition::stitched_spans;
        let m = method().with_batch(
            BatchSpec::new(
                |v: &Vec<i64>| v.len(),
                |inputs| {
                    Arc::new(inputs.iter().flat_map(|v| v.iter().copied()).collect::<Vec<i64>>())
                },
                |fused: i64, _counts| vec![fused], // sums don't demux; see below
            )
            .with_compat(|v| v.len() as u64 % 2),
        );
        assert!(m.has_batch_version());
        let a = Arc::new(vec![1i64, 2, 3]);
        let b = Arc::new(vec![10i64, 20]);
        assert_eq!(m.batch_items(&a), 3);
        assert_ne!(m.batch_compat(&a), m.batch_compat(&b), "odd/even lengths differ");
        let fused = m.batch_compose(&[a.clone(), b.clone()]);
        assert_eq!(*fused, vec![1, 2, 3, 10, 20]);
        // the span bookkeeping the batcher uses to cut results back up
        let spans = stitched_spans(&[3, 2]);
        assert_eq!((spans[0].lo, spans[0].hi), (0, 3));
        assert_eq!((spans[1].lo, spans[1].hi), (3, 5));
        assert!(!method().has_batch_version(), "specs are opt-in");
    }

    #[test]
    fn invocations_record_history() {
        let e = Engine::new(2);
        let m = method();
        m.invoke(&e, None, &vec![1, 2, 3]).unwrap();
        m.invoke(&e, None, &vec![4, 5, 6]).unwrap();
        let h = e.scheduler().history("Sum.sum").expect("history");
        assert_eq!(h.smp_runs, 2);
        assert!(h.smp_secs.iter().all(|&s| s >= 0.0));
        assert_eq!(h.device_runs, 0);
        // seeded device history (measured wall) steers a later auto decision
        e.scheduler().record_device("Sum.sum", Duration::from_secs(5), &DeviceStats::default());
        e.scheduler().record_device("Sum.sum", Duration::from_secs(5), &DeviceStats::default());
        assert_eq!(e.scheduler().decide("Sum.sum"), Choice::Smp);
    }
}
