//! Device-side (GPU) versions of the benchmarks — the master code of
//! paper Algorithm 2, driving the AOT-compiled kernels through a
//! [`DeviceSession`]: allocate/put, launch per kernel site (one launch per
//! `sync` iteration for SOR, Listing 17), reduce the tail on the host,
//! get the results back.
//!
//! LUFact is intentionally absent from the figure path — the paper omits
//! it on GPU (§7.3: per-invocation whole-matrix copies dwarf the kernel) —
//! but a fused-factorization driver is kept for the ablation study.

use anyhow::{anyhow, Result};

use crate::device::{Arg, DeviceSession};
use crate::runtime::HostTensor;

use super::crypt::{Problem as CryptProblem, BLOCK_BYTES, SUBKEYS};
use super::sparse::Problem as SparseProblem;

// ---------------------------------------------------------------------------
// Crypt
// ---------------------------------------------------------------------------

/// Bytes → 16-bit words in u32 lanes (same convention as `crypt::load_block`).
pub fn pack_words(bytes: &[u8]) -> Vec<u32> {
    assert_eq!(bytes.len() % 2, 0);
    bytes.chunks_exact(2).map(|c| u32::from(c[0]) << 8 | u32::from(c[1])).collect()
}

/// Inverse of [`pack_words`]: 16-bit words in u32 lanes → bytes.
pub fn unpack_words(words: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(words.len() * 2);
    for &w in words {
        out.push((w >> 8) as u8);
        out.push((w & 0xFF) as u8);
    }
    out
}

/// One cipher pass on the device.  The whole vector crosses the bus both
/// ways — the cost structure that makes GPU-Crypt lose to the CPU and the
/// host-memory-sharing 320M beat the Fermi (§7.3).
pub fn crypt_pass(
    session: &mut DeviceSession<'_>,
    src: &[u8],
    keys: &[u32; SUBKEYS],
) -> Result<Vec<u8>> {
    let nblocks = src.len() / BLOCK_BYTES;
    let info = session
        .registry()
        .find_by_meta("crypt", "blocks", nblocks)
        .ok_or_else(|| anyhow!("no crypt artifact for {nblocks} blocks"))?;
    let name = info.name.clone();
    let words = HostTensor::mat_u32(pack_words(src), nblocks, 4);
    let keys_t = HostTensor::vec_u32(keys.to_vec());
    let out =
        session.launch_to_host(&name, &[Arg::Host(&words), Arg::Host(&keys_t)], nblocks)?;
    Ok(unpack_words(out[0].as_u32()?))
}

/// Full benchmark: encrypt then decrypt (both passes offloaded).
pub fn crypt_run(session: &mut DeviceSession<'_>, p: &CryptProblem) -> Result<(Vec<u8>, Vec<u8>)> {
    let enc = crypt_pass(session, &p.data, &p.ekeys)?;
    let dec = crypt_pass(session, &enc, &p.dkeys)?;
    Ok((enc, dec))
}

// ---------------------------------------------------------------------------
// Series
// ---------------------------------------------------------------------------

/// Coefficients [ (a_n, b_n); count ] computed in device chunks; a_0
/// halved on the host (the paper's top-level/SOMD split).  Single
/// precision, as the paper's Aparapi back-end forces (§7.3).
pub fn series_run(session: &mut DeviceSession<'_>, count: usize) -> Result<Vec<(f32, f32)>> {
    let mut out = series_run_range(session, 0, count)?;
    out[0].0 /= 2.0;
    out[0].1 = 0.0;
    Ok(out)
}

/// Coefficients (a_n, b_n) for `n` in `[lo, hi)` only — the hybrid lane's
/// device share: the `series_chunk` artifact takes its starting index as
/// an input, so a sub-range costs proportionally fewer chunk launches
/// than the whole space (the last chunk may overhang; its surplus lanes
/// are computed-and-dropped, the §5.2 boundary-divergence cost).  No a_0
/// special-casing — the caller owns the top-level split.
pub fn series_run_range(
    session: &mut DeviceSession<'_>,
    lo: usize,
    hi: usize,
) -> Result<Vec<(f32, f32)>> {
    let info = session
        .registry()
        .info("series_chunk")
        .map_err(|e| anyhow!("{e}"))?;
    let chunk = info.meta_usize("chunk").ok_or_else(|| anyhow!("series chunk meta"))?;
    let name = info.name.clone();
    let mut out = Vec::with_capacity(hi.saturating_sub(lo));
    let mut n0 = lo;
    while n0 < hi {
        // scalar shape () vs manifest [1]: encode as [1]
        let t = HostTensor::F32(vec![n0 as f32], vec![1]);
        let res = session.launch_to_host(&name, &[Arg::Host(&t)], chunk)?;
        let ab = res[0].as_f32()?;
        let take = chunk.min(hi - n0);
        for i in 0..take {
            out.push((ab[i], ab[chunk + i]));
        }
        n0 += chunk;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// SOR
// ---------------------------------------------------------------------------

/// SOR on the device: the matrix is `put` once (Aparapi explicit mode,
/// Listing 17), then one kernel launch per `sync` iteration — global
/// synchronization only exists at kernel boundaries (§5.2) — and the
/// Gtotal reduction runs on-device before a scalar `get`.
pub fn sor_run(
    session: &mut DeviceSession<'_>,
    g0: &[f32],
    n: usize,
    iters: usize,
) -> Result<(Vec<f32>, f64)> {
    let step = session
        .registry()
        .by_bench("sor")
        .into_iter()
        .find(|i| i.name.starts_with("sor_step") && i.meta_usize("n") == Some(n))
        .ok_or_else(|| anyhow!("no sor_step artifact for n={n}"))?
        .name
        .clone();
    let sum = session
        .registry()
        .by_bench("sor")
        .into_iter()
        .find(|i| i.name.starts_with("sor_sum") && i.meta_usize("n") == Some(n))
        .ok_or_else(|| anyhow!("no sor_sum artifact for n={n}"))?
        .name
        .clone();

    let mut g = session.put(&HostTensor::mat_f32(g0.to_vec(), n, n))?;
    for _ in 0..iters {
        let out = session.launch(&step, &[Arg::Buf(g)], n * n)?;
        session.free(g)?;
        g = out[0];
    }
    let total_id = session.launch(&sum, &[Arg::Buf(g)], n * n)?[0];
    let total = session.get(total_id)?;
    session.free(total_id)?;
    let gt = session.get(g)?;
    session.free(g)?;
    let total = total.as_f32()?[0] as f64;
    Ok((gt.as_f32()?.to_vec(), total))
}

// ---------------------------------------------------------------------------
// SparseMatMult
// ---------------------------------------------------------------------------

/// The JG 200-round loop as the paper's Aparapi master would run it: the
/// triplet arrays are `put` once, then the accumulation kernel is
/// re-launched per round with y chained device-resident.  (The fused
/// fori_loop artifact exists as an ablation — XLA hoists the invariant
/// product out of it, silently collapsing the workload; see
/// `benches/ablations.rs`.)  User-defined partitioning is ignored on GPU
/// (§5.2) — the kernel's flat nnz tiling replaces it.
pub fn spmv_run(session: &mut DeviceSession<'_>, p: &SparseProblem) -> Result<Vec<f32>> {
    let name = session
        .registry()
        .by_bench("sparsematmult")
        .into_iter()
        .find(|i| i.name.starts_with("spmv_acc") && i.meta_usize("n") == Some(p.n))
        .ok_or_else(|| anyhow!("no spmv_acc artifact for n={}", p.n))?
        .name
        .clone();
    let nnz = p.val.len();
    let val = session.put(&HostTensor::vec_f32(p.val.iter().map(|&v| v as f32).collect()))?;
    let row = session.put(&HostTensor::vec_s32(p.row.iter().map(|&v| v as i32).collect()))?;
    let col = session.put(&HostTensor::vec_s32(p.col.iter().map(|&v| v as i32).collect()))?;
    let x = session.put(&HostTensor::vec_f32(p.x.iter().map(|&v| v as f32).collect()))?;
    let mut y = session.put(&HostTensor::vec_f32(vec![0.0; p.n]))?;
    for _ in 0..p.iterations {
        let out = session.launch(
            &name,
            &[Arg::Buf(val), Arg::Buf(row), Arg::Buf(col), Arg::Buf(x), Arg::Buf(y)],
            nnz,
        )?;
        session.free(y)?;
        y = out[0];
    }
    let host = session.get(y)?;
    for id in [val, row, col, x, y] {
        session.free(id)?;
    }
    Ok(host.as_f32()?.to_vec())
}

// ---------------------------------------------------------------------------
// LUFact (ablation only)
// ---------------------------------------------------------------------------

/// Fused on-device LU factorization (what the paper's `single`-construct
/// future work would enable).  Returns (LU, pivots).
pub fn lufact_fused(
    session: &mut DeviceSession<'_>,
    a: &[f32],
    n: usize,
) -> Result<(Vec<f32>, Vec<i32>)> {
    let name = session
        .registry()
        .by_bench("lufact")
        .into_iter()
        .find(|i| i.name.starts_with("lufact_fused") && i.meta_usize("n") == Some(n))
        .ok_or_else(|| anyhow!("no fused lufact artifact for n={n}"))?
        .name
        .clone();
    let t = HostTensor::mat_f32(a.to_vec(), n, n);
    let out = session.launch_to_host(&name, &[Arg::Host(&t)], n * n)?;
    Ok((out[0].as_f32()?.to_vec(), out[1].as_s32()?.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceProfile;
    use crate::runtime::Registry;

    fn reg() -> Registry {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Registry::load(dir).unwrap()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let bytes: Vec<u8> = (0..64).collect();
        assert_eq!(unpack_words(&pack_words(&bytes)), bytes);
    }

    #[test]
    fn series_device_matches_rust_sequential() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::passthrough());
        let count = 600; // forces 1 chunk + prefix handling
        let got = series_run(&mut s, count).unwrap();
        let want = super::super::series::sequential(count, 1000);
        assert_eq!(got.len(), count);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.0 as f64 - w.0).abs() < 5e-3 && (g.1 as f64 - w.1).abs() < 5e-3,
                "{g:?} vs {w:?}"
            );
        }
        assert!(s.stats().launches >= 1);
    }

    #[test]
    fn series_range_matches_sequential_slice() {
        let r = reg();
        let mut s = DeviceSession::new(&r, DeviceProfile::passthrough());
        let (lo, hi) = (5usize, 700usize);
        let got = series_run_range(&mut s, lo, hi).unwrap();
        let want = super::super::series::sequential(hi, 1000);
        assert_eq!(got.len(), hi - lo);
        for (i, g) in got.iter().enumerate() {
            let w = want[lo + i];
            assert!(
                (g.0 as f64 - w.0).abs() < 5e-3 && (g.1 as f64 - w.1).abs() < 5e-3,
                "n={} {g:?} vs {w:?}",
                lo + i
            );
        }
        // a sub-range pays one chunk launch, not the whole space
        assert_eq!(s.stats().launches, 1);
    }

    #[test]
    fn spmv_device_matches_rust_sequential() {
        let r = reg();
        // must match the AOT size for class A
        let info = r.info("spmv_acc_A").unwrap();
        let n = info.meta_usize("n").unwrap();
        let p = SparseProblem::generate(n, n * 5, 200, 77);
        let mut s = DeviceSession::new(&r, DeviceProfile::passthrough());
        let got = spmv_run(&mut s, &p).unwrap();
        let want = super::super::sparse::sequential(&p);
        let mut max_rel = 0.0f64;
        for (g, w) in got.iter().zip(&want) {
            let denom = w.abs().max(1.0);
            max_rel = max_rel.max((*g as f64 - w).abs() / denom);
        }
        assert!(max_rel < 2e-2, "max_rel={max_rel}");
    }
}
