//! JavaGrande Section-2 benchmark substrate (paper §7).
//!
//! Every benchmark exists in up to four versions:
//!
//! 1. **sequential** — the baseline of Table 1;
//! 2. **SOMD** — the paper's annotated-method version, expressed through
//!    the [`crate::somd`] API;
//! 3. **JG-style** — the hand-tuned multithreaded decomposition of the
//!    JavaGrande suite (the comparison series in Figure 10);
//! 4. **GPU** — the device-offloaded version (Algorithm 2 master driving
//!    the AOT Pallas/XLA kernels; Figure 11);
//! 5. **hybrid** — for the co-execution workloads ([`hybrid`]), one
//!    invocation split across the SMP pool and the device at the
//!    scheduler's learned ratio.
//!
//! [`harness`] regenerates the paper's tables/figures; [`modeled`] holds
//! the calibrated parallel-makespan model used on this 1-core testbed;
//! [`serve`] is the serving-layer load harness (open-loop arrival sweep,
//! batched vs unbatched) plus the batchable method builders it and the
//! serving correctness suite share; [`fleet`] is the device-fleet
//! sharding report (one invocation split N-way across SMP and every
//! fleet lane, fleet vs best-single-lane wall); [`cluster`] is the
//! remote-lane sharding report (one invocation split across SMP and
//! peer processes over TCP, with per-peer RTT percentiles); [`pipeline`]
//! is the fused execution-plan report (device-resident chains vs
//! per-stage round-trips) plus the reusable pipeline stage builders.

pub mod cluster;
pub mod crypt;
pub mod fleet;
pub mod gpu;
pub mod harness;
pub mod hybrid;
pub mod interp;
pub mod lufact;
pub mod modeled;
pub mod obs;
pub mod params;
pub mod pipeline;
pub mod serve;
pub mod series;
pub mod sor;
pub mod sparse;

pub use params::{Class, Sizes};
