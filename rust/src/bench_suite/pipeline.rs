//! `somd bench pipeline` — fused execution plans vs per-stage
//! round-trips (tentpole of the method-pipelines PR).
//!
//! Each row chains committed workloads into an
//! [`ExecutionPlan`](crate::somd::pipeline::ExecutionPlan) and runs it
//! twice per rep: **fused** (device-resident intermediates, memoized
//! uploads, H2D/compute overlap) and as the **per-stage round-trip**
//! reference (every boundary pays the full D2H+H2D, exactly as isolated
//! invocations would).  Both runs must agree bitwise — the comparison is
//! on the modeled clocks only.  `--check` gates on the largest chain:
//! fused may not lose to the round-trip reference, at least one stage
//! boundary must be *provably* resident (zero exit D2H bytes at the
//! hop), and a run where any stage fell back to SMP is refused as
//! vacuous rather than passed.
//!
//! The module also hosts the reusable stage builders ([`crypt_stage`],
//! [`sor_step_stage`], [`sor_sum_stage`]) that `tests/pipeline_exec.rs`
//! drives through every lane resolution.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::backend::PipelineSpec;
use crate::bench_suite::crypt::{self, BLOCK_BYTES, SUBKEYS};
use crate::bench_suite::{gpu, hybrid};
use crate::device::Arg;
use crate::runtime::{HostTensor, Registry};
use crate::somd::pipeline::{hybrid_fraction_from_env, ExecutionPlan};
use crate::somd::{Engine, Rules, Scheduler, SchedulerConfig, Target};
use crate::util::json::Json;
use crate::util::timer::middle_tier_mean;

/// The artifact registry for stage evaluators that must locate their
/// kernels from inside a plan: the default search first (CWD /
/// `SOMD_ARTIFACTS`), then the in-tree artifacts as a fallback so the
/// test binaries work from any working directory.
pub fn bench_registry() -> Result<Registry> {
    Registry::load_default().or_else(|_| {
        Registry::load(std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    })
}

/// The smallest committed SOR artifact with the given name prefix:
/// `(artifact name, grid side n)`.
pub fn sor_art(registry: &Registry, prefix: &str) -> Result<(String, usize)> {
    let info = registry
        .by_bench("sor")
        .into_iter()
        .filter(|i| i.name.starts_with(prefix))
        .min_by_key(|i| i.meta_usize("n").unwrap_or(usize::MAX))
        .ok_or_else(|| anyhow!("no committed sor artifact with prefix '{prefix}'"))?;
    let n = info.meta_usize("n").ok_or_else(|| anyhow!("sor artifact lacks meta n"))?;
    Ok((info.name.clone(), n))
}

// ---------------------------------------------------------------------------
// Stage builders
// ---------------------------------------------------------------------------

/// One IDEA cipher-pass stage over a packed-words tensor (`nblocks×4`
/// u32).  The key schedule is baked into the stage — it is stage
/// configuration, not flowing data — so the tensor chain is exactly
/// `words → words` and encrypt→decrypt chains compose by stacking two
/// of these.  Integer arithmetic: bitwise identical on every lane.
pub fn crypt_stage(keys: [u32; SUBKEYS]) -> PipelineSpec {
    PipelineSpec::new(move |ts: &[HostTensor]| {
        let words = ts[0].as_u32()?;
        let nblocks = words.len() / 4;
        let out = crypt::sequential(&gpu::unpack_words(words), &keys);
        Ok(vec![HostTensor::mat_u32(gpu::pack_words(&out), nblocks, 4)])
    })
    .with_device(move |sess, ids| {
        // 4 words per block, 4 bytes per resident u32
        let nblocks = sess.memory().bytes_of(ids[0])? / 16;
        let name = sess
            .registry()
            .find_by_meta("crypt", "blocks", nblocks)
            .ok_or_else(|| anyhow!("no crypt artifact for {nblocks} blocks"))?
            .name
            .clone();
        let keys_t = HostTensor::vec_u32(keys.to_vec());
        let mut out = sess.launch(&name, &[Arg::Buf(ids[0]), Arg::Host(&keys_t)], nblocks)?;
        sess.free(ids[0])?;
        let first = out.remove(0);
        for id in out {
            sess.free(id)?;
        }
        Ok(vec![first])
    })
    .with_hybrid(move |engine, registry, ts| {
        let words = ts[0].as_u32()?;
        let nblocks = words.len() / 4;
        let bytes = gpu::unpack_words(words);
        let m = hybrid::crypt_hybrid_generic();
        let input = crypt::PassInput { src: &bytes, keys };
        let (out, _) =
            m.invoke_hybrid(engine, registry, &input, Some(hybrid_fraction_from_env()))?;
        Ok(vec![HostTensor::mat_u32(gpu::pack_words(&out), nblocks, 4)])
    })
}

/// `iters` red-black SOR sweeps over an `n×n` f32 grid.  The SMP
/// evaluator interprets the same committed artifact on the host, so
/// smp- and device-resolved runs agree bitwise (the device lane is the
/// same interpreter behind modeled transfers).
pub fn sor_step_stage(iters: usize) -> PipelineSpec {
    PipelineSpec::new(move |ts: &[HostTensor]| {
        let registry = bench_registry()?;
        let (name, _) = sor_art(&registry, "sor_step")?;
        let art = registry.artifact(&name)?;
        let mut g = ts[0].clone();
        for _ in 0..iters {
            g = art.execute(&[g])?.remove(0);
        }
        Ok(vec![g])
    })
    .with_device(move |sess, ids| {
        let (name, n) = sor_art(sess.registry(), "sor_step")?;
        let mut g = ids[0];
        for _ in 0..iters {
            let mut out = sess.launch(&name, &[Arg::Buf(g)], n * n)?;
            sess.free(g)?;
            g = out.remove(0);
            for id in out {
                sess.free(id)?;
            }
        }
        Ok(vec![g])
    })
}

/// The on-device Gtotal reduction: grid in, scalar out.
pub fn sor_sum_stage() -> PipelineSpec {
    PipelineSpec::new(|ts: &[HostTensor]| {
        let registry = bench_registry()?;
        let (name, _) = sor_art(&registry, "sor_sum")?;
        let art = registry.artifact(&name)?;
        Ok(art.execute(&[ts[0].clone()])?)
    })
    .with_device(|sess, ids| {
        let (name, n) = sor_art(sess.registry(), "sor_sum")?;
        let mut out = sess.launch(&name, &[Arg::Buf(ids[0])], n * n)?;
        sess.free(ids[0])?;
        let first = out.remove(0);
        for id in out {
            sess.free(id)?;
        }
        Ok(vec![first])
    })
}

// ---------------------------------------------------------------------------
// Measurement
// ---------------------------------------------------------------------------

/// One measured chain of the pipeline benchmark.
#[derive(Debug, Clone)]
pub struct PipelineRow {
    /// Chain label.
    pub bench: String,
    /// Number of plan stages.
    pub stages: usize,
    /// Bytes of the plan's input tensor.
    pub input_bytes: usize,
    /// Middle-tier mean of the fused run's modeled seconds.
    pub fused_secs: f64,
    /// Middle-tier mean of the per-stage round-trip's modeled seconds.
    pub roundtrip_secs: f64,
    /// Provably resident stage boundaries in the fused run (downstream
    /// stage entered resident AND upstream exit paid zero D2H bytes).
    pub resident_boundaries: usize,
    /// Transfer bytes the fused run skipped at resident boundaries.
    pub skipped_bytes: usize,
    /// `roundtrip_secs / fused_secs`.
    pub speedup: f64,
    /// Stage executions (across both paths and all reps) that fell back
    /// to SMP — non-zero makes the comparison vacuous.
    pub fell_back_runs: usize,
}

/// A crypt chain of `pairs` encrypt→decrypt passes, plus the stage
/// names a rules file must pin to the device lane.
fn crypt_chain(p: &crypt::Problem, pairs: usize) -> (ExecutionPlan, Vec<String>) {
    let mut plan = ExecutionPlan::new();
    let mut names = Vec::new();
    for i in 0..pairs {
        let e = format!("PipeCrypt.encrypt{i}");
        let d = format!("PipeCrypt.decrypt{i}");
        plan = plan.stage(e.clone(), crypt_stage(p.ekeys));
        plan = plan.stage(d.clone(), crypt_stage(p.dkeys));
        names.push(e);
        names.push(d);
    }
    (plan, names)
}

fn mean_secs(xs: &[f64]) -> f64 {
    let ds: Vec<Duration> = xs.iter().map(|&s| Duration::from_secs_f64(s)).collect();
    middle_tier_mean(&ds).as_secs_f64()
}

/// Run every chain `reps` times on a one-lane fermi fleet, fused and
/// round-trip, verifying bitwise agreement on each rep.
pub fn measure(reps: usize, workers: usize) -> Result<Vec<PipelineRow>> {
    let registry = bench_registry()?;
    let artifacts_dir = registry.dir().to_path_buf();

    let blocks = registry.info("crypt_A")?.meta_usize("blocks").ok_or_else(|| {
        anyhow!("crypt_A artifact lacks meta blocks")
    })?;
    let p = crypt::Problem::generate(blocks * BLOCK_BYTES, 42);
    let words = HostTensor::mat_u32(gpu::pack_words(&p.data), blocks, 4);

    let (_, n) = sor_art(&registry, "sor_step")?;
    let grid: Vec<f32> = (0..n * n).map(|i| ((i * 31 + 7) % 1000) as f32 / 1000.0).collect();
    let grid_t = HostTensor::mat_f32(grid, n, n);
    let sor_plan = ExecutionPlan::new()
        .stage("PipeSor.step", sor_step_stage(3))
        .stage("PipeSor.sum", sor_sum_stage());

    let (crypt2, crypt2_names) = crypt_chain(&p, 1);
    let (crypt4, crypt4_names) = crypt_chain(&p, 2);
    let chains: Vec<(&str, ExecutionPlan, Vec<String>, HostTensor)> = vec![
        ("crypt-x2", crypt2, crypt2_names, words.clone()),
        ("sor-x2", sor_plan, vec!["PipeSor.step".into(), "PipeSor.sum".into()], grid_t),
        ("crypt-x4", crypt4, crypt4_names, words),
    ];

    let mut rows = Vec::new();
    for (bench, plan, names, input) in chains {
        let mut rules = Rules::empty();
        for name in &names {
            rules.set(name.clone(), Target::Device("fermi".to_string()));
        }
        let engine = Engine::with_rules(workers, rules)
            .with_scheduler(Scheduler::new(SchedulerConfig {
                min_device_items: 1,
                ..Default::default()
            }))
            .with_device_fleet(&artifacts_dir, &["fermi"])?;

        let mut fused_secs = Vec::with_capacity(reps);
        let mut roundtrip_secs = Vec::with_capacity(reps);
        let mut resident_boundaries = 0;
        let mut skipped_bytes = 0;
        let mut fell_back_runs = 0;
        for _ in 0..reps {
            let fused = plan.run(&engine, &registry, vec![input.clone()], true)?;
            let reference = plan.run(&engine, &registry, vec![input.clone()], false)?;
            if fused.outputs != reference.outputs {
                bail!("fused and round-trip outputs diverged on {bench}");
            }
            fused_secs.push(fused.modeled_secs);
            roundtrip_secs.push(reference.modeled_secs);
            resident_boundaries = fused.resident_boundaries;
            skipped_bytes = fused
                .stages
                .iter()
                .filter_map(|s| s.stats.as_ref())
                .map(|st| st.skipped_transfer_bytes())
                .sum();
            fell_back_runs += fused.stages.iter().filter(|s| s.fell_back).count()
                + reference.stages.iter().filter(|s| s.fell_back).count();
        }
        let f = mean_secs(&fused_secs);
        let r = mean_secs(&roundtrip_secs);
        rows.push(PipelineRow {
            bench: bench.to_string(),
            stages: plan.len(),
            input_bytes: input.bytes(),
            fused_secs: f,
            roundtrip_secs: r,
            resident_boundaries,
            skipped_bytes,
            speedup: if f > 0.0 { r / f } else { 0.0 },
            fell_back_runs,
        });
    }
    Ok(rows)
}

/// Render the rows as the `BENCH_pipeline.json` schema
/// (`pipeline_fused/v1`, documented in `docs/BENCHMARKS.md`).
pub fn to_json(rows: &[PipelineRow], reps: usize, workers: usize) -> Json {
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str("pipeline_fused/v1".to_string()));
    top.insert("reps".to_string(), Json::Num(reps as f64));
    top.insert("workers".to_string(), Json::Num(workers as f64));
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("bench".to_string(), Json::Str(r.bench.clone()));
            m.insert("stages".to_string(), Json::Num(r.stages as f64));
            m.insert("input_bytes".to_string(), Json::Num(r.input_bytes as f64));
            m.insert("fused_secs".to_string(), Json::Num(r.fused_secs));
            m.insert("roundtrip_secs".to_string(), Json::Num(r.roundtrip_secs));
            m.insert(
                "resident_boundaries".to_string(),
                Json::Num(r.resident_boundaries as f64),
            );
            m.insert("skipped_bytes".to_string(), Json::Num(r.skipped_bytes as f64));
            m.insert("speedup".to_string(), Json::Num(r.speedup));
            m.insert("fell_back_runs".to_string(), Json::Num(r.fell_back_runs as f64));
            Json::Obj(m)
        })
        .collect();
    top.insert("chains".to_string(), Json::Arr(arr));
    Json::Obj(top)
}

/// Print the table, write `out_path`, and with `check` gate the largest
/// chain: fused within `tol` of (in practice, faster than) the
/// round-trip reference, at least one provably resident boundary, and
/// no vacuous pass through SMP fallbacks.
pub fn report(reps: usize, workers: usize, out_path: &str, check: bool, tol: f64) -> Result<()> {
    let rows = measure(reps, workers)?;
    println!(
        "== Method pipelines: fused device-resident chains vs per-stage round-trips \
         (workers {workers}, reps {reps}, modeled clocks) =="
    );
    println!(
        "{:<10} {:>7} {:>11} {:>13} {:>13} {:>9} {:>13} {:>9}",
        "Chain", "stages", "bytes", "Fused (s)", "Rndtrip (s)", "resident", "skipped (B)", "speedup"
    );
    for r in &rows {
        println!(
            "{:<10} {:>7} {:>11} {:>13.6} {:>13.6} {:>9} {:>13} {:>8.2}x{}",
            r.bench,
            r.stages,
            r.input_bytes,
            r.fused_secs,
            r.roundtrip_secs,
            r.resident_boundaries,
            r.skipped_bytes,
            r.speedup,
            if r.fell_back_runs > 0 {
                format!("  ({} stage runs fell back to SMP)", r.fell_back_runs)
            } else {
                String::new()
            }
        );
    }
    std::fs::write(out_path, to_json(&rows, reps, workers).dump())
        .map_err(|e| anyhow!("writing {out_path}: {e}"))?;
    println!("wrote {out_path}");
    if check {
        let largest = rows
            .iter()
            .max_by_key(|r| r.stages)
            .ok_or_else(|| anyhow!("no chains measured"))?;
        if largest.fell_back_runs > 0 {
            // a fallen-back stage ran on SMP in the fused path too — the
            // fused-vs-roundtrip comparison would pass vacuously
            bail!(
                "{} stage runs of {} fell back to SMP — the pipeline gate would be vacuous",
                largest.fell_back_runs,
                largest.bench
            );
        }
        if largest.resident_boundaries < 1 {
            bail!(
                "no provably resident stage boundary on {} (expected ≥ 1 hop with zero \
                 exit D2H bytes)",
                largest.bench
            );
        }
        if largest.fused_secs > largest.roundtrip_secs * tol {
            bail!(
                "fused pipeline is slower than per-stage round-trips on {}: {:.6}s vs \
                 {:.6}s (tol {tol})",
                largest.bench,
                largest.fused_secs,
                largest.roundtrip_secs
            );
        }
        println!(
            "check ok: fused beats per-stage round-trips on {} ({:.6}s vs {:.6}s, \
             {} resident boundaries, {} bytes skipped)",
            largest.bench,
            largest.fused_secs,
            largest.roundtrip_secs,
            largest.resident_boundaries,
            largest.skipped_bytes
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_chains_fuse_faster_with_resident_boundaries() {
        let rows = measure(1, 2).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.fell_back_runs, 0, "{}: all-device chains must not fall back", r.bench);
            assert_eq!(
                r.resident_boundaries,
                r.stages - 1,
                "{}: every interior boundary of an all-device chain stays resident",
                r.bench
            );
            assert!(r.skipped_bytes > 0, "{}: skipped transfers counted", r.bench);
            assert!(
                r.fused_secs <= r.roundtrip_secs,
                "{}: fused modeled clock must not exceed the round-trip ({} vs {})",
                r.bench,
                r.fused_secs,
                r.roundtrip_secs
            );
        }
        let largest = rows.iter().max_by_key(|r| r.stages).unwrap();
        assert_eq!(largest.bench, "crypt-x4");
        assert_eq!(largest.stages, 4);
    }
}
