//! The serving-layer load harness + the batchable benchmark methods.
//!
//! Two parts:
//!
//! * **Batchable methods** — [`vecadd_batched`] (the Listing-8 shape:
//!   f32 adds are exact, so a coalesced batch must be bitwise identical
//!   to N sequential invocations) and [`crypt_batched`] (one IDEA cipher
//!   pass over an *owned* input, with a key-fingerprint compatibility
//!   key: passes under different subkey schedules must never share a
//!   launch).  `rust/tests/serve_batching.rs` drives both through the
//!   compose/split round-trip suite.
//! * **The open-loop load harness** — [`run_load`] fires `requests`
//!   requests at a fixed arrival rate (`arrival_hz`; 0 = unthrottled
//!   saturation) from `clients` client threads into a
//!   [`Service`], measuring per-request latency from the request's
//!   *scheduled* arrival to batch completion (so coordinated omission
//!   cannot flatter the percentiles), and [`report`] sweeps arrival
//!   rates in batched vs unbatched mode.
//! * **The QoS scenario matrix** — [`run_qos`] drives multiple tenant
//!   streams ([`TenantLoad`]: arrival rate, class mix, deadline,
//!   cancellation pattern) into one service and reports per-class and
//!   per-tenant outcomes; [`report`] crosses tenant count × arrival
//!   rate × input size × class mix, plus three *gated* saturation
//!   scenarios (priority under overload, quota protection, cancellation
//!   relief), emitting the combined `serve_qos/v1` `BENCH_serve.json`.
//!
//! With `check`, the report gates on the serving layer's reasons to
//! exist: batched throughput must be at least the unbatched throughput
//! (within `tol`) at the highest arrival rate with a non-vacuous mean
//! batch (≥ 2 requests); under saturation Interactive p99 must beat
//! Batch p99 with at least one request shed; an in-quota tenant's
//! goodput next to a greedy flooder must stay within 10% of its
//! isolated goodput; and cancelling half the queued requests must raise
//! survivor goodput.  Schema documented in `docs/BENCHMARKS.md`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::backend::{BatchSpec, HeteroMethod};
use crate::serve::{AdmissionPolicy, Class, Service, ServiceConfig, SubmitOpts};
use crate::somd::partition::Block1D;
use crate::somd::reduction::Assemble;
use crate::somd::{BlockPart, Engine, SomdMethod};
use crate::util::json::Json;
use crate::util::prng::Xorshift64;
use crate::util::stats::percentiles;

use super::crypt::{self, BLOCK_BYTES, SUBKEYS};

const SEED: u64 = 0x5e7e_2026;

// ---------------------------------------------------------------------------
// Batchable method builders
// ---------------------------------------------------------------------------

/// Listing-8 vector addition with a batch-compose/split spec: requests
/// concatenate element-wise into one fused add and split back by element
/// count.  f32 addition is exact per lane, so the coalesced result is
/// bitwise identical to per-request invocations — the serving
/// correctness suite's workhorse.
pub fn vecadd_batched() -> HeteroMethod<(Vec<f32>, Vec<f32>), BlockPart, (), Vec<f32>> {
    let smp = SomdMethod::new(
        "VecAdd.add",
        |inp: &(Vec<f32>, Vec<f32>), n| Block1D::new().ranges(inp.0.len(), n),
        |_, _| (),
        |inp, p, _, _| {
            let (a, b) = inp;
            p.own.iter().map(|i| a[i] + b[i]).collect::<Vec<f32>>()
        },
        Assemble,
    );
    HeteroMethod::smp_only(smp).with_batch(vecadd_batch_spec())
}

/// The [`BatchSpec`] of [`vecadd_batched`], exposed so tests can attach
/// it to device-capable variants of the same method.
pub fn vecadd_batch_spec() -> BatchSpec<(Vec<f32>, Vec<f32>), Vec<f32>> {
    BatchSpec::new(
        |inp: &(Vec<f32>, Vec<f32>)| inp.0.len(),
        |inputs| {
            let total: usize = inputs.iter().map(|i| i.0.len()).sum();
            let mut a = Vec::with_capacity(total);
            let mut b = Vec::with_capacity(total);
            for i in inputs {
                a.extend_from_slice(&i.0);
                b.extend_from_slice(&i.1);
            }
            Arc::new((a, b))
        },
        |fused: Vec<f32>, counts| {
            let mut out = Vec::with_capacity(counts.len());
            let mut it = fused.into_iter();
            for &c in counts {
                out.push(it.by_ref().take(c).collect::<Vec<f32>>());
            }
            out
        },
    )
}

/// An owned Crypt pass request (the serving layer needs `'static`
/// inputs, so unlike [`crypt::PassInput`] the source is owned).
pub struct CryptServeInput {
    /// Source bytes (8-byte aligned: whole cipher blocks).
    pub src: Vec<u8>,
    /// The subkey schedule of this pass.
    pub keys: [u32; SUBKEYS],
}

/// FNV-1a over a subkey schedule: the compatibility key of
/// [`crypt_batched`].
fn key_fingerprint(keys: &[u32; SUBKEYS]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &k in keys {
        h ^= u64::from(k);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One IDEA cipher pass with a batch spec: the index space is cipher
/// blocks, requests concatenate block-wise, and only requests under the
/// *same* subkey schedule may fuse (two keys in one launch would cipher
/// the wrong spans).  Integer IDEA is exact, so coalesced ciphertext is
/// bitwise identical to the sequential cipher per request.
pub fn crypt_batched() -> HeteroMethod<CryptServeInput, BlockPart, (), Vec<u8>> {
    let smp = SomdMethod::new(
        "Crypt.cipher",
        |inp: &CryptServeInput, n| Block1D::new().ranges(inp.src.len() / BLOCK_BYTES, n),
        |_, _| (),
        |inp, p, _, _| crypt::cipher_partial(&inp.src, &inp.keys, p.own.lo, p.own.hi),
        Assemble,
    );
    HeteroMethod::smp_only(smp).with_batch(
        BatchSpec::new(
            |inp: &CryptServeInput| inp.src.len() / BLOCK_BYTES,
            |inputs| {
                let total: usize = inputs.iter().map(|i| i.src.len()).sum();
                let mut src = Vec::with_capacity(total);
                for i in inputs {
                    src.extend_from_slice(&i.src);
                }
                Arc::new(CryptServeInput { src, keys: inputs[0].keys })
            },
            |fused: Vec<u8>, counts| {
                let mut out = Vec::with_capacity(counts.len());
                let mut off = 0usize;
                for &c in counts {
                    let bytes = c * BLOCK_BYTES;
                    out.push(fused[off..off + bytes].to_vec());
                    off += bytes;
                }
                out
            },
        )
        .with_compat(|inp| key_fingerprint(&inp.keys)),
    )
}

// ---------------------------------------------------------------------------
// Open-loop load harness
// ---------------------------------------------------------------------------

/// One load run's shape.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Open-loop arrival rate in requests/second across all clients;
    /// `0.0` means unthrottled (every request scheduled at t=0 — the
    /// saturation row the `--check` gate reads).
    pub arrival_hz: f64,
    /// Total requests fired.
    pub requests: usize,
    /// Client threads the arrival stream is interleaved across.
    pub clients: usize,
    /// Elements per vecadd request.
    pub elems: usize,
    /// Engine worker (MI) count.
    pub workers: usize,
}

/// One measured row of the sweep.
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// `"batched"` or `"unbatched"`.
    pub mode: String,
    /// Human-readable arrival rate (`"4000/s"` or `"max"`).
    pub arrival: String,
    /// Numeric arrival rate (0.0 = unthrottled).
    pub arrival_hz: f64,
    /// Requests fired.
    pub requests: usize,
    /// Client threads.
    pub clients: usize,
    /// Elements per request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
    /// Latency percentiles, milliseconds (scheduled arrival → batch
    /// completion).
    pub p50_ms: f64,
    /// 95th-percentile latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile latency, milliseconds.
    pub p99_ms: f64,
    /// Worst-case latency, milliseconds.
    pub max_ms: f64,
    /// Completed requests per second (first scheduled arrival → last
    /// completion).
    pub throughput_rps: f64,
    /// Mean requests per executed batch.
    pub mean_batch: f64,
    /// Largest executed batch, in requests.
    pub max_batch: u64,
    /// Fused batches executed.
    pub batches: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
}

/// Run one open-loop load: `spec.requests` vecadd requests at
/// `spec.arrival_hz` through a fresh [`Service`], batched
/// (`max_batch_items` = 32 requests' worth, 1 ms linger) or unbatched
/// (`max_batch_items` = 1 — every request its own launch through the
/// identical code path, the honest control).
pub fn run_load(batched: bool, spec: &LoadSpec) -> Result<ServeRow> {
    let cfg = if batched {
        ServiceConfig {
            max_batch_items: spec.elems.saturating_mul(32).max(1),
            max_batch_delay: Duration::from_micros(1_000),
            queue_depth: spec.requests.max(1),
            admission: AdmissionPolicy::Block,
            ..ServiceConfig::default()
        }
    } else {
        ServiceConfig {
            max_batch_items: 1,
            max_batch_delay: Duration::ZERO,
            queue_depth: spec.requests.max(1),
            admission: AdmissionPolicy::Block,
            ..ServiceConfig::default()
        }
    };
    let service = Service::with_config(Engine::new(spec.workers), cfg);
    let client = service.register(Arc::new(vecadd_batched())).map_err(|e| anyhow!("{e}"))?;

    // deterministic inputs, generated before the clock starts
    let inputs: Vec<Arc<(Vec<f32>, Vec<f32>)>> = (0..spec.requests)
        .map(|i| {
            let mut rng = Xorshift64::new(SEED ^ (i as u64).wrapping_mul(0x9e37_79b9));
            let a: Vec<f32> = (0..spec.elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
            let b: Vec<f32> = (0..spec.elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
            Arc::new((a, b))
        })
        .collect();

    let clients = spec.clients.max(1);
    let base = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(spec.requests);
    let mut last_completed = base;
    let mut failed = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let client = client.clone();
            let inputs = &inputs;
            handles.push(s.spawn(move || {
                let mut tickets = Vec::new();
                let mut failed = 0usize;
                let mut i = c;
                while i < inputs.len() {
                    let scheduled = if spec.arrival_hz > 0.0 {
                        base + Duration::from_secs_f64(i as f64 / spec.arrival_hz)
                    } else {
                        base
                    };
                    let now = Instant::now();
                    if scheduled > now {
                        std::thread::sleep(scheduled - now);
                    }
                    match client.submit(inputs[i].clone()) {
                        Ok(t) => tickets.push((scheduled, t)),
                        Err(_) => failed += 1,
                    }
                    i += clients;
                }
                let mut done = Vec::with_capacity(tickets.len());
                for (scheduled, t) in tickets {
                    match t.wait() {
                        Ok(o) => {
                            let lat =
                                o.completed_at.saturating_duration_since(scheduled).as_secs_f64();
                            done.push((lat, o.completed_at));
                        }
                        Err(_) => failed += 1,
                    }
                }
                (done, failed)
            }));
        }
        for h in handles {
            let (done, f) = h.join().expect("load client thread");
            failed += f;
            for (lat, at) in done {
                latencies.push(lat);
                if at > last_completed {
                    last_completed = at;
                }
            }
        }
    });
    service.drain();
    let m = service.metrics();
    if failed > 0 || m.failed > 0 {
        bail!("{failed} request(s) failed during the load run (metrics: {} failed)", m.failed);
    }
    if latencies.is_empty() {
        bail!("load run completed no requests");
    }

    let span = last_completed.saturating_duration_since(base).as_secs_f64();
    let p = percentiles(&latencies);
    Ok(ServeRow {
        mode: if batched { "batched" } else { "unbatched" }.to_string(),
        arrival: if spec.arrival_hz > 0.0 {
            format!("{:.0}/s", spec.arrival_hz)
        } else {
            "max".to_string()
        },
        arrival_hz: spec.arrival_hz.max(0.0),
        requests: spec.requests,
        clients,
        elems: spec.elems,
        workers: spec.workers,
        p50_ms: p.p50 * 1e3,
        p95_ms: p.p95 * 1e3,
        p99_ms: p.p99 * 1e3,
        max_ms: p.max * 1e3,
        throughput_rps: if span > 0.0 { latencies.len() as f64 / span } else { 0.0 },
        mean_batch: m.mean_batch_requests(),
        max_batch: m.max_batch_requests,
        batches: m.batches,
        rejected: m.rejected,
    })
}

/// Render the combined report as the `serve_qos/v1` `BENCH_serve.json`
/// schema (see `docs/BENCHMARKS.md`): the calibrated capacity, the
/// baseline batched-vs-unbatched sweep, and the QoS scenario rows.
pub fn to_json(capacity_rps: f64, baseline: &[ServeRow], scenarios: &[QosRow]) -> Json {
    use std::collections::BTreeMap;
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str("serve_qos/v1".to_string()));
    top.insert("capacity_rps".to_string(), Json::Num(capacity_rps));
    let arr: Vec<Json> = baseline
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("mode".to_string(), Json::Str(r.mode.clone()));
            m.insert("arrival".to_string(), Json::Str(r.arrival.clone()));
            m.insert("arrival_hz".to_string(), Json::Num(r.arrival_hz));
            m.insert("requests".to_string(), Json::Num(r.requests as f64));
            m.insert("clients".to_string(), Json::Num(r.clients as f64));
            m.insert("elems".to_string(), Json::Num(r.elems as f64));
            m.insert("workers".to_string(), Json::Num(r.workers as f64));
            m.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
            m.insert("p95_ms".to_string(), Json::Num(r.p95_ms));
            m.insert("p99_ms".to_string(), Json::Num(r.p99_ms));
            m.insert("max_ms".to_string(), Json::Num(r.max_ms));
            m.insert("throughput_rps".to_string(), Json::Num(r.throughput_rps));
            m.insert("mean_batch".to_string(), Json::Num(r.mean_batch));
            m.insert("max_batch".to_string(), Json::Num(r.max_batch as f64));
            m.insert("batches".to_string(), Json::Num(r.batches as f64));
            m.insert("rejected".to_string(), Json::Num(r.rejected as f64));
            Json::Obj(m)
        })
        .collect();
    top.insert("baseline".to_string(), Json::Arr(arr));
    top.insert("scenarios".to_string(), Json::Arr(scenarios.iter().map(QosRow::to_json).collect()));
    Json::Obj(top)
}

/// The full sweep's shape: per-rate [`LoadSpec`]s are derived from this.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Arrival rates, one unbatched + one batched row each; the *last*
    /// is the gate's "highest" (use `0.0` = unthrottled saturation).
    pub rates: Vec<f64>,
    /// Requests per row.
    pub requests: usize,
    /// Client threads per row.
    pub clients: usize,
    /// Elements per request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
}

// ---------------------------------------------------------------------------
// QoS scenario matrix
// ---------------------------------------------------------------------------

/// Probabilistic class mix of one tenant's request stream (weights need
/// not sum to 1; they are normalized at pick time).
#[derive(Debug, Clone, Copy)]
pub struct ClassMix {
    /// Weight of [`Class::Interactive`].
    pub interactive: f64,
    /// Weight of [`Class::Batch`].
    pub batch: f64,
    /// Weight of [`Class::BestEffort`].
    pub best_effort: f64,
}

impl ClassMix {
    /// Everything latency-sensitive.
    pub const INTERACTIVE_ONLY: ClassMix =
        ClassMix { interactive: 1.0, batch: 0.0, best_effort: 0.0 };
    /// Everything throughput traffic.
    pub const BATCH_ONLY: ClassMix = ClassMix { interactive: 0.0, batch: 1.0, best_effort: 0.0 };
    /// The saturation matrix's mixed stream: 40% interactive, 40% batch,
    /// 20% best-effort.
    pub const MIXED: ClassMix = ClassMix { interactive: 0.4, batch: 0.4, best_effort: 0.2 };

    /// Draw one class per the weights.
    pub fn pick(&self, rng: &mut Xorshift64) -> Class {
        let total = self.interactive + self.batch + self.best_effort;
        if total <= 0.0 {
            return Class::Interactive;
        }
        let x = rng.f64() * total;
        if x < self.interactive {
            Class::Interactive
        } else if x < self.interactive + self.batch {
            Class::Batch
        } else {
            Class::BestEffort
        }
    }

    /// Compact row label (`i40b40e20`).
    pub fn label(&self) -> String {
        format!(
            "i{:.0}b{:.0}e{:.0}",
            self.interactive * 100.0,
            self.batch * 100.0,
            self.best_effort * 100.0
        )
    }
}

/// One tenant's request stream within a [`QosScenario`].
#[derive(Debug, Clone)]
pub struct TenantLoad {
    /// Tenant identity carried in [`SubmitOpts`].
    pub tenant: String,
    /// Open-loop arrival rate for this tenant (0.0 = unthrottled).
    pub arrival_hz: f64,
    /// Requests this tenant fires.
    pub requests: usize,
    /// Class mix of the stream.
    pub mix: ClassMix,
    /// Relative deadline attached to every request (`None` = none).
    pub deadline: Option<Duration>,
    /// Cancel every k-th request immediately after submitting it
    /// (0 = never) — the cancellation-relief scenario's knob.
    pub cancel_every: usize,
}

/// One QoS scenario: several tenant streams into one freshly built
/// service.
#[derive(Debug, Clone)]
pub struct QosScenario {
    /// Row name in the report (`saturation-mix`, `quota-shared`, …).
    pub name: String,
    /// The tenant streams (one client thread each).
    pub loads: Vec<TenantLoad>,
    /// Elements per vecadd request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
    /// Admission depth of the method queue.
    pub queue_depth: usize,
    /// Full-queue policy.
    pub admission: AdmissionPolicy,
    /// Per-tenant pending cap (`None` = no quota).
    pub tenant_quota: Option<usize>,
    /// Batch cap in *requests* (`max_batch_items` = this × `elems`) —
    /// kept small so dispatch order, not one giant batch, decides who
    /// waits.
    pub max_batch_requests: usize,
    /// The queue's no-starvation bound.
    pub aging_bound: Duration,
}

/// Per-class outcome of one scenario.
#[derive(Debug, Clone)]
pub struct ClassStat {
    /// The class.
    pub class: Class,
    /// Submit attempts carrying this class.
    pub offered: usize,
    /// Requests of this class that completed.
    pub completed: usize,
    /// Median completion latency, milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile latency, milliseconds.
    pub p99_ms: f64,
    /// Completions per second of offered-load span.
    pub goodput_rps: f64,
}

/// Per-tenant outcome of one scenario.
#[derive(Debug, Clone)]
pub struct TenantStat {
    /// Tenant identity.
    pub tenant: String,
    /// Submit attempts by this tenant.
    pub offered: usize,
    /// This tenant's completed requests.
    pub completed: usize,
    /// Completions per second of this tenant's own offered-load span.
    pub goodput_rps: f64,
}

/// One measured QoS scenario row.
#[derive(Debug, Clone)]
pub struct QosRow {
    /// Scenario name.
    pub name: String,
    /// Tenant streams.
    pub tenants: usize,
    /// Total submit attempts across tenants.
    pub requests: usize,
    /// Elements per request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
    /// Admission depth.
    pub queue_depth: usize,
    /// `"block"` or `"reject"`.
    pub admission: String,
    /// Per-tenant pending cap (0 = none).
    pub tenant_quota: usize,
    /// Offered-load span in seconds (the goodput denominator: the
    /// longest configured tenant stream, or the wall when every stream
    /// is unthrottled).
    pub span_s: f64,
    /// First arrival → last completion, seconds.
    pub wall_s: f64,
    /// Completions per second of wall time.
    pub throughput_rps: f64,
    /// Completions per second of offered-load span — the survivor
    /// goodput the cancellation gate compares.
    pub goodput_rps: f64,
    /// Mean requests per executed batch.
    pub mean_batch: f64,
    /// Fused batches executed.
    pub batches: u64,
    /// Requests admitted.
    pub submitted: u64,
    /// Requests completed.
    pub completed: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
    /// Requests turned away by the per-tenant quota.
    pub quota_rejected: u64,
    /// Queued requests shed for higher-class newcomers.
    pub shed: u64,
    /// Queued requests dropped past their deadline.
    pub expired: u64,
    /// Requests cancelled (queued + in-flight).
    pub cancelled: u64,
    /// The subset of `cancelled` dropped while still queued.
    pub cancelled_queued: u64,
    /// Per-class outcomes.
    pub classes: Vec<ClassStat>,
    /// Per-tenant outcomes.
    pub tenants_detail: Vec<TenantStat>,
}

impl QosRow {
    /// Per-class stat lookup (every row carries all three classes).
    pub fn class(&self, class: Class) -> &ClassStat {
        &self.classes[class.index()]
    }

    /// Sum of goodput over tenants whose name starts with `prefix`.
    pub fn tenant_goodput(&self, prefix: &str) -> f64 {
        self.tenants_detail
            .iter()
            .filter(|t| t.tenant.starts_with(prefix))
            .map(|t| t.goodput_rps)
            .sum()
    }

    /// This row as a `serve_qos/v1` scenario object.
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("tenants".to_string(), Json::Num(self.tenants as f64));
        m.insert("requests".to_string(), Json::Num(self.requests as f64));
        m.insert("elems".to_string(), Json::Num(self.elems as f64));
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("queue_depth".to_string(), Json::Num(self.queue_depth as f64));
        m.insert("admission".to_string(), Json::Str(self.admission.clone()));
        m.insert("tenant_quota".to_string(), Json::Num(self.tenant_quota as f64));
        m.insert("span_s".to_string(), Json::Num(self.span_s));
        m.insert("wall_s".to_string(), Json::Num(self.wall_s));
        m.insert("throughput_rps".to_string(), Json::Num(self.throughput_rps));
        m.insert("goodput_rps".to_string(), Json::Num(self.goodput_rps));
        m.insert("mean_batch".to_string(), Json::Num(self.mean_batch));
        m.insert("batches".to_string(), Json::Num(self.batches as f64));
        m.insert("submitted".to_string(), Json::Num(self.submitted as f64));
        m.insert("completed".to_string(), Json::Num(self.completed as f64));
        m.insert("rejected".to_string(), Json::Num(self.rejected as f64));
        m.insert("quota_rejected".to_string(), Json::Num(self.quota_rejected as f64));
        m.insert("shed".to_string(), Json::Num(self.shed as f64));
        m.insert("expired".to_string(), Json::Num(self.expired as f64));
        m.insert("cancelled".to_string(), Json::Num(self.cancelled as f64));
        m.insert("cancelled_queued".to_string(), Json::Num(self.cancelled_queued as f64));
        let classes: Vec<Json> = self
            .classes
            .iter()
            .map(|c| {
                let mut cm = BTreeMap::new();
                cm.insert("class".to_string(), Json::Str(c.class.name().to_string()));
                cm.insert("offered".to_string(), Json::Num(c.offered as f64));
                cm.insert("completed".to_string(), Json::Num(c.completed as f64));
                cm.insert("p50_ms".to_string(), Json::Num(c.p50_ms));
                cm.insert("p95_ms".to_string(), Json::Num(c.p95_ms));
                cm.insert("p99_ms".to_string(), Json::Num(c.p99_ms));
                cm.insert("goodput_rps".to_string(), Json::Num(c.goodput_rps));
                Json::Obj(cm)
            })
            .collect();
        m.insert("classes".to_string(), Json::Arr(classes));
        let tenants: Vec<Json> = self
            .tenants_detail
            .iter()
            .map(|t| {
                let mut tm = BTreeMap::new();
                tm.insert("tenant".to_string(), Json::Str(t.tenant.clone()));
                tm.insert("offered".to_string(), Json::Num(t.offered as f64));
                tm.insert("completed".to_string(), Json::Num(t.completed as f64));
                tm.insert("goodput_rps".to_string(), Json::Num(t.goodput_rps));
                Json::Obj(tm)
            })
            .collect();
        m.insert("tenants_detail".to_string(), Json::Arr(tenants));
        Json::Obj(m)
    }
}

/// What one tenant thread measured.
struct TenantOut {
    /// Completion latencies, seconds, per [`Class::index`].
    lat: [Vec<f64>; 3],
    /// Submit attempts per class.
    offered: [usize; 3],
    completed: usize,
    /// This tenant's last completion, seconds since the run base.
    last_completed_s: f64,
    error: Option<String>,
}

/// Run one QoS scenario: one client thread per [`TenantLoad`], all into
/// a fresh [`Service`] over vecadd.  Latency is measured from the
/// request's scheduled arrival when the stream is throttled (open-loop,
/// coordinated-omission-honest) and from the actual submit instant when
/// unthrottled (where "scheduled at t=0" would only measure submission
/// order, not queue treatment).
pub fn run_qos(scn: &QosScenario) -> Result<QosRow> {
    if scn.loads.is_empty() {
        bail!("QoS scenario '{}' has no tenant loads", scn.name);
    }
    let cfg = ServiceConfig {
        max_batch_items: scn.elems.saturating_mul(scn.max_batch_requests.max(1)).max(1),
        max_batch_delay: Duration::from_micros(200),
        queue_depth: scn.queue_depth,
        admission: scn.admission,
        tenant_quota: scn.tenant_quota,
        aging_bound: scn.aging_bound,
        sched_snapshot: None,
    };
    let service = Service::with_config(Engine::new(scn.workers), cfg);
    let client = service.register(Arc::new(vecadd_batched())).map_err(|e| anyhow!("{e}"))?;
    let base = Instant::now();

    let mut outs: Vec<TenantOut> = Vec::with_capacity(scn.loads.len());
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(scn.loads.len());
        for (ti, load) in scn.loads.iter().enumerate() {
            let client = client.clone();
            let elems = scn.elems;
            handles.push(s.spawn(move || {
                let mut rng =
                    Xorshift64::new(SEED ^ (ti as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                let mut out = TenantOut {
                    lat: [Vec::new(), Vec::new(), Vec::new()],
                    offered: [0; 3],
                    completed: 0,
                    last_completed_s: 0.0,
                    error: None,
                };
                let mut tickets = Vec::with_capacity(load.requests);
                for i in 0..load.requests {
                    let scheduled = if load.arrival_hz > 0.0 {
                        base + Duration::from_secs_f64(i as f64 / load.arrival_hz)
                    } else {
                        base
                    };
                    let now = Instant::now();
                    if scheduled > now {
                        std::thread::sleep(scheduled - now);
                    }
                    let a: Vec<f32> = (0..elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
                    let b: Vec<f32> = (0..elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
                    let class = load.mix.pick(&mut rng);
                    let mut opts = SubmitOpts::class(class).tenant(load.tenant.clone());
                    if let Some(d) = load.deadline {
                        opts = opts.deadline(d);
                    }
                    out.offered[class.index()] += 1;
                    let t_ref = if load.arrival_hz > 0.0 { scheduled } else { Instant::now() };
                    match client.submit_with(Arc::new((a, b)), opts) {
                        Ok(t) => {
                            if load.cancel_every > 0 && (i + 1) % load.cancel_every == 0 {
                                t.cancel();
                            }
                            tickets.push((class, t_ref, t));
                        }
                        // rejected / over-quota / shed outcomes are
                        // counted by the service metrics
                        Err(_) => {}
                    }
                }
                for (class, t_ref, t) in tickets {
                    match t.wait() {
                        Ok(o) => {
                            out.lat[class.index()].push(
                                o.completed_at.saturating_duration_since(t_ref).as_secs_f64(),
                            );
                            out.completed += 1;
                            let at = o.completed_at.saturating_duration_since(base).as_secs_f64();
                            if at > out.last_completed_s {
                                out.last_completed_s = at;
                            }
                        }
                        Err(crate::serve::ServeError::Failed(msg)) => {
                            out.error = Some(msg);
                        }
                        // cancelled / expired / shed: the service
                        // metrics keep these distinguishable
                        Err(_) => {}
                    }
                }
                out
            }));
        }
        for h in handles {
            outs.push(h.join().expect("qos tenant thread"));
        }
    });
    service.drain();
    let m = service.metrics();
    for out in &outs {
        if let Some(msg) = &out.error {
            bail!("scenario '{}': request failed: {msg}", scn.name);
        }
    }
    if m.failed > 0 {
        bail!("scenario '{}': {} request(s) failed", scn.name, m.failed);
    }

    let wall_s = outs.iter().map(|o| o.last_completed_s).fold(0.0, f64::max);
    let mut span_s = 0.0f64;
    for l in &scn.loads {
        if l.arrival_hz > 0.0 {
            span_s = span_s.max(l.requests as f64 / l.arrival_hz);
        }
    }
    if span_s == 0.0 {
        span_s = wall_s;
    }
    let span_div = span_s.max(1e-9);

    let classes: Vec<ClassStat> = Class::ALL
        .iter()
        .map(|&class| {
            let i = class.index();
            let lat: Vec<f64> = outs.iter().flat_map(|o| o.lat[i].iter().copied()).collect();
            let offered: usize = outs.iter().map(|o| o.offered[i]).sum();
            let p = if lat.is_empty() { None } else { Some(percentiles(&lat)) };
            ClassStat {
                class,
                offered,
                completed: lat.len(),
                p50_ms: p.as_ref().map_or(0.0, |p| p.p50 * 1e3),
                p95_ms: p.as_ref().map_or(0.0, |p| p.p95 * 1e3),
                p99_ms: p.as_ref().map_or(0.0, |p| p.p99 * 1e3),
                goodput_rps: lat.len() as f64 / span_div,
            }
        })
        .collect();
    let tenants_detail: Vec<TenantStat> = scn
        .loads
        .iter()
        .zip(&outs)
        .map(|(l, o)| {
            let tenant_span = if l.arrival_hz > 0.0 {
                l.requests as f64 / l.arrival_hz
            } else {
                wall_s
            };
            TenantStat {
                tenant: l.tenant.clone(),
                offered: o.offered.iter().sum(),
                completed: o.completed,
                goodput_rps: o.completed as f64 / tenant_span.max(1e-9),
            }
        })
        .collect();
    let completed_total: usize = outs.iter().map(|o| o.completed).sum();

    Ok(QosRow {
        name: scn.name.clone(),
        tenants: scn.loads.len(),
        requests: scn.loads.iter().map(|l| l.requests).sum(),
        elems: scn.elems,
        workers: scn.workers,
        queue_depth: scn.queue_depth,
        admission: match scn.admission {
            AdmissionPolicy::Block => "block".to_string(),
            AdmissionPolicy::Reject => "reject".to_string(),
        },
        tenant_quota: scn.tenant_quota.unwrap_or(0),
        span_s,
        wall_s,
        throughput_rps: completed_total as f64 / wall_s.max(1e-9),
        goodput_rps: completed_total as f64 / span_div,
        mean_batch: m.mean_batch_requests(),
        batches: m.batches,
        submitted: m.submitted,
        completed: m.completed,
        rejected: m.rejected,
        quota_rejected: m.quota_rejected,
        shed: m.shed,
        expired: m.expired,
        cancelled: m.cancelled,
        cancelled_queued: m.cancelled_queued,
        classes,
        tenants_detail,
    })
}

/// The scenario list of one report: the ungated tenant-count × arrival
/// rate × input size × class-mix matrix, then the three gated
/// saturation scenarios.  `cap` is the calibrated single-tenant
/// unthrottled capacity at `elems` = 512 under the same batch shape.
fn qos_scenarios(cap: f64, workers: usize, smoke: bool) -> Vec<QosScenario> {
    let aging = Duration::from_millis(150);
    let mut scns = Vec::new();

    // -- the matrix: tenants x rate factor x elems x mix (ungated) --
    let tenant_counts: &[usize] = if smoke { &[4] } else { &[1, 4] };
    let factors: &[f64] = if smoke { &[1.5] } else { &[0.6, 1.5] };
    let sizes: &[usize] = &[256, 1024];
    let mixes: &[ClassMix] = &[ClassMix::INTERACTIVE_ONLY, ClassMix::MIXED];
    let total_requests = if smoke { 120 } else { 240 };
    for &tenants in tenant_counts {
        for &factor in factors {
            for &elems in sizes {
                // capacity scales roughly inversely with request size
                let cap_e = (cap * 512.0 / elems as f64).max(1.0);
                for mix in mixes {
                    let per_tenant = (total_requests / tenants).max(1);
                    let rate = factor * cap_e / tenants as f64;
                    scns.push(QosScenario {
                        name: format!("matrix-t{tenants}-r{factor:.1}x-e{elems}-{}", mix.label()),
                        loads: (0..tenants)
                            .map(|t| TenantLoad {
                                tenant: format!("t{t}"),
                                arrival_hz: rate,
                                requests: per_tenant,
                                mix: *mix,
                                deadline: None,
                                cancel_every: 0,
                            })
                            .collect(),
                        elems,
                        workers,
                        queue_depth: 256,
                        admission: AdmissionPolicy::Block,
                        tenant_quota: None,
                        max_batch_requests: 4,
                        aging_bound: aging,
                    });
                }
            }
        }
    }

    // -- gated: priority under saturation --
    // three mixed-class tenants at 1.8x capacity into a shallow Reject
    // queue: Interactive must hold its tail while Batch absorbs the
    // aging bound, and full-queue arrivals must shed lower classes.
    let dur = if smoke { 1.2 } else { 2.5 };
    let sat_rate = 0.6 * cap; // x3 tenants = 1.8x capacity
    let sat_requests = ((sat_rate * dur).ceil() as usize).clamp(60, 6000);
    scns.push(QosScenario {
        name: "saturation-mix".to_string(),
        loads: (0..3)
            .map(|t| TenantLoad {
                tenant: format!("t{t}"),
                arrival_hz: sat_rate,
                requests: sat_requests,
                mix: ClassMix::MIXED,
                deadline: None,
                cancel_every: 0,
            })
            .collect(),
        elems: 512,
        workers,
        queue_depth: 32,
        admission: AdmissionPolicy::Reject,
        tenant_quota: None,
        max_batch_requests: 4,
        aging_bound: aging,
    });

    // -- gated: quota protection (isolated, then next to a flooder) --
    let quota_dur = if smoke { 1.2 } else { 2.0 };
    let polite_rate = 0.15 * cap;
    let polite_requests = ((polite_rate * quota_dur).ceil() as usize).clamp(20, 3000);
    let greedy_rate = 1.5 * cap;
    let greedy_requests = ((greedy_rate * quota_dur).ceil() as usize).clamp(60, 9000);
    let polite = |t: usize| TenantLoad {
        tenant: format!("polite{t}"),
        arrival_hz: polite_rate,
        requests: polite_requests,
        mix: ClassMix::INTERACTIVE_ONLY,
        deadline: None,
        cancel_every: 0,
    };
    let quota_base = QosScenario {
        name: "quota-isolated".to_string(),
        loads: vec![polite(0), polite(1)],
        elems: 512,
        workers,
        queue_depth: 64,
        admission: AdmissionPolicy::Reject,
        tenant_quota: Some(8),
        max_batch_requests: 4,
        aging_bound: aging,
    };
    scns.push(quota_base.clone());
    let mut quota_shared = quota_base;
    quota_shared.name = "quota-shared".to_string();
    quota_shared.loads.push(TenantLoad {
        tenant: "greedy".to_string(),
        arrival_hz: greedy_rate,
        requests: greedy_requests,
        mix: ClassMix::BATCH_ONLY,
        deadline: None,
        cancel_every: 0,
    });
    scns.push(quota_shared);

    // -- gated: cancellation relief --
    // one tenant at 1.8x capacity with a deadline every request; the
    // paired run cancels every 2nd request right after submitting.
    // Without cancellation the backlog grows until deadlines expire;
    // cancelling half brings the survivors back under capacity.
    let cancel_rate = 1.8 * cap;
    let cancel_requests = ((cancel_rate * quota_dur).ceil() as usize).clamp(60, 9000);
    let cancel_load = |every: usize| TenantLoad {
        tenant: "c0".to_string(),
        arrival_hz: cancel_rate,
        requests: cancel_requests,
        mix: ClassMix::INTERACTIVE_ONLY,
        deadline: Some(Duration::from_millis(300)),
        cancel_every: every,
    };
    for (name, every) in [("cancel-off", 0usize), ("cancel-on", 2)] {
        scns.push(QosScenario {
            name: name.to_string(),
            loads: vec![cancel_load(every)],
            elems: 512,
            workers,
            queue_depth: cancel_requests.max(1),
            admission: AdmissionPolicy::Block,
            tenant_quota: None,
            max_batch_requests: 4,
            aging_bound: Duration::from_millis(500),
        });
    }
    scns
}

/// Apply the `--check` gates over the scenario rows (see the module
/// docs): priority inversion, quota protection, cancellation relief,
/// and non-vacuousness (at least one shed and one cancelled request
/// across the report).
fn check_qos(rows: &[QosRow]) -> Result<()> {
    let find = |name: &str| -> Result<&QosRow> {
        rows.iter()
            .find(|r| r.name == name)
            .ok_or_else(|| anyhow!("scenario '{name}' missing from the report"))
    };

    let sat = find("saturation-mix")?;
    let (ia, ba) = (sat.class(Class::Interactive), sat.class(Class::Batch));
    if ia.completed < 10 || ba.completed < 10 {
        bail!(
            "vacuous saturation-mix row: {} interactive / {} batch completions (need >= 10 each)",
            ia.completed,
            ba.completed
        );
    }
    if sat.shed == 0 {
        bail!("saturation-mix shed nothing — the overload scenario never overloaded");
    }
    if ia.p99_ms >= ba.p99_ms {
        bail!(
            "priority inversion under saturation: interactive p99 {:.2} ms >= batch p99 {:.2} ms",
            ia.p99_ms,
            ba.p99_ms
        );
    }
    println!(
        "check ok: saturation-mix interactive p99 {:.2} ms < batch p99 {:.2} ms \
         ({} shed, {} rejected)",
        ia.p99_ms, ba.p99_ms, sat.shed, sat.rejected
    );

    let isolated = find("quota-isolated")?;
    let shared = find("quota-shared")?;
    let (gi, gs) = (isolated.tenant_goodput("polite"), shared.tenant_goodput("polite"));
    if shared.quota_rejected == 0 {
        bail!("quota-shared rejected nothing over quota — the flooder never hit its cap");
    }
    if gs < 0.9 * gi {
        bail!(
            "quota failed to protect in-quota tenants: polite goodput {gs:.0} req/s next to the \
             flooder vs {gi:.0} req/s isolated (need >= 90%)"
        );
    }
    println!(
        "check ok: polite goodput {gs:.0} req/s beside the flooder vs {gi:.0} req/s isolated \
         ({} over-quota rejections)",
        shared.quota_rejected
    );

    let off = find("cancel-off")?;
    let on = find("cancel-on")?;
    if on.cancelled == 0 {
        bail!("cancel-on cancelled nothing");
    }
    if off.expired == 0 {
        bail!("cancel-off expired nothing — the overload scenario never missed a deadline");
    }
    if on.goodput_rps < 1.05 * off.goodput_rps {
        bail!(
            "cancelling half the queue did not raise survivor goodput: {:.0} vs {:.0} req/s \
             (need >= 1.05x)",
            on.goodput_rps,
            off.goodput_rps
        );
    }
    println!(
        "check ok: survivor goodput {:.0} req/s with cancellation vs {:.0} req/s without \
         ({} cancelled, {} expired without)",
        on.goodput_rps, off.goodput_rps, on.cancelled, off.expired
    );
    Ok(())
}

/// Run the full report: the baseline arrival sweep (unbatched + batched
/// row per rate), then the QoS scenario matrix; print the tables, write
/// `out_path` (`serve_qos/v1`), and with `check` apply every gate —
/// batched ≥ unbatched within `tol` at the highest rate (refusing
/// vacuous rows), priority under saturation, quota protection, and
/// cancellation relief.
pub fn report(sweep: &SweepSpec, out_path: &str, check: bool, tol: f64, smoke: bool) -> Result<()> {
    let SweepSpec { rates, requests, clients, elems, workers } = sweep;
    let (requests, clients, elems, workers) = (*requests, *clients, *elems, *workers);
    if rates.is_empty() {
        bail!("serve bench needs at least one arrival rate");
    }
    println!(
        "== Serving layer: open-loop load, {requests} reqs x {elems} elems, \
         {clients} clients, {workers} workers =="
    );
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>10} {:>9}",
        "Mode", "arrival", "p50 (ms)", "p95 (ms)", "p99 (ms)", "thruput r/s", "mean bat", "rejected"
    );
    let mut rows = Vec::new();
    for &hz in rates {
        let spec = LoadSpec { arrival_hz: hz, requests, clients, elems, workers };
        for batched in [false, true] {
            let r = run_load(batched, &spec)?;
            println!(
                "{:<10} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>12.0} {:>10.1} {:>9}",
                r.mode, r.arrival, r.p50_ms, r.p95_ms, r.p99_ms, r.throughput_rps, r.mean_batch,
                r.rejected
            );
            rows.push(r);
        }
    }

    // calibrate: unthrottled single-tenant run in the exact batch shape
    // the QoS scenarios use, so their overload factors are honest
    let cal = QosScenario {
        name: "calibrate".to_string(),
        loads: vec![TenantLoad {
            tenant: "cal".to_string(),
            arrival_hz: 0.0,
            requests: if smoke { 120 } else { 240 },
            mix: ClassMix::INTERACTIVE_ONLY,
            deadline: None,
            cancel_every: 0,
        }],
        elems: 512,
        workers,
        queue_depth: 256,
        admission: AdmissionPolicy::Block,
        tenant_quota: None,
        max_batch_requests: 4,
        aging_bound: Duration::from_millis(150),
    };
    let cap = run_qos(&cal)?.throughput_rps.max(1.0);
    println!("== QoS scenario matrix (calibrated capacity {cap:.0} req/s) ==");
    println!(
        "{:<26} {:>7} {:>8} {:>11} {:>11} {:>9} {:>6} {:>7} {:>7} {:>7}",
        "Scenario", "tenants", "reqs", "goodput r/s", "int p99", "bat p99", "shed", "expired",
        "cancel", "quota"
    );
    let mut scenarios = Vec::new();
    for scn in qos_scenarios(cap, workers, smoke) {
        let r = run_qos(&scn)?;
        println!(
            "{:<26} {:>7} {:>8} {:>11.0} {:>11.2} {:>9.2} {:>6} {:>7} {:>7} {:>7}",
            r.name,
            r.tenants,
            r.requests,
            r.goodput_rps,
            r.class(Class::Interactive).p99_ms,
            r.class(Class::Batch).p99_ms,
            r.shed,
            r.expired,
            r.cancelled,
            r.quota_rejected
        );
        scenarios.push(r);
    }

    std::fs::write(out_path, to_json(cap, &rows, &scenarios).dump())
        .map_err(|e| anyhow!("writing {out_path}: {e}"))?;
    println!("wrote {out_path}");
    if check {
        // the baseline gate reads the final rate's pair:
        // [..., unbatched, batched]
        let batched = rows.last().expect("rows nonempty");
        let unbatched = &rows[rows.len() - 2];
        assert_eq!(batched.mode, "batched");
        assert_eq!(unbatched.mode, "unbatched");
        if batched.mean_batch < 2.0 {
            bail!(
                "vacuous batched row at the highest arrival rate: mean batch {:.2} requests \
                 (< 2) — coalescing never happened, the throughput comparison proves nothing",
                batched.mean_batch
            );
        }
        if batched.throughput_rps * tol < unbatched.throughput_rps {
            bail!(
                "batched throughput lost to unbatched at the highest arrival rate: \
                 {:.0} vs {:.0} req/s (tol {tol})",
                batched.throughput_rps,
                unbatched.throughput_rps
            );
        }
        println!(
            "check ok: batched {:.0} req/s >= unbatched {:.0} req/s at arrival '{}' \
             (mean batch {:.1} requests)",
            batched.throughput_rps, unbatched.throughput_rps, batched.arrival, batched.mean_batch
        );
        check_qos(&scenarios)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mix_pick_follows_the_weights() {
        let mut rng = Xorshift64::new(7);
        for _ in 0..64 {
            assert_eq!(ClassMix::INTERACTIVE_ONLY.pick(&mut rng), Class::Interactive);
            assert_eq!(ClassMix::BATCH_ONLY.pick(&mut rng), Class::Batch);
        }
        let mut seen = [0usize; 3];
        for _ in 0..4096 {
            seen[ClassMix::MIXED.pick(&mut rng).index()] += 1;
        }
        assert!(seen.iter().all(|&n| n > 0), "mixed stream draws every class: {seen:?}");
        assert_eq!(ClassMix::MIXED.label(), "i40b40e20");
    }

    #[test]
    fn qos_report_schema_has_the_v1_shape() {
        let row = QosRow {
            name: "x".to_string(),
            tenants: 1,
            requests: 2,
            elems: 4,
            workers: 1,
            queue_depth: 8,
            admission: "block".to_string(),
            tenant_quota: 0,
            span_s: 1.0,
            wall_s: 1.0,
            throughput_rps: 2.0,
            goodput_rps: 2.0,
            mean_batch: 1.0,
            batches: 2,
            submitted: 2,
            completed: 2,
            rejected: 0,
            quota_rejected: 0,
            shed: 0,
            expired: 0,
            cancelled: 0,
            cancelled_queued: 0,
            classes: Class::ALL
                .iter()
                .map(|&class| ClassStat {
                    class,
                    offered: 0,
                    completed: 0,
                    p50_ms: 0.0,
                    p95_ms: 0.0,
                    p99_ms: 0.0,
                    goodput_rps: 0.0,
                })
                .collect(),
            tenants_detail: vec![],
        };
        let dump = to_json(100.0, &[], std::slice::from_ref(&row)).dump();
        for key in ["serve_qos/v1", "capacity_rps", "baseline", "scenarios", "cancelled_queued"] {
            assert!(dump.contains(key), "missing {key} in {dump}");
        }
        assert_eq!(row.class(Class::Batch).class, Class::Batch);
    }

    #[test]
    fn key_fingerprint_separates_key_schedules() {
        let mut a = [7u32; SUBKEYS];
        let b = [7u32; SUBKEYS];
        assert_eq!(key_fingerprint(&a), key_fingerprint(&b));
        a[51] ^= 1;
        assert_ne!(key_fingerprint(&a), key_fingerprint(&b));
    }

    #[test]
    fn vecadd_spec_round_trips_ragged_sizes() {
        let m = vecadd_batched();
        let inputs: Vec<Arc<(Vec<f32>, Vec<f32>)>> = [3usize, 1, 5]
            .iter()
            .map(|&n| {
                Arc::new((
                    (0..n).map(|i| i as f32).collect::<Vec<f32>>(),
                    (0..n).map(|i| (i * 2) as f32).collect::<Vec<f32>>(),
                ))
            })
            .collect();
        let counts: Vec<usize> = inputs.iter().map(|i| m.batch_items(i)).collect();
        let fused = m.batch_compose(&inputs);
        assert_eq!(fused.0.len(), 9);
        let result = m.smp.invoke(&fused, 2);
        let parts = m.batch_split(result, &counts);
        assert_eq!(parts.len(), 3);
        for (inp, part) in inputs.iter().zip(&parts) {
            let want: Vec<f32> = inp.0.iter().zip(&inp.1).map(|(a, b)| a + b).collect();
            assert_eq!(part, &want);
        }
    }

    #[test]
    fn smp_share_of_fused_space_matches_direct_invoke() {
        use crate::somd::master::run_mis;
        let inp = ((0..64).map(|i| i as f32).collect::<Vec<f32>>(), vec![1.0f32; 64]);
        let parts = Block1D::new().ranges(inp.0.len(), 3);
        let partials = run_mis(&inp, &parts, &(), &|inp: &(Vec<f32>, Vec<f32>), p, _: &(), _| {
            p.own.iter().map(|i| inp.0[i] + inp.1[i]).collect::<Vec<f32>>()
        });
        let flat: Vec<f32> = partials.into_iter().flatten().collect();
        assert_eq!(flat, vecadd_batched().smp.invoke(&inp, 5));
    }
}
