//! The serving-layer load harness + the batchable benchmark methods.
//!
//! Two parts:
//!
//! * **Batchable methods** — [`vecadd_batched`] (the Listing-8 shape:
//!   f32 adds are exact, so a coalesced batch must be bitwise identical
//!   to N sequential invocations) and [`crypt_batched`] (one IDEA cipher
//!   pass over an *owned* input, with a key-fingerprint compatibility
//!   key: passes under different subkey schedules must never share a
//!   launch).  `rust/tests/serve_batching.rs` drives both through the
//!   compose/split round-trip suite.
//! * **The open-loop load harness** — [`run_load`] fires `requests`
//!   requests at a fixed arrival rate (`arrival_hz`; 0 = unthrottled
//!   saturation) from `clients` client threads into a
//!   [`Service`], measuring per-request latency from the request's
//!   *scheduled* arrival to batch completion (so coordinated omission
//!   cannot flatter the percentiles), and [`report`] sweeps arrival
//!   rates in batched vs unbatched mode, emitting `BENCH_serve.json`.
//!
//! With `check`, the report gates on the serving layer's reason to
//! exist: at the highest arrival rate, batched throughput must be at
//! least the unbatched throughput (within `tol`), and the batched row
//! must be non-vacuous — a mean of ≥ 2 requests per executed batch.
//! Schema documented in `docs/BENCHMARKS.md`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::backend::{BatchSpec, HeteroMethod};
use crate::serve::{AdmissionPolicy, Service, ServiceConfig};
use crate::somd::partition::Block1D;
use crate::somd::reduction::Assemble;
use crate::somd::{BlockPart, Engine, SomdMethod};
use crate::util::json::Json;
use crate::util::prng::Xorshift64;
use crate::util::stats::percentiles;

use super::crypt::{self, BLOCK_BYTES, SUBKEYS};

const SEED: u64 = 0x5e7e_2026;

// ---------------------------------------------------------------------------
// Batchable method builders
// ---------------------------------------------------------------------------

/// Listing-8 vector addition with a batch-compose/split spec: requests
/// concatenate element-wise into one fused add and split back by element
/// count.  f32 addition is exact per lane, so the coalesced result is
/// bitwise identical to per-request invocations — the serving
/// correctness suite's workhorse.
pub fn vecadd_batched() -> HeteroMethod<(Vec<f32>, Vec<f32>), BlockPart, (), Vec<f32>> {
    let smp = SomdMethod::new(
        "VecAdd.add",
        |inp: &(Vec<f32>, Vec<f32>), n| Block1D::new().ranges(inp.0.len(), n),
        |_, _| (),
        |inp, p, _, _| {
            let (a, b) = inp;
            p.own.iter().map(|i| a[i] + b[i]).collect::<Vec<f32>>()
        },
        Assemble,
    );
    HeteroMethod::smp_only(smp).with_batch(vecadd_batch_spec())
}

/// The [`BatchSpec`] of [`vecadd_batched`], exposed so tests can attach
/// it to device-capable variants of the same method.
pub fn vecadd_batch_spec() -> BatchSpec<(Vec<f32>, Vec<f32>), Vec<f32>> {
    BatchSpec::new(
        |inp: &(Vec<f32>, Vec<f32>)| inp.0.len(),
        |inputs| {
            let total: usize = inputs.iter().map(|i| i.0.len()).sum();
            let mut a = Vec::with_capacity(total);
            let mut b = Vec::with_capacity(total);
            for i in inputs {
                a.extend_from_slice(&i.0);
                b.extend_from_slice(&i.1);
            }
            Arc::new((a, b))
        },
        |fused: Vec<f32>, counts| {
            let mut out = Vec::with_capacity(counts.len());
            let mut it = fused.into_iter();
            for &c in counts {
                out.push(it.by_ref().take(c).collect::<Vec<f32>>());
            }
            out
        },
    )
}

/// An owned Crypt pass request (the serving layer needs `'static`
/// inputs, so unlike [`crypt::PassInput`] the source is owned).
pub struct CryptServeInput {
    /// Source bytes (8-byte aligned: whole cipher blocks).
    pub src: Vec<u8>,
    /// The subkey schedule of this pass.
    pub keys: [u32; SUBKEYS],
}

/// FNV-1a over a subkey schedule: the compatibility key of
/// [`crypt_batched`].
fn key_fingerprint(keys: &[u32; SUBKEYS]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &k in keys {
        h ^= u64::from(k);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One IDEA cipher pass with a batch spec: the index space is cipher
/// blocks, requests concatenate block-wise, and only requests under the
/// *same* subkey schedule may fuse (two keys in one launch would cipher
/// the wrong spans).  Integer IDEA is exact, so coalesced ciphertext is
/// bitwise identical to the sequential cipher per request.
pub fn crypt_batched() -> HeteroMethod<CryptServeInput, BlockPart, (), Vec<u8>> {
    let smp = SomdMethod::new(
        "Crypt.cipher",
        |inp: &CryptServeInput, n| Block1D::new().ranges(inp.src.len() / BLOCK_BYTES, n),
        |_, _| (),
        |inp, p, _, _| crypt::cipher_partial(&inp.src, &inp.keys, p.own.lo, p.own.hi),
        Assemble,
    );
    HeteroMethod::smp_only(smp).with_batch(
        BatchSpec::new(
            |inp: &CryptServeInput| inp.src.len() / BLOCK_BYTES,
            |inputs| {
                let total: usize = inputs.iter().map(|i| i.src.len()).sum();
                let mut src = Vec::with_capacity(total);
                for i in inputs {
                    src.extend_from_slice(&i.src);
                }
                Arc::new(CryptServeInput { src, keys: inputs[0].keys })
            },
            |fused: Vec<u8>, counts| {
                let mut out = Vec::with_capacity(counts.len());
                let mut off = 0usize;
                for &c in counts {
                    let bytes = c * BLOCK_BYTES;
                    out.push(fused[off..off + bytes].to_vec());
                    off += bytes;
                }
                out
            },
        )
        .with_compat(|inp| key_fingerprint(&inp.keys)),
    )
}

// ---------------------------------------------------------------------------
// Open-loop load harness
// ---------------------------------------------------------------------------

/// One load run's shape.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Open-loop arrival rate in requests/second across all clients;
    /// `0.0` means unthrottled (every request scheduled at t=0 — the
    /// saturation row the `--check` gate reads).
    pub arrival_hz: f64,
    /// Total requests fired.
    pub requests: usize,
    /// Client threads the arrival stream is interleaved across.
    pub clients: usize,
    /// Elements per vecadd request.
    pub elems: usize,
    /// Engine worker (MI) count.
    pub workers: usize,
}

/// One measured row of the sweep.
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// `"batched"` or `"unbatched"`.
    pub mode: String,
    /// Human-readable arrival rate (`"4000/s"` or `"max"`).
    pub arrival: String,
    /// Numeric arrival rate (0.0 = unthrottled).
    pub arrival_hz: f64,
    /// Requests fired.
    pub requests: usize,
    /// Client threads.
    pub clients: usize,
    /// Elements per request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
    /// Latency percentiles, milliseconds (scheduled arrival → batch
    /// completion).
    pub p50_ms: f64,
    /// 95th-percentile latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile latency, milliseconds.
    pub p99_ms: f64,
    /// Worst-case latency, milliseconds.
    pub max_ms: f64,
    /// Completed requests per second (first scheduled arrival → last
    /// completion).
    pub throughput_rps: f64,
    /// Mean requests per executed batch.
    pub mean_batch: f64,
    /// Largest executed batch, in requests.
    pub max_batch: u64,
    /// Fused batches executed.
    pub batches: u64,
    /// Requests turned away by admission control.
    pub rejected: u64,
}

/// Run one open-loop load: `spec.requests` vecadd requests at
/// `spec.arrival_hz` through a fresh [`Service`], batched
/// (`max_batch_items` = 32 requests' worth, 1 ms linger) or unbatched
/// (`max_batch_items` = 1 — every request its own launch through the
/// identical code path, the honest control).
pub fn run_load(batched: bool, spec: &LoadSpec) -> Result<ServeRow> {
    let cfg = if batched {
        ServiceConfig {
            max_batch_items: spec.elems.saturating_mul(32).max(1),
            max_batch_delay: Duration::from_micros(1_000),
            queue_depth: spec.requests.max(1),
            admission: AdmissionPolicy::Block,
            sched_snapshot: None,
        }
    } else {
        ServiceConfig {
            max_batch_items: 1,
            max_batch_delay: Duration::ZERO,
            queue_depth: spec.requests.max(1),
            admission: AdmissionPolicy::Block,
            sched_snapshot: None,
        }
    };
    let service = Service::with_config(Engine::new(spec.workers), cfg);
    let client = service.register(Arc::new(vecadd_batched())).map_err(|e| anyhow!("{e}"))?;

    // deterministic inputs, generated before the clock starts
    let inputs: Vec<Arc<(Vec<f32>, Vec<f32>)>> = (0..spec.requests)
        .map(|i| {
            let mut rng = Xorshift64::new(SEED ^ (i as u64).wrapping_mul(0x9e37_79b9));
            let a: Vec<f32> = (0..spec.elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
            let b: Vec<f32> = (0..spec.elems).map(|_| f32::from(rng.u16()) / 256.0).collect();
            Arc::new((a, b))
        })
        .collect();

    let clients = spec.clients.max(1);
    let base = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(spec.requests);
    let mut last_completed = base;
    let mut failed = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let client = client.clone();
            let inputs = &inputs;
            handles.push(s.spawn(move || {
                let mut tickets = Vec::new();
                let mut failed = 0usize;
                let mut i = c;
                while i < inputs.len() {
                    let scheduled = if spec.arrival_hz > 0.0 {
                        base + Duration::from_secs_f64(i as f64 / spec.arrival_hz)
                    } else {
                        base
                    };
                    let now = Instant::now();
                    if scheduled > now {
                        std::thread::sleep(scheduled - now);
                    }
                    match client.submit(inputs[i].clone()) {
                        Ok(t) => tickets.push((scheduled, t)),
                        Err(_) => failed += 1,
                    }
                    i += clients;
                }
                let mut done = Vec::with_capacity(tickets.len());
                for (scheduled, t) in tickets {
                    match t.wait() {
                        Ok(o) => {
                            let lat =
                                o.completed_at.saturating_duration_since(scheduled).as_secs_f64();
                            done.push((lat, o.completed_at));
                        }
                        Err(_) => failed += 1,
                    }
                }
                (done, failed)
            }));
        }
        for h in handles {
            let (done, f) = h.join().expect("load client thread");
            failed += f;
            for (lat, at) in done {
                latencies.push(lat);
                if at > last_completed {
                    last_completed = at;
                }
            }
        }
    });
    service.drain();
    let m = service.metrics();
    if failed > 0 || m.failed > 0 {
        bail!("{failed} request(s) failed during the load run (metrics: {} failed)", m.failed);
    }
    if latencies.is_empty() {
        bail!("load run completed no requests");
    }

    let span = last_completed.saturating_duration_since(base).as_secs_f64();
    let p = percentiles(&latencies);
    Ok(ServeRow {
        mode: if batched { "batched" } else { "unbatched" }.to_string(),
        arrival: if spec.arrival_hz > 0.0 {
            format!("{:.0}/s", spec.arrival_hz)
        } else {
            "max".to_string()
        },
        arrival_hz: spec.arrival_hz.max(0.0),
        requests: spec.requests,
        clients,
        elems: spec.elems,
        workers: spec.workers,
        p50_ms: p.p50 * 1e3,
        p95_ms: p.p95 * 1e3,
        p99_ms: p.p99 * 1e3,
        max_ms: p.max * 1e3,
        throughput_rps: if span > 0.0 { latencies.len() as f64 / span } else { 0.0 },
        mean_batch: m.mean_batch_requests(),
        max_batch: m.max_batch_requests,
        batches: m.batches,
        rejected: m.rejected,
    })
}

/// Render the sweep as the `BENCH_serve.json` schema (see
/// `docs/BENCHMARKS.md`).
pub fn to_json(rows: &[ServeRow]) -> Json {
    use std::collections::BTreeMap;
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str("serve_load/v1".to_string()));
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("mode".to_string(), Json::Str(r.mode.clone()));
            m.insert("arrival".to_string(), Json::Str(r.arrival.clone()));
            m.insert("arrival_hz".to_string(), Json::Num(r.arrival_hz));
            m.insert("requests".to_string(), Json::Num(r.requests as f64));
            m.insert("clients".to_string(), Json::Num(r.clients as f64));
            m.insert("elems".to_string(), Json::Num(r.elems as f64));
            m.insert("workers".to_string(), Json::Num(r.workers as f64));
            m.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
            m.insert("p95_ms".to_string(), Json::Num(r.p95_ms));
            m.insert("p99_ms".to_string(), Json::Num(r.p99_ms));
            m.insert("max_ms".to_string(), Json::Num(r.max_ms));
            m.insert("throughput_rps".to_string(), Json::Num(r.throughput_rps));
            m.insert("mean_batch".to_string(), Json::Num(r.mean_batch));
            m.insert("max_batch".to_string(), Json::Num(r.max_batch as f64));
            m.insert("batches".to_string(), Json::Num(r.batches as f64));
            m.insert("rejected".to_string(), Json::Num(r.rejected as f64));
            Json::Obj(m)
        })
        .collect();
    top.insert("rows".to_string(), Json::Arr(arr));
    Json::Obj(top)
}

/// The full sweep's shape: per-rate [`LoadSpec`]s are derived from this.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Arrival rates, one unbatched + one batched row each; the *last*
    /// is the gate's "highest" (use `0.0` = unthrottled saturation).
    pub rates: Vec<f64>,
    /// Requests per row.
    pub requests: usize,
    /// Client threads per row.
    pub clients: usize,
    /// Elements per request.
    pub elems: usize,
    /// Engine workers.
    pub workers: usize,
}

/// Run the arrival sweep (unbatched + batched row per rate), print the
/// table, write `out_path`, and with `check` gate on batched throughput
/// ≥ unbatched within `tol` at the highest rate — refusing vacuous rows
/// (mean batch < 2 requests).
pub fn report(sweep: &SweepSpec, out_path: &str, check: bool, tol: f64) -> Result<()> {
    let SweepSpec { rates, requests, clients, elems, workers } = sweep;
    let (requests, clients, elems, workers) = (*requests, *clients, *elems, *workers);
    if rates.is_empty() {
        bail!("serve bench needs at least one arrival rate");
    }
    println!(
        "== Serving layer: open-loop load, {requests} reqs x {elems} elems, \
         {clients} clients, {workers} workers =="
    );
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>10} {:>12} {:>10} {:>9}",
        "Mode", "arrival", "p50 (ms)", "p95 (ms)", "p99 (ms)", "thruput r/s", "mean bat", "rejected"
    );
    let mut rows = Vec::new();
    for &hz in rates {
        let spec = LoadSpec { arrival_hz: hz, requests, clients, elems, workers };
        for batched in [false, true] {
            let r = run_load(batched, &spec)?;
            println!(
                "{:<10} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>12.0} {:>10.1} {:>9}",
                r.mode, r.arrival, r.p50_ms, r.p95_ms, r.p99_ms, r.throughput_rps, r.mean_batch,
                r.rejected
            );
            rows.push(r);
        }
    }
    std::fs::write(out_path, to_json(&rows).dump())
        .map_err(|e| anyhow!("writing {out_path}: {e}"))?;
    println!("wrote {out_path}");
    if check {
        // the gate reads the final rate's pair: [..., unbatched, batched]
        let batched = rows.last().expect("rows nonempty");
        let unbatched = &rows[rows.len() - 2];
        assert_eq!(batched.mode, "batched");
        assert_eq!(unbatched.mode, "unbatched");
        if batched.mean_batch < 2.0 {
            bail!(
                "vacuous batched row at the highest arrival rate: mean batch {:.2} requests \
                 (< 2) — coalescing never happened, the throughput comparison proves nothing",
                batched.mean_batch
            );
        }
        if batched.throughput_rps * tol < unbatched.throughput_rps {
            bail!(
                "batched throughput lost to unbatched at the highest arrival rate: \
                 {:.0} vs {:.0} req/s (tol {tol})",
                batched.throughput_rps,
                unbatched.throughput_rps
            );
        }
        println!(
            "check ok: batched {:.0} req/s >= unbatched {:.0} req/s at arrival '{}' \
             (mean batch {:.1} requests)",
            batched.throughput_rps, unbatched.throughput_rps, batched.arrival, batched.mean_batch
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_fingerprint_separates_key_schedules() {
        let mut a = [7u32; SUBKEYS];
        let b = [7u32; SUBKEYS];
        assert_eq!(key_fingerprint(&a), key_fingerprint(&b));
        a[51] ^= 1;
        assert_ne!(key_fingerprint(&a), key_fingerprint(&b));
    }

    #[test]
    fn vecadd_spec_round_trips_ragged_sizes() {
        let m = vecadd_batched();
        let inputs: Vec<Arc<(Vec<f32>, Vec<f32>)>> = [3usize, 1, 5]
            .iter()
            .map(|&n| {
                Arc::new((
                    (0..n).map(|i| i as f32).collect::<Vec<f32>>(),
                    (0..n).map(|i| (i * 2) as f32).collect::<Vec<f32>>(),
                ))
            })
            .collect();
        let counts: Vec<usize> = inputs.iter().map(|i| m.batch_items(i)).collect();
        let fused = m.batch_compose(&inputs);
        assert_eq!(fused.0.len(), 9);
        let result = m.smp.invoke(&fused, 2);
        let parts = m.batch_split(result, &counts);
        assert_eq!(parts.len(), 3);
        for (inp, part) in inputs.iter().zip(&parts) {
            let want: Vec<f32> = inp.0.iter().zip(&inp.1).map(|(a, b)| a + b).collect();
            assert_eq!(part, &want);
        }
    }

    #[test]
    fn smp_share_of_fused_space_matches_direct_invoke() {
        use crate::somd::master::run_mis;
        let inp = ((0..64).map(|i| i as f32).collect::<Vec<f32>>(), vec![1.0f32; 64]);
        let parts = Block1D::new().ranges(inp.0.len(), 3);
        let partials = run_mis(&inp, &parts, &(), &|inp: &(Vec<f32>, Vec<f32>), p, _: &(), _| {
            p.own.iter().map(|i| inp.0[i] + inp.1[i]).collect::<Vec<f32>>()
        });
        let flat: Vec<f32> = partials.into_iter().flatten().collect();
        assert_eq!(flat, vecadd_batched().smp.invoke(&inp, 5));
    }
}
